"""Layer-2 JAX compute graphs.

These are the dense-phase computations the Rust coordinator offloads
through PJRT. Each function composes the Layer-1 Pallas kernel
(`kernels.pairwise`) with the surrounding jnp glue, and is lowered ONCE by
`aot.py` to HLO text. Python never runs on the request path.

Entry points (all shapes fixed at AOT time, callers pad):

* ``distance_tile``       — the raw pairwise tile (euclidean | hamming);
* ``neighbor_count_tile`` — distance tile + per-query ε-neighbor counts
  (the degree histogram primitive of Table I);
* ``voronoi_assign``      — nearest-center index and distance for a block
  of points against the landmark set (phase 1 of Algorithm 5).
"""

import jax
import jax.numpy as jnp

from .kernels import pairwise


def distance_tile(metric: str):
    """Return the raw pairwise-distance function for ``metric``."""
    if metric == "euclidean":
        kernel = pairwise.euclidean_pairwise
    elif metric == "hamming":
        kernel = pairwise.hamming_pairwise
    elif metric == "manhattan":
        kernel = pairwise.manhattan_pairwise
    else:
        raise ValueError(f"unknown metric {metric!r}")

    def fn(q, r):
        return (kernel(q, r),)

    return fn


def neighbor_count_tile(metric: str):
    """Distance tile plus per-query count of entries ≤ ε."""
    dist = distance_tile(metric)

    def fn(q, r, eps):
        (d,) = dist(q, r)
        counts = jnp.sum((d <= eps).astype(jnp.float32), axis=1)
        return d, counts

    return fn


def voronoi_assign(x, c):
    """Nearest-center assignment of points ``x`` against centers ``c``.

    Returns (cell index as f32 — avoids cross-runtime i32 literal
    handling — and the distance d(p, C)). Composes the L1 kernel.
    """
    d = pairwise.euclidean_pairwise(x, c)
    idx = jnp.argmin(d, axis=1).astype(jnp.float32)
    return idx, jnp.min(d, axis=1)


def lower_to_hlo_text(fn, example_args):
    """Lower a jitted function to HLO **text** — the interchange format.

    jax ≥ 0.5 serializes HloModuleProto with 64-bit instruction ids which
    the Rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
    the text parser reassigns ids and round-trips cleanly
    (see /opt/xla-example/README.md).
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
