"""Pure-jnp correctness oracles for the Pallas kernels.

Deliberately the most naive formulation (explicit broadcast differences)
so that a bug in the matmul-form kernels cannot be mirrored here.
"""

import jax.numpy as jnp


def euclidean_pairwise_ref(q, r):
    """Naive ``(nq, nr)`` Euclidean distances via broadcasting."""
    diff = q[:, None, :] - r[None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def hamming_pairwise_ref(q, r):
    """Naive Hamming distances over 0/1 encodings (count of mismatches)."""
    neq = jnp.abs(q[:, None, :] - r[None, :, :])
    return jnp.sum(neq, axis=-1)


def voronoi_assign_ref(x, c):
    """Nearest-center index and distance for every point of ``x``."""
    d = euclidean_pairwise_ref(x, c)
    idx = jnp.argmin(d, axis=1)
    return idx.astype(jnp.float32), jnp.min(d, axis=1)


def manhattan_pairwise_ref(q, r):
    """Naive Manhattan distances via broadcasting."""
    return jnp.sum(jnp.abs(q[:, None, :] - r[None, :, :]), axis=-1)
