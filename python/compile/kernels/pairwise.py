"""Layer-1 Pallas kernels: tiled pairwise-distance computation.

The compute hot-spot of every dense phase (brute-force tiles, Voronoi
assignment, SNN block filtering) is a ``|Q| x |R|`` distance tile. Both
metrics reduce to one matmul plus rank-1 corrections, which is the
MXU-friendly formulation (DESIGN.md §Hardware-Adaptation):

* Euclidean:  D² = ‖q‖² + ‖r‖² − 2·QRᵀ
* Hamming (on 0/1 float encodings): D = ‖q‖₁ + ‖r‖₁ − 2·QRᵀ

The kernel grid walks (num_q_tiles, num_r_tiles); each program instance
loads a ``(TQ, D)`` query block and a ``(TR, D)`` reference block into VMEM
(BlockSpec), runs the ``(TQ, D) x (D, TR)`` contraction on the MXU, and
writes one ``(TQ, TR)`` output tile. For the Table-I dimensions
(D ≤ 800) the working set is ≤ 0.9 MB — far inside the ~16 MB VMEM.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and the interpreted lowering emits plain HLO that the
Rust runtime's PJRT CPU client runs directly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the 8x128 TPU vector lane layout, and a
# good MXU shape; small enough that (2·T·D + T²) floats stay in VMEM at
# D = 800.
TILE_Q = 64
TILE_R = 64


def _euclidean_kernel(q_ref, r_ref, o_ref):
    """One (TQ, TR) Euclidean tile: norms + MXU contraction, then sqrt."""
    q = q_ref[...]
    r = r_ref[...]
    qn = jnp.sum(q * q, axis=1, keepdims=True)        # (TQ, 1)
    rn = jnp.sum(r * r, axis=1, keepdims=True).T       # (1, TR)
    dot = jax.lax.dot_general(
        q, r,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (TQ, TR) on the MXU
    d2 = jnp.maximum(qn + rn - 2.0 * dot, 0.0)
    o_ref[...] = jnp.sqrt(d2)


def _hamming_kernel(q_ref, r_ref, o_ref):
    """One (TQ, TR) Hamming tile on 0/1 float encodings."""
    q = q_ref[...]
    r = r_ref[...]
    qn = jnp.sum(q, axis=1, keepdims=True)
    rn = jnp.sum(r, axis=1, keepdims=True).T
    dot = jax.lax.dot_general(
        q, r,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = qn + rn - 2.0 * dot


def _pairwise(kernel, q, r, tile_q, tile_r):
    """Tiled pallas_call over the (query, reference) grid."""
    nq, d = q.shape
    nr, _ = r.shape
    assert nq % tile_q == 0 and nr % tile_r == 0, (
        f"caller must pad: got ({nq}, {nr}) for tiles ({tile_q}, {tile_r})"
    )
    grid = (nq // tile_q, nr // tile_r)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Query block: row-tile i, all of D (the HBM->VMEM schedule).
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            # Reference block: row-tile j, all of D.
            pl.BlockSpec((tile_r, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_r), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nr), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(q, r)


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_r"))
def euclidean_pairwise(q, r, tile_q=TILE_Q, tile_r=TILE_R):
    """``(nq, nr)`` Euclidean distance matrix (inputs padded to tiles)."""
    return _pairwise(_euclidean_kernel, q, r, tile_q, tile_r)


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_r"))
def hamming_pairwise(q, r, tile_q=TILE_Q, tile_r=TILE_R):
    """``(nq, nr)`` Hamming distance matrix over 0/1 float encodings."""
    return _pairwise(_hamming_kernel, q, r, tile_q, tile_r)


def vmem_bytes(tile_q: int, tile_r: int, d: int) -> int:
    """Estimated VMEM working set of one program instance (f32 bytes):
    query block + reference block + output tile (+ norms)."""
    return 4 * (tile_q * d + tile_r * d + tile_q * tile_r + tile_q + tile_r)


def mxu_flops_fraction(tile_q: int, tile_r: int, d: int) -> float:
    """Fraction of the tile's FLOPs that land on the MXU (the matmul)
    versus the VPU (norms, broadcast adds, sqrt)."""
    matmul = 2.0 * tile_q * tile_r * d
    vpu = 2.0 * (tile_q + tile_r) * d + 4.0 * tile_q * tile_r
    return matmul / (matmul + vpu)


def _manhattan_kernel(q_ref, r_ref, o_ref):
    """One (TQ, TR) Manhattan (l1) tile.

    Unlike the Euclidean/Hamming kernels there is no matmul form — this is
    a VPU (vector-unit) kernel: the (TQ, TR, D) broadcast difference is
    reduced along D. VMEM budget forces smaller tiles (see
    MANHATTAN_TILE): 32·32·800·4 B ≈ 3.3 MB at the largest Table-I
    dimension, still inside the ~16 MB VMEM.
    """
    q = q_ref[...]
    r = r_ref[...]
    o_ref[...] = jnp.sum(jnp.abs(q[:, None, :] - r[None, :, :]), axis=-1)


# l1 tiles are VPU-bound and materialize (TQ, TR, D); keep them small.
MANHATTAN_TILE = 32


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_r"))
def manhattan_pairwise(q, r, tile_q=MANHATTAN_TILE, tile_r=MANHATTAN_TILE):
    """``(nq, nr)`` Manhattan distance matrix (inputs padded to tiles)."""
    return _pairwise(_manhattan_kernel, q, r, tile_q, tile_r)
