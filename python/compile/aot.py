"""AOT compilation driver: lower the Layer-2 graphs to HLO text artifacts.

Run once by ``make artifacts``; the Rust runtime
(`rust/src/runtime/`) loads the HLO text via
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU client.
Python never runs on the request path.

Artifact set (see the manifest written next to them):

* ``pairwise_{metric}_d{D}``      — (TQ, D) x (TR, D) -> (TQ, TR) distance
  tile for each supported padded dimension D;
* ``voronoi_assign_d{D}_m{M}``   — (NB, D) x (M, D) -> cell idx + d(p, C).

Shapes are fixed at lowering time; the Rust side pads queries up to the
tile and dimension grid (zero padding is exact for both distance
formulations).

Usage: python -m compile.aot --out-dir ../artifacts [--report]
"""

import argparse
import os

from . import model

# Padded dimension grid: covers every Table-I dataset dimension
# (20, 32, 40, 55, 78, 96, 128, 256, 800) with zero-pad to the next entry.
DIMS = [32, 64, 128, 256, 800]
TILE_Q = 64
TILE_R = 64
# Voronoi assignment block: NB points against M centers.
VOR_BLOCK = 256
VOR_CENTERS = 64


def _spec(shape):
    import jax
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts(out_dir: str, report: bool = False) -> list:
    os.makedirs(out_dir, exist_ok=True)
    entries = []  # (name, kind, tq, tr, d, extra, filename)

    for metric in ("euclidean", "hamming", "manhattan"):
        fn = model.distance_tile(metric)
        # l1 is a VPU kernel with a (TQ, TR, D) working set — smaller tiles.
        tq = tr = TILE_Q if metric != "manhattan" else 32
        for d in DIMS:
            name = f"pairwise_{metric}_d{d}"
            text = model.lower_to_hlo_text(
                fn, (_spec((tq, d)), _spec((tr, d)))
            )
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            entries.append((name, f"pairwise_{metric}", tq, tr, d, 0, f"{name}.hlo.txt"))
            if report:
                _report(name, text, tq, tr, d)

    for d in DIMS:
        name = f"voronoi_assign_d{d}_m{VOR_CENTERS}"
        text = model.lower_to_hlo_text(
            model.voronoi_assign, (_spec((VOR_BLOCK, d)), _spec((VOR_CENTERS, d)))
        )
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append((name, "voronoi_assign", VOR_BLOCK, VOR_CENTERS, d, 0, f"{name}.hlo.txt"))

    # Manifest: one line per artifact, whitespace-delimited.
    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# name kind tile_q tile_r dim extra file\n")
        for e in entries:
            f.write(" ".join(str(x) for x in e) + "\n")
    return entries


def _report(name: str, hlo_text: str, tq: int, tr: int, d: int) -> None:
    """L2 profile: op census of the lowered module (fusion sanity) plus the
    L1 VMEM/MXU estimates. Used by the §Perf pass."""
    from .kernels import pairwise

    ops = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" in line and not line.startswith(("HloModule", "ENTRY", "}", "//")):
            rhs = line.split("=", 1)[1].strip()
            head = rhs.split("(")[0].split()
            if not head:
                continue
            ops[head[-1]] = ops.get(head[-1], 0) + 1
    dots = sum(v for k, v in ops.items() if "dot" in k)
    print(f"[{name}] ops={sum(ops.values())} dot={dots} "
          f"vmem={pairwise.vmem_bytes(tq, tr, d)/1024:.1f}KiB "
          f"mxu_flop_frac={pairwise.mxu_flops_fraction(tq, tr, d):.4f}")
    top = sorted(ops.items(), key=lambda kv: -kv[1])[:8]
    print(f"  top ops: {top}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--report", action="store_true",
                    help="print per-artifact op census + VMEM/MXU estimates")
    args = ap.parse_args()
    entries = build_artifacts(args.out_dir, report=args.report)
    print(f"wrote {len(entries)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
