"""Layer-2 correctness: model graphs vs oracle + AOT lowering sanity."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(0xBEEF)


def test_distance_tile_euclidean():
    q = RNG.normal(size=(64, 32)).astype(np.float32)
    r = RNG.normal(size=(64, 32)).astype(np.float32)
    (d,) = model.distance_tile("euclidean")(q, r)
    np.testing.assert_allclose(
        np.asarray(d), np.asarray(ref.euclidean_pairwise_ref(q, r)), rtol=2e-4, atol=2e-4
    )


def test_distance_tile_unknown_metric():
    with pytest.raises(ValueError):
        model.distance_tile("wasserstein")


def test_neighbor_count_tile():
    q = RNG.normal(size=(64, 8)).astype(np.float32)
    d, counts = model.neighbor_count_tile("euclidean")(q, q, np.float32(0.5))
    dm = np.asarray(ref.euclidean_pairwise_ref(q, q))
    want = (dm <= 0.5).sum(axis=1).astype(np.float32)
    np.testing.assert_allclose(np.asarray(counts), want, atol=1e-3)
    assert d.shape == (64, 64)


def test_voronoi_assign_matches_ref():
    x = RNG.normal(size=(256, 16)).astype(np.float32)
    c = RNG.normal(size=(64, 16)).astype(np.float32)
    idx, dist = model.voronoi_assign(x, c)
    widx, wdist = ref.voronoi_assign_ref(x, c)
    np.testing.assert_allclose(np.asarray(idx), np.asarray(widx))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(wdist), rtol=2e-4, atol=2e-4)


def test_hlo_text_lowering_roundtrippable():
    import jax
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    text = model.lower_to_hlo_text(model.distance_tile("euclidean"), (spec, spec))
    assert "HloModule" in text
    assert "f32[64,64]" in text  # output tile shape present
    # The MXU contraction must survive lowering as a dot.
    assert " dot(" in text or " dot " in text


def test_hlo_no_redundant_recompute():
    """The lowered module should contain exactly one dot (no recomputation
    of the contraction) — the L2 §Perf invariant."""
    import jax
    import jax.numpy as jnp

    spec = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    text = model.lower_to_hlo_text(model.distance_tile("euclidean"), (spec, spec))
    dots = sum(1 for line in text.splitlines() if " dot(" in line)
    assert dots == 1, f"expected a single dot, found {dots}"
