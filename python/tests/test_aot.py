"""AOT driver: artifact generation, manifest format, HLO content."""

import os

from compile import aot


def test_build_artifacts_writes_manifest_and_hlo(tmp_path):
    out = str(tmp_path / "artifacts")
    entries = aot.build_artifacts(out)
    # 3 metrics × 5 dims pairwise + 5 voronoi = 20 artifacts.
    assert len(entries) == 20
    manifest = os.path.join(out, "manifest.txt")
    assert os.path.exists(manifest)

    with open(manifest) as f:
        lines = [l for l in f if l.strip() and not l.startswith("#")]
    assert len(lines) == 20
    for line in lines:
        name, kind, tq, tr, dim, extra, fname = line.split()
        assert kind in (
            "pairwise_euclidean", "pairwise_hamming", "pairwise_manhattan", "voronoi_assign",
        )
        assert int(tq) > 0 and int(tr) > 0 and int(dim) > 0
        path = os.path.join(out, fname)
        assert os.path.exists(path), fname
        text = open(path).read()
        assert text.startswith("HloModule"), f"{fname} is not HLO text"
        # MXU-path modules carry exactly one dot (the L2 no-recompute
        # invariant); the Manhattan kernel is VPU-only — no dot at all.
        dots = sum(1 for l in text.splitlines() if " dot(" in l)
        if kind == "pairwise_manhattan":
            assert dots == 0, f"{fname}: l1 should have no dot, found {dots}"
        else:
            assert dots == 1, f"{fname}: expected 1 dot, found {dots}"


def test_dimension_grid_covers_table1():
    table1_dims = [20, 32, 40, 55, 78, 96, 128, 256, 800]
    for d in table1_dims:
        assert any(pd >= d for pd in aot.DIMS), f"no padded dim for {d}"
