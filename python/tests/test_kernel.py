"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps tile counts, dimensions and value distributions;
assert_allclose against ref.py per the repo's correctness strategy.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise, ref

RNG = np.random.default_rng(0xC0DE)


def _rand(nq, nr, d, scale=1.0):
    q = RNG.normal(size=(nq, d)).astype(np.float32) * scale
    r = RNG.normal(size=(nr, d)).astype(np.float32) * scale
    return q, r


# ---------------------------------------------------------------------------
# Euclidean kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    qt=st.integers(min_value=1, max_value=3),
    rt=st.integers(min_value=1, max_value=3),
    d=st.sampled_from([1, 3, 8, 32, 100]),
    tile=st.sampled_from([8, 16, 64]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_euclidean_matches_ref(qt, rt, d, tile, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(qt * tile, d)).astype(np.float32)
    r = rng.normal(size=(rt * tile, d)).astype(np.float32)
    got = np.asarray(pairwise.euclidean_pairwise(q, r, tile_q=tile, tile_r=tile))
    want = np.asarray(ref.euclidean_pairwise_ref(q, r))
    # atol accounts for the matmul-form cancellation on near-zero
    # distances: |d̂² − d²| ≲ ε·(‖q‖² + ‖r‖²) ⇒ |d̂ − d| ≲ √(ε·norms).
    norms = float(np.sqrt((q * q).sum(1).max() + (r * r).sum(1).max()))
    atol = max(2e-4, 4.0 * np.sqrt(1.2e-7) * norms)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=atol)


def test_euclidean_zero_distance_diagonal():
    # The matmul formulation cancels ‖x‖² + ‖x‖² − 2‖x‖²; float32
    # cancellation leaves O(√(ε·‖x‖²)) residue on the diagonal, so the
    # tolerance is scaled, not exact (the Rust coordinator never relies on
    # exact zeros — the ε filter uses the same formulation on both sides).
    q, _ = _rand(64, 64, 16)
    got = np.asarray(pairwise.euclidean_pairwise(q, q))
    assert np.all(np.diag(got) <= 2e-2)


def test_euclidean_large_values_stable():
    q, r = _rand(64, 64, 32, scale=1e3)
    got = np.asarray(pairwise.euclidean_pairwise(q, r))
    want = np.asarray(ref.euclidean_pairwise_ref(q, r))
    np.testing.assert_allclose(got, want, rtol=1e-3)
    assert np.all(got >= 0.0)


def test_euclidean_zero_padding_is_exact():
    # Zero columns (dimension padding) must not change distances.
    q, r = _rand(64, 64, 24)
    qp = np.zeros((64, 32), np.float32)
    rp = np.zeros((64, 32), np.float32)
    qp[:, :24], rp[:, :24] = q, r
    a = np.asarray(pairwise.euclidean_pairwise(q, r))
    b = np.asarray(pairwise.euclidean_pairwise(qp, rp))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_euclidean_rejects_unpadded_rows():
    q, r = _rand(65, 64, 8)
    with pytest.raises(AssertionError):
        pairwise.euclidean_pairwise(q, r)


# ---------------------------------------------------------------------------
# Hamming kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    qt=st.integers(min_value=1, max_value=3),
    rt=st.integers(min_value=1, max_value=2),
    d=st.sampled_from([1, 16, 64, 256]),
    tile=st.sampled_from([8, 64]),
)
def test_hamming_matches_ref(qt, rt, d, tile):
    q = RNG.integers(0, 2, size=(qt * tile, d)).astype(np.float32)
    r = RNG.integers(0, 2, size=(rt * tile, d)).astype(np.float32)
    got = np.asarray(pairwise.hamming_pairwise(q, r, tile_q=tile, tile_r=tile))
    want = np.asarray(ref.hamming_pairwise_ref(q, r))
    np.testing.assert_allclose(got, want, rtol=0, atol=1e-3)


def test_hamming_is_integer_valued():
    q = RNG.integers(0, 2, size=(64, 128)).astype(np.float32)
    got = np.asarray(pairwise.hamming_pairwise(q, q))
    np.testing.assert_allclose(got, np.round(got), atol=1e-3)
    assert np.allclose(np.diag(got), 0.0, atol=1e-3)


def test_hamming_complement_is_full_distance():
    q = np.zeros((64, 32), np.float32)
    r = np.ones((64, 32), np.float32)
    got = np.asarray(pairwise.hamming_pairwise(q, r))
    np.testing.assert_allclose(got, 32.0, atol=1e-3)


# ---------------------------------------------------------------------------
# VMEM / MXU estimates (DESIGN.md §Hardware-Adaptation invariants)
# ---------------------------------------------------------------------------

def test_vmem_budget_within_16mb_for_all_table1_dims():
    for d in [20, 32, 40, 55, 78, 96, 128, 256, 800]:
        assert pairwise.vmem_bytes(64, 64, d) < 16 * 2**20


def test_mxu_fraction_dominates_at_realistic_dims():
    # At D >= 32 the matmul should carry >= 90% of the FLOPs.
    for d in [32, 128, 800]:
        assert pairwise.mxu_flops_fraction(64, 64, d) >= 0.90


# ---------------------------------------------------------------------------
# Manhattan kernel (VPU path, no matmul form)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    qt=st.integers(min_value=1, max_value=3),
    rt=st.integers(min_value=1, max_value=3),
    d=st.sampled_from([1, 8, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_manhattan_matches_ref(qt, rt, d, seed):
    rng = np.random.default_rng(seed)
    tile = 32
    q = rng.normal(size=(qt * tile, d)).astype(np.float32)
    r = rng.normal(size=(rt * tile, d)).astype(np.float32)
    got = np.asarray(pairwise.manhattan_pairwise(q, r))
    want = np.asarray(ref.manhattan_pairwise_ref(q, r))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * d)


def test_manhattan_zero_diagonal_exact():
    # l1 has no cancellation: the diagonal is exactly zero.
    q, _ = _rand(32, 32, 16)
    got = np.asarray(pairwise.manhattan_pairwise(q, q))
    assert np.all(np.diag(got) == 0.0)
