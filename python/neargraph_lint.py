#!/usr/bin/env python3
"""Python mirror of `neargraph::lint` (rust/src/lint/).

The container that grows this repository carries no Rust toolchain, so the
lint engine — like every other subsystem since PR 1 — ships with an
executable Python mirror that implements the *same* tokenizer and rule
semantics and runs over the *real* `rust/src` tree in-container.  The
committed `LINT_REPORT.json` is produced by this script; the Rust module is
a line-for-line port and `tests/lint_selftest.rs` re-checks the same
fixture corpus under cargo.

Usage:
    python3 python/neargraph_lint.py --src rust/src \
        [--registry rust/tests/wire_adversarial.rs] \
        [--docs README.md --docs DESIGN.md] \
        [--fixtures rust/tests/lint_fixtures] \
        [--json LINT_REPORT.json] [--deny-warnings] [--quiet]

Rules (see DESIGN.md §12):
  R1 no-alloc-hot-path    bans Vec::new / vec! / .collect / .to_vec /
                          .clone / String::from / format! / Box::new inside
                          hot modules (covertree/{query,layout,scratch,knn}.rs,
                          metric/*, serve/engine.rs) except fns marked
                          `// lint: cold`.
  R2 total-ordering       bans .partial_cmp, f32/f64::max|min paths, and
                          .max(..)/.min(..) whose arguments look float-typed
                          (float literal or .abs()/.sqrt() call), crate-wide.
  R3 panic-free-decode    bans .unwrap / .expect / panic-family macros inside
                          any fn returning Result<_, WireError> and inside
                          serve/{protocol,server}.rs; additionally bans
                          assert-family macros and `[`-indexing (instead of
                          .get) inside the WireError fns.
  R4 harness-registration every wire decoder fn discovered in src/ must be
                          referenced (impl type ident + method ident) in
                          tests/wire_adversarial.rs.
  R5 config-doc-parity    every "key" string-literal match arm in config/
                          must appear verbatim (word-bounded) in README.md
                          or DESIGN.md.

Waivers: `// lint: allow(rule-a, rule-b) reason="..."` — trailing on the
offending line, standalone above the offending line, or standalone above a
fn header (waives the rules for the whole fn).  `// lint: cold` standalone
above a fn header exempts the fn from R1.  Malformed or unused directives
are themselves findings (rule `lint-directive`) so waiver creep is visible.
"""

import json
import os
import sys

KNOWN_RULES = (
    "no-alloc-hot-path",
    "total-ordering",
    "panic-free-decode",
    "harness-registration",
    "config-doc-parity",
)

HOT_FILES = {
    "covertree/query.rs",
    "covertree/layout.rs",
    "covertree/scratch.rs",
    "covertree/knn.rs",
    "covertree/epoch.rs",
    "covertree/dualtree.rs",
    "serve/engine.rs",
}
HOT_PREFIXES = ("metric/",)

R3_FILES = {"serve/protocol.rs", "serve/server.rs"}

ALLOC_CALLS = {"collect", "to_vec", "clone"}
PANIC_MACROS = {"panic", "unreachable", "todo", "unimplemented"}
ASSERT_MACROS = {"assert", "assert_eq", "assert_ne"}

IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
IDENT_CONT = IDENT_START | set("0123456789")
DIGITS = set("0123456789")


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------

class Tok(object):
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind  # ident | num | str | char | lifetime | punct
        self.text = text
        self.line = line

    def __repr__(self):
        return "Tok(%s,%r,%d)" % (self.kind, self.text, self.line)


class Comment(object):
    __slots__ = ("line", "text", "standalone", "next_tok")

    def __init__(self, line, text, standalone):
        self.line = line
        self.text = text
        self.standalone = standalone  # no code token earlier on this line
        self.next_tok = -1  # index of next significant token (filled later)


def tokenize(src):
    """Return (tokens, comments). Comments carry their raw text sans the
    comment markers; `standalone` is True when no significant token precedes
    the comment on its own line."""
    toks = []
    comments = []
    i = 0
    n = len(src)
    line = 1
    last_tok_line = 0  # line of the most recent significant token
    pending_next = []  # comments awaiting their next-token index

    def push(kind, text, ln):
        # Merge '::' '->' '=>' from single punct chars.
        if kind == "punct" and toks:
            prev = toks[-1]
            if prev.kind == "punct" and prev.line == ln:
                pair = prev.text + text
                if pair in ("::", "->", "=>"):
                    prev.text = pair
                    return
        toks.append(Tok(kind, text, ln))

    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # Comments ----------------------------------------------------------
        if c == "/" and i + 1 < n and src[i + 1] == "/":
            j = i + 2
            while j < n and src[j] != "\n":
                j += 1
            body = src[i:j]
            # strip '//', optional third '/' or '!'
            t = body[2:]
            if t[:1] in ("/", "!"):
                t = t[1:]
            cm = Comment(line, t.strip(), last_tok_line != line)
            comments.append(cm)
            pending_next.append(cm)
            i = j
            continue
        if c == "/" and i + 1 < n and src[i + 1] == "*":
            depth = 1
            start_line = line
            j = i + 2
            while j < n and depth > 0:
                if src[j] == "\n":
                    line += 1
                    j += 1
                elif src[j] == "/" and j + 1 < n and src[j + 1] == "*":
                    depth += 1
                    j += 2
                elif src[j] == "*" and j + 1 < n and src[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            body = src[i + 2 : max(i + 2, j - 2)]
            cm = Comment(start_line, body.strip(), last_tok_line != start_line)
            comments.append(cm)
            pending_next.append(cm)
            i = j
            continue
        # Raw / byte strings ------------------------------------------------
        if c in "rb":
            j = i
            prefix = c
            if c == "b" and j + 1 < n and src[j + 1] == "r":
                prefix = "br"
                j += 1
            if c == "r" and j + 1 < n and src[j + 1] == "b":
                prefix = "rb"
                j += 1
            k = j + 1
            hashes = 0
            while k < n and src[k] == "#":
                hashes += 1
                k += 1
            if "r" in prefix and k < n and src[k] == '"':
                # raw string: ends at '"' + hashes '#'
                close = '"' + "#" * hashes
                end = src.find(close, k + 1)
                if end < 0:
                    end = n
                text = src[i : end + len(close)]
                ln = line
                line += text.count("\n")
                push("str", text, ln)
                for cm in pending_next:
                    cm.next_tok = len(toks) - 1
                pending_next = []
                last_tok_line = ln
                i = end + len(close)
                continue
            if c == "b" and i + 1 < n and src[i + 1] == '"':
                i += 1  # fall through to plain string below
                c = '"'
            elif c == "b" and i + 1 < n and src[i + 1] == "'":
                # byte char literal b'x'
                j = i + 2
                if j < n and src[j] == "\\":
                    j += 2
                else:
                    j += 1
                while j < n and src[j] != "'":
                    j += 1
                push("char", src[i : j + 1], line)
                for cm in pending_next:
                    cm.next_tok = len(toks) - 1
                pending_next = []
                last_tok_line = line
                i = j + 1
                continue
        # Strings -----------------------------------------------------------
        if c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == '"':
                    break
                j += 1
            text = src[i : j + 1]
            ln = line
            line += text.count("\n")
            push("str", text, ln)
            for cm in pending_next:
                cm.next_tok = len(toks) - 1
            pending_next = []
            last_tok_line = ln
            i = j + 1
            continue
        # Char literal vs lifetime ------------------------------------------
        if c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 3
                while j < n and src[j] != "'":
                    j += 1
                push("char", src[i : j + 1], line)
                i = j + 1
            elif i + 2 < n and src[i + 2] == "'" and src[i + 1] != "'":
                push("char", src[i : i + 3], line)
                i = i + 3
            else:
                j = i + 1
                while j < n and src[j] in IDENT_CONT:
                    j += 1
                push("lifetime", src[i:j], line)
                i = j
            for cm in pending_next:
                cm.next_tok = len(toks) - 1
            pending_next = []
            last_tok_line = line
            continue
        # Numbers -----------------------------------------------------------
        if c in DIGITS:
            j = i
            is_float = False
            if src.startswith("0x", i) or src.startswith("0b", i) or src.startswith("0o", i):
                j = i + 2
                while j < n and (src[j] in IDENT_CONT):
                    j += 1
            else:
                while j < n and (src[j] in DIGITS or src[j] == "_"):
                    j += 1
                if j < n and src[j] == "." and j + 1 < n and src[j + 1] in DIGITS:
                    is_float = True
                    j += 1
                    while j < n and (src[j] in DIGITS or src[j] == "_"):
                        j += 1
                elif j < n and src[j] == "." and not (
                    j + 1 < n and (src[j + 1] == "." or src[j + 1] in IDENT_START)
                ):
                    # trailing-dot float like `1.`
                    is_float = True
                    j += 1
                if j < n and src[j] in "eE" and j + 1 < n and (
                    src[j + 1] in DIGITS or src[j + 1] in "+-"
                ):
                    is_float = True
                    j += 2
                    while j < n and (src[j] in DIGITS or src[j] == "_"):
                        j += 1
                # suffix (f32, u8, usize...)
                s = j
                while j < n and src[j] in IDENT_CONT:
                    j += 1
                if src[s:j] in ("f32", "f64"):
                    is_float = True
            push("num", src[i:j], line)
            toks[-1].kind = "fnum" if is_float else "num"
            for cm in pending_next:
                cm.next_tok = len(toks) - 1
            pending_next = []
            last_tok_line = line
            i = j
            continue
        # Identifiers -------------------------------------------------------
        if c in IDENT_START:
            j = i + 1
            while j < n and src[j] in IDENT_CONT:
                j += 1
            push("ident", src[i:j], line)
            for cm in pending_next:
                cm.next_tok = len(toks) - 1
            pending_next = []
            last_tok_line = line
            i = j
            continue
        # Punctuation -------------------------------------------------------
        push("punct", c, line)
        for cm in pending_next:
            cm.next_tok = len(toks) - 1
        pending_next = []
        last_tok_line = line
        i += 1
    return toks, comments


# ---------------------------------------------------------------------------
# Directives
# ---------------------------------------------------------------------------

class Directive(object):
    __slots__ = ("kind", "rules", "reason", "line", "standalone", "next_tok", "used", "error")

    def __init__(self, kind, line, standalone, next_tok):
        self.kind = kind  # cold | allow | bad
        self.rules = []
        self.reason = ""
        self.line = line
        self.standalone = standalone
        self.next_tok = next_tok
        self.used = False
        self.error = ""


def parse_directives(comments):
    out = []
    for cm in comments:
        t = cm.text
        if not t.startswith("lint:"):
            continue
        body = t[5:].strip()
        d = Directive("bad", cm.line, cm.standalone, cm.next_tok)
        if body == "cold":
            d.kind = "cold"
        elif body.startswith("allow"):
            rest = body[5:].lstrip()
            if not rest.startswith("("):
                d.error = "expected '(' after allow"
            else:
                close = rest.find(")")
                if close < 0:
                    d.error = "unclosed allow(...)"
                else:
                    names = [s.strip() for s in rest[1:close].split(",") if s.strip()]
                    bad = [nm for nm in names if nm not in KNOWN_RULES]
                    tail = rest[close + 1 :].strip()
                    if not names:
                        d.error = "allow() lists no rules"
                    elif bad:
                        d.error = "unknown rule '%s'" % bad[0]
                    elif not tail.startswith('reason="'):
                        d.error = 'waiver missing reason="..."'
                    else:
                        endq = tail.find('"', 8)
                        reason = tail[8:endq] if endq > 8 else ""
                        if not reason.strip():
                            d.error = "waiver reason is empty"
                        else:
                            d.kind = "allow"
                            d.rules = names
                            d.reason = reason
        else:
            d.error = "unknown lint directive '%s'" % body.split(" ")[0]
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# Source model: fns, impl/trait context, test regions
# ---------------------------------------------------------------------------

class Fn(object):
    __slots__ = (
        "name", "impl_type", "in_trait", "is_test", "is_cold",
        "params", "ret", "item_start", "fn_kw", "body_start", "body_end",
        "sig_line", "body_end_line",
    )

    def __init__(self):
        self.name = ""
        self.impl_type = None
        self.in_trait = False
        self.is_test = False
        self.is_cold = False
        self.params = []      # token objects inside the signature parens
        self.ret = []         # token texts between -> and the body
        self.item_start = -1  # token index incl. visibility / attributes
        self.fn_kw = -1
        self.body_start = -1  # index of the '{' (or -1 for decl-only)
        self.body_end = -1
        self.sig_line = 0
        self.body_end_line = 0


class FileModel(object):
    __slots__ = ("path", "toks", "comments", "directives", "fns", "test_lines")

    def __init__(self, path):
        self.path = path
        self.toks = []
        self.comments = []
        self.directives = []
        self.fns = []
        self.test_lines = set()  # lines inside #[cfg(test)] mod bodies


def _match_brace(toks, i):
    """i points at '{'; return index of the matching '}' (or len-1)."""
    depth = 0
    n = len(toks)
    while i < n:
        t = toks[i].text
        if t == "{":
            depth += 1
        elif t == "}":
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return n - 1


def _skip_angles(toks, i):
    """i points at '<'; return index just past the matching '>'."""
    depth = 0
    n = len(toks)
    while i < n:
        t = toks[i].text
        if t == "<":
            depth += 1
        elif t == ">":
            depth -= 1
            if depth == 0:
                return i + 1
        elif t in ("{", ";"):
            return i  # malformed; bail
        i += 1
    return n


def _attr_info(toks, i):
    """i points at '#'; returns (end_index_exclusive, idents_inside)."""
    n = len(toks)
    j = i + 1
    if j < n and toks[j].text == "!":
        j += 1
    if j >= n or toks[j].text != "[":
        return i + 1, []
    depth = 0
    idents = []
    while j < n:
        t = toks[j]
        if t.text == "[":
            depth += 1
        elif t.text == "]":
            depth -= 1
            if depth == 0:
                return j + 1, idents
        elif t.kind == "ident":
            idents.append(t.text)
        j += 1
    return n, idents


def _item_start(toks, fn_kw):
    """Walk back from the `fn` keyword over visibility/qualifiers/attributes
    to the first token of the item."""
    j = fn_kw - 1
    while j >= 0:
        t = toks[j].text
        if t in ("pub", "unsafe", "const", "async", "default", "extern"):
            j -= 1
        elif toks[j].kind == "str" and j >= 1 and toks[j - 1].text == "extern":
            j -= 1
        elif t == ")":
            # pub(crate) / pub(in path)
            depth = 0
            k = j
            while k >= 0:
                if toks[k].text == ")":
                    depth += 1
                elif toks[k].text == "(":
                    depth -= 1
                    if depth == 0:
                        break
                k -= 1
            j = k - 1
        elif t == "]":
            # attribute group
            depth = 0
            k = j
            while k >= 0:
                if toks[k].text == "]":
                    depth += 1
                elif toks[k].text == "[":
                    depth -= 1
                    if depth == 0:
                        break
                k -= 1
            if k >= 1 and toks[k - 1].text == "#":
                j = k - 2
            else:
                break
        else:
            break
    return j + 1


def parse_file(path, text):
    fm = FileModel(path)
    toks, comments = tokenize(text)
    fm.toks = toks
    fm.comments = comments
    fm.directives = parse_directives(comments)
    n = len(toks)

    # context stack: (kind, name, depth_at_open); depth counts '{'
    stack = []
    depth = 0
    pending_attr_idents = []
    i = 0
    while i < n:
        t = toks[i]
        txt = t.text
        if txt == "#" :
            end, idents = _attr_info(toks, i)
            pending_attr_idents.extend(idents)
            i = end
            continue
        if txt == "{":
            depth += 1
            pending_attr_idents = []
            i += 1
            continue
        if txt == "}":
            depth -= 1
            while stack and stack[-1][2] > depth:
                stack.pop()
            i += 1
            continue
        if txt == "impl" and t.kind == "ident":
            j = i + 1
            if j < n and toks[j].text == "<":
                j = _skip_angles(toks, j)
            # collect header until '{' or ';' at angle depth 0
            run = []
            angle = 0
            while j < n:
                tt = toks[j].text
                if tt == "<":
                    angle += 1
                elif tt == ">":
                    angle -= 1
                elif angle == 0 and tt in ("{", ";", "where"):
                    break
                run.append(toks[j])
                j += 1
            # skip a where clause
            while j < n and toks[j].text not in ("{", ";"):
                j += 1
            if j < n and toks[j].text == "{":
                # type name: after last top-level 'for' if present
                segs = run
                for k in range(len(run) - 1, -1, -1):
                    if run[k].text == "for":
                        segs = run[k + 1 :]
                        break
                name = None
                for tk in segs:
                    if tk.text == "<":
                        break
                    if tk.kind == "ident" and tk.text not in ("dyn", "mut"):
                        name = tk.text
                stack.append(("impl", name or "?", depth + 1))
                depth += 1
                i = j + 1
                pending_attr_idents = []
                continue
            i = j + 1
            pending_attr_idents = []
            continue
        if txt == "trait" and t.kind == "ident":
            j = i + 1
            name = toks[j].text if j < n and toks[j].kind == "ident" else "?"
            while j < n and toks[j].text not in ("{", ";"):
                j += 1
            if j < n and toks[j].text == "{":
                stack.append(("trait", name, depth + 1))
                depth += 1
            i = j + 1
            pending_attr_idents = []
            continue
        if txt == "mod" and t.kind == "ident":
            j = i + 1
            is_test_mod = any(a == "cfg" for a in pending_attr_idents) and any(
                a == "test" for a in pending_attr_idents
            )
            while j < n and toks[j].text not in ("{", ";"):
                j += 1
            if j < n and toks[j].text == "{":
                in_test = is_test_mod or any(s[0] == "mod_test" for s in stack)
                kind = "mod_test" if in_test else "mod"
                if kind == "mod_test":
                    close = _match_brace(toks, j)
                    for ln in range(toks[j].line, toks[close].line + 1):
                        fm.test_lines.add(ln)
                stack.append((kind, "", depth + 1))
                depth += 1
            i = j + 1
            pending_attr_idents = []
            continue
        if txt == "fn" and t.kind == "ident":
            f = Fn()
            f.fn_kw = i
            f.item_start = _item_start(toks, i)
            f.sig_line = toks[f.item_start].line
            f.is_test = (
                ("test" in pending_attr_idents and "cfg" not in pending_attr_idents)
                or any(s[0] == "mod_test" for s in stack)
            )
            if "cfg" in pending_attr_idents and "test" in pending_attr_idents:
                f.is_test = True
            for s in reversed(stack):
                if s[0] == "impl":
                    f.impl_type = s[1]
                    break
                if s[0] == "trait":
                    f.in_trait = True
                    break
            j = i + 1
            if j < n and toks[j].kind == "ident":
                f.name = toks[j].text
                j += 1
            if j < n and toks[j].text == "<":
                j = _skip_angles(toks, j)
            if j < n and toks[j].text == "(":
                pd = 0
                j0 = j
                while j < n:
                    if toks[j].text == "(":
                        pd += 1
                    elif toks[j].text == ")":
                        pd -= 1
                        if pd == 0:
                            break
                    j += 1
                f.params = toks[j0 + 1 : j]
                j += 1
            if j < n and toks[j].text == "->":
                j += 1
                angle = 0
                while j < n:
                    tt = toks[j].text
                    if tt == "<":
                        angle += 1
                    elif tt == ">":
                        angle -= 1
                    elif angle <= 0 and tt in ("{", ";", "where"):
                        break
                    f.ret.append(tt)
                    j += 1
            while j < n and toks[j].text not in ("{", ";"):
                j += 1
            if j < n and toks[j].text == "{":
                f.body_start = j
                f.body_end = _match_brace(toks, j)
                f.body_end_line = toks[f.body_end].line
                fm.fns.append(f)
                # walk *into* the body (nested fns are parsed too)
                depth += 1
                stack.append(("fnbody", f.name, depth))
                i = j + 1
            else:
                f.body_end_line = toks[min(j, n - 1)].line
                fm.fns.append(f)
                i = j + 1
            pending_attr_idents = []
            continue
        pending_attr_idents = []
        i += 1

    # attach cold markers
    for d in fm.directives:
        if d.kind != "cold":
            continue
        for f in fm.fns:
            if f.item_start <= d.next_tok <= (f.body_start if f.body_start >= 0 else f.fn_kw + 4):
                f.is_cold = True
                d.used = True
                break
    return fm


# ---------------------------------------------------------------------------
# Findings / waivers
# ---------------------------------------------------------------------------

class Finding(object):
    __slots__ = ("rule", "file", "line", "message", "waived")

    def __init__(self, rule, file, line, message):
        self.rule = rule
        self.file = file
        self.line = line
        self.message = message
        self.waived = None  # reason string when waived

    def as_json(self):
        return {
            "rule": self.rule,
            "file": self.file,
            "line": self.line,
            "message": self.message,
            "waived": self.waived,
        }


def fn_is_scanned(f):
    return not f.is_test and f.body_start >= 0


# ---- R1 -------------------------------------------------------------------

def r1_hot_alloc(fm, findings):
    rel = fm.path
    if rel not in HOT_FILES and not any(rel.startswith(p) for p in HOT_PREFIXES):
        return
    toks = fm.toks
    for f in fm.fns:
        if not fn_is_scanned(f) or f.is_cold:
            continue
        i = f.body_start
        while i <= f.body_end:
            t = toks[i]
            nxt = toks[i + 1].text if i + 1 < len(toks) else ""
            nx2 = toks[i + 2].text if i + 2 < len(toks) else ""
            hit = None
            if t.kind == "ident" and t.text == "Vec" and nxt == "::" and nx2 == "new":
                hit = "Vec::new"
            elif t.kind == "ident" and t.text == "vec" and nxt == "!":
                hit = "vec!"
            elif t.kind == "ident" and t.text == "String" and nxt == "::" and nx2 == "from":
                hit = "String::from"
            elif t.kind == "ident" and t.text == "format" and nxt == "!":
                hit = "format!"
            elif t.kind == "ident" and t.text == "Box" and nxt == "::" and nx2 == "new":
                hit = "Box::new"
            elif t.text == "." and i + 1 < len(toks) and toks[i + 1].kind == "ident" \
                    and toks[i + 1].text in ALLOC_CALLS:
                hit = "." + toks[i + 1].text
            if hit:
                findings.append(Finding(
                    "no-alloc-hot-path", rel, t.line,
                    "%s in hot fn `%s` (mark `// lint: cold` or waive)" % (hit, f.name),
                ))
            i += 1


# ---- R2 -------------------------------------------------------------------

def _call_args_float(toks, open_paren):
    """open_paren indexes '('; True when the argument tokens contain a float
    literal or an .abs()/.sqrt() call — the distance-typed heuristic."""
    depth = 0
    i = open_paren
    n = len(toks)
    while i < n:
        t = toks[i]
        if t.text == "(":
            depth += 1
        elif t.text == ")":
            depth -= 1
            if depth == 0:
                return False
        elif t.kind == "fnum":
            return True
        elif t.text == "." and i + 1 < n and toks[i + 1].text in ("abs", "sqrt"):
            return True
        i += 1
    return False


def r2_total_ordering(fm, findings):
    toks = fm.toks
    for f in fm.fns:
        if not fn_is_scanned(f):
            continue
        i = f.body_start
        while i <= f.body_end:
            t = toks[i]
            nxt = toks[i + 1] if i + 1 < len(toks) else None
            nx2 = toks[i + 2] if i + 2 < len(toks) else None
            if t.text == "." and nxt is not None and nxt.kind == "ident":
                m = nxt.text
                if m == "partial_cmp":
                    findings.append(Finding(
                        "total-ordering", fm.path, t.line,
                        ".partial_cmp on distances — use total_cmp",
                    ))
                elif m in ("max", "min") and nx2 is not None and nx2.text == "(" \
                        and _call_args_float(toks, i + 2):
                    findings.append(Finding(
                        "total-ordering", fm.path, t.line,
                        ".%s(..) with float argument — use total_cmp selection" % m,
                    ))
            elif t.kind == "ident" and t.text in ("f32", "f64") and nxt is not None \
                    and nxt.text == "::" and nx2 is not None and nx2.text in ("max", "min"):
                findings.append(Finding(
                    "total-ordering", fm.path, t.line,
                    "%s::%s as fn value — use total_cmp selection" % (t.text, nx2.text),
                ))
            i += 1


# ---- R3 -------------------------------------------------------------------

def _ret_is_wire_result(f):
    return "Result" in f.ret and "WireError" in f.ret


def r3_panic_free(fm, findings):
    toks = fm.toks
    file_scope = fm.path in R3_FILES
    for f in fm.fns:
        if not fn_is_scanned(f):
            continue
        wire = _ret_is_wire_result(f)
        if not (wire or file_scope):
            continue
        i = f.body_start
        while i <= f.body_end:
            t = toks[i]
            nxt = toks[i + 1] if i + 1 < len(toks) else None
            if t.text == "." and nxt is not None and nxt.kind == "ident" \
                    and nxt.text in ("unwrap", "expect"):
                findings.append(Finding(
                    "panic-free-decode", fm.path, t.line,
                    ".%s in %s — return a typed error" % (
                        nxt.text, "WireError decoder" if wire else "serve runtime"),
                ))
            elif t.kind == "ident" and nxt is not None and nxt.text == "!" and (
                t.text in PANIC_MACROS or (wire and t.text in ASSERT_MACROS)
            ):
                findings.append(Finding(
                    "panic-free-decode", fm.path, t.line,
                    "%s! in %s — return a typed error" % (
                        t.text, "WireError decoder" if wire else "serve runtime"),
                ))
            elif wire and t.text == "[" and i > f.body_start:
                prev = toks[i - 1]
                if prev.kind == "ident" or prev.text in (")", "]"):
                    findings.append(Finding(
                        "panic-free-decode", fm.path, t.line,
                        "indexing in WireError decoder — use .get()/try_take",
                    ))
            i += 1


# ---- R4 -------------------------------------------------------------------

DECODER_EXACT = {"try_from_bytes", "from_bytes", "try_from_snapshot_bytes"}


def _is_decoder(f):
    if f.in_trait or f.is_test:
        return False
    nm = f.name
    named = nm in DECODER_EXACT or nm.endswith("_from_bytes") or (
        nm.startswith("decode_") and _ret_is_wire_result(f)
    )
    if not named:
        return False
    # exactly one parameter, and it mentions u8 (i.e. &[u8])
    depth = 0
    commas = 0
    has_any = False
    for t in f.params:
        has_any = True
        if t.text in ("(", "[", "<"):
            depth += 1
        elif t.text in (")", "]", ">"):
            depth -= 1
        elif t.text == "," and depth == 0:
            commas += 1
    if not has_any or commas != 0:
        return False
    if not any(t.text == "u8" for t in f.params):
        return False
    if any(t.text == "self" for t in f.params):
        return False
    return True


def r4_registration(files, registry_idents, findings):
    for fm in files:
        for f in fm.fns:
            if f.body_start < 0 or not _is_decoder(f):
                continue
            name_ok = f.name in registry_idents
            type_ok = f.impl_type is None or f.impl_type in registry_idents
            if not (name_ok and type_ok):
                who = "%s::%s" % (f.impl_type, f.name) if f.impl_type else f.name
                findings.append(Finding(
                    "harness-registration", fm.path, f.sig_line,
                    "decoder `%s` is not exercised by tests/wire_adversarial.rs" % who,
                ))


# ---- R5 -------------------------------------------------------------------

def _is_config_key(s):
    if not s:
        return False
    for part in s.split("."):
        if not part:
            return False
        if part[0] not in "abcdefghijklmnopqrstuvwxyz":
            return False
        for c in part:
            if c not in "abcdefghijklmnopqrstuvwxyz0123456789_":
                return False
    return True


def _word_bounded(doc, key):
    start = 0
    while True:
        idx = doc.find(key, start)
        if idx < 0:
            return False
        before = doc[idx - 1] if idx > 0 else " "
        after_i = idx + len(key)
        after = doc[after_i] if after_i < len(doc) else " "
        if before not in IDENT_CONT and before != "." and after not in IDENT_CONT \
                and after != ".":
            return True
        start = idx + 1


def r5_config_docs(fm, docs_text, findings):
    if not fm.path.startswith("config/"):
        return
    toks = fm.toks
    for f in fm.fns:
        if not fn_is_scanned(f):
            continue
        i = f.body_start
        while i <= f.body_end:
            t = toks[i]
            if t.kind == "str" and i + 1 <= f.body_end and toks[i + 1].text == "=>":
                lit = t.text
                if lit.startswith('"') and lit.endswith('"'):
                    key = lit[1:-1]
                    if _is_config_key(key) and not _word_bounded(docs_text, key):
                        findings.append(Finding(
                            "config-doc-parity", fm.path, t.line,
                            'config key "%s" is not documented in README.md/DESIGN.md' % key,
                        ))
            i += 1


# ---------------------------------------------------------------------------
# Waiver application
# ---------------------------------------------------------------------------

def apply_waivers(fm, findings):
    """Mark findings in `fm` waived per its directives; emit lint-directive
    findings for malformed or unused directives."""
    mine = [f for f in findings if f.file == fm.path and f.rule in KNOWN_RULES]
    extra = []
    for d in fm.directives:
        if d.kind == "bad":
            extra.append(Finding("lint-directive", fm.path, d.line, d.error))
            continue
        if d.kind == "cold":
            if not d.used:
                extra.append(Finding(
                    "lint-directive", fm.path, d.line,
                    "`lint: cold` marker does not precede a fn",
                ))
            continue
        # allow(...)
        scope_fn = None
        if d.standalone:
            for f in fm.fns:
                if f.item_start <= d.next_tok <= (f.body_start if f.body_start >= 0 else f.fn_kw + 4):
                    scope_fn = f
                    break
        if scope_fn is not None:
            lines = (scope_fn.sig_line, scope_fn.body_end_line)
        elif d.standalone:
            nxt_line = fm.toks[d.next_tok].line if 0 <= d.next_tok < len(fm.toks) else -1
            lines = (nxt_line, nxt_line)
        else:
            lines = (d.line, d.line)
        hit = False
        for f in mine:
            if f.waived is None and f.rule in d.rules and lines[0] <= f.line <= lines[1]:
                f.waived = d.reason
                hit = True
        if hit:
            d.used = True
        else:
            extra.append(Finding(
                "lint-directive", fm.path, d.line,
                "unused waiver for %s — remove it" % ",".join(d.rules),
            ))
    findings.extend(extra)


# ---------------------------------------------------------------------------
# Fixture expectations (`//~ rule-a, rule-b` trailing comments)
# ---------------------------------------------------------------------------

def fixture_expectations(fm):
    exp = []
    for cm in fm.comments:
        if cm.text.startswith("~"):
            for nm in cm.text[1:].split(","):
                nm = nm.strip()
                if nm:
                    exp.append((fm.path, cm.line, nm))
    return exp


def fixture_virtual_path(text):
    for raw in text.splitlines():
        line = raw.strip()
        if line.startswith("//"):
            body = line.lstrip("/").lstrip("!").strip()
            if body.startswith("lint-fixture:"):
                rest = body[len("lint-fixture:") :].strip()
                if rest.startswith("virtual="):
                    return rest[len("virtual=") :].strip()
        elif line:
            break
    return None


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def collect_rs(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fn in sorted(filenames):
            if fn.endswith(".rs"):
                out.append(os.path.join(dirpath, fn))
    return out


def scan_tree(src_root, registry_path, docs_text):
    files = []
    for path in collect_rs(src_root):
        rel = os.path.relpath(path, src_root).replace(os.sep, "/")
        with open(path, "r") as fh:
            files.append(parse_file(rel, fh.read()))
    registry_idents = set()
    if registry_path and os.path.exists(registry_path):
        with open(registry_path, "r") as fh:
            rtoks, _ = tokenize(fh.read())
        registry_idents = {t.text for t in rtoks if t.kind == "ident"}
    findings = []
    for fm in files:
        r1_hot_alloc(fm, findings)
        r2_total_ordering(fm, findings)
        r3_panic_free(fm, findings)
        r5_config_docs(fm, docs_text, findings)
    r4_registration(files, registry_idents, findings)
    for fm in files:
        apply_waivers(fm, findings)
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return files, findings


def scan_fixtures(fixture_root):
    """Fixture corpus: each .rs carries `// lint-fixture: virtual=<path>`;
    DOCS.md is the doc corpus; the file with virtual tests/wire_adversarial.rs
    is the registry.  Returns (expected, actual, ok)."""
    files = []
    registry_idents = set()
    docs_text = ""
    docs_path = os.path.join(fixture_root, "DOCS.md")
    if os.path.exists(docs_path):
        with open(docs_path, "r") as fh:
            docs_text = fh.read()
    expectations = []
    for path in collect_rs(fixture_root):
        with open(path, "r") as fh:
            text = fh.read()
        virtual = fixture_virtual_path(text) or os.path.basename(path)
        if virtual == "tests/wire_adversarial.rs":
            rtoks, _ = tokenize(text)
            registry_idents = {t.text for t in rtoks if t.kind == "ident"}
            continue
        fm = parse_file(virtual, text)
        files.append(fm)
        expectations.extend(fixture_expectations(fm))
    findings = []
    for fm in files:
        r1_hot_alloc(fm, findings)
        r2_total_ordering(fm, findings)
        r3_panic_free(fm, findings)
        r5_config_docs(fm, docs_text, findings)
    r4_registration(files, registry_idents, findings)
    for fm in files:
        apply_waivers(fm, findings)
    actual = sorted(
        (f.file, f.line, f.rule) for f in findings if f.waived is None
    )
    expected = sorted(set(expectations))
    return expected, actual, expected == actual


def main(argv):
    src = "rust/src"
    registry = None
    docs = []
    json_out = None
    fixtures = None
    deny = False
    quiet = False
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--src":
            i += 1
            src = argv[i]
        elif a == "--registry":
            i += 1
            registry = argv[i]
        elif a == "--docs":
            i += 1
            docs.append(argv[i])
        elif a == "--json":
            i += 1
            json_out = argv[i]
        elif a == "--fixtures":
            i += 1
            fixtures = argv[i]
        elif a == "--deny-warnings":
            deny = True
        elif a == "--quiet":
            quiet = True
        else:
            sys.stderr.write("unknown arg %s\n" % a)
            return 2
        i += 1

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(src)))
    if registry is None:
        registry = os.path.join(os.path.dirname(os.path.abspath(src)), "tests", "wire_adversarial.rs")
    if not docs:
        docs = [os.path.join(repo_root, "README.md"), os.path.join(repo_root, "DESIGN.md")]
    docs_text = ""
    for d in docs:
        if os.path.exists(d):
            with open(d, "r") as fh:
                docs_text += fh.read() + "\n"

    files, findings = scan_tree(src, registry, docs_text)
    unwaived = [f for f in findings if f.waived is None]
    waived = [f for f in findings if f.waived is not None]

    fixture_result = None
    if fixtures:
        expected, actual, ok = scan_fixtures(fixtures)
        fixture_result = {
            "root": fixtures,
            "expected": len(expected),
            "actual": len(actual),
            "matched": ok,
        }
        if not ok:
            missing = [e for e in expected if e not in actual]
            surplus = [a for a in actual if a not in expected]
            for e in missing:
                sys.stderr.write("fixture MISSING %s:%d %s\n" % e)
            for s in surplus:
                sys.stderr.write("fixture SURPLUS %s:%d %s\n" % s)

    if not quiet:
        for f in findings:
            tag = "waived(%s)" % f.waived if f.waived else "DENY"
            print("%s:%d [%s] %s %s" % (f.file, f.line, f.rule, f.message, tag))
        print(
            "lint: %d file(s), %d fn(s), %d finding(s) (%d waived, %d unwaived)"
            % (
                len(files),
                sum(len(fm.fns) for fm in files),
                len(findings),
                len(waived),
                len(unwaived),
            )
        )
        if fixture_result:
            print("fixtures: %s" % ("ok" if fixture_result["matched"] else "MISMATCH"))

    if json_out:
        waiver_inventory = []
        for fm in files:
            for d in fm.directives:
                if d.kind == "allow" and d.used:
                    waiver_inventory.append({
                        "file": fm.path,
                        "line": d.line,
                        "rules": d.rules,
                        "reason": d.reason,
                    })
        report = {
            "version": 1,
            "generator": "python/neargraph_lint.py",
            "src": src,
            "files_scanned": len(files),
            "fns_scanned": sum(len(fm.fns) for fm in files),
            "findings_unwaived": len(unwaived),
            "waiver_count": len(waiver_inventory),
            "waivers": waiver_inventory,
            "findings": [f.as_json() for f in findings],
        }
        if fixture_result:
            report["fixtures"] = fixture_result
        with open(json_out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=False)
            fh.write("\n")

    bad = bool(unwaived) or (fixture_result and not fixture_result["matched"])
    if deny and bad:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
