//! Vietoris–Rips complex construction — the topological-data-analysis
//! workload from the paper's introduction (the ε-graph is the 1-skeleton;
//! higher simplices are cliques).
//!
//! Builds the ε-graph at a sweep of scales over a noisy circle and counts
//! simplices + Betti-0 (components) per scale, watching the circle's
//! connectivity appear.
//!
//! ```text
//! cargo run --release --example rips
//! ```

use neargraph::dist::run_epsilon_graph;
use neargraph::prelude::*;

fn main() {
    // A noisy circle in the plane.
    let mut rng = Rng::new(5);
    let n = 400usize;
    let mut points = DenseMatrix::new(2);
    for _ in 0..n {
        let t = rng.f64() * std::f64::consts::TAU;
        let r = 1.0 + rng.normal() * 0.03;
        points.push(&[(r * t.cos()) as f32, (r * t.sin()) as f32]);
    }

    println!("{:<8} {:>7} {:>9} {:>11} {:>6}", "eps", "edges", "triangles", "tetrahedra", "b0");
    for eps in [0.05f64, 0.1, 0.2, 0.4] {
        let cfg = RunConfig { ranks: 4, algorithm: Algorithm::LandmarkRing, ..Default::default() };
        let result = run_epsilon_graph(&points, Euclidean, eps, &cfg);
        let g = &result.graph;

        // 2-simplices: triangles = edges (u,v) with common neighbors w>v.
        let mut triangles = 0u64;
        let mut tetrahedra = 0u64;
        for (u, v) in result.edges.edges().iter().copied() {
            let common: Vec<u32> = intersect(g.neighbors(u as usize), g.neighbors(v as usize))
                .into_iter()
                .filter(|&w| w > v)
                .collect();
            triangles += common.len() as u64;
            // 3-simplices: pairs (w1, w2) in `common` that are adjacent.
            for (i, &w1) in common.iter().enumerate() {
                for &w2 in &common[i + 1..] {
                    if g.neighbors(w1 as usize).binary_search(&w2).is_ok() {
                        tetrahedra += 1;
                    }
                }
            }
        }
        let (_, b0) = g.components();
        println!(
            "{:<8} {:>7} {:>9} {:>11} {:>6}",
            eps,
            g.num_edges(),
            triangles,
            tetrahedra,
            b0
        );
    }
    println!("\nAs eps grows the noisy circle connects into a single component (b0 -> 1).");
}

fn intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}
