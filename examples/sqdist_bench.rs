// microbench for sq_dist variants
use neargraph::util::Rng;
use std::time::Instant;

#[inline(never)]
fn v_current(a: &[f32], b: &[f32]) -> f32 { neargraph::metric::euclidean::sq_dist(a, b) }

#[inline(never)]
fn v_8acc(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let c = n / 8;
    let mut acc = [0.0f32; 8];
    for k in 0..c {
        let i = k * 8;
        for j in 0..8 {
            let d = a[i + j] - b[i + j];
            acc[j] += d * d;
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in c * 8..n {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[inline(never)]
fn v_chunks(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for j in 0..8 {
            let d = xa[j] - xb[j];
            acc[j] += d * d;
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        s += d * d;
    }
    s
}

fn bench(name: &str, f: fn(&[f32], &[f32]) -> f32, a: &[Vec<f32>], iters: usize) {
    let t = Instant::now();
    let mut acc = 0.0f32;
    for _ in 0..iters {
        for i in 0..a.len() {
            acc += f(&a[i], &a[(i + 7) % a.len()]);
        }
    }
    std::hint::black_box(acc);
    let dt = t.elapsed().as_secs_f64();
    let dists = (iters * a.len()) as f64;
    println!("{name:<10} {:>8.1} Mdist/s ({dt:.3}s)", dists / dt / 1e6);
}

fn main() {
    let mut rng = Rng::new(1);
    for dim in [20usize, 55, 128, 800] {
        println!("--- dim={dim}");
        let pts: Vec<Vec<f32>> =
            (0..256).map(|_| (0..dim).map(|_| rng.normal_f32()).collect()).collect();
        let iters = (40_000_000 / (dim * 256)).max(1);
        bench("current", v_current, &pts, iters);
        bench("8acc", v_8acc, &pts, iters);
        bench("chunks8", v_chunks, &pts, iters);
    }
}
