//! Quickstart: build an ε-graph with each of the three distributed
//! algorithms and inspect the result.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use neargraph::dist::run_epsilon_graph;
use neargraph::prelude::*;
use neargraph::util::fmt_secs;

fn main() {
    // 1. Some clustered data: 2 000 points on a 4-dimensional manifold
    //    embedded in 16 ambient dimensions.
    let mut rng = Rng::new(42);
    let points = neargraph::data::synthetic::manifold_mixture(&mut rng, 2_000, 16, 4, 8, 0.08);

    // 2. Pick ε for ~25 neighbors per vertex on average.
    let eps = neargraph::data::calibrate_eps(&points, &Euclidean, 25.0, 40_000, &mut rng);
    println!("calibrated eps = {eps:.4}");

    // 3. Build the ε-graph with each algorithm on 8 simulated MPI ranks.
    for algorithm in Algorithm::ALL {
        let cfg = RunConfig { ranks: 8, algorithm, ..Default::default() };
        let result = run_epsilon_graph(&points, Euclidean, eps, &cfg);
        let stats = result.graph.degree_stats();
        println!(
            "{:<14} edges={:<6} avg_degree={:<6.2} makespan={}",
            algorithm.name(),
            stats.num_edges,
            stats.avg_degree,
            fmt_secs(result.makespan)
        );
    }

    // 4. The graph is a plain CSR: walk a neighborhood.
    let cfg = RunConfig { ranks: 4, ..Default::default() };
    let result = run_epsilon_graph(&points, Euclidean, eps, &cfg);
    let v = 0;
    println!(
        "vertex {v} has {} neighbors; first few: {:?}",
        result.graph.degree(v),
        &result.graph.neighbors(v)[..result.graph.degree(v).min(8)]
    );

    // 5. Single-node usage: the cover tree directly.
    let tree = CoverTree::build(&points, &Euclidean, &Default::default());
    let hits = tree.query_vec(&Euclidean, points.row(0), eps);
    println!("cover-tree query of point 0: {} hits (incl. itself)", hits.len());

    // 6. The same index answers k-NN queries (extension beyond the paper's
    //    fixed-radius scope).
    let knn = tree.knn(&Euclidean, points.row(0), 6);
    println!(
        "6-NN of point 0: {:?}",
        knn.iter().map(|&(id, d)| (id, (d * 1000.0).round() / 1000.0)).collect::<Vec<_>>()
    );

    // 7. Every search structure sits behind one facade; results carry
    //    their distances, so the ε-graph comes out weighted.
    let index = build_index(
        IndexKind::CoverTree, &points, Euclidean, &IndexParams::default(),
    )
    .expect("cover tree supports every metric");
    let graph = neargraph::index::epsilon_graph(index.as_ref(), eps, &Pool::new(4));
    let (v, w) = graph.neighbor_entries(0).next().expect("vertex 0 has a neighbor");
    println!(
        "facade ({}): {} weighted edges; first edge of vertex 0: -> {v} at d={w:.4}",
        index.kind().name(),
        graph.num_edges()
    );
}
