// standalone perf driver: heavy landmark run
use neargraph::dist::{run_epsilon_graph, Algorithm, RunConfig};
use neargraph::prelude::*;
fn main() {
    let mut rng = Rng::new(7);
    let pts = neargraph::data::synthetic::manifold_mixture(&mut rng, 20_000, 64, 8, 20, 0.07);
    let eps = neargraph::data::calibrate_eps(&pts, &Euclidean, 60.0, 60_000, &mut rng);
    let cfg = RunConfig { ranks: 16, algorithm: Algorithm::LandmarkColl, ..Default::default() };
    let t = std::time::Instant::now();
    let res = run_epsilon_graph(&pts, Euclidean, eps, &cfg);
    println!("edges={} makespan={:.3} wall={:.3}", res.graph.num_edges(), res.makespan, t.elapsed().as_secs_f64());
}
