//! Perf driver for the shared-memory parallel cover tree (PR 2): build +
//! ε self-join on a Table-I-style dense workload, sequential vs pooled,
//! emitting a machine-readable `BENCH_pr2.json` so the perf trajectory
//! accumulates across PRs.
//!
//! ```text
//! cargo run --release --example perf_driver -- [--n 50000] [--dim 16] \
//!     [--threads 1,2,4] [--target-degree 30] [--out BENCH_pr2.json]
//! ```
//!
//! The driver also asserts that every thread count reproduces the
//! single-thread edge set and distance-call counts exactly (the
//! determinism gate, on the bench workload itself).

use neargraph::covertree::{BuildParams, CoverTree};
use neargraph::metric::{Counted, Euclidean};
use neargraph::util::{Pool, Rng};
use std::time::Instant;

struct Run {
    threads: usize,
    build_s: f64,
    join_s: f64,
    build_dists: u64,
    join_dists: u64,
    edges: u64,
    edge_hash: u64,
}

fn main() {
    let args = neargraph::cli::Args::from_env().unwrap_or_else(|e| fail(&e));
    let n = args.get_usize("n").unwrap_or_else(|e| fail(&e)).unwrap_or(50_000);
    let dim = args.get_usize("dim").unwrap_or_else(|e| fail(&e)).unwrap_or(16);
    let target_degree =
        args.get_f64("target-degree").unwrap_or_else(|e| fail(&e)).unwrap_or(30.0);
    let threads_arg = args.get_or("threads", "1,2,4").to_string();
    let out_path = args.get_or("out", "BENCH_pr2.json").to_string();
    args.reject_unknown().unwrap_or_else(|e| fail(&e));
    let thread_list: Vec<usize> = threads_arg
        .split(',')
        .map(|t| t.trim().parse().unwrap_or_else(|_| fail(&format!("bad --threads {t:?}"))))
        .collect();

    let mut rng = Rng::new(7);
    let dataset = format!("gaussian_mixture(n={n},d={dim},k=32,sigma=0.05)");
    eprintln!("[perf_driver] generating {dataset}");
    let pts = neargraph::data::synthetic::gaussian_mixture(&mut rng, n, dim, 32, 0.05);
    let eps = neargraph::data::calibrate_eps(&pts, &Euclidean, target_degree, 60_000, &mut rng);
    eprintln!("[perf_driver] eps={eps:.6} (target degree {target_degree})");

    let params = BuildParams::default();
    let mut runs: Vec<Run> = Vec::new();
    for &threads in &thread_list {
        let pool = Pool::new(threads);
        let counted = Counted::new(Euclidean);

        let t0 = Instant::now();
        let tree = CoverTree::build_par(&pts, &counted, &params, &pool);
        let build_s = t0.elapsed().as_secs_f64();
        let build_dists = counted.count();
        counted.counter().reset();

        let mut edges = 0u64;
        let mut edge_hash = 0u64;
        let t1 = Instant::now();
        tree.eps_self_join_par(&counted, eps, &pool, |a, b| {
            edges += 1;
            // Order-independent edge-set fingerprint (sum of mixed pairs).
            edge_hash = edge_hash.wrapping_add(mix(((a as u64) << 32) | b as u64));
        });
        let join_s = t1.elapsed().as_secs_f64();
        let join_dists = counted.count();

        eprintln!(
            "[perf_driver] threads={threads}: build {build_s:.3}s ({build_dists} dists), \
             join {join_s:.3}s ({join_dists} dists), {edges} edges"
        );
        runs.push(Run { threads, build_s, join_s, build_dists, join_dists, edges, edge_hash });
    }

    // Determinism gate on the bench workload: every run must agree with
    // the first bit-for-bit (edge set and distance-call counts).
    let base = &runs[0];
    for r in &runs[1..] {
        assert_eq!(r.edges, base.edges, "edge count changed at threads={}", r.threads);
        assert_eq!(r.edge_hash, base.edge_hash, "edge set changed at threads={}", r.threads);
        assert_eq!(r.build_dists, base.build_dists, "build dists changed at threads={}", r.threads);
        assert_eq!(r.join_dists, base.join_dists, "join dists changed at threads={}", r.threads);
    }

    let (seq_total, best) = summarize(&runs);
    let json = render_json(&dataset, n, dim, eps, &runs, seq_total, best);
    std::fs::write(&out_path, &json).unwrap_or_else(|e| fail(&format!("{out_path}: {e}")));
    println!("{json}");
    eprintln!("[perf_driver] wrote {out_path}");
}

fn summarize(runs: &[Run]) -> (f64, &Run) {
    let seq_total = runs[0].build_s + runs[0].join_s;
    let best = runs
        .iter()
        .min_by(|a, b| (a.build_s + a.join_s).total_cmp(&(b.build_s + b.join_s)))
        .unwrap();
    (seq_total, best)
}

fn render_json(
    dataset: &str,
    n: usize,
    dim: usize,
    eps: f64,
    runs: &[Run],
    seq_total: f64,
    best: &Run,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"pr2_parallel_covertree\",\n");
    s.push_str(&format!("  \"dataset\": \"{dataset}\",\n"));
    s.push_str(&format!("  \"n\": {n},\n  \"dim\": {dim},\n  \"eps\": {eps},\n"));
    s.push_str("  \"runs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"threads\": {}, \"build_s\": {:.6}, \"join_s\": {:.6}, \
             \"build_dist_calls\": {}, \"join_dist_calls\": {}, \"edges\": {}}}{}\n",
            r.threads,
            r.build_s,
            r.join_s,
            r.build_dists,
            r.join_dists,
            r.edges,
            if i + 1 < runs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"best_threads\": {},\n  \"speedup_build\": {:.4},\n  \"speedup_total\": {:.4}\n",
        best.threads,
        runs[0].build_s / best.build_s.max(1e-12),
        seq_total / (best.build_s + best.join_s).max(1e-12)
    ));
    s.push_str("}\n");
    s
}

/// splitmix64 finalizer — order-independent accumulation of edge pairs.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

fn fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}
