//! Perf driver: build + ε self-join on a Table-I-style dense workload,
//! sequential vs pooled (the PR 2 trajectory), the same join through the
//! `neargraph::index` facade (PR 3), the k-NN paths when `--knn k` is set
//! (PR 4), a traversal section (PR 5): the flat level-ordered
//! layout vs the legacy build-order traversal on the same batch, with
//! distance-call parity asserted and — via the counting global allocator
//! below — a proof that a warmed [`QueryScratch`] makes steady-state
//! batch queries **allocation-free**, **plus** a serve section (PR 6):
//! the query daemon under pipelined offered load, sweeping the request
//! coalescing window against a per-query baseline (throughput and
//! p50/p99 latency per setting) with the same allocator proving the
//! warmed engine batch path allocation-free, **plus** a chaos section
//! (PR 7): a seeded fault-injected distributed run whose edge set must
//! match its clean twin bit-for-bit, with the fault counters and the
//! virtual-time cost of the retries landing in the JSON, **plus** a
//! mutation section (PR 9): the mutable epoch-tree backend under rolling
//! insert/delete churn, with the insert path's amortized allocation
//! count gated (the children-`Vec` clone regression guard), compactions
//! asserted to fire, and the warmed epoch read path — base, delta and
//! tombstones all populated — proved allocation-free by the same
//! counting allocator, **plus** a kernel section (PR 10): the scalar
//! per-pair leaf filter vs the K-lane SoA kernels on identical leaf
//! visits (ns/pair per metric family, emission bits asserted equal) and
//! the dual-tree self-join vs the batched join per thread count, with
//! the cross-path edge-set fingerprint asserted. Emits machine-readable
//! `BENCH_pr10.json` so the perf trajectory accumulates across PRs.
//!
//! ```text
//! cargo run --release --example perf_driver -- [--n 50000] [--dim 16] \
//!     [--threads 1,2,4] [--target-degree 30] [--knn 16] \
//!     [--out BENCH_pr10.json]
//! ```
//!
//! The driver asserts that every thread count — and every facade backend
//! it times — reproduces the single-thread direct edge set exactly, that
//! the flat traversal reproduces the legacy emission (pairs, distance
//! bits and distance-call count), and that every k-NN path reproduces the
//! identical row fingerprint (the determinism gates, on the bench
//! workload itself).

use neargraph::comm::{FaultCounters, FaultPlan};
use neargraph::covertree::{BuildParams, CoverTree, EpochParams, InsertCoverTree, QueryScratch};
use neargraph::dist::{run_knn_graph, try_run_epsilon_graph, Algorithm, RunConfig};
use neargraph::graph::{GraphSink, KnnGraph};
use neargraph::index::{
    build_index_par, CoverTreeIndex, IndexKind, IndexParams, InsertCoverTreeIndex, MutableOps,
    NearIndex,
};
use neargraph::metric::{Counted, Euclidean, Hamming, Levenshtein, Metric, SoaTile};
use neargraph::points::PointSet;
use neargraph::serve::{serve, BatchOutput, QueryBatch, QueryOp, ServeConfig, ServeEngine};
use neargraph::testkit::serve_sim::{latencies_sorted, percentile, run_clients, ClientPlan, SimQuery};
use neargraph::util::{Pool, Rng};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counting wrapper around the system allocator: every `alloc`,
/// `alloc_zeroed` and growing `realloc` bumps one relaxed counter. The
/// traversal section reads it around a warmed batch query to prove the
/// steady state allocates nothing.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

struct Run {
    threads: usize,
    build_s: f64,
    join_s: f64,
    build_dists: u64,
    join_dists: u64,
    edges: u64,
    edge_hash: u64,
}

struct FacadeRun {
    kind: IndexKind,
    threads: usize,
    build_s: f64,
    join_s: f64,
    edges: u64,
    edge_hash: u64,
}

struct KnnRun {
    /// "facade" or a distributed algorithm name.
    mode: String,
    threads: usize,
    total_s: f64,
    arcs: u64,
    row_hash: u64,
}

/// The PR 5 traversal comparison: flat SoA layout + warmed scratch vs the
/// legacy build-order traversal, on one sequential batch.
struct TraversalRun {
    batch: usize,
    pairs: u64,
    legacy_s: f64,
    flat_s: f64,
    legacy_dists: u64,
    flat_dists: u64,
    /// Heap allocations during the measured (second, warmed) flat batch —
    /// the acceptance gate demands 0 for batches ≥ 1024 queries.
    steady_state_allocs: u64,
}

/// One serve-daemon load point: a coalescing setting under the same
/// scripted pipelined client mix.
struct ServeRun {
    label: &'static str,
    window_us: u64,
    max_batch: usize,
    queries: u64,
    wall_s: f64,
    qps: f64,
    p50_us: u64,
    p99_us: u64,
    mean_batch: f64,
}

/// The PR 7 chaos point: one survivable seeded fault schedule against its
/// clean twin on the same distributed run, with edge-set equality
/// asserted and the injected-fault counters recorded.
struct ChaosRun {
    algorithm: &'static str,
    ranks: usize,
    n: usize,
    clean_makespan: f64,
    faulty_makespan: f64,
    faulty_wall_s: f64,
    counters: FaultCounters,
}

/// One PR 10 kernel point: the scalar per-pair leaf filter vs the
/// K-lane SoA kernel on identical leaf visits for one metric family,
/// with the emission (ids and weight bits, in order) asserted equal.
struct KernelRun {
    metric: &'static str,
    pairs: u64,
    scalar_ns_per_pair: f64,
    lane_ns_per_pair: f64,
}

/// One PR 10 self-join strategy point: batched vs dual-tree at one
/// thread count, both asserted onto the single-thread edge fingerprint.
struct DualRun {
    threads: usize,
    batched_s: f64,
    dual_s: f64,
}

/// The PR 9 mutation point: the mutable epoch backend under rolling
/// churn, with the insert-allocation regression guard and the warmed
/// epoch read path's allocation gate.
struct MutationRun {
    base: usize,
    insert_batch: usize,
    insert_s: f64,
    insert_allocs_per_point: f64,
    churn_rounds: usize,
    churn_s: f64,
    churn_ops_per_s: f64,
    compactions: u64,
    epoch_steady_state_allocs: u64,
}

/// Order-independent fingerprint of a k-NN graph's (vertex, neighbor,
/// distance-bits) arcs — identical iff the certified rows are identical.
fn knn_fingerprint(g: &KnnGraph) -> u64 {
    let mut hash = 0u64;
    for u in 0..g.num_vertices() {
        for (v, d) in g.row_entries(u) {
            hash = hash
                .wrapping_add(mix(((u as u64) << 32) | v as u64).wrapping_add(mix(d.to_bits())));
        }
    }
    hash
}

/// Order-independent edge-set fingerprint sink (unweighted, so direct and
/// facade paths hash identically).
#[derive(Default)]
struct HashSink {
    edges: u64,
    hash: u64,
}

impl GraphSink for HashSink {
    fn accept(&mut self, a: u32, b: u32, _w: f64) {
        let (a, b) = if a < b { (a, b) } else { (b, a) };
        self.edges += 1;
        self.hash = self.hash.wrapping_add(mix(((a as u64) << 32) | b as u64));
    }
}

fn main() {
    let args = neargraph::cli::Args::from_env().unwrap_or_else(|e| fail(&e));
    let n = args.get_usize("n").unwrap_or_else(|e| fail(&e)).unwrap_or(50_000);
    let dim = args.get_usize("dim").unwrap_or_else(|e| fail(&e)).unwrap_or(16);
    let target_degree =
        args.get_f64("target-degree").unwrap_or_else(|e| fail(&e)).unwrap_or(30.0);
    let knn_k = args.get_usize("knn").unwrap_or_else(|e| fail(&e)).unwrap_or(0);
    let threads_arg = args.get_or("threads", "1,2,4").to_string();
    let out_path = args.get_or("out", "BENCH_pr10.json").to_string();
    args.reject_unknown().unwrap_or_else(|e| fail(&e));
    let thread_list: Vec<usize> = threads_arg
        .split(',')
        .map(|t| t.trim().parse().unwrap_or_else(|_| fail(&format!("bad --threads {t:?}"))))
        .collect();

    let mut rng = Rng::new(7);
    let dataset = format!("gaussian_mixture(n={n},d={dim},k=32,sigma=0.05)");
    eprintln!("[perf_driver] generating {dataset}");
    let pts = neargraph::data::synthetic::gaussian_mixture(&mut rng, n, dim, 32, 0.05);
    let eps = neargraph::data::calibrate_eps(&pts, &Euclidean, target_degree, 60_000, &mut rng);
    eprintln!("[perf_driver] eps={eps:.6} (target degree {target_degree})");

    // ------------------------------------------------------------------
    // Direct path: the PR 2 measurement, unchanged for comparability.
    // ------------------------------------------------------------------
    let params = BuildParams::default();
    let mut runs: Vec<Run> = Vec::new();
    for &threads in &thread_list {
        let pool = Pool::new(threads);
        let counted = Counted::new(Euclidean);

        let t0 = Instant::now();
        let tree = CoverTree::build_par(&pts, &counted, &params, &pool);
        let build_s = t0.elapsed().as_secs_f64();
        let build_dists = counted.count();
        counted.counter().reset();

        let mut sink = HashSink::default();
        let t1 = Instant::now();
        tree.eps_self_join_par(&counted, eps, &pool, |a, b, d| sink.accept(a, b, d));
        let join_s = t1.elapsed().as_secs_f64();
        let join_dists = counted.count();

        eprintln!(
            "[perf_driver] direct threads={threads}: build {build_s:.3}s ({build_dists} dists), \
             join {join_s:.3}s ({join_dists} dists), {} edges",
            sink.edges
        );
        runs.push(Run {
            threads,
            build_s,
            join_s,
            build_dists,
            join_dists,
            edges: sink.edges,
            edge_hash: sink.hash,
        });
    }

    // Determinism gate on the bench workload: every run must agree with
    // the first bit-for-bit (edge set and distance-call counts).
    let base = &runs[0];
    for r in &runs[1..] {
        assert_eq!(r.edges, base.edges, "edge count changed at threads={}", r.threads);
        assert_eq!(r.edge_hash, base.edge_hash, "edge set changed at threads={}", r.threads);
        assert_eq!(r.build_dists, base.build_dists, "build dists changed at threads={}", r.threads);
        assert_eq!(r.join_dists, base.join_dists, "join dists changed at threads={}", r.threads);
    }

    // ------------------------------------------------------------------
    // Traversal section (PR 5): flat SoA layout + warmed scratch vs the
    // legacy build-order traversal. Same tree, same ≥1024-query batch,
    // sequential on this thread (the allocator counter is global, so
    // nothing else may run). Gates: identical emission fingerprint,
    // identical distance-call count, zero steady-state allocations.
    // ------------------------------------------------------------------
    let traversal = {
        let tree = CoverTree::build(&pts, &Euclidean, &params);
        let batch = n.min(2048);
        let queries = pts.slice(0, batch);
        let counted = Counted::new(Euclidean);

        let mut legacy_pairs = 0u64;
        let mut legacy_hash = 0u64;
        let t0 = Instant::now();
        tree.query_batch_legacy(&counted, &queries, eps, |q, gid, d| {
            legacy_pairs += 1;
            legacy_hash = legacy_hash
                .wrapping_add(mix(((q as u64) << 32) | gid as u64).wrapping_add(mix(d.to_bits())));
        });
        let legacy_s = t0.elapsed().as_secs_f64();
        let legacy_dists = counted.count();
        counted.counter().reset();

        // Warm the scratch (first call sizes the arena/stack), then
        // measure the second, identical call with the allocation counter.
        let mut scratch = QueryScratch::new();
        tree.query_batch_with(&counted, &queries, eps, &mut scratch, |_, _, _| {});
        counted.counter().reset();
        let mut flat_pairs = 0u64;
        let mut flat_hash = 0u64;
        let alloc0 = allocations();
        let t1 = Instant::now();
        tree.query_batch_with(&counted, &queries, eps, &mut scratch, |q, gid, d| {
            flat_pairs += 1;
            flat_hash = flat_hash
                .wrapping_add(mix(((q as u64) << 32) | gid as u64).wrapping_add(mix(d.to_bits())));
        });
        let flat_s = t1.elapsed().as_secs_f64();
        let steady_state_allocs = allocations() - alloc0;
        let flat_dists = counted.count();

        eprintln!(
            "[perf_driver] traversal batch={batch}: legacy {legacy_s:.4}s ({legacy_dists} dists) \
             vs flat {flat_s:.4}s ({flat_dists} dists), {flat_pairs} pairs, \
             {steady_state_allocs} steady-state allocs"
        );
        assert_eq!(flat_pairs, legacy_pairs, "flat traversal changed the result count");
        assert_eq!(flat_hash, legacy_hash, "flat traversal changed pairs or distance bits");
        assert_eq!(flat_dists, legacy_dists, "flat traversal changed the distance-call count");
        if batch >= 1024 {
            assert_eq!(
                steady_state_allocs, 0,
                "warmed batch query must be allocation-free (batch={batch})"
            );
        }
        TraversalRun {
            batch,
            pairs: flat_pairs,
            legacy_s,
            flat_s,
            legacy_dists,
            flat_dists,
            steady_state_allocs,
        }
    };

    // ------------------------------------------------------------------
    // Kernel section (PR 10): scalar `Metric::leaf_filter` vs the K-lane
    // SoA kernels (`Metric::leaf_filter_with`) on identical leaf visits,
    // one point per metric family. Conformance rides the measurement:
    // emission order, ids and weight bits must match exactly.
    // ------------------------------------------------------------------
    let kernel_runs = {
        let mut krng = Rng::new(9);
        let dense = pts.slice(0, n.min(1_024));
        let codes = neargraph::data::synthetic::hamming_clusters(&mut krng, 1_024, 256, 16, 0.05);
        let strs = neargraph::data::synthetic::reads(&mut krng, 256, 48, 8, 0.08);
        vec![
            bench_kernel("euclidean", &dense, &Euclidean, eps, 8),
            bench_kernel("hamming", &codes, &Hamming, 28.0, 8),
            bench_kernel("levenshtein", &strs, &Levenshtein, 8.0, 1),
        ]
    };

    // ------------------------------------------------------------------
    // Self-join strategy (PR 10): batched queries vs the dual-tree
    // traversal on the same tree, per thread count. Both paths must
    // reproduce the single-thread direct edge fingerprint exactly.
    // ------------------------------------------------------------------
    let dual_runs = {
        let tree = CoverTree::build(&pts, &Euclidean, &params);
        let mut out: Vec<DualRun> = Vec::new();
        for &threads in &thread_list {
            let pool = Pool::new(threads);
            let mut batched = HashSink::default();
            let t0 = Instant::now();
            tree.eps_self_join_par(&Euclidean, eps, &pool, |a, b, d| batched.accept(a, b, d));
            let batched_s = t0.elapsed().as_secs_f64();
            let mut dual = HashSink::default();
            let t1 = Instant::now();
            tree.eps_self_join_dual_par(&Euclidean, eps, &pool, |a, b, d| dual.accept(a, b, d));
            let dual_s = t1.elapsed().as_secs_f64();
            eprintln!(
                "[perf_driver] selfjoin threads={threads}: batched {batched_s:.3}s vs \
                 dual {dual_s:.3}s, {} edges",
                dual.edges
            );
            assert_eq!(
                (batched.edges, batched.hash),
                (base.edges, base.edge_hash),
                "batched self-join drifted at threads={threads}"
            );
            assert_eq!(
                (dual.edges, dual.hash),
                (base.edges, base.edge_hash),
                "dual-tree self-join drifted at threads={threads}"
            );
            out.push(DualRun { threads, batched_s, dual_s });
        }
        out
    };

    // ------------------------------------------------------------------
    // Facade path: the same work through `Box<dyn NearIndex>` (cover
    // tree — overhead should be noise) plus the SNN backend (a genuinely
    // different algorithm, for scale). Brute force and the insertion tree
    // are O(n²)-ish on this workload and are timed only at small n.
    // ------------------------------------------------------------------
    let mut facade: Vec<FacadeRun> = Vec::new();
    let mut kinds = vec![IndexKind::CoverTree, IndexKind::Snn];
    if n <= 5_000 {
        kinds.push(IndexKind::BruteForce);
        kinds.push(IndexKind::InsertCoverTree);
    }
    for kind in kinds {
        for &threads in &thread_list {
            let pool = Pool::new(threads);
            let t0 = Instant::now();
            let index = build_index_par(kind, &pts, Euclidean, &IndexParams::default(), &pool)
                .unwrap_or_else(|e| fail(&e.to_string()));
            let build_s = t0.elapsed().as_secs_f64();
            let mut sink = HashSink::default();
            let t1 = Instant::now();
            index.eps_self_join_par(eps, &pool, &mut sink);
            let join_s = t1.elapsed().as_secs_f64();
            eprintln!(
                "[perf_driver] facade {} threads={threads}: build {build_s:.3}s, \
                 join {join_s:.3}s, {} edges",
                kind.name(),
                sink.edges
            );
            assert_eq!(sink.edges, base.edges, "{} edge count drifted", kind.name());
            assert_eq!(sink.hash, base.edge_hash, "{} edge set drifted", kind.name());
            facade.push(FacadeRun {
                kind,
                threads,
                build_s,
                join_s,
                edges: sink.edges,
                edge_hash: sink.hash,
            });
        }
    }

    // ------------------------------------------------------------------
    // k-NN paths (--knn k): facade knn_graph per thread count + the three
    // distributed radius-refinement layouts. Every run must produce the
    // identical row fingerprint (the k-NN determinism gate).
    // ------------------------------------------------------------------
    let mut knn_runs: Vec<KnnRun> = Vec::new();
    if knn_k > 0 {
        let mut reference: Option<u64> = None;
        for &threads in &thread_list {
            let pool = Pool::new(threads);
            let params = IndexParams::default();
            let index = build_index_par(IndexKind::CoverTree, &pts, Euclidean, &params, &pool)
                .unwrap_or_else(|e| fail(&e.to_string()));
            let t0 = Instant::now();
            let g = index.knn_graph(knn_k, &pool);
            let total_s = t0.elapsed().as_secs_f64();
            let row_hash = knn_fingerprint(&g);
            eprintln!(
                "[perf_driver] knn facade threads={threads}: {total_s:.3}s, {} arcs",
                g.num_arcs()
            );
            match reference {
                None => reference = Some(row_hash),
                Some(r) => assert_eq!(r, row_hash, "facade knn rows drifted at threads={threads}"),
            }
            knn_runs.push(KnnRun {
                mode: "facade".into(),
                threads,
                total_s,
                arcs: g.num_arcs() as u64,
                row_hash,
            });
        }
        let threads = *thread_list.last().unwrap();
        for algorithm in Algorithm::ALL {
            let cfg = RunConfig { ranks: 4, algorithm, threads: threads * 4, ..Default::default() };
            let t0 = Instant::now();
            let res = run_knn_graph(&pts, Euclidean, knn_k, &cfg);
            let total_s = t0.elapsed().as_secs_f64();
            let row_hash = knn_fingerprint(&res.knn);
            eprintln!(
                "[perf_driver] knn {} ranks=4: {total_s:.3}s wall, makespan {:.3}s",
                algorithm.name(),
                res.makespan
            );
            assert_eq!(
                reference.unwrap(),
                row_hash,
                "{} knn rows drifted from the facade",
                algorithm.name()
            );
            knn_runs.push(KnnRun {
                mode: algorithm.name().into(),
                threads,
                total_s,
                arcs: res.knn.num_arcs() as u64,
                row_hash,
            });
        }
    }

    // ------------------------------------------------------------------
    // Serve section (PR 6): the query daemon under pipelined offered
    // load. One cover tree, cloned per setting; the same scripted client
    // mix replayed against a per-query baseline (window 0, batch 1) and
    // two coalescing windows. Throughput and tail latency land in the
    // JSON; answers are not re-verified here (the soak suite owns
    // bit-equality) — this section measures.
    // ------------------------------------------------------------------
    let serve_threads = *thread_list.last().unwrap();
    let serve_tree = CoverTree::build(&pts, &Euclidean, &params);
    let serve_plans: Vec<ClientPlan> = (0..4)
        .map(|c| ClientPlan {
            queries: (0..500)
                .map(|q| SimQuery::Eps { point: (c * 500 + q * 7) % n, eps })
                .collect(),
            pipeline: 16,
            timeout_ms: 0,
        })
        .collect();
    let offered: u64 = serve_plans.iter().map(|p| p.queries.len() as u64).sum();
    let mut serve_runs: Vec<ServeRun> = Vec::new();
    for (label, window_us, max_batch) in
        [("per-query", 0u64, 1usize), ("win100us", 100, 256), ("win500us", 500, 256)]
    {
        let index = Box::new(CoverTreeIndex::from_tree(serve_tree.clone(), Euclidean));
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            coalesce_us: window_us,
            max_batch,
            threads: serve_threads,
            // A generous deadline arms the per-ticket deadline check on
            // every reply (the path the allocation gate must cover)
            // without ever firing under bench load.
            deadline_us: 60_000_000,
            ..Default::default()
        };
        let server = serve(index, &cfg).unwrap_or_else(|e| fail(&e.to_string()));
        let addr = server.local_addr().to_string();
        let t0 = Instant::now();
        let reports = run_clients(&addr, &pts, &serve_plans)
            .unwrap_or_else(|e| fail(&format!("serve bench clients: {e}")));
        let wall_s = t0.elapsed().as_secs_f64();
        let stats = server.shutdown_and_join();
        assert_eq!(stats.queries, offered, "{label}: daemon lost queries");
        assert_eq!(stats.deadline_misses, 0, "{label}: bench load must never miss 60s deadlines");
        let lat = latencies_sorted(&reports);
        let run = ServeRun {
            label,
            window_us,
            max_batch,
            queries: offered,
            wall_s,
            qps: offered as f64 / wall_s.max(1e-12),
            p50_us: percentile(&lat, 0.50),
            p99_us: percentile(&lat, 0.99),
            mean_batch: stats.mean_batch(),
        };
        eprintln!(
            "[perf_driver] serve {label}: {:.0} q/s, p50 {}us, p99 {}us, mean batch {:.1}",
            run.qps, run.p50_us, run.p99_us, run.mean_batch
        );
        serve_runs.push(run);
    }

    // Allocation gate on the warmed engine batch path — the path every
    // coalesced batch drains through. One lane (the pool's inline path),
    // sequential on this thread: the TCP/decode side allocates by design
    // (reply frames cross threads), so the gate covers exactly the
    // engine's execute. First call warms lane scratch and output
    // buffers; the second, identical call must not touch the allocator.
    let serve_steady_allocs = {
        let engine = ServeEngine::new(
            Box::new(CoverTreeIndex::from_tree(serve_tree.clone(), Euclidean)),
            1,
        );
        let gate_batch = n.min(2048);
        let mut batch = QueryBatch::new_like(&pts);
        for q in 0..gate_batch {
            batch.push(&pts.slice(q, q + 1), QueryOp::Eps(eps));
        }
        let mut out = BatchOutput::new();
        engine.execute(&batch, &mut out);
        let alloc0 = allocations();
        engine.execute(&batch, &mut out);
        let allocs = allocations() - alloc0;
        assert_eq!(out.len(), gate_batch, "engine dropped queries");
        eprintln!(
            "[perf_driver] serve engine batch={gate_batch}: {allocs} steady-state allocs"
        );
        assert_eq!(allocs, 0, "warmed serve engine batch must be allocation-free");
        allocs
    };

    // ------------------------------------------------------------------
    // Chaos section (PR 7): a survivable seeded fault lottery over the
    // systolic ring, against a clean twin on the same subset. The gate is
    // bit-equality of the edge sets; the payload is the fault counters
    // and the virtual-time price of riding out the lottery (retries and
    // delays are charged to the virtual clock, so the makespan delta is
    // the overhead the α-β model attributes to the faults).
    // ------------------------------------------------------------------
    let chaos = {
        let chaos_n = n.min(2_000);
        let chaos_pts = pts.slice(0, chaos_n);
        let ranks = 4usize;
        let cfg = RunConfig { ranks, algorithm: Algorithm::SystolicRing, ..Default::default() };
        let clean = try_run_epsilon_graph(&chaos_pts, Euclidean, eps, &cfg)
            .unwrap_or_else(|e| fail(&format!("chaos clean twin: {e}")));
        let mut faulty_cfg = cfg;
        faulty_cfg.faults = Some(FaultPlan {
            drop: 0.1,
            corrupt: 0.1,
            duplicate: 0.05,
            delay: 0.1,
            delay_us: 50,
            seed: 0xC405,
            ..Default::default()
        });
        let t0 = Instant::now();
        let faulty = try_run_epsilon_graph(&chaos_pts, Euclidean, eps, &faulty_cfg)
            .unwrap_or_else(|e| fail(&format!("chaos lottery unsurvivable: {e}")));
        let faulty_wall_s = t0.elapsed().as_secs_f64();
        assert_eq!(
            faulty.edges.edges(),
            clean.edges.edges(),
            "faulty run diverged from its clean twin"
        );
        assert!(faulty.faults.any(), "the bench lottery must actually fire");
        let c = faulty.faults;
        eprintln!(
            "[perf_driver] chaos systolic-ring ranks={ranks} n={chaos_n}: \
             drops={} corrupts={} duplicates={} retries={} dup_discards={} \
             corrupt_discards={} delayed_us={}, makespan {:.3}s (clean {:.3}s)",
            c.drops,
            c.corrupts,
            c.duplicates,
            c.retries,
            c.dup_discards,
            c.corrupt_discards,
            c.delayed_us,
            faulty.makespan,
            clean.makespan
        );
        ChaosRun {
            algorithm: "systolic-ring",
            ranks,
            n: chaos_n,
            clean_makespan: clean.makespan,
            faulty_makespan: faulty.makespan,
            faulty_wall_s,
            counters: c,
        }
    };

    // ------------------------------------------------------------------
    // Mutation section (PR 9): the mutable epoch-tree backend under
    // churn, sequential on this thread (the allocator counter is
    // global). Three gates ride the measurements: the insert path's
    // amortized allocation count — the regression guard for the
    // children-Vec clone the PR removed from the cover-set expansion —
    // compactions actually firing under the rolling insert/delete mix,
    // and the warmed epoch read path (ε and k-NN, with base, delta and
    // tombstones all populated) touching the allocator zero times.
    // ------------------------------------------------------------------
    let mutation = {
        let m_total = n.min(4_096);
        let m_base = m_total - m_total / 4;
        let base = pts.slice(0, m_base);

        // Insert-allocation regression, on the bare structure the fix
        // touched. The fixed descent allocates only the per-level cover
        // vectors plus amortized container growth — ~5-7 allocations per
        // insert on this workload — while the old `children.clone()`
        // added one Vec clone per expanded node per insert, ~13/point
        // here. The bound sits between the two with ~1.5x margin each
        // way, so the clone creeping back fails this run.
        let mut bare = InsertCoverTree::build(&base, &Euclidean);
        let batch = pts.slice(m_base, m_total);
        let alloc0 = allocations();
        let t0 = Instant::now();
        bare.insert_from(&Euclidean, &batch);
        let insert_s = t0.elapsed().as_secs_f64();
        let insert_allocs = allocations() - alloc0;
        let insert_allocs_per_point = insert_allocs as f64 / batch.len().max(1) as f64;
        eprintln!(
            "[perf_driver] mutation insert: {} points in {insert_s:.4}s, \
             {insert_allocs_per_point:.1} allocs/point",
            batch.len()
        );
        assert!(
            insert_allocs_per_point <= 10.0,
            "insert allocations regressed ({insert_allocs_per_point:.1}/point): \
             the cover-set expansion must not clone child lists"
        );

        // Facade churn through `MutableOps`: each round inserts one point
        // and tombstones the previous round's insert, so the delta cap
        // is crossed repeatedly and the loop ends back at the base live
        // set (the conformance suite owns bit-equality; this measures).
        let params = IndexParams {
            epoch: EpochParams { delta_cap: 64, compact_frac: 0.25 },
            ..IndexParams::default()
        };
        let index = InsertCoverTreeIndex::build(&base, Euclidean, &params);
        let churn_rounds = m_base.min(1_024);
        let mut prev: Option<u32> = None;
        let t1 = Instant::now();
        for i in 0..churn_rounds {
            let row = (i * 13) % m_base;
            let got = index.insert(&pts.slice(row, row + 1));
            if let Some(gid) = prev.take() {
                assert!(index.delete(gid), "churn delete missed gid {gid}");
            }
            prev = Some(got.start);
        }
        if let Some(gid) = prev.take() {
            assert!(index.delete(gid));
        }
        let churn_s = t1.elapsed().as_secs_f64();
        let compactions = index.epoch();
        assert!(compactions > 0, "churn never crossed the compaction triggers");
        assert_eq!(index.live(), m_base, "net-zero churn must end at the base live set");

        // Epoch read gate, in the richest read state: a nonempty delta
        // (below the cap, so no compaction elides it) plus tombstones in
        // both base and delta. First pass warms the scratch stacks, the
        // candidate heap and the output buffer; the second, identical
        // pass must not allocate.
        let fresh = index.insert(&pts.slice(0, 32.min(m_base)));
        assert!(index.delete(fresh.start));
        assert!(index.delete(0), "base gid 0 must still be live after net-zero churn");
        assert!(index.tombstones() > 0, "the read gate must cover tombstone skipping");
        let et = index.epoch_tree();
        let mut scratch = QueryScratch::new();
        let mut hits: Vec<(u32, f64)> = Vec::new();
        et.eps_query_with(&Euclidean, pts.point(1), eps, &mut scratch, &mut hits);
        et.knn_with(&Euclidean, pts.point(1), 8, &mut scratch, &mut hits);
        hits.clear();
        let alloc1 = allocations();
        et.eps_query_with(&Euclidean, pts.point(1), eps, &mut scratch, &mut hits);
        hits.clear();
        et.knn_with(&Euclidean, pts.point(1), 8, &mut scratch, &mut hits);
        let epoch_steady_state_allocs = allocations() - alloc1;
        let run = MutationRun {
            base: m_base,
            insert_batch: batch.len(),
            insert_s,
            insert_allocs_per_point,
            churn_rounds,
            churn_s,
            churn_ops_per_s: (2 * churn_rounds) as f64 / churn_s.max(1e-12),
            compactions,
            epoch_steady_state_allocs,
        };
        eprintln!(
            "[perf_driver] mutation churn: {} rounds in {churn_s:.4}s \
             ({:.0} ops/s, {compactions} compactions), \
             {epoch_steady_state_allocs} steady-state epoch-read allocs",
            run.churn_rounds, run.churn_ops_per_s
        );
        assert_eq!(
            epoch_steady_state_allocs, 0,
            "warmed epoch reads (base + delta + tombstones) must be allocation-free"
        );
        run
    };

    lint_waiver_parity();

    let (seq_total, best) = summarize(&runs);
    let json = render_json(
        &dataset,
        n,
        dim,
        eps,
        &runs,
        &facade,
        &knn_runs,
        &traversal,
        &kernel_runs,
        &dual_runs,
        &serve_runs,
        serve_steady_allocs,
        &chaos,
        &mutation,
        seq_total,
        best,
    );
    std::fs::write(&out_path, &json).unwrap_or_else(|e| fail(&format!("{out_path}: {e}")));
    println!("{json}");
    eprintln!("[perf_driver] wrote {out_path}");
}

/// Lint-waiver parity gate (DESIGN.md §12): the committed LINT_REPORT.json
/// must agree with the live tree — a waiver added or removed without
/// regenerating the report fails the perf run, as does any unwaived
/// finding. Skipped with a note when run from a cwd without the repo-root
/// report (cargo runs examples from the crate root, where it exists).
fn lint_waiver_parity() {
    let report_path = std::path::Path::new("../LINT_REPORT.json");
    let src = std::path::Path::new("src");
    if !report_path.exists() || !src.is_dir() {
        eprintln!("[perf_driver] lint parity skipped (no ../LINT_REPORT.json from this cwd)");
        return;
    }
    let report = std::fs::read_to_string(report_path)
        .unwrap_or_else(|e| fail(&format!("LINT_REPORT.json: {e}")));
    let committed = report
        .lines()
        .find_map(|l| {
            l.trim()
                .strip_prefix("\"waiver_count\":")
                .map(|v| v.trim_end_matches(',').trim().parse::<usize>())
        })
        .and_then(Result::ok)
        .unwrap_or_else(|| fail("LINT_REPORT.json has no waiver_count field"));
    let docs = ["../README.md", "../DESIGN.md"]
        .iter()
        .filter_map(|p| std::fs::read_to_string(p).ok())
        .collect::<Vec<_>>()
        .join("\n");
    let registry = std::path::Path::new("tests/wire_adversarial.rs");
    let (files, findings) = neargraph::lint::scan_tree(src, Some(registry), &docs)
        .unwrap_or_else(|e| fail(&format!("lint scan: {e}")));
    let live = neargraph::lint::used_waivers(&files).len();
    let unwaived = findings.iter().filter(|f| f.waived.is_none()).count();
    assert_eq!(
        unwaived, 0,
        "unwaived lint findings present; run `cargo run --example lint_driver -- --src src`"
    );
    assert_eq!(
        live, committed,
        "live waiver count {live} != LINT_REPORT.json waiver_count {committed}; \
         regenerate the report"
    );
    eprintln!(
        "[perf_driver] lint parity ok: {live} waiver(s) match LINT_REPORT.json, 0 unwaived"
    );
}

/// Time the scalar leaf filter vs the K-lane kernel over the same leaf
/// visits (`active` queries against a sweep of reference rows `j`),
/// asserting identical emission first. `reps` scales the timed loop so
/// cheap metrics still measure above clock noise.
fn bench_kernel<P: PointSet, M: Metric<P>>(
    name: &'static str,
    pts: &P,
    metric: &M,
    eps: f64,
    reps: usize,
) -> KernelRun {
    let n = pts.len();
    let active: Vec<(u32, f64)> = (0..n.min(256) as u32).map(|q| (q, 0.0)).collect();
    let js: Vec<usize> = (0..n).step_by(7).take(64).collect();

    // Conformance gate: ids and weight bits, in emission order.
    let mut tile = SoaTile::new();
    let mut scalar_hits: Vec<(u32, u64)> = Vec::new();
    let mut lane_hits: Vec<(u32, u64)> = Vec::new();
    for &j in &js {
        metric.leaf_filter(pts, &active, pts, j, eps, &mut |q, d| {
            scalar_hits.push((q, d.to_bits()))
        });
        metric.leaf_filter_with(pts, &active, pts, j, eps, &mut tile, &mut |q, d| {
            lane_hits.push((q, d.to_bits()))
        });
    }
    assert_eq!(
        scalar_hits, lane_hits,
        "{name}: K-lane kernel diverged from the scalar leaf filter"
    );

    let pairs = (active.len() * js.len() * reps) as u64;
    let mut guard = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        for &j in &js {
            metric.leaf_filter(pts, &active, pts, j, eps, &mut |q, _| {
                guard = guard.wrapping_add(q as u64)
            });
        }
    }
    let scalar_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for _ in 0..reps {
        for &j in &js {
            metric.leaf_filter_with(pts, &active, pts, j, eps, &mut tile, &mut |q, _| {
                guard = guard.wrapping_add(q as u64)
            });
        }
    }
    let lane_s = t1.elapsed().as_secs_f64();
    std::hint::black_box(guard);
    let run = KernelRun {
        metric: name,
        pairs,
        scalar_ns_per_pair: scalar_s * 1e9 / pairs.max(1) as f64,
        lane_ns_per_pair: lane_s * 1e9 / pairs.max(1) as f64,
    };
    eprintln!(
        "[perf_driver] kernel {name}: scalar {:.2} ns/pair vs K-lane {:.2} ns/pair \
         ({pairs} pairs)",
        run.scalar_ns_per_pair, run.lane_ns_per_pair
    );
    run
}

fn summarize(runs: &[Run]) -> (f64, &Run) {
    let seq_total = runs[0].build_s + runs[0].join_s;
    let best = runs
        .iter()
        .min_by(|a, b| (a.build_s + a.join_s).total_cmp(&(b.build_s + b.join_s)))
        .unwrap();
    (seq_total, best)
}

#[allow(clippy::too_many_arguments)]
fn render_json(
    dataset: &str,
    n: usize,
    dim: usize,
    eps: f64,
    runs: &[Run],
    facade: &[FacadeRun],
    knn_runs: &[KnnRun],
    traversal: &TraversalRun,
    kernel_runs: &[KernelRun],
    dual_runs: &[DualRun],
    serve_runs: &[ServeRun],
    serve_steady_allocs: u64,
    chaos: &ChaosRun,
    mutation: &MutationRun,
    seq_total: f64,
    best: &Run,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"pr10_kernel_dualtree\",\n");
    s.push_str(&format!("  \"dataset\": \"{dataset}\",\n"));
    s.push_str(&format!("  \"n\": {n},\n  \"dim\": {dim},\n  \"eps\": {eps},\n"));
    s.push_str(&format!(
        "  \"traversal\": {{\"batch\": {}, \"pairs\": {}, \"legacy_s\": {:.6}, \
         \"flat_s\": {:.6}, \"legacy_dist_calls\": {}, \"flat_dist_calls\": {}, \
         \"steady_state_allocs\": {}, \"flat_speedup\": {:.4}}},\n",
        traversal.batch,
        traversal.pairs,
        traversal.legacy_s,
        traversal.flat_s,
        traversal.legacy_dists,
        traversal.flat_dists,
        traversal.steady_state_allocs,
        traversal.legacy_s / traversal.flat_s.max(1e-12)
    ));
    s.push_str("  \"direct_runs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"threads\": {}, \"build_s\": {:.6}, \"join_s\": {:.6}, \
             \"build_dist_calls\": {}, \"join_dist_calls\": {}, \"edges\": {}}}{}\n",
            r.threads,
            r.build_s,
            r.join_s,
            r.build_dists,
            r.join_dists,
            r.edges,
            if i + 1 < runs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"facade_runs\": [\n");
    for (i, r) in facade.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"index\": \"{}\", \"threads\": {}, \"build_s\": {:.6}, \
             \"join_s\": {:.6}, \"edges\": {}, \"edge_hash\": {}}}{}\n",
            r.kind.name(),
            r.threads,
            r.build_s,
            r.join_s,
            r.edges,
            r.edge_hash,
            if i + 1 < facade.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"kernel_runs\": [\n");
    for (i, r) in kernel_runs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"metric\": \"{}\", \"pairs\": {}, \"scalar_ns_per_pair\": {:.3}, \
             \"lane_ns_per_pair\": {:.3}, \"lane_speedup\": {:.4}}}{}\n",
            r.metric,
            r.pairs,
            r.scalar_ns_per_pair,
            r.lane_ns_per_pair,
            r.scalar_ns_per_pair / r.lane_ns_per_pair.max(1e-12),
            if i + 1 < kernel_runs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"dualtree_runs\": [\n");
    for (i, r) in dual_runs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"threads\": {}, \"batched_s\": {:.6}, \"dual_s\": {:.6}, \
             \"dual_speedup\": {:.4}}}{}\n",
            r.threads,
            r.batched_s,
            r.dual_s,
            r.batched_s / r.dual_s.max(1e-12),
            if i + 1 < dual_runs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"knn_runs\": [\n");
    for (i, r) in knn_runs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"mode\": \"{}\", \"threads\": {}, \"total_s\": {:.6}, \
             \"arcs\": {}, \"row_hash\": {}}}{}\n",
            r.mode,
            r.threads,
            r.total_s,
            r.arcs,
            r.row_hash,
            if i + 1 < knn_runs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"serve_runs\": [\n");
    for (i, r) in serve_runs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"label\": \"{}\", \"window_us\": {}, \"max_batch\": {}, \
             \"queries\": {}, \"wall_s\": {:.6}, \"qps\": {:.1}, \"p50_us\": {}, \
             \"p99_us\": {}, \"mean_batch\": {:.2}}}{}\n",
            r.label,
            r.window_us,
            r.max_batch,
            r.queries,
            r.wall_s,
            r.qps,
            r.p50_us,
            r.p99_us,
            r.mean_batch,
            if i + 1 < serve_runs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"serve_steady_state_allocs\": {serve_steady_allocs},\n"));
    s.push_str(&format!(
        "  \"chaos\": {{\"algorithm\": \"{}\", \"ranks\": {}, \"n\": {}, \
         \"clean_makespan_s\": {:.6}, \"faulty_makespan_s\": {:.6}, \
         \"faulty_wall_s\": {:.6}, \"drops\": {}, \"corrupts\": {}, \
         \"duplicates\": {}, \"retries\": {}, \"dup_discards\": {}, \
         \"corrupt_discards\": {}, \"delayed_us\": {}}},\n",
        chaos.algorithm,
        chaos.ranks,
        chaos.n,
        chaos.clean_makespan,
        chaos.faulty_makespan,
        chaos.faulty_wall_s,
        chaos.counters.drops,
        chaos.counters.corrupts,
        chaos.counters.duplicates,
        chaos.counters.retries,
        chaos.counters.dup_discards,
        chaos.counters.corrupt_discards,
        chaos.counters.delayed_us
    ));
    s.push_str(&format!(
        "  \"mutation\": {{\"base\": {}, \"insert_batch\": {}, \"insert_s\": {:.6}, \
         \"insert_allocs_per_point\": {:.2}, \"churn_rounds\": {}, \"churn_s\": {:.6}, \
         \"churn_ops_per_s\": {:.1}, \"compactions\": {}, \
         \"epoch_steady_state_allocs\": {}}},\n",
        mutation.base,
        mutation.insert_batch,
        mutation.insert_s,
        mutation.insert_allocs_per_point,
        mutation.churn_rounds,
        mutation.churn_s,
        mutation.churn_ops_per_s,
        mutation.compactions,
        mutation.epoch_steady_state_allocs
    ));
    // Facade overhead: cover-tree facade total vs direct total at the same
    // thread count (same underlying traversals; the delta is dispatch +
    // sink indirection).
    for r in facade.iter().filter(|r| r.kind == IndexKind::CoverTree) {
        if let Some(d) = runs.iter().find(|d| d.threads == r.threads) {
            let direct = d.build_s + d.join_s;
            let via = r.build_s + r.join_s;
            s.push_str(&format!(
                "  \"facade_overhead_threads{}\": {:.4},\n",
                r.threads,
                (via - direct) / direct.max(1e-12)
            ));
        }
    }
    s.push_str(&format!(
        "  \"best_threads\": {},\n  \"speedup_build\": {:.4},\n  \"speedup_total\": {:.4}\n",
        best.threads,
        runs[0].build_s / best.build_s.max(1e-12),
        seq_total / (best.build_s + best.join_s).max(1e-12)
    ));
    s.push_str("}\n");
    s
}

/// splitmix64 finalizer — order-independent accumulation of edge pairs.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

fn fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}
