//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Proves all layers compose:
//!   * L1/L2 — the AOT-compiled JAX/Pallas pairwise kernel executed through
//!     PJRT, cross-checked tile-for-tile against the native backend;
//!   * L3 — the three distributed algorithms on the simulated MPI runtime,
//!     swept over rank counts on a sift-analog workload, with exact
//!     verification against brute force and per-phase breakdowns.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```text
//! make artifacts && cargo run --release --example scaling_demo
//! ```

use neargraph::baseline::{brute_force_edges, Snn, SnnParams};
use neargraph::bench::{build_workload, timed, Workload};
use neargraph::data::registry::DatasetSpec;
use neargraph::dist::run_epsilon_graph;
use neargraph::metric::engine::{NativeBackend, TileBackend};
use neargraph::prelude::*;
use neargraph::runtime::PjrtEngine;
use neargraph::util::fmt_secs;

fn main() {
    println!("=== neargraph end-to-end driver (sift analog) ===\n");

    // ------------------------------------------------------------------
    // Layer 1/2: AOT kernel through PJRT vs native backend.
    // ------------------------------------------------------------------
    let spec = DatasetSpec::by_name("sift").unwrap();
    let n = 4_000;
    let workload = build_workload(spec, n, 7);
    let Workload::Dense { pts, eps, .. } = workload else { unreachable!() };
    let eps_mid = eps[1]; // the ~70-neighbor point of the sweep

    match PjrtEngine::load_default() {
        Some(engine) => {
            let q = pts.slice(0, 256);
            let r = pts.slice(256, 512);
            let (pjrt_tile, t_pjrt) = timed(|| engine.euclidean_tile(&q, &r));
            let (native_tile, t_native) = timed(|| NativeBackend.euclidean_tile(&q, &r));
            let max_err = pjrt_tile
                .iter()
                .zip(&native_tile)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!(
                "L1/L2 PJRT kernel: 256x256x{}d tile, max |pjrt - native| = {max_err:.2e}",
                pts.dim()
            );
            println!(
                "      pjrt {} vs native {} (CPU-interpret path; TPU perf is estimated in DESIGN.md)",
                fmt_secs(t_pjrt),
                fmt_secs(t_native)
            );
            assert!(max_err < 2e-2, "PJRT/native disagreement");
        }
        None => println!("L1/L2 SKIPPED: artifacts missing (run `make artifacts`)"),
    }

    // ------------------------------------------------------------------
    // Ground truth + sequential SNN baseline.
    // ------------------------------------------------------------------
    println!("\nworkload: sift analog, n={n}, dim={}, eps={eps_mid:.4}", pts.dim());
    let (want, t_brute) = timed(|| brute_force_edges(&pts, &Euclidean, eps_mid));
    println!(
        "brute force: {} edges (avg degree {:.1}) in {}",
        want.edges().len(),
        2.0 * want.edges().len() as f64 / n as f64,
        fmt_secs(t_brute)
    );
    let (snn_time, snn_edges) = {
        let (snn, t_build) = timed(|| Snn::build(&pts, &SnnParams::default()));
        let (e, t_join) = timed(|| snn.self_join(eps_mid));
        (t_build + t_join, e)
    };
    // SNN evaluates d² in the matmul form (‖x‖²+‖y‖²−2⟨x,y⟩) while brute
    // force uses the difference form; pairs within float32 noise of the ε
    // boundary can flip between the two *exact* algorithms. Demand the
    // symmetric difference stays at boundary-noise level.
    let a: std::collections::BTreeSet<_> = snn_edges.edges().iter().copied().collect();
    let b: std::collections::BTreeSet<_> = want.edges().iter().copied().collect();
    let sym_diff = a.symmetric_difference(&b).count();
    assert!(
        (sym_diff as f64) < 1e-3 * want.edges().len() as f64,
        "SNN diverges beyond boundary noise: {sym_diff} differing pairs"
    );
    println!(
        "SNN (sequential SOTA baseline): {} edges ({} boundary flips) in {}",
        snn_edges.edges().len(),
        sym_diff,
        fmt_secs(snn_time)
    );

    // ------------------------------------------------------------------
    // Layer 3: strong scaling of the three distributed algorithms.
    // ------------------------------------------------------------------
    println!("\nstrong scaling (simulated makespan, seconds):");
    println!(
        "{:<7} {:>14} {:>14} {:>14}",
        "ranks", "systolic-ring", "landmark-coll", "landmark-ring"
    );
    for ranks in [1usize, 2, 4, 8, 16, 32] {
        let mut row = format!("{ranks:<7}");
        for algorithm in Algorithm::ALL {
            let cfg = RunConfig { ranks, algorithm, ..Default::default() };
            let res = run_epsilon_graph(&pts, Euclidean, eps_mid, &cfg);
            assert_eq!(res.edges.edges(), want.edges(), "{} wrong at {ranks} ranks",
                       algorithm.name());
            row += &format!(" {:>14.6}", res.makespan);
        }
        println!("{row}");
    }

    // ------------------------------------------------------------------
    // Per-phase breakdown at 16 ranks (the Fig-3/4/5 view).
    // ------------------------------------------------------------------
    println!("\nlandmark-coll phase breakdown at 16 ranks (rank: compute+comm):");
    let cfg = RunConfig { ranks: 16, algorithm: Algorithm::LandmarkColl, ..Default::default() };
    let res = run_epsilon_graph(&pts, Euclidean, eps_mid, &cfg);
    for r in res.ranks.iter().take(4) {
        print!("  rank {:>2}:", r.rank);
        for phase in ["partition", "tree", "ghost"] {
            if let Some(p) = r.stats.phases().get(phase) {
                print!("  {phase}={:.4}+{:.4}", p.compute, p.comm);
            }
        }
        println!();
    }
    println!("  ... ({} ranks total)", res.ranks.len());
    println!("\nEND-TO-END OK: all layers compose; every distributed run was exact.");
}
