//! Edit-distance near-neighbor graph over synthetic sequencing reads —
//! the non-Euclidean, expensive-metric use case (genomic overlap
//! detection) that motivates general metric support. SNN-style methods
//! cannot run here (no coordinates, no principal components); cover trees
//! only need the triangle inequality.
//!
//! ```text
//! cargo run --release --example genomic_reads
//! ```

use neargraph::dist::run_epsilon_graph;
use neargraph::prelude::*;
use neargraph::util::fmt_secs;

fn main() {
    // 640 reads of length ~60 from 6 ancestor sequences, 4% mutation rate;
    // the last 40 are held out as a "fresh batch" for the bipartite demo.
    let mut rng = Rng::new(11);
    let all_reads = neargraph::data::synthetic::reads(&mut rng, 640, 60, 6, 0.04);
    let reads = all_reads.slice(0, 600);
    let fresh = all_reads.slice(600, 640);
    println!("{} reads, lengths {}..{}",
        reads.len(),
        (0..reads.len()).map(|i| reads.str_len(i)).min().unwrap(),
        (0..reads.len()).map(|i| reads.str_len(i)).max().unwrap());

    // Reads from the same ancestor differ by ~2·0.04·60 ≈ 5 edits;
    // different ancestors are ~45 edits apart. eps = 12 separates cleanly.
    let eps = 12.0;
    let metric = Counted::new(Levenshtein);
    let cfg = RunConfig { ranks: 6, algorithm: Algorithm::LandmarkRing, ..Default::default() };
    let result = run_epsilon_graph(&reads, metric.clone(), eps, &cfg);

    println!(
        "eps-graph: {} edges, avg degree {:.1}, makespan {} ({} distance evaluations)",
        result.graph.num_edges(),
        result.graph.avg_degree(),
        fmt_secs(result.makespan),
        metric.count()
    );

    // The connected components should recover the ancestor families.
    let (comp, ncomp) = result.graph.components();
    let mut sizes = vec![0usize; ncomp];
    for &c in &comp {
        sizes[c] += 1;
    }
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    println!("components: {ncomp}; largest: {:?}", &sizes[..sizes.len().min(8)]);

    // Compare with the quadratic baseline's distance-call budget.
    let n = reads.len() as u64;
    let brute_calls = n * (n - 1) / 2;
    println!(
        "distance calls: {} vs brute-force {} ({}x saved)",
        metric.count(),
        brute_calls,
        brute_calls / metric.count().max(1)
    );
    assert!(ncomp >= 6, "ancestor families should not merge at eps=12");

    // Bonus: bipartite mode — match the held-out batch against the corpus
    // without recomputing the corpus self-join (the serving shape).
    let hits = neargraph::dist::run_bipartite_join(&reads, &fresh, Levenshtein, eps, &cfg);
    println!(
        "bipartite: {} held-out reads matched into {} (read, corpus) pairs in {}",
        fresh.len(),
        hits.pairs.len(),
        fmt_secs(hits.makespan)
    );
}
