//! Machine gate for the repository's source invariants (CI `lint` job).
//!
//! Runs `neargraph::lint` (DESIGN.md §12) over a source tree and exits
//! nonzero under `--deny-warnings` when any unwaived finding remains or
//! the fixture corpus disagrees with the engine:
//!
//! ```text
//! cargo run --example lint_driver -- --src rust/src --deny-warnings
//! cargo run --example lint_driver -- --src src \
//!     --fixtures tests/lint_fixtures --json LINT_REPORT.json
//! ```
//!
//! The same flags drive `python/neargraph_lint.py`, the in-container
//! mirror that generated the committed `LINT_REPORT.json`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match neargraph::lint::main_from_args(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("lint_driver: {e}");
            std::process::exit(2);
        }
    }
}
