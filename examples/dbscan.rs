//! DBSCAN clustering on top of the distributed ε-graph — one of the
//! downstream algorithms the paper's introduction motivates.
//!
//! DBSCAN with parameters (ε, minPts) is: core points are vertices of the
//! ε-graph with degree ≥ minPts−1; clusters are connected components of
//! the core-point subgraph; border points attach to any adjacent core
//! cluster; everything else is noise.
//!
//! ```text
//! cargo run --release --example dbscan
//! ```

use neargraph::dist::run_epsilon_graph;
use neargraph::prelude::*;

fn main() {
    // Three well-separated blobs plus scattered uniform noise.
    let mut rng = Rng::new(9);
    let mut points = neargraph::data::synthetic::gaussian_mixture(&mut rng, 900, 3, 3, 0.02);
    let noise = neargraph::data::synthetic::uniform(&mut rng, 100, 3, 1.0);
    points.extend_from(&noise);
    let n = points.len();

    let eps = 0.08;
    let min_pts = 5usize;

    // Distributed ε-graph (the expensive step DBSCAN delegates to us).
    let cfg = RunConfig { ranks: 8, algorithm: Algorithm::LandmarkColl, ..Default::default() };
    let result = run_epsilon_graph(&points, Euclidean, eps, &cfg);
    let g = &result.graph;

    // Core points: degree ≥ minPts − 1 (the point itself counts).
    let core: Vec<bool> = (0..n).map(|v| g.degree(v) + 1 >= min_pts).collect();

    // Clusters = connected components over core-core edges.
    let mut cluster = vec![usize::MAX; n];
    let mut next = 0usize;
    let mut stack = Vec::new();
    for s in 0..n {
        if !core[s] || cluster[s] != usize::MAX {
            continue;
        }
        cluster[s] = next;
        stack.push(s);
        while let Some(u) = stack.pop() {
            for &w in g.neighbors(u) {
                let w = w as usize;
                if core[w] && cluster[w] == usize::MAX {
                    cluster[w] = next;
                    stack.push(w);
                }
            }
        }
        next += 1;
    }
    // Border points: adopt any adjacent core point's cluster.
    for v in 0..n {
        if core[v] || cluster[v] != usize::MAX {
            continue;
        }
        if let Some(&c) = g.neighbors(v).iter().find(|&&w| core[w as usize]) {
            cluster[v] = cluster[c as usize];
        }
    }

    let noise_count = cluster.iter().filter(|&&c| c == usize::MAX).count();
    println!("DBSCAN(eps={eps}, minPts={min_pts}) over {n} points:");
    println!("  clusters found: {next}");
    for c in 0..next {
        let size = cluster.iter().filter(|&&x| x == c).count();
        println!("  cluster {c}: {size} points");
    }
    println!("  noise: {noise_count} points");
    assert_eq!(next, 3, "expected the three planted blobs");
    assert!(noise_count >= 40, "most uniform noise should be labeled noise");
    println!("OK: recovered the planted structure");
}
