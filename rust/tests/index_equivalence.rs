//! Backend-equivalence sweep for the `neargraph::index` facade: every
//! [`IndexKind`] must return the identical edge set — and weights equal
//! within [`WEIGHT_TOL`] — across dense / Hamming / Levenshtein points and
//! 1 / 4 / 8 pool threads (DESIGN.md §8).
//!
//! SNN is dense-Euclidean-only by contract: on the other point families it
//! must fail `build_index` with a typed `Unsupported` error, not panic.
//!
//! Datasets come from the shared `testkit::scenario` source.

use neargraph::baseline::brute_force_weighted;
use neargraph::graph::{assert_same_weighted_graph, WeightedEdgeList, WEIGHT_TOL};
use neargraph::index::{build_index, epsilon_graph, IndexError, IndexKind, IndexParams};
use neargraph::prelude::*;
use neargraph::testkit::scenario;

const POOL_SIZES: [usize; 3] = [1, 4, 8];

/// Self-join every supported backend at every pool size and compare the
/// canonical weighted edge sets against the brute-force scalar reference.
fn sweep<P, M>(pts: &P, metric: M, eps: f64, supported: &[IndexKind], what: &str)
where
    P: PointSet,
    M: Metric<P>,
{
    let want = brute_force_weighted(pts, &metric, eps);
    for &kind in supported {
        let index = build_index(kind, pts, metric.clone(), &IndexParams::default())
            .unwrap_or_else(|e| panic!("{what}: {} failed to build: {e}", kind.name()));
        for threads in POOL_SIZES {
            let pool = Pool::new(threads);
            let mut got = WeightedEdgeList::new();
            index.eps_self_join_par(eps, &pool, &mut got);
            assert_same_weighted_graph(
                got,
                want.clone(),
                WEIGHT_TOL,
                &format!("{what}/{}/threads={threads}", kind.name()),
            );
        }
    }
}

#[test]
fn dense_euclidean_all_backends() {
    let pts = scenario::dense_clusters(7001, 220);
    for eps in [0.1, 0.35] {
        sweep(&pts, Euclidean, eps, &IndexKind::ALL, "dense");
    }
}

#[test]
fn dense_with_duplicates_all_backends() {
    // Zero-distance pairs stress the weight paths (matmul-form kernels
    // must not report phantom nonzero distances).
    let pts = scenario::dense_duplicates(7002, 90, 60);
    sweep(&pts, Euclidean, 0.15, &IndexKind::ALL, "dense+dups");
    sweep(&pts, Euclidean, 0.0, &IndexKind::ALL, "dense+dups eps=0");
}

#[test]
fn hamming_backends_match_and_snn_is_rejected() {
    let codes = scenario::hamming_codes(7003, 180);
    let supported =
        [IndexKind::BruteForce, IndexKind::CoverTree, IndexKind::InsertCoverTree];
    for eps in [10.0, 28.0] {
        sweep(&codes, Hamming, eps, &supported, "hamming");
    }
    match build_index(IndexKind::Snn, &codes, Hamming, &IndexParams::default()) {
        Err(IndexError::Unsupported { kind: IndexKind::Snn, .. }) => {}
        other => panic!("SNN on Hamming must be Unsupported, got {:?}", other.is_ok()),
    }
}

#[test]
fn levenshtein_backends_match_and_snn_is_rejected() {
    let reads = scenario::string_pool(7004, 100);
    let supported =
        [IndexKind::BruteForce, IndexKind::CoverTree, IndexKind::InsertCoverTree];
    for eps in [2.0, 5.0] {
        sweep(&reads, Levenshtein, eps, &supported, "levenshtein");
    }
    assert!(matches!(
        build_index(IndexKind::Snn, &reads, Levenshtein, &IndexParams::default()),
        Err(IndexError::Unsupported { .. })
    ));
}

/// Collects canonically-oriented `(u, v, weight_bits)` triples — the
/// bit-exact comparison form the tolerance-based graph assert can't give.
#[derive(Default)]
struct BitSink(Vec<(u32, u32, u64)>);

impl neargraph::graph::GraphSink for BitSink {
    fn accept(&mut self, u: u32, v: u32, w: f64) {
        if u != v {
            self.0.push((u.min(v), u.max(v), w.to_bits()));
        }
    }
}

/// The dual-tree conformance gate: `index.dualtree` must emit exactly the
/// batched self-join's edge set — weight bits included — on both the
/// sequential and the pooled facade paths at every pool size.
fn dual_sweep<P, M>(pts: &P, metric: M, eps: f64, what: &str)
where
    P: PointSet,
    M: Metric<P>,
{
    let batched =
        build_index(IndexKind::CoverTree, pts, metric.clone(), &IndexParams::default())
            .unwrap_or_else(|e| panic!("{what}: batched build failed: {e}"));
    let dual = build_index(
        IndexKind::CoverTree,
        pts,
        metric.clone(),
        &IndexParams { dualtree: true, ..Default::default() },
    )
    .unwrap_or_else(|e| panic!("{what}: dualtree build failed: {e}"));

    let mut want = BitSink::default();
    batched.eps_self_join(eps, &mut want);
    want.0.sort_unstable();
    want.0.dedup();

    let mut got = BitSink::default();
    dual.eps_self_join(eps, &mut got);
    got.0.sort_unstable();
    got.0.dedup();
    assert_eq!(got.0, want.0, "{what}: sequential dual-tree edge set + weight bits");

    for threads in POOL_SIZES {
        let pool = Pool::new(threads);
        let mut got = BitSink::default();
        dual.eps_self_join_par(eps, &pool, &mut got);
        got.0.sort_unstable();
        got.0.dedup();
        assert_eq!(
            got.0, want.0,
            "{what}/threads={threads}: parallel dual-tree edge set + weight bits"
        );
    }
}

#[test]
fn dualtree_self_join_bit_equal_dense() {
    let pts = scenario::dense_clusters(7011, 220);
    for eps in [0.1, 0.35] {
        dual_sweep(&pts, Euclidean, eps, "dense/dual");
    }
    let dups = scenario::dense_duplicates(7012, 90, 60);
    dual_sweep(&dups, Euclidean, 0.15, "dense+dups/dual");
    dual_sweep(&dups, Euclidean, 0.0, "dense+dups/dual eps=0");
}

#[test]
fn dualtree_self_join_bit_equal_hamming_and_levenshtein() {
    let codes = scenario::hamming_codes(7013, 180);
    for eps in [10.0, 28.0] {
        dual_sweep(&codes, Hamming, eps, "hamming/dual");
    }
    let reads = scenario::string_pool(7014, 100);
    for eps in [2.0, 5.0] {
        dual_sweep(&reads, Levenshtein, eps, "levenshtein/dual");
    }
}

#[test]
fn eps_batch_equivalent_on_external_queries() {
    // Batch queries against a foreign query set (not the self-join path).
    let pts = scenario::dense_clusters(7005, 150);
    let queries = scenario::dense_clusters(70051, 40);
    let eps = 0.4;
    let mut want: Vec<(u32, u32, u64)> = Vec::new();
    for q in 0..queries.len() {
        for i in 0..pts.len() {
            let d = Euclidean.dist_between(&queries, q, &pts, i);
            if d <= eps {
                want.push((q as u32, i as u32, d.to_bits()));
            }
        }
    }
    want.sort_unstable();
    for kind in IndexKind::ALL {
        let index = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
        for threads in POOL_SIZES {
            let pool = Pool::new(threads);
            let mut got: Vec<(u32, u32, u64)> = Vec::new();
            index.eps_batch_par(&queries, eps, &pool, &mut |q, gid, d| {
                got.push((q, gid, d.to_bits()));
            });
            got.sort_unstable();
            assert_eq!(got, want, "{}/threads={threads} (weights bit-exact)", kind.name());
        }
    }
}

#[test]
fn knn_batch_equivalent_across_backends() {
    let pts = scenario::dense_clusters(7006, 160);
    let queries = scenario::dense_clusters(70061, 12);
    let k = 9;
    let reference = build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default())
        .unwrap()
        .knn_batch(&queries, k);
    for kind in [IndexKind::CoverTree, IndexKind::InsertCoverTree, IndexKind::Snn] {
        let index = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
        for threads in POOL_SIZES {
            let pool = Pool::new(threads);
            let got = index.knn_batch_par(&queries, k, &pool);
            assert_eq!(got.len(), reference.len(), "{}", kind.name());
            for (q, (g, w)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(g.len(), w.len());
                for (x, y) in g.iter().zip(w) {
                    // Distances must agree exactly; ids may differ only on
                    // exact distance ties.
                    assert_eq!(x.1, y.1, "{} q={q}", kind.name());
                }
            }
        }
    }
}

#[test]
fn insert_covertree_facade_matches_covertree_exactly() {
    // The historical parity gap: InsertCoverTree had no batch path. Via
    // the facade's default impls it must now answer batch + self-join
    // queries identically (ids AND weight bits) to the batch CoverTree on
    // the same data.
    let pts = scenario::dense_clusters(7007, 200);
    let queries = scenario::dense_clusters(70071, 30);
    let eps = 0.3;
    let batch = build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default())
        .unwrap();
    let insert =
        build_index(IndexKind::InsertCoverTree, &pts, Euclidean, &IndexParams::default())
            .unwrap();

    let mut a: Vec<(u32, u32, u64)> = Vec::new();
    batch.eps_batch(&queries, eps, &mut |q, gid, d| a.push((q, gid, d.to_bits())));
    a.sort_unstable();
    let mut b: Vec<(u32, u32, u64)> = Vec::new();
    insert.eps_batch(&queries, eps, &mut |q, gid, d| b.push((q, gid, d.to_bits())));
    b.sort_unstable();
    assert_eq!(a, b, "incremental-build + facade batch must match CoverTree bit-for-bit");

    for threads in POOL_SIZES {
        let pool = Pool::new(threads);
        let ga = epsilon_graph(batch.as_ref(), eps, &pool);
        let gb = epsilon_graph(insert.as_ref(), eps, &pool);
        assert_eq!(ga, gb, "threads={threads}: facade graphs must be identical");
    }
}
