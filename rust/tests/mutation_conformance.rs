//! PR 9 gate: randomized insert/delete/query interleavings over the
//! mutable epoch-tree backend must stay **bit-equal to a brute-force
//! rebuild at every step** — through delta growth, tombstone accrual,
//! threshold compactions and forced compactions — for every metric
//! family and from both one and several concurrent reader threads.
//!
//! The model is a plain live list `(gid, pool_row)`: after every mutation
//! step, ε answers (compared as id-sorted multisets with exact bits) and
//! k-NN answers (compared in the facade's canonical `(dist, gid)` order,
//! ties included) must match a scan of the live list. Satellite checks
//! ride along: ids are permanent and never reused across compactions,
//! deletes of unknown or already-dead ids report `false`, and a snapshot
//! taken mid-life elides tombstones yet answers identically after reload.

use neargraph::covertree::EpochParams;
use neargraph::index::{
    build_index, IndexKind, IndexParams, InsertCoverTreeIndex, MutableOps, NearIndex,
};
use neargraph::metric::{Euclidean, Hamming, Levenshtein, Metric};
use neargraph::points::{DenseMatrix, HammingCodes, PointSet, StringSet};
use neargraph::testkit::scenario;
use neargraph::util::Rng;

/// Compaction policy tightened so a modest schedule crosses both
/// triggers (delta overflow and tombstone fraction) many times.
fn tight_params() -> IndexParams {
    IndexParams {
        epoch: EpochParams { delta_cap: 12, compact_frac: 0.15 },
        ..Default::default()
    }
}

fn brute_eps<'a, P: PointSet, M: Metric<P>>(
    pool: &'a P,
    live: &[(u32, usize)],
    metric: &M,
    q: P::Point<'a>,
    eps: f64,
) -> Vec<(u32, f64)> {
    let mut out: Vec<(u32, f64)> = live
        .iter()
        .map(|&(gid, row)| (gid, metric.dist(q, pool.point(row))))
        .filter(|&(_, d)| d <= eps)
        .collect();
    out.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    out
}

fn brute_knn<'a, P: PointSet, M: Metric<P>>(
    pool: &'a P,
    live: &[(u32, usize)],
    metric: &M,
    q: P::Point<'a>,
    k: usize,
) -> Vec<(u32, f64)> {
    let mut all: Vec<(u32, f64)> = live
        .iter()
        .map(|&(gid, row)| (gid, metric.dist(q, pool.point(row))))
        .collect();
    all.sort_unstable_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

fn bits(pairs: &[(u32, f64)]) -> Vec<(u32, u64)> {
    pairs.iter().map(|&(g, d)| (g, d.to_bits())).collect()
}

/// Verify one query point against the live-list model, ε and k-NN both.
fn check_point<P: PointSet, M: Metric<P>>(
    index: &dyn NearIndex<P, M>,
    pool: &P,
    live: &[(u32, usize)],
    metric: &M,
    row: usize,
    eps: f64,
    k: usize,
    step: usize,
) {
    let q = pool.point(row);
    let mut got = Vec::new();
    index.eps_query(q, eps, &mut got);
    got.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let want = brute_eps(pool, live, metric, q, eps);
    assert_eq!(
        bits(&got),
        bits(&want),
        "eps answer diverged from brute force at step {step} (query row {row}, eps {eps})"
    );
    let got_k = index.knn(q, k);
    let want_k = brute_knn(pool, live, metric, q, k);
    assert_eq!(
        bits(&got_k),
        bits(&want_k),
        "knn answer diverged from brute force at step {step} (query row {row}, k {k})"
    );
}

/// Run one seeded schedule. `pool` rows `0..start` seed the index (gids
/// are the row numbers); later rows feed inserts in order, so a gid's
/// coordinates are always `pool.point(row)` for a tracked `row`.
#[allow(clippy::too_many_arguments)]
fn run_schedule<P: PointSet, M: Metric<P>>(
    pool: &P,
    metric: M,
    seed: u64,
    start: usize,
    steps: usize,
    threads: usize,
    eps_of: &dyn Fn(&mut Rng) -> f64,
) {
    let index = build_index(
        IndexKind::InsertCoverTree,
        &pool.slice(0, start),
        metric.clone(),
        &tight_params(),
    )
    .unwrap();
    let index = index.as_ref();
    let mutable = index.mutable().expect("the insert backend is mutable");

    let mut rng = Rng::new(seed);
    let mut live: Vec<(u32, usize)> = (0..start).map(|row| (row as u32, row)).collect();
    let mut dead: Vec<u32> = Vec::new();
    let mut cursor = start; // next unused pool row
    let mut next_gid = start as u32;

    for step in 0..steps {
        match rng.below(10) {
            0..=4 => {
                // Insert a small batch of fresh pool rows (ids must be
                // assigned contiguously from the permanent counter).
                let batch = 1 + rng.below(3).min(pool.len().saturating_sub(cursor));
                if cursor + batch <= pool.len() {
                    let got = mutable.insert(&pool.slice(cursor, cursor + batch));
                    assert_eq!(
                        (got.start, got.end),
                        (next_gid, next_gid + batch as u32),
                        "insert assigned unexpected ids at step {step}"
                    );
                    for j in 0..batch {
                        live.push((next_gid + j as u32, cursor + j));
                    }
                    cursor += batch;
                    next_gid += batch as u32;
                }
            }
            5..=7 => {
                if !live.is_empty() {
                    let victim = live.swap_remove(rng.below(live.len()));
                    assert!(
                        mutable.delete(victim.0),
                        "delete of live gid {} failed at step {step}",
                        victim.0
                    );
                    dead.push(victim.0);
                }
            }
            8 => {
                mutable.compact();
                assert_eq!(mutable.tombstones(), 0, "compaction left tombstones at step {step}");
            }
            _ => {
                // Deletes of unknown or already-dead ids are misses, and
                // misses must never perturb the live set.
                assert!(!mutable.delete(next_gid + 1000));
                if let Some(&gone) = dead.last() {
                    assert!(!mutable.delete(gone), "double delete of gid {gone} at step {step}");
                }
            }
        }
        assert_eq!(mutable.live(), live.len(), "live count drifted at step {step}");

        // Every step gets verified — compaction points included — from
        // one or several concurrent reader threads.
        let eps = eps_of(&mut rng);
        let k = 1 + rng.below(6);
        if threads <= 1 {
            let row = rng.below(pool.len());
            check_point(index, pool, &live, &metric, row, eps, k, step);
        } else {
            let rows: Vec<usize> = (0..threads).map(|_| rng.below(pool.len())).collect();
            std::thread::scope(|s| {
                for &row in &rows {
                    let live = &live;
                    let metric = &metric;
                    s.spawn(move || check_point(index, pool, live, metric, row, eps, k, step));
                }
            });
        }
    }
    assert!(mutable.epoch() > 0, "the schedule never compacted — tighten the triggers");
}

#[test]
fn dense_schedules_stay_bit_equal_to_brute_force() {
    let pool = scenario::dense_clusters(9100, 240);
    for seed in [1u64, 2, 3] {
        run_schedule(&pool, Euclidean, 0x9100 + seed, 120, 120, 1, &|rng| 0.1 + 0.6 * rng.f64());
    }
}

#[test]
fn dense_schedule_verifies_from_four_reader_threads() {
    let pool = scenario::dense_clusters(9101, 200);
    run_schedule(&pool, Euclidean, 0x9101, 100, 80, 4, &|rng| 0.1 + 0.6 * rng.f64());
}

#[test]
fn hamming_schedules_stay_bit_equal_to_brute_force() {
    let pool = scenario::hamming_codes(9102, 140);
    run_schedule(&pool, Hamming, 0x9102, 70, 90, 1, &|rng| (6 + rng.below(26)) as f64);
    run_schedule(&pool, Hamming, 0x9103, 70, 60, 4, &|rng| (6 + rng.below(26)) as f64);
}

#[test]
fn levenshtein_schedules_stay_bit_equal_to_brute_force() {
    let pool = scenario::string_pool(9104, 70);
    run_schedule(&pool, Levenshtein, 0x9104, 35, 50, 1, &|rng| (1 + rng.below(6)) as f64);
    run_schedule(&pool, Levenshtein, 0x9105, 35, 40, 4, &|rng| (1 + rng.below(6)) as f64);
}

#[test]
fn snapshots_taken_mid_life_elide_tombstones_and_answer_identically() {
    let pool = scenario::dense_clusters(9106, 160);
    let params = tight_params();
    let index = InsertCoverTreeIndex::build(&pool.slice(0, 120), Euclidean, &params);
    let mut rng = Rng::new(0x9106);
    let mut live: Vec<(u32, usize)> = (0..120).map(|row| (row as u32, row)).collect();
    // Churn: 20 inserts, 30 deletes — leaves tombstones in both base and delta.
    let got = index.insert(&pool.slice(120, 140));
    assert_eq!((got.start, got.end), (120, 140));
    live.extend((120..140).map(|row| (row as u32, row)));
    for _ in 0..30 {
        let victim = live.swap_remove(rng.below(live.len()));
        assert!(index.delete(victim.0));
    }

    let bytes = index.snapshot_bytes().unwrap();
    assert_eq!(index.tombstones(), 0, "snapshotting compacts first");
    let back = InsertCoverTreeIndex::from_snapshot_bytes(&bytes, Euclidean, &params).unwrap();
    assert_eq!(back.num_points(), live.len());

    for row in 0..pool.len() {
        let q = pool.point(row);
        let mut a = Vec::new();
        let mut b = Vec::new();
        index.eps_query(q, 0.45, &mut a);
        back.eps_query(q, 0.45, &mut b);
        a.sort_unstable_by(|x, y| x.0.cmp(&y.0).then(x.1.total_cmp(&y.1)));
        b.sort_unstable_by(|x, y| x.0.cmp(&y.0).then(x.1.total_cmp(&y.1)));
        assert_eq!(bits(&a), bits(&b), "reloaded snapshot diverged on row {row}");
        assert_eq!(bits(&index.knn(q, 5)), bits(&back.knn(q, 5)));
    }

    // Ids keep advancing past the reload — never reused.
    let more = back.mutable().unwrap().insert(&pool.slice(140, 141));
    assert_eq!(more.start, 140);
}
