//! Integration sweep: every distributed algorithm must produce the exact
//! brute-force edge set across {metric × dataset shape × rank count × ε ×
//! strategy} — the repo's primary correctness gate (DESIGN.md §6).
//!
//! Since the weighted redesign the gate is three-sided for every
//! configuration: (1) the edge set matches brute force exactly, (2) the
//! weighted result's distances match the scalar metric within
//! [`WEIGHT_TOL`], and (3) the `NearGraph`'s unweighted CSR projection is
//! bit-identical to the CSR the pre-redesign pipeline built from the same
//! edge set.
//!
//! Datasets come from the shared `testkit::scenario` source.

use neargraph::baseline::brute_force_weighted;
use neargraph::dist::{
    run_epsilon_graph, Algorithm, AssignStrategy, CenterStrategy, RunConfig, RunResult,
};
use neargraph::graph::{assert_same_graph, assert_same_weighted_graph, WeightedEdgeList, WEIGHT_TOL};
use neargraph::prelude::*;
use neargraph::testkit::scenario;

/// The full three-sided check of one distributed result against the
/// weighted brute-force reference.
fn check_result(got: &RunResult, want: &WeightedEdgeList, n: usize, ctx: &str) {
    assert_same_graph(got.edges.clone(), want.unweighted(), ctx);
    assert_same_weighted_graph(got.weighted.clone(), want.clone(), WEIGHT_TOL, ctx);
    assert_eq!(
        got.graph.clone().into_unweighted(),
        want.unweighted().into_csr(n),
        "{ctx}: unweighted CSR projection must be bit-identical"
    );
}

#[test]
fn euclidean_full_sweep() {
    let mut rng = Rng::new(9001);
    let datasets = [
        ("clustered", scenario::dense_clusters(9001, 220)),
        ("manifold", scenario::dense_manifold(90011, 220)),
        ("uniform", scenario::dense_uniform(90012, 220)),
    ];
    for (dname, pts) in &datasets {
        for eps_quantile in [5.0, 40.0] {
            let eps = neargraph::data::calibrate_eps(pts, &Euclidean, eps_quantile, 20_000, &mut rng);
            let want = brute_force_weighted(pts, &Euclidean, eps);
            for ranks in [1usize, 3, 6, 13] {
                for algorithm in Algorithm::ALL {
                    let cfg = RunConfig { ranks, algorithm, ..Default::default() };
                    let got = run_epsilon_graph(pts, Euclidean, eps, &cfg);
                    check_result(
                        &got,
                        &want,
                        pts.len(),
                        &format!("{dname}/{}/{ranks}ranks/eps={eps:.3}", algorithm.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn hamming_sweep() {
    let codes = scenario::hamming_codes(9002, 200);
    for eps in [8.0, 20.0, 48.0] {
        let want = brute_force_weighted(&codes, &Hamming, eps);
        for algorithm in Algorithm::ALL {
            let cfg = RunConfig { ranks: 5, algorithm, ..Default::default() };
            let got = run_epsilon_graph(&codes, Hamming, eps, &cfg);
            check_result(&got, &want, codes.len(), &format!("hamming/{}", algorithm.name()));
        }
    }
}

#[test]
fn edit_distance_sweep() {
    let reads = scenario::string_pool(9003, 120);
    for eps in [2.0, 6.0] {
        let want = brute_force_weighted(&reads, &Levenshtein, eps);
        for algorithm in Algorithm::ALL {
            let cfg = RunConfig { ranks: 4, algorithm, ..Default::default() };
            let got = run_epsilon_graph(&reads, Levenshtein, eps, &cfg);
            check_result(&got, &want, reads.len(), &format!("edit/{}", algorithm.name()));
        }
    }
}

#[test]
fn exotic_metrics_sweep() {
    // Manhattan / Chebyshev / angular: only the metric axioms are assumed.
    let pts = scenario::dense_clusters(9004, 150);
    for algorithm in Algorithm::ALL {
        let cfg = RunConfig { ranks: 4, algorithm, ..Default::default() };

        let want = brute_force_weighted(&pts, &Manhattan, 0.6);
        let got = run_epsilon_graph(&pts, Manhattan, 0.6, &cfg);
        check_result(&got, &want, pts.len(), &format!("manhattan/{}", algorithm.name()));

        let want = brute_force_weighted(&pts, &Chebyshev, 0.25);
        let got = run_epsilon_graph(&pts, Chebyshev, 0.25, &cfg);
        check_result(&got, &want, pts.len(), &format!("chebyshev/{}", algorithm.name()));

        let want = brute_force_weighted(&pts, &Cosine, 0.3);
        let got = run_epsilon_graph(&pts, Cosine, 0.3, &cfg);
        check_result(&got, &want, pts.len(), &format!("cosine/{}", algorithm.name()));
    }
}

#[test]
fn strategy_cross_product() {
    let pts = scenario::dense_duplicates(9005, 100, 60); // skewed cells
    let eps = 0.15;
    let want = brute_force_weighted(&pts, &Euclidean, eps);
    for centers in [CenterStrategy::Random, CenterStrategy::Greedy] {
        for assignment in [AssignStrategy::Multiway, AssignStrategy::Cyclic] {
            for algorithm in [Algorithm::LandmarkColl, Algorithm::LandmarkRing] {
                for num_centers in [0usize, 3, 25] {
                    let cfg = RunConfig {
                        ranks: 6,
                        algorithm,
                        centers,
                        assignment,
                        num_centers,
                        ..Default::default()
                    };
                    let got = run_epsilon_graph(&pts, Euclidean, eps, &cfg);
                    check_result(
                        &got,
                        &want,
                        pts.len(),
                        &format!("{centers:?}/{assignment:?}/{}/m={num_centers}", algorithm.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn extreme_configs() {
    let pts = scenario::dense_clusters(9006, 64);
    let want = brute_force_weighted(&pts, &Euclidean, 0.3);
    // ranks > points, centers > points, leaf size 1 and huge.
    for (ranks, num_centers, leaf_size) in
        [(100, 0, 8), (4, 1000, 8), (4, 0, 1), (4, 0, 10_000), (2, 1, 8)]
    {
        for algorithm in Algorithm::ALL {
            let cfg = RunConfig { ranks, algorithm, num_centers, leaf_size, ..Default::default() };
            let got = run_epsilon_graph(&pts, Euclidean, 0.3, &cfg);
            check_result(
                &got,
                &want,
                pts.len(),
                &format!("{}/r{ranks}/m{num_centers}/z{leaf_size}", algorithm.name()),
            );
        }
    }
}

#[test]
fn huge_eps_yields_complete_graph() {
    let pts = scenario::dense_uniform(9007, 60);
    let n = 60u64;
    for algorithm in Algorithm::ALL {
        let cfg = RunConfig { ranks: 4, algorithm, ..Default::default() };
        let got = run_epsilon_graph(&pts, Euclidean, 1e9, &cfg);
        assert_eq!(got.graph.num_edges() as u64, n * (n - 1) / 2, "{}", algorithm.name());
    }
}

#[test]
fn determinism_across_runs() {
    let pts = scenario::dense_clusters(9008, 150);
    for algorithm in Algorithm::ALL {
        let cfg = RunConfig { ranks: 4, algorithm, ..Default::default() };
        let a = run_epsilon_graph(&pts, Euclidean, 0.3, &cfg);
        let b = run_epsilon_graph(&pts, Euclidean, 0.3, &cfg);
        assert_eq!(a.edges.edges(), b.edges.edges(), "{} not deterministic", algorithm.name());
        assert_eq!(
            a.weighted.edges(),
            b.weighted.edges(),
            "{} weights not deterministic",
            algorithm.name()
        );
    }
}
