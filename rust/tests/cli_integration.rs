//! Launcher (CLI) integration: drive the `neargraph` binary end to end —
//! dataset listing, config loading, graph construction with verification,
//! and edge-list output.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_neargraph"))
}

#[test]
fn datasets_lists_all_nine() {
    let out = bin().arg("datasets").output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in
        ["faces", "artificial40", "corel", "deep", "covtype", "twitter", "sift", "sift-hamming", "word2bits"]
    {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
}

#[test]
fn run_with_verify_and_output() {
    let tmp = std::env::temp_dir().join("neargraph_cli_edges.txt");
    let out = bin()
        .args([
            "run", "--dataset", "corel", "--points", "250", "--ranks", "3",
            "--algorithm", "landmark-ring", "--target-degree", "12",
            "--verify", "--output",
        ])
        .arg(&tmp)
        .output()
        .expect("spawn");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("VERIFIED"), "no verification in:\n{text}");
    let edges = std::fs::read_to_string(&tmp).expect("edge file written");
    let n_lines = edges.lines().count();
    assert!(n_lines > 0, "empty edge file");
    // Every line is "u v" with u < v.
    for line in edges.lines() {
        let mut it = line.split_whitespace();
        let u: u32 = it.next().unwrap().parse().unwrap();
        let v: u32 = it.next().unwrap().parse().unwrap();
        assert!(u < v);
        assert!(v < 250);
    }
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn run_with_index_facade_and_weighted_outputs() {
    // The facade path: single-node build through --index, verified against
    // brute force, with both weighted writers exercised.
    let tsv = std::env::temp_dir().join("neargraph_cli_graph.tsv");
    let csr = std::env::temp_dir().join("neargraph_cli_graph.csr");
    for kind in ["brute-force", "cover-tree", "insert-cover-tree", "snn"] {
        let out = bin()
            .args([
                "run", "--dataset", "corel", "--points", "200", "--index", kind,
                "--target-degree", "10", "--verify", "--out",
            ])
            .arg(&tsv)
            .args(["--out-format", "tsv"])
            .output()
            .expect("spawn");
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(
            out.status.success(),
            "{kind} stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(text.contains("VERIFIED"), "{kind}: no verification in:\n{text}");
        assert!(text.contains("index facade"), "{kind}: facade banner missing:\n{text}");
        // Every tsv line is "u<TAB>v<TAB>w" with u < v and a finite weight.
        let body = std::fs::read_to_string(&tsv).expect("tsv written");
        assert!(body.lines().count() > 0, "{kind}: empty graph file");
        for line in body.lines() {
            let mut it = line.split('\t');
            let u: u32 = it.next().unwrap().parse().unwrap();
            let v: u32 = it.next().unwrap().parse().unwrap();
            let w: f32 = it.next().unwrap().parse().unwrap();
            assert!(u < v && v < 200);
            assert!(w.is_finite() && w >= 0.0);
        }
    }
    // Binary CSR round-trips through the documented file format.
    let out = bin()
        .args([
            "run", "--dataset", "corel", "--points", "200", "--index", "cover-tree",
            "--target-degree", "10", "--out",
        ])
        .arg(&csr)
        .args(["--out-format", "csr"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let bytes = std::fs::read(&csr).expect("csr written");
    let graph = neargraph::graph::NearGraph::from_bytes(&bytes).expect("valid csr file");
    assert_eq!(graph.num_vertices(), 200);
    assert!(graph.num_edges() > 0);
    std::fs::remove_file(&tsv).ok();
    std::fs::remove_file(&csr).ok();
}

#[test]
fn run_knn_mode_verifies_and_writes() {
    // Distributed k-NN path: exact rows, binary NGK-KNN1 output.
    let knn_file = std::env::temp_dir().join("neargraph_cli_graph.knn");
    let out = bin()
        .args([
            "run", "--dataset", "corel", "--points", "150", "--ranks", "3",
            "--algorithm", "landmark-ring", "--knn", "6", "--verify", "--out",
        ])
        .arg(&knn_file)
        .args(["--out-format", "csr"])
        .output()
        .expect("spawn");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("VERIFIED"), "no verification in:\n{text}");
    assert!(text.contains("knn: k=6"), "knn banner missing:\n{text}");
    let bytes = std::fs::read(&knn_file).expect("knn file written");
    let graph = neargraph::graph::KnnGraph::from_bytes(&bytes).expect("valid NGK-KNN1 file");
    assert_eq!(graph.num_vertices(), 150);
    assert_eq!(graph.k(), 6);
    assert_eq!(graph.num_arcs(), 150 * 6);
    std::fs::remove_file(&knn_file).ok();

    // Facade k-NN path.
    let out = bin()
        .args([
            "run", "--dataset", "corel", "--points", "120", "--index", "cover-tree",
            "--knn", "4", "--verify",
        ])
        .output()
        .expect("spawn");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("VERIFIED"), "facade knn not verified:\n{text}");
    assert!(text.contains("index facade"), "facade banner missing:\n{text}");
}

#[test]
fn knn_and_eps_are_mutually_exclusive() {
    let out = bin()
        .args(["run", "--dataset", "corel", "--points", "50", "--knn", "5", "--eps", "0.3"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("mutually exclusive"),
        "unexpected stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn run_with_unsupported_index_fails_cleanly() {
    // SNN on a Hamming dataset must exit with the typed error message, not
    // a panic/abort.
    let out = bin()
        .args(["run", "--dataset", "sift-hamming", "--points", "100", "--index", "snn"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("does not support"), "unexpected stderr:\n{err}");
}

#[test]
fn run_hamming_dataset() {
    let out = bin()
        .args([
            "run", "--dataset", "sift-hamming", "--points", "200", "--ranks", "4",
            "--algorithm", "systolic-ring", "--verify",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("VERIFIED"));
}

#[test]
fn run_with_thread_pool_stays_exact() {
    let out = bin()
        .args([
            "run", "--dataset", "corel", "--points", "300", "--ranks", "2",
            "--threads", "4", "--algorithm", "landmark-coll", "--target-degree", "12",
            "--verify",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("VERIFIED"), "no verification in:\n{text}");
    assert!(text.contains("2 ranks x 2 pool threads"), "pool width missing in:\n{text}");
}

#[test]
fn config_file_loading() {
    let tmp = std::env::temp_dir().join("neargraph_cli_cfg.toml");
    std::fs::write(
        &tmp,
        "dataset = \"faces\"\npoints = 200\ntarget_degree = 10.0\n[run]\nranks = 2\nalgorithm = \"landmark-coll\"\n",
    )
    .unwrap();
    let out = bin().args(["run", "--config"]).arg(&tmp).arg("--verify").output().expect("spawn");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("dataset=faces"));
    assert!(text.contains("VERIFIED"));
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn unknown_flag_rejected() {
    let out = bin().args(["run", "--bogus-flag", "1"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag"));
}

#[test]
fn unknown_dataset_rejected() {
    let out = bin().args(["run", "--dataset", "imagenet"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dataset"));
}
