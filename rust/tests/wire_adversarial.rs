//! Adversarial wire-decoder suite: every length-checked decoder in the
//! crate is held to the shared `testkit::wire` mutation contract —
//! pristine bytes decode, **every** truncation and extension is a typed
//! `WireError`, and no single-bit flip can panic the decoder (corrupt
//! length prefixes must error before allocating).
//!
//! Covered formats: `Bundle` (dense / Hamming / string payloads),
//! `EdgeBundle`, `KnnBundle` (all three wire shapes), `WeightedEdgeList`,
//! the `NGW-CSR1` weighted graph file, the `NGK-KNN1` directed k-NN
//! file, the serve daemon's request/response frames, the `NGI-IDX1`
//! index snapshot (all three point families), the fault layer's
//! sequence-numbered envelopes and the `NGC-CKP1` checkpoint frame.
//!
//! Stateful decoders (the envelope stream) additionally run the
//! `check_stream_decoder` replay battery: every frame delivered twice and
//! out of order must dedup or error — never panic, never double-deliver.

use neargraph::covertree::BuildParams;
use neargraph::dist::{Bundle, EdgeBundle, KnnBundle};
use neargraph::graph::{KnnGraph, NearGraph, WeightedEdgeList};
use neargraph::prelude::*;
use neargraph::serve::{ErrorCode, Request, Response};
use neargraph::testkit::{scenario, wire};

#[test]
fn bundle_dense_mutations() {
    let pts = scenario::dense_clusters(8601, 8);
    let b = Bundle {
        pts: pts.clone(),
        gids: (0..8).collect(),
        cells: (0..8).map(|i| i % 3).collect(),
        dpc: (0..8).map(|i| i as f64 * 0.25).collect(),
    };
    wire::check_wire_decoder("bundle/dense", &b.to_bytes(), &|bytes| {
        Bundle::<DenseMatrix>::try_from_bytes(bytes)
    });
    // Metadata-less shape (systolic blocks).
    let lean = Bundle { pts, gids: (0..8).collect(), cells: Vec::new(), dpc: Vec::new() };
    wire::check_wire_decoder("bundle/dense-lean", &lean.to_bytes(), &|bytes| {
        Bundle::<DenseMatrix>::try_from_bytes(bytes)
    });
}

#[test]
fn bundle_hamming_mutations() {
    let codes = scenario::hamming_codes(8602, 6);
    let b = Bundle { pts: codes, gids: (10..16).collect(), cells: Vec::new(), dpc: Vec::new() };
    wire::check_wire_decoder("bundle/hamming", &b.to_bytes(), &|bytes| {
        Bundle::<HammingCodes>::try_from_bytes(bytes)
    });
}

#[test]
fn bundle_string_mutations() {
    let reads = scenario::string_pool(8603, 6);
    let b = Bundle {
        pts: reads,
        gids: (0..6).collect(),
        cells: Vec::new(),
        dpc: (0..6).map(|i| i as f64).collect(),
    };
    wire::check_wire_decoder("bundle/strings", &b.to_bytes(), &|bytes| {
        Bundle::<StringSet>::try_from_bytes(bytes)
    });
}

#[test]
fn edge_bundle_mutations() {
    let mut edges = WeightedEdgeList::new();
    edges.push(0, 3, 0.5);
    edges.push(2, 7, 1.25);
    edges.push(1, 4, 0.0);
    let eb = EdgeBundle { source: 3, edges };
    wire::check_wire_decoder("edge-bundle", &eb.to_bytes(), &EdgeBundle::from_bytes);
}

#[test]
fn edge_list_mutations() {
    use neargraph::graph::EdgeList;
    let mut edges = EdgeList::new();
    edges.push(0, 5);
    edges.push(3, 1);
    edges.push(2, 2);
    wire::check_wire_decoder("edge-list", &edges.to_bytes(), &EdgeList::from_bytes);
    // The empty list is a legal wire value (a rank with no local edges).
    wire::check_wire_decoder("edge-list/empty", &EdgeList::new().to_bytes(), &EdgeList::from_bytes);
}

#[test]
fn weighted_edge_list_mutations() {
    let mut edges = WeightedEdgeList::new();
    for i in 0..10u32 {
        edges.push(i, i + 3, 0.125 * i as f64);
    }
    wire::check_wire_decoder("weighted-edges", &edges.to_bytes(), &WeightedEdgeList::from_bytes);
}

#[test]
fn near_graph_csr_mutations() {
    // A real graph through the NGW-CSR1 file format: symmetric adjacency,
    // paired weights — plenty of cross-invariants for flips to violate
    // (they must error, not panic).
    let pts = scenario::dense_clusters(8604, 24);
    let idx = build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default())
        .unwrap();
    let mut sink = WeightedEdgeList::new();
    idx.eps_self_join(0.6, &mut sink);
    let graph = sink.into_near_graph(24);
    assert!(graph.num_edges() > 0, "need a non-trivial graph");
    wire::check_wire_decoder("near-graph", &graph.to_bytes(), &NearGraph::from_bytes);
}

#[test]
fn knn_graph_mutations() {
    let pts = scenario::dense_clusters(8605, 20);
    let idx = build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default())
        .unwrap();
    let graph = idx.knn_graph(3, &Pool::new(1));
    wire::check_wire_decoder("knn-graph", &graph.to_bytes(), &KnnGraph::from_bytes);
}

#[test]
fn knn_bundle_mutations() {
    let pts = scenario::dense_clusters(8606, 5);
    let rows: Vec<Vec<(u32, f64)>> =
        (0..5).map(|i| vec![((i as u32 + 1) % 5, i as f64 + 0.5)]).collect();
    let caps: Vec<f64> = rows.iter().map(|r| r[0].1).collect();
    let b = KnnBundle::from_rows(1, pts, (0..5).collect(), Vec::new(), caps, &rows);
    wire::check_wire_decoder("knn-bundle", &b.to_bytes(), &|bytes| {
        KnnBundle::<DenseMatrix>::try_from_bytes(bytes)
    });
}

// ---- serve daemon frames (DESIGN.md §10.1) -------------------------------

#[test]
fn serve_request_dense_mutations() {
    let pts = scenario::dense_clusters(8607, 4);
    let one = pts.slice(2, 3);
    let eps = Request::Eps { id: 0xDEAD_BEEF, eps: 0.75, point: one.clone() };
    wire::check_wire_decoder("serve/req-eps-dense", &eps.to_bytes(), &|bytes| {
        Request::<DenseMatrix>::try_from_bytes(bytes)
    });
    let knn = Request::Knn { id: 7, k: 5, point: one };
    wire::check_wire_decoder("serve/req-knn-dense", &knn.to_bytes(), &|bytes| {
        Request::<DenseMatrix>::try_from_bytes(bytes)
    });
    let bye = Request::<DenseMatrix>::Shutdown { id: u64::MAX };
    wire::check_wire_decoder("serve/req-shutdown", &bye.to_bytes(), &|bytes| {
        Request::<DenseMatrix>::try_from_bytes(bytes)
    });
}

#[test]
fn serve_request_hamming_mutations() {
    let codes = scenario::hamming_codes(8608, 3);
    let one = codes.slice(1, 2);
    let eps = Request::Eps { id: 11, eps: 16.0, point: one.clone() };
    wire::check_wire_decoder("serve/req-eps-hamming", &eps.to_bytes(), &|bytes| {
        Request::<HammingCodes>::try_from_bytes(bytes)
    });
    let knn = Request::Knn { id: 12, k: 2, point: one };
    wire::check_wire_decoder("serve/req-knn-hamming", &knn.to_bytes(), &|bytes| {
        Request::<HammingCodes>::try_from_bytes(bytes)
    });
}

#[test]
fn serve_request_string_mutations() {
    let reads = scenario::string_pool(8609, 3);
    let one = reads.slice(0, 1);
    let eps = Request::Eps { id: 21, eps: 3.0, point: one.clone() };
    wire::check_wire_decoder("serve/req-eps-strings", &eps.to_bytes(), &|bytes| {
        Request::<StringSet>::try_from_bytes(bytes)
    });
    let knn = Request::Knn { id: 22, k: 1, point: one };
    wire::check_wire_decoder("serve/req-knn-strings", &knn.to_bytes(), &|bytes| {
        Request::<StringSet>::try_from_bytes(bytes)
    });
}

#[test]
fn serve_response_mutations() {
    let hits = Response::Hits {
        id: 0x0123_4567_89AB_CDEF,
        hits: vec![(3, 0.25), (9, 1.5), (0, 0.0)],
    };
    wire::check_wire_decoder("serve/resp-hits", &hits.to_bytes(), &Response::try_from_bytes);
    // An empty hit list is a legal (and common) ε answer.
    let empty = Response::Hits { id: 5, hits: Vec::new() };
    wire::check_wire_decoder("serve/resp-hits-empty", &empty.to_bytes(), &Response::try_from_bytes);
    let err = Response::Error { id: 42, code: ErrorCode::Overloaded };
    wire::check_wire_decoder("serve/resp-error", &err.to_bytes(), &Response::try_from_bytes);
    let bye = Response::Bye { id: 43 };
    wire::check_wire_decoder("serve/resp-bye", &bye.to_bytes(), &Response::try_from_bytes);
}

#[test]
fn serve_health_mutations() {
    let req = Request::<DenseMatrix>::Health { id: 77 };
    wire::check_wire_decoder("serve/req-health", &req.to_bytes(), &|bytes| {
        Request::<DenseMatrix>::try_from_bytes(bytes)
    });
    let resp = Response::Health {
        id: 78,
        health: neargraph::serve::Health {
            queue_depth: 3,
            lanes: 2,
            queries: 1000,
            batches: 40,
            overloads: 5,
            bad_frames: 1,
            deadline_misses: 7,
        },
    };
    wire::check_wire_decoder("serve/resp-health", &resp.to_bytes(), &Response::try_from_bytes);
}

#[test]
fn serve_mutate_mutations() {
    let pts = scenario::dense_clusters(8613, 5);
    let req = Request::Mutate {
        id: 91,
        inserts: pts.slice(0, 2),
        deletes: vec![4, 17, u32::MAX],
    };
    wire::check_wire_decoder("serve/req-mutate", &req.to_bytes(), &|bytes| {
        Request::<DenseMatrix>::try_from_bytes(bytes)
    });
    // Delete-only mutates carry an empty point set — still a legal frame.
    let lean = Request::Mutate { id: 92, inserts: pts.slice(0, 0), deletes: vec![8] };
    wire::check_wire_decoder("serve/req-mutate-lean", &lean.to_bytes(), &|bytes| {
        Request::<DenseMatrix>::try_from_bytes(bytes)
    });
    let resp = Response::Mutated {
        id: 93,
        outcome: neargraph::serve::MutateOutcome {
            first_gid: 500,
            inserted: 2,
            deleted: 1,
            epoch: 9,
            live: 501,
        },
    };
    wire::check_wire_decoder("serve/resp-mutated", &resp.to_bytes(), &Response::try_from_bytes);
}

// ---- fault-layer envelopes and checkpoint frames (DESIGN.md §11) ---------

#[test]
fn envelope_mutations() {
    use neargraph::comm::{decode_envelope, encode_envelope};
    let payload: Vec<u8> = (0..37u8).collect();
    wire::check_wire_decoder("envelope", &encode_envelope(9, &payload), &decode_envelope);
    // Empty payloads ride the same framing (zero-byte sends are legal).
    wire::check_wire_decoder("envelope/empty", &encode_envelope(0, &[]), &decode_envelope);
}

#[test]
fn envelope_stream_replay_battery() {
    use neargraph::comm::{encode_envelope, EnvelopeStream};
    let frames: Vec<Vec<u8>> =
        (0..5u64).map(|seq| encode_envelope(seq, &[0xA5; 11])).collect();
    wire::check_stream_decoder("envelope-stream", &frames, &mut || {
        let mut s = EnvelopeStream::default();
        move |bytes: &[u8]| s.accept(bytes)
    });
}

#[test]
fn checkpoint_frame_mutations() {
    use neargraph::dist::checkpoint::{decode_frame, encode_frame};
    let data: Vec<u8> = (0..64u8).rev().collect();
    let bytes = encode_frame(0x5EED_F00D, 1, 4, "selfjoin", &data);
    wire::check_wire_decoder("checkpoint-frame", &bytes, &decode_frame);
}

// ---- NGI-IDX1 index snapshots --------------------------------------------

#[test]
fn snapshot_dense_mutations() {
    let pts = scenario::dense_clusters(8610, 12);
    let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
    let bytes = tree.to_snapshot_bytes().unwrap();
    wire::check_wire_decoder("snapshot/dense", &bytes, &|b| {
        CoverTree::<DenseMatrix>::try_from_snapshot_bytes(b)
    });
}

#[test]
fn snapshot_hamming_mutations() {
    let codes = scenario::hamming_codes(8611, 8);
    let tree = CoverTree::build(&codes, &Hamming, &BuildParams::default());
    let bytes = tree.to_snapshot_bytes().unwrap();
    wire::check_wire_decoder("snapshot/hamming", &bytes, &|b| {
        CoverTree::<HammingCodes>::try_from_snapshot_bytes(b)
    });
}

#[test]
fn snapshot_string_mutations() {
    let reads = scenario::string_pool(8612, 6);
    let tree = CoverTree::build(&reads, &Levenshtein, &BuildParams::default());
    let bytes = tree.to_snapshot_bytes().unwrap();
    wire::check_wire_decoder("snapshot/strings", &bytes, &|b| {
        CoverTree::<StringSet>::try_from_snapshot_bytes(b)
    });
}
