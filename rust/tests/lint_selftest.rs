//! Self-test for `neargraph::lint` (DESIGN.md §12).
//!
//! Three layers: the shared fixture corpus in `tests/lint_fixtures/`
//! (also run by `python/neargraph_lint.py`, holding the Rust engine and
//! the in-container mirror equivalent), tokenizer edge cases, and the
//! directive/waiver grammar.

use std::path::Path;

use neargraph::lint::parse::{parse_directives, parse_file, DirKind};
use neargraph::lint::rules::{apply_waivers, r1_hot_alloc, r2_total_ordering, r3_panic_free};
use neargraph::lint::tokenize::{tokenize, TokKind};
use neargraph::lint::{render_report, scan_fixtures, scan_tree, Finding};

// ---------------------------------------------------------------------------
// Fixture corpus
// ---------------------------------------------------------------------------

#[test]
fn fixture_corpus_matches_expectations() {
    let fx = scan_fixtures(Path::new("tests/lint_fixtures")).expect("fixture scan");
    assert!(
        fx.ok,
        "fixture mismatch\nexpected: {:?}\nactual:   {:?}",
        fx.expected, fx.actual
    );
    // The corpus exercises every rule; an empty expectation list would mean
    // the fixtures rotted away.
    assert!(fx.expected.len() >= 15, "fixture corpus shrank: {:?}", fx.expected);
    for rule in [
        "no-alloc-hot-path",
        "total-ordering",
        "panic-free-decode",
        "harness-registration",
        "config-doc-parity",
        "lint-directive",
    ] {
        assert!(
            fx.expected.iter().any(|(_, _, r)| r == rule),
            "no fixture expectation for rule {rule}"
        );
    }
}

#[test]
fn real_tree_is_clean() {
    // The committed source must lint clean: every finding waived with a
    // reason. Runs from the crate root (cargo sets the test cwd there).
    let docs = [Path::new("../README.md"), Path::new("../DESIGN.md")]
        .iter()
        .filter(|p| p.exists())
        .map(|p| std::fs::read_to_string(p).expect("doc corpus"))
        .collect::<Vec<_>>()
        .join("\n");
    let registry = Path::new("tests/wire_adversarial.rs");
    let (files, findings) =
        scan_tree(Path::new("src"), Some(registry), &docs).expect("scan src tree");
    assert!(files.len() > 50, "src scan found suspiciously few files: {}", files.len());
    let unwaived: Vec<&Finding> = findings.iter().filter(|f| f.waived.is_none()).collect();
    assert!(
        unwaived.is_empty(),
        "unwaived lint findings in src:\n{}",
        unwaived
            .iter()
            .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

// ---------------------------------------------------------------------------
// Tokenizer edge cases
// ---------------------------------------------------------------------------

#[test]
fn tokenizer_nested_block_comments() {
    let (toks, comments) = tokenize("/* a /* b */ c */ fn x() {}");
    assert_eq!(comments.len(), 1);
    assert_eq!(comments[0].text, "a /* b */ c");
    assert!(comments[0].standalone);
    assert_eq!(toks[0].text, "fn");
}

#[test]
fn tokenizer_raw_and_byte_strings() {
    let (toks, _) = tokenize(r###"let s = r#"quote " inside"#; let b = b"bytes";"###);
    let strs: Vec<&str> =
        toks.iter().filter(|t| t.kind == TokKind::Str).map(|t| t.text.as_str()).collect();
    assert_eq!(strs, vec![r##"r#"quote " inside"#"##, "\"bytes\""]);
    // an identifier starting with 'r' is not a raw string
    let (toks, _) = tokenize("let radius = 1;");
    assert!(toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "radius"));
}

#[test]
fn tokenizer_lifetime_vs_char() {
    let (toks, _) = tokenize("fn f<'a>(x: &'a u8) -> char { 'x' }");
    assert!(toks.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
    assert!(toks.iter().any(|t| t.kind == TokKind::Char && t.text == "'x'"));
    let (toks, _) = tokenize("let c = '\\n'; let b = b'q';");
    assert!(toks.iter().any(|t| t.kind == TokKind::Char && t.text == "'\\n'"));
    assert!(toks.iter().any(|t| t.kind == TokKind::Char && t.text == "b'q'"));
}

#[test]
fn tokenizer_float_classification() {
    let cases: [(&str, TokKind); 7] = [
        ("1.5", TokKind::FNum),
        ("2.", TokKind::FNum),
        ("1e9", TokKind::FNum),
        ("3f64", TokKind::FNum),
        ("7", TokKind::Num),
        ("0x1f", TokKind::Num),
        ("4u32", TokKind::Num),
    ];
    for (src, want) in cases {
        let (toks, _) = tokenize(src);
        assert_eq!(toks[0].kind, want, "literal {src:?}");
    }
    // `1..4` is a range of integers, not a trailing-dot float
    let (toks, _) = tokenize("for i in 1..4 {}");
    let one = toks.iter().find(|t| t.text == "1").expect("range start");
    assert_eq!(one.kind, TokKind::Num);
}

#[test]
fn tokenizer_comment_text_in_strings_is_inert() {
    let (toks, comments) = tokenize("let s = \"// lint: cold\"; // real comment");
    assert_eq!(comments.len(), 1);
    assert_eq!(comments[0].text, "real comment");
    assert!(!comments[0].standalone);
    assert!(toks.iter().any(|t| t.kind == TokKind::Str));
}

#[test]
fn tokenizer_merges_fat_arrow_and_path_sep() {
    let (toks, _) = tokenize("\"k\" => a::b");
    let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
    assert_eq!(texts, vec!["\"k\"", "=>", "a", "::", "b"]);
}

// ---------------------------------------------------------------------------
// Directive grammar
// ---------------------------------------------------------------------------

#[test]
fn directive_parsing() {
    let src = "\
// lint: allow(total-ordering, panic-free-decode) reason=\"why not\"
// lint: allow(nope) reason=\"x\"
// lint: allow(total-ordering)
// lint: allow(total-ordering) reason=\"\"
// lint: cold
// lint: frobnicate
";
    let (_, comments) = tokenize(src);
    let ds = parse_directives(&comments);
    assert_eq!(ds.len(), 6);
    assert_eq!(ds[0].kind, DirKind::Allow);
    assert_eq!(ds[0].rules, vec!["total-ordering", "panic-free-decode"]);
    assert_eq!(ds[0].reason, "why not");
    assert_eq!(ds[1].kind, DirKind::Bad);
    assert!(ds[1].error.contains("unknown rule 'nope'"), "{}", ds[1].error);
    assert_eq!(ds[2].kind, DirKind::Bad);
    assert!(ds[2].error.contains("missing reason"), "{}", ds[2].error);
    assert_eq!(ds[3].kind, DirKind::Bad);
    assert!(ds[3].error.contains("empty"), "{}", ds[3].error);
    assert_eq!(ds[4].kind, DirKind::Cold);
    assert_eq!(ds[5].kind, DirKind::Bad);
    assert!(ds[5].error.contains("unknown lint directive"), "{}", ds[5].error);
}

// ---------------------------------------------------------------------------
// Rule + waiver behavior on inline sources
// ---------------------------------------------------------------------------

fn lint_one(path: &str, src: &str) -> Vec<Finding> {
    let mut fm = parse_file(path, src);
    let mut findings = Vec::new();
    r1_hot_alloc(&fm, &mut findings);
    r2_total_ordering(&fm, &mut findings);
    r3_panic_free(&fm, &mut findings);
    apply_waivers(&mut fm, &mut findings);
    findings
}

#[test]
fn hot_path_rule_respects_cold_and_file_set() {
    let src = "pub fn f() { let v = vec![1]; }";
    assert_eq!(lint_one("covertree/query.rs", src).len(), 1);
    assert_eq!(lint_one("metric/edit.rs", src).len(), 1);
    // same code in a non-hot module: clean
    assert_eq!(lint_one("dist/mod.rs", src).len(), 0);
    // cold marker exempts the fn
    let cold = "// lint: cold\npub fn f() { let v = vec![1]; }";
    assert_eq!(lint_one("covertree/query.rs", cold).len(), 0);
}

#[test]
fn ordering_rule_heuristic() {
    let float_clamp = "fn f(d: f64) -> f64 { d.max(0.0) }";
    let int_clamp = "fn f(n: usize) -> usize { n.max(1) }";
    let abs_arg = "fn f(d: f64, t: f64) -> f64 { d.min(t.abs()) }";
    assert_eq!(lint_one("any/mod.rs", float_clamp).len(), 1);
    assert_eq!(lint_one("any/mod.rs", int_clamp).len(), 0);
    assert_eq!(lint_one("any/mod.rs", abs_arg).len(), 1);
}

#[test]
fn wire_decoder_rule_scopes() {
    let wire = "fn d(b: &[u8]) -> Result<u8, WireError> { Ok(b[0]) }";
    let plain = "fn d(b: &[u8]) -> u8 { b[0] }";
    assert_eq!(lint_one("points/mod.rs", wire).len(), 1);
    assert_eq!(lint_one("points/mod.rs", plain).len(), 0);
    // serve files ban panics in every fn, but not indexing
    let serve = "fn go(x: Option<u8>) -> u8 { x.unwrap() }";
    assert_eq!(lint_one("serve/server.rs", serve).len(), 1);
    assert_eq!(lint_one("serve/engine.rs", serve).len(), 0);
}

#[test]
fn waiver_scopes_and_unused_waivers() {
    // fn-scope waiver above the header covers the whole body
    let fn_scope = "\
// lint: allow(no-alloc-hot-path) reason=\"setup\"
pub fn f() { let a = vec![1]; let b = a.clone(); }";
    let fs = lint_one("covertree/query.rs", fn_scope);
    assert!(fs.iter().all(|f| f.waived.is_some()), "{fs:?}");
    assert_eq!(fs.len(), 2);

    // trailing waiver covers its line only
    let trailing = "\
pub fn f() {
    let a = vec![1]; // lint: allow(no-alloc-hot-path) reason=\"one line\"
    let b = a.clone();
}";
    let tr = lint_one("covertree/query.rs", trailing);
    assert_eq!(tr.iter().filter(|f| f.waived.is_some()).count(), 1);
    assert_eq!(tr.iter().filter(|f| f.waived.is_none()).count(), 1);

    // a waiver that matches nothing is itself a finding
    let unused = "\
// lint: allow(total-ordering) reason=\"matches nothing\"
pub fn f() -> u32 { 7 }";
    let un = lint_one("dist/mod.rs", unused);
    assert_eq!(un.len(), 1);
    assert_eq!(un[0].rule, "lint-directive");
    assert!(un[0].message.contains("unused waiver"), "{}", un[0].message);
}

#[test]
fn report_counts_waivers() {
    let fx = scan_fixtures(Path::new("tests/lint_fixtures")).expect("fixture scan");
    assert!(fx.ok);
    let docs = std::fs::read_to_string("../README.md").unwrap_or_default();
    let (files, findings) =
        scan_tree(Path::new("src"), Some(Path::new("tests/wire_adversarial.rs")), &docs)
            .expect("scan");
    let report = render_report("src", &files, &findings, Some(&fx));
    assert!(report.contains("\"waiver_count\""));
    assert!(report.contains("\"matched\": true"));
}
