//! Chaos conformance: the headline gate for the fault-injection layer
//! (DESIGN.md §11). Under a seeded fault matrix — drop / corrupt /
//! duplicate / delay lotteries across algorithms, rank counts and seeds —
//! every **survivable** schedule must yield a graph bit-equal to the
//! fault-free run (the retry/dedup machinery is invisible in the output),
//! and every **unsurvivable** one must come back as a typed [`DistError`]
//! in bounded virtual time: never a panic, never a hang, never a silently
//! wrong graph. Kill-and-resume closes the loop: a run killed at a phase
//! boundary restarts from its checkpoint directory and still reproduces
//! the fault-free graph bit-for-bit.

use neargraph::comm::FaultPlan;
use neargraph::dist::{
    try_run_epsilon_graph, try_run_knn_graph, Algorithm, DistError, RunConfig,
};
use neargraph::metric::Euclidean;
use neargraph::testkit::scenario;

const EPS: f64 = 0.6;
const N: usize = 60;

fn pts() -> neargraph::points::DenseMatrix {
    scenario::dense_clusters(0xC405, N)
}

fn cfg(algorithm: Algorithm, ranks: usize) -> RunConfig {
    RunConfig { ranks, algorithm, ..Default::default() }
}

/// A lottery loud enough to exercise every fault path (the counters must
/// come back nonzero) yet survivable: the retry budget covers it.
fn survivable(seed: u64) -> FaultPlan {
    FaultPlan {
        drop: 0.15,
        corrupt: 0.15,
        duplicate: 0.1,
        delay: 0.1,
        delay_us: 50,
        seed,
        ..Default::default()
    }
}

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("neargraph-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn survivable_fault_schedules_are_bit_identical_to_the_clean_run() {
    let pts = pts();
    for algorithm in Algorithm::ALL {
        for ranks in [2usize, 4] {
            let clean = try_run_epsilon_graph(&pts, Euclidean, EPS, &cfg(algorithm, ranks))
                .expect("clean run succeeds");
            assert!(!clean.faults.any(), "clean run must count zero faults");
            for seed in [1u64, 0xBAD5EED, 777] {
                let mut faulty_cfg = cfg(algorithm, ranks);
                faulty_cfg.faults = Some(survivable(seed));
                let faulty = try_run_epsilon_graph(&pts, Euclidean, EPS, &faulty_cfg)
                    .unwrap_or_else(|e| {
                        panic!("{} x{ranks} seed {seed:#x} unsurvivable: {e}", algorithm.name())
                    });
                assert_eq!(
                    faulty.edges.edges(),
                    clean.edges.edges(),
                    "{} x{ranks} seed {seed:#x} diverged under faults",
                    algorithm.name()
                );
                assert!(
                    faulty.faults.any(),
                    "{} x{ranks} seed {seed:#x}: this lottery must actually fire",
                    algorithm.name()
                );
                // Retries and delays are charged into the virtual clock,
                // never wall-clock sleeps — the makespan stays bounded.
                assert!(
                    faulty.makespan.is_finite() && faulty.makespan > 0.0,
                    "fault charges must stay inside the virtual clock"
                );
            }
        }
    }
}

#[test]
fn fault_schedules_replay_bit_identically_by_seed() {
    // Same seed ⇒ same counters (the whole schedule replays); different
    // seed ⇒ (almost surely) different counters.
    let pts = pts();
    let mut c = cfg(Algorithm::SystolicRing, 4);
    c.faults = Some(survivable(42));
    let a = try_run_epsilon_graph(&pts, Euclidean, EPS, &c).expect("survivable");
    let b = try_run_epsilon_graph(&pts, Euclidean, EPS, &c).expect("survivable");
    assert_eq!(a.faults, b.faults, "one seed, one schedule");
    c.faults = Some(survivable(43));
    let d = try_run_epsilon_graph(&pts, Euclidean, EPS, &c).expect("survivable");
    assert_ne!(a.faults, d.faults, "a different seed draws a different schedule");
    assert_eq!(a.edges.edges(), d.edges.edges(), "the graph never varies with the seed");
}

#[test]
fn unsurvivable_schedules_are_typed_errors_not_hangs() {
    // drop = 1 (every transmission lost) and corrupt = 1 (every
    // transmission mangled) exhaust the retry budget on the first p2p
    // exchange. landmark-coll moves ghosts over collectives only, so the
    // ring-using algorithms are the ones with p2p traffic to starve.
    let pts = pts();
    for algorithm in [Algorithm::SystolicRing, Algorithm::LandmarkRing] {
        for (label, plan) in [
            ("drop", FaultPlan { drop: 1.0, ..Default::default() }),
            ("corrupt", FaultPlan { corrupt: 1.0, ..Default::default() }),
        ] {
            let mut c = cfg(algorithm, 2);
            c.faults = Some(plan);
            let err = try_run_epsilon_graph(&pts, Euclidean, EPS, &c)
                .expect_err("no schedule survives a total blackout");
            assert!(
                matches!(err, DistError::PeerUnreachable { .. }),
                "{} {label}=1.0: wanted PeerUnreachable, got {err}",
                algorithm.name()
            );
        }
    }
}

#[test]
fn killed_ranks_are_typed_errors_for_every_algorithm() {
    let pts = pts();
    for algorithm in Algorithm::ALL {
        let mut c = cfg(algorithm, 4);
        c.faults = Some(FaultPlan {
            kill_rank: Some(1),
            kill_phase: Some("tree".into()),
            ..Default::default()
        });
        let err = try_run_epsilon_graph(&pts, Euclidean, EPS, &c)
            .expect_err("a killed rank cannot produce a graph");
        // The root cause wins aggregation: the killed rank, not the
        // bystanders that aborted because of it.
        match err {
            DistError::RankKilled { rank, ref phase } => {
                assert_eq!((rank, phase.as_str()), (1, "tree"), "{}", algorithm.name())
            }
            other => panic!("{}: wanted RankKilled, got {other}", algorithm.name()),
        }
    }
}

#[test]
fn kill_then_resume_reproduces_the_clean_graph_bit_for_bit() {
    let pts = pts();
    for algorithm in Algorithm::ALL {
        let clean = try_run_epsilon_graph(&pts, Euclidean, EPS, &cfg(algorithm, 2))
            .expect("clean run succeeds");
        let dir = fresh_dir(&format!("eps-{}", algorithm.name()));

        // First attempt: checkpointing on, rank 1 killed at the tree
        // boundary — the run dies with the typed error.
        let mut c = cfg(algorithm, 2);
        c.checkpoint_dir = Some(dir.clone());
        c.faults = Some(FaultPlan {
            kill_rank: Some(1),
            kill_phase: Some("tree".into()),
            ..Default::default()
        });
        let err = try_run_epsilon_graph(&pts, Euclidean, EPS, &c).expect_err("killed");
        assert!(matches!(err, DistError::RankKilled { rank: 1, .. }), "{}", algorithm.name());

        // Restart with --resume: the kill is disarmed (the crash already
        // happened), the run completes and matches the clean graph.
        c.resume = true;
        let recovered = try_run_epsilon_graph(&pts, Euclidean, EPS, &c)
            .expect("the restarted run completes");
        assert!(!recovered.resumed, "first restart recomputes (no final checkpoints yet)");
        assert_eq!(
            recovered.edges.edges(),
            clean.edges.edges(),
            "{}: recovery diverged from the clean run",
            algorithm.name()
        );

        // A second resume finds every rank's final checkpoint and takes
        // the fast path — still bit-identical.
        let resumed = try_run_epsilon_graph(&pts, Euclidean, EPS, &c)
            .expect("resume from final checkpoints");
        assert!(resumed.resumed, "{}: final checkpoints must shortcut the run", algorithm.name());
        assert_eq!(resumed.edges.edges(), clean.edges.edges());
        assert_eq!(resumed.makespan, 0.0, "no simulated work on the fast path");

        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn knn_survives_faults_and_kill_resume_bit_for_bit() {
    let pts = pts();
    let k = 4usize;
    let clean = try_run_knn_graph(&pts, Euclidean, k, &cfg(Algorithm::LandmarkColl, 2))
        .expect("clean knn run succeeds");

    // Survivable lottery: rows bit-equal, counters nonzero.
    let mut c = cfg(Algorithm::LandmarkColl, 2);
    c.faults = Some(survivable(9));
    let faulty = try_run_knn_graph(&pts, Euclidean, k, &c).expect("survivable");
    assert_eq!(faulty.knn.to_bytes(), clean.knn.to_bytes(), "knn rows diverged under faults");
    assert!(faulty.faults.any());

    // Kill at the refine boundary, then restart-with-resume, then the
    // checkpointed fast path.
    let dir = fresh_dir("knn");
    let mut c = cfg(Algorithm::LandmarkColl, 2);
    c.checkpoint_dir = Some(dir.clone());
    c.faults = Some(FaultPlan {
        kill_rank: Some(0),
        kill_phase: Some("refine".into()),
        ..Default::default()
    });
    let err = try_run_knn_graph(&pts, Euclidean, k, &c).expect_err("killed");
    assert!(matches!(err, DistError::RankKilled { rank: 0, .. }));
    c.resume = true;
    let recovered = try_run_knn_graph(&pts, Euclidean, k, &c).expect("restart completes");
    assert_eq!(recovered.knn.to_bytes(), clean.knn.to_bytes());
    let resumed = try_run_knn_graph(&pts, Euclidean, k, &c).expect("fast path");
    assert!(resumed.resumed);
    assert_eq!(resumed.knn.to_bytes(), clean.knn.to_bytes());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoints_from_a_faulty_run_serve_a_clean_resume() {
    // The fingerprint deliberately excludes fault knobs: a survivable
    // faulty run writes the same bytes its clean twin would, so a clean
    // `--resume` may consume them directly.
    let pts = pts();
    let dir = fresh_dir("cross");
    let mut faulty_cfg = cfg(Algorithm::SystolicRing, 2);
    faulty_cfg.checkpoint_dir = Some(dir.clone());
    faulty_cfg.faults = Some(survivable(5));
    let faulty = try_run_epsilon_graph(&pts, Euclidean, EPS, &faulty_cfg).expect("survivable");

    let mut clean_cfg = cfg(Algorithm::SystolicRing, 2);
    clean_cfg.checkpoint_dir = Some(dir.clone());
    clean_cfg.resume = true;
    let resumed = try_run_epsilon_graph(&pts, Euclidean, EPS, &clean_cfg).expect("resume");
    assert!(resumed.resumed, "the faulty run's finals must satisfy the clean fingerprint");
    assert_eq!(resumed.edges.edges(), faulty.edges.edges());
    let _ = std::fs::remove_dir_all(&dir);
}
