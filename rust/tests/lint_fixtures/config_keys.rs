// lint-fixture: virtual=config/mod.rs
//! R5 fixture: `"key" =>` match arms in config/ must appear word-bounded
//! in the doc corpus (DOCS.md here). `prefix` only occurs in DOCS.md as a
//! substring of "prefixed", which must not count; `Mixed.Case` is not a
//! config-key-shaped literal at all.

pub fn apply(key: &str, cfg: &mut u32) -> Result<(), String> {
    match key {
        "documented.key" => *cfg = 1,
        "undocumented.key" => *cfg = 2, //~ config-doc-parity
        "prefix" => *cfg = 3, //~ config-doc-parity
        "Mixed.Case" => *cfg = 4,
        other => return Err(format!("unknown config key {other:?}")),
    }
    Ok(())
}
