// lint-fixture: virtual=covertree/query.rs
//! R1 fixture: allocation idioms inside a hot module. Each flagged line
//! carries a `//~` expectation; the cold fn and the test mod are exempt.

pub fn hot_query(n: usize) -> usize {
    let mut ids: Vec<u32> = Vec::new(); //~ no-alloc-hot-path
    ids.reserve(n);
    let copied = ids.to_vec(); //~ no-alloc-hot-path
    let twin = copied.clone(); //~ no-alloc-hot-path
    let boxed = Box::new(n); //~ no-alloc-hot-path
    let label = String::from("q"); //~ no-alloc-hot-path
    let row = vec![0u8; n]; //~ no-alloc-hot-path
    let msg = format!("{n}"); //~ no-alloc-hot-path
    twin.len() + row.len() + label.len() + msg.len() + *boxed
}

pub fn collected(n: usize) -> usize {
    let sq: Vec<usize> = (0..n).map(|i| i * i).collect(); //~ no-alloc-hot-path
    sq.len()
}

// lint: cold
pub fn build_scratch(n: usize) -> Vec<f32> {
    // cold fns may allocate freely
    vec![0.0f32; n]
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_fns_are_exempt() {
        let v = vec![1, 2, 3];
        assert_eq!(v.clone().len(), 3);
    }
}
