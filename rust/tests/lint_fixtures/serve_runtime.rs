// lint-fixture: virtual=serve/server.rs
//! R3 fixture, file scope: every fn in the serve runtime is panic-free,
//! but indexing and asserts stay legal outside WireError decoders.

pub fn reader_loop(input: Option<u32>) -> u32 {
    input.unwrap() //~ panic-free-decode
}

pub fn no_panics(x: u32) -> u32 {
    if x > 9000 {
        panic!("too big"); //~ panic-free-decode
    }
    x
}

pub fn indexing_is_ok_here(buf: &[u8]) -> u8 {
    // file-scope R3 bans panics, not indexing (that is decoder-only)
    if buf.is_empty() {
        0
    } else {
        buf[0]
    }
}

pub fn asserts_allowed(x: u32) {
    assert!(x < 10, "bound");
}
