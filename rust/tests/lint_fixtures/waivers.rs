// lint-fixture: virtual=covertree/scratch.rs
//! Waiver grammar fixture: every placement form (fn-scope, standalone
//! line, trailing) plus the failure modes, which are findings themselves.

// lint: allow(no-alloc-hot-path) reason="fn-scope waiver: setup allocations are amortized"
pub fn fn_scope_waived(n: usize) -> Vec<u32> {
    let mut out = Vec::new();
    out.resize(n, 0);
    out.clone()
}

pub fn line_scope(n: usize) -> usize {
    // lint: allow(no-alloc-hot-path) reason="standalone waiver covers only the next line"
    let held: Vec<u8> = vec![0; n];
    let leaked = held.to_vec(); //~ no-alloc-hot-path
    leaked.len() + held.len()
}

pub fn trailing(n: usize) -> usize {
    let v = vec![1u8; n]; // lint: allow(no-alloc-hot-path) reason="trailing waiver"
    v.len()
}

/* lint: allow(no-such-rule) reason="r" */ //~ lint-directive
/* lint: allow(total-ordering) */ //~ lint-directive
/* lint: frobnicate */ //~ lint-directive
/* lint: allow(no-alloc-hot-path) reason="this waiver matches nothing" */ //~ lint-directive
pub fn clean(n: usize) -> usize {
    n + 1
}

/* lint: cold */ //~ lint-directive
