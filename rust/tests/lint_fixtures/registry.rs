// lint-fixture: virtual=tests/wire_adversarial.rs
//! Registry fixture: the file playing the adversarial harness. A decoder
//! counts as registered when its impl-type ident AND method ident both
//! appear among this file's identifiers.

fn exercise_frame() {
    let frame = Frame::from_bytes(&[1, 2, 3]);
    let _ = frame;
}
