// lint-fixture: virtual=points/frame.rs
//! R3/R4 fixture: WireError decoders must be panic-free, index-free, and
//! registered in the adversarial harness. `Frame::from_bytes` appears in
//! the registry fixture; `Orphan::try_from_bytes` does not.

pub struct WireError;

pub struct Frame {
    pub tag: u8,
}

impl Frame {
    pub fn from_bytes(bytes: &[u8]) -> Result<Frame, WireError> {
        let tag = bytes[0]; //~ panic-free-decode
        assert!(bytes.len() > 1); //~ panic-free-decode
        let _second = bytes.get(1).copied().unwrap(); //~ panic-free-decode
        Ok(Frame { tag })
    }

    pub fn tag_from_bytes(&self, bytes: &[u8]) -> u8 {
        // takes &self and two params: not a decoder, not scanned by R3
        bytes.len() as u8
    }
}

pub struct Orphan;

impl Orphan {
    pub fn try_from_bytes(bytes: &[u8]) -> Result<Orphan, WireError> { //~ harness-registration
        match bytes.first() {
            Some(_) => Ok(Orphan),
            None => Err(WireError),
        }
    }
}

pub fn helper_len(bytes: &[u8]) -> usize {
    // not a decoder name and no WireError return: unwrap_or is fine here
    bytes.first().copied().map(|b| b as usize).unwrap_or(0)
}
