// lint-fixture: virtual=dist/refine.rs
//! R2 fixture: float ordering idioms are banned crate-wide; integer
//! clamps pass because the argument heuristic sees no float.

pub fn fold_radius(ds: &[f64]) -> f64 {
    ds.iter().copied().fold(0.0, f64::max) //~ total-ordering
}

pub fn clamp_low(d: f64) -> f64 {
    d.max(0.0) //~ total-ordering
}

pub fn int_clamp(leaf: usize) -> usize {
    leaf.max(1)
}

pub fn compare(a: f64, b: f64) -> bool {
    a.partial_cmp(&b).is_some() //~ total-ordering
}

pub fn mag_clamp(d: f64, lim: f64) -> f64 {
    d.min(lim.abs()) //~ total-ordering
}
