//! PJRT ↔ native backend integration: the AOT-compiled JAX/Pallas kernel
//! must agree with the hand-written Rust tiles on random shapes, and the
//! dense brute-force path must produce the same graph through either
//! backend. Skips (with a notice) when artifacts have not been built.

use neargraph::baseline::{brute_force_edges, brute_force_tiled};
use neargraph::data::synthetic;
use neargraph::metric::engine::{NativeBackend, TileBackend};
use neargraph::prelude::*;
use neargraph::runtime::PjrtEngine;

fn engine() -> Option<PjrtEngine> {
    match PjrtEngine::load_default() {
        Some(e) => Some(e),
        None => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn euclidean_tiles_match_native_on_random_shapes() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1234);
    for &(nq, nr, d) in &[(1usize, 1usize, 1usize), (64, 64, 32), (65, 63, 20), (130, 7, 55), (10, 200, 128), (3, 3, 300)] {
        let q = synthetic::uniform(&mut rng, nq, d, 2.0);
        let r = synthetic::uniform(&mut rng, nr, d, 2.0);
        let got = e.euclidean_tile(&q, &r);
        let want = NativeBackend.euclidean_tile(&q, &r);
        assert_eq!(got.len(), want.len());
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-2 + 1e-3 * w.abs(),
                "({nq},{nr},{d}) idx {k}: pjrt {g} vs native {w}"
            );
        }
    }
}

#[test]
fn hamming_tiles_match_native_on_random_shapes() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1235);
    for &(nq, nr, bits) in &[(64usize, 64usize, 64usize), (70, 3, 256), (5, 129, 100), (33, 33, 800)] {
        let q = synthetic::hamming_clusters(&mut rng, nq, bits, 2, 0.2);
        let r = synthetic::hamming_clusters(&mut rng, nr, bits, 2, 0.2);
        let got = e.hamming_tile(&q, &r);
        let want = NativeBackend.hamming_tile(&q, &r);
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 0.5, "({nq},{nr},{bits}) idx {k}: {g} vs {w}");
        }
    }
}

#[test]
fn tiled_brute_force_same_graph_through_pjrt() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1236);
    let pts = synthetic::gaussian_mixture(&mut rng, 300, 24, 5, 0.15);
    let eps = neargraph::data::calibrate_eps(&pts, &Euclidean, 20.0, 20_000, &mut rng);
    let scalar = brute_force_edges(&pts, &Euclidean, eps);
    let native_tiles = brute_force_tiled(&pts, &NativeBackend, eps, 64);
    let pjrt_tiles = brute_force_tiled(&pts, &e, eps, 64);
    assert_eq!(scalar.edges(), native_tiles.edges(), "native tiles diverge");
    // PJRT may flip pairs within float noise of the boundary.
    let a: std::collections::BTreeSet<_> = pjrt_tiles.edges().iter().copied().collect();
    let b: std::collections::BTreeSet<_> = scalar.edges().iter().copied().collect();
    let sym = a.symmetric_difference(&b).count();
    assert!(
        sym <= scalar.edges().len() / 500 + 2,
        "PJRT graph diverges beyond boundary noise: {sym} pairs"
    );
}

#[test]
fn engine_is_shareable_across_rank_threads() {
    // The engine must be usable concurrently from simulated MPI ranks
    // (Send + Sync via the internal mutex).
    let Some(e) = engine() else { return };
    let e = std::sync::Arc::new(e);
    let mut rng = Rng::new(1237);
    let pts = synthetic::uniform(&mut rng, 64, 32, 1.0);
    let want = NativeBackend.euclidean_tile(&pts, &pts);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let e = e.clone();
            let pts = pts.clone();
            let want = want.clone();
            s.spawn(move || {
                let got = e.euclidean_tile(&pts, &pts);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-2);
                }
            });
        }
    });
}

#[test]
fn voronoi_assign_matches_native_assignment() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1238);
    let pts = synthetic::gaussian_mixture(&mut rng, 300, 16, 6, 0.1);
    let centers = pts.slice(0, 20);
    let got = e.try_voronoi_assign(&pts, &centers).expect("voronoi assign failed");
    let want = neargraph::voronoi::assign_to_centers(&pts, &centers, &Euclidean);
    assert_eq!(got.len(), want.len());
    let mut flips = 0usize;
    for (k, ((gc, gd), (wc, wd))) in got.iter().zip(&want).enumerate() {
        // Distances agree to kernel tolerance; the argmin may flip only
        // between centers within that tolerance of each other.
        assert!((gd - wd).abs() < 1e-2 + 1e-3 * wd.abs(), "idx {k}: {gd} vs {wd}");
        if gc != wc {
            let d_g = Euclidean.dist_between(&pts, k, &centers, *gc as usize);
            assert!((d_g - wd).abs() < 1e-2, "idx {k}: wrong cell {gc} (d={d_g}) vs {wc} (d={wd})");
            flips += 1;
        }
    }
    assert!(flips < 10, "too many near-tie flips: {flips}");
}

#[test]
fn voronoi_assign_rejects_too_many_centers() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1239);
    let pts = synthetic::uniform(&mut rng, 100, 8, 1.0);
    let centers = pts.slice(0, 100); // > the 64-center artifact capacity
    assert!(e.try_voronoi_assign(&pts, &centers).is_err());
}

#[test]
fn manhattan_tiles_match_native_on_random_shapes() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1240);
    for &(nq, nr, d) in &[(32usize, 32usize, 16usize), (40, 20, 55), (7, 70, 256)] {
        let q = synthetic::uniform(&mut rng, nq, d, 2.0);
        let r = synthetic::uniform(&mut rng, nr, d, 2.0);
        let got = e.manhattan_tile(&q, &r);
        let want = NativeBackend.manhattan_tile(&q, &r);
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-3 + 1e-4 * w.abs(),
                "({nq},{nr},{d}) idx {k}: pjrt {g} vs native {w}"
            );
        }
    }
}
