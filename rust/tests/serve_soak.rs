//! Soak suite for the serve daemon: concurrent pipelining clients firing
//! interleaved ε/k-NN queries over seeded scenario datasets, with every
//! reply held **bit-equal** to a brute-force oracle — regardless of how
//! the coalescer happened to cut batches. Also: overload produces the
//! typed reply (never OOM, never a dropped connection mid-reply), and
//! shutdown drains every admitted query before the daemon exits.

use neargraph::index::{build_index, IndexKind, IndexParams, NearIndex};
use neargraph::metric::{Euclidean, Hamming, Metric};
use neargraph::points::PointSet;
use neargraph::serve::{serve, Client, ErrorCode, Response, ServeConfig};
use neargraph::testkit::scenario;
use neargraph::testkit::serve_sim::{run_clients, ClientPlan, SimQuery};
use neargraph::util::Rng;

fn ephemeral(cfg: ServeConfig) -> ServeConfig {
    ServeConfig { addr: "127.0.0.1:0".into(), ..cfg }
}

fn bits(pairs: &[(u32, f64)]) -> Vec<(u32, u64)> {
    pairs.iter().map(|&(g, d)| (g, d.to_bits())).collect()
}

/// id-sorted bit view (ε replies arrive in daemon traversal order; the
/// oracle emits id order — the multiset must match exactly).
fn sorted_bits(pairs: &[(u32, f64)]) -> Vec<(u32, u64)> {
    let mut v = bits(pairs);
    v.sort_unstable();
    v
}

/// Interleaved ε/k-NN plans over `pts`, seeded per client.
fn mixed_plans(
    seed: u64,
    clients: usize,
    queries_per_client: usize,
    n_points: usize,
    eps: f64,
    k: usize,
    pipeline: usize,
) -> Vec<ClientPlan> {
    (0..clients)
        .map(|c| {
            let mut rng = Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let queries = (0..queries_per_client)
                .map(|_| {
                    let point = rng.below(n_points);
                    if rng.below(2) == 0 {
                        SimQuery::Eps { point, eps }
                    } else {
                        SimQuery::Knn { point, k }
                    }
                })
                .collect();
            ClientPlan { queries, pipeline, timeout_ms: 0 }
        })
        .collect()
}

/// Check every reply in `reports` against the brute-force oracle.
fn assert_oracle_equal<P: PointSet, M: Metric<P>>(
    pts: &P,
    metric: M,
    plans: &[ClientPlan],
    reports: &[neargraph::testkit::serve_sim::SimReport],
) {
    let oracle =
        build_index(IndexKind::BruteForce, pts, metric, &IndexParams::default()).unwrap();
    let mut want = Vec::new();
    for (c, (plan, report)) in plans.iter().zip(reports).enumerate() {
        assert_eq!(report.replies.len(), plan.queries.len(), "client {c} lost replies");
        for (r, q) in report.replies.iter().zip(&plan.queries) {
            let Response::Hits { hits, .. } = &r.response else {
                panic!("client {c} query {} got {:?}", r.seq, r.response);
            };
            match *q {
                SimQuery::Eps { point, eps } => {
                    want.clear();
                    oracle.eps_query(pts.point(point), eps, &mut want);
                    assert_eq!(
                        sorted_bits(hits),
                        sorted_bits(&want),
                        "client {c} eps query {} diverged",
                        r.seq
                    );
                }
                SimQuery::Knn { point, k } => {
                    want.clear();
                    want.extend(oracle.knn(pts.point(point), k));
                    assert_eq!(bits(hits), bits(&want), "client {c} knn query {} diverged", r.seq);
                }
            }
        }
    }
}

fn soak<P: PointSet, M: Metric<P>>(pts: P, metric: M, eps: f64, k: usize, cfg: ServeConfig) {
    let index =
        build_index(IndexKind::CoverTree, &pts, metric.clone(), &IndexParams::default()).unwrap();
    let server = serve(index, &ephemeral(cfg)).unwrap();
    let addr = server.local_addr().to_string();

    let plans = mixed_plans(0x50AC, 8, 400, pts.len(), eps, k, 16);
    let reports = run_clients(&addr, &pts, &plans).unwrap();
    assert_oracle_equal(&pts, metric, &plans, &reports);

    let stats = server.shutdown_and_join();
    assert_eq!(stats.queries, 8 * 400, "every admitted query answered through the batch path");
    assert_eq!(stats.overloads, 0, "default queue cap must not overload this load");
}

#[test]
fn dense_soak_concurrent_clients_match_oracle() {
    soak(
        scenario::dense_clusters(11, 600),
        Euclidean,
        0.9,
        6,
        ServeConfig { coalesce_us: 150, max_batch: 64, threads: 4, ..Default::default() },
    );
}

#[test]
fn hamming_soak_concurrent_clients_match_oracle() {
    soak(
        scenario::hamming_codes(23, 400),
        Hamming,
        20.0,
        5,
        ServeConfig { coalesce_us: 80, max_batch: 32, threads: 2, ..Default::default() },
    );
}

#[test]
fn answers_are_window_invariant() {
    // The same scripted load under no coalescing, a tiny window and a huge
    // batch-hungry window must produce identical reply bytes per query —
    // batch boundaries are invisible in the answers.
    let pts = scenario::dense_manifold(5, 300);
    let plans = mixed_plans(77, 4, 120, pts.len(), 0.7, 4, 8);
    let mut per_window = Vec::new();
    for (coalesce_us, max_batch) in [(0u64, 1usize), (200, 64), (4_000, 512)] {
        let index =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        let server = serve(
            index,
            &ephemeral(ServeConfig { coalesce_us, max_batch, threads: 3, ..Default::default() }),
        )
        .unwrap();
        let reports = run_clients(&server.local_addr().to_string(), &pts, &plans).unwrap();
        let digest: Vec<Vec<(u32, u64)>> = reports
            .iter()
            .flat_map(|rep| {
                rep.replies.iter().map(|r| match &r.response {
                    Response::Hits { hits, .. } => sorted_bits(hits),
                    other => panic!("unexpected reply {other:?}"),
                })
            })
            .collect();
        per_window.push(digest);
        server.shutdown_and_join();
    }
    assert_eq!(per_window[0], per_window[1], "window 0 vs 200us diverged");
    assert_eq!(per_window[0], per_window[2], "window 0 vs 4ms diverged");
}

#[test]
fn overload_is_typed_and_connection_survives() {
    // A tiny queue over a deliberately slow backend (brute force, 20k
    // points, one lane) forces overload — the reader outpaces the
    // dispatcher — and every query still gets exactly one reply (hits or
    // the typed overload error) on a connection that stays usable after.
    let pts = scenario::dense_uniform(3, 20_000);
    let index =
        build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default()).unwrap();
    let server = serve(
        index,
        &ephemeral(ServeConfig {
            coalesce_us: 1_000_000,
            max_batch: 4,
            queue_cap: 4,
            threads: 1,
            ..Default::default()
        }),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let total = 64usize;
    let mut client = Client::connect(&addr).unwrap();
    for i in 0..total {
        client.send_eps(i as u64, &pts.slice(i % pts.len(), i % pts.len() + 1), 0.5).unwrap();
    }
    let mut answered = vec![false; total];
    let mut overloaded = 0usize;
    for _ in 0..total {
        match client.recv().unwrap() {
            Response::Hits { id, .. } => {
                assert!(!std::mem::replace(&mut answered[id as usize], true), "double reply {id}");
            }
            Response::Error { id, code } => {
                assert_eq!(code, ErrorCode::Overloaded, "unexpected error for {id}");
                assert!(!std::mem::replace(&mut answered[id as usize], true), "double reply {id}");
                overloaded += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(answered.iter().all(|&a| a), "every query got exactly one reply");
    assert!(overloaded > 0, "the tiny queue must overload under this burst");

    // The connection is still usable after overload replies.
    client.send_knn(9_999, &pts.slice(0, 1), 3).unwrap();
    match client.recv().unwrap() {
        Response::Hits { id, hits } => assert_eq!((id, hits.len()), (9_999, 3)),
        other => panic!("unexpected reply {other:?}"),
    }
    let stats = server.shutdown_and_join();
    assert_eq!(stats.overloads as usize, overloaded);
}

#[test]
fn blown_deadlines_degrade_to_typed_errors_exactly_once() {
    // A 50 ms coalescing window with a huge batch cap makes every admitted
    // query wait out the window — far past the 1 µs deadline (a late-joiner
    // still pays execute time) — so each one must degrade to the typed
    // deadline-exceeded error: exactly one reply per query, never a stale
    // answer, never a hang.
    let pts = scenario::dense_uniform(17, 300);
    let index =
        build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
    let server = serve(
        index,
        &ephemeral(ServeConfig {
            coalesce_us: 50_000,
            max_batch: 512,
            threads: 2,
            deadline_us: 1,
            ..Default::default()
        }),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    client.set_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    let total = 32usize;
    for i in 0..total {
        client.send_eps(i as u64, &pts.slice(i, i + 1), 0.5).unwrap();
    }
    let mut answered = vec![false; total];
    for _ in 0..total {
        match client.recv().unwrap() {
            Response::Error { id, code } => {
                assert_eq!(code, ErrorCode::DeadlineExceeded, "unexpected error for {id}");
                assert!(!std::mem::replace(&mut answered[id as usize], true), "double reply {id}");
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(answered.iter().all(|&a| a), "every query got exactly one reply");

    // The connection survives, and the health probe — answered on the
    // reader thread, bypassing the batch queue — sees the misses.
    client.send_health(9_000).unwrap();
    match client.recv().unwrap() {
        Response::Health { id, health } => {
            assert_eq!(id, 9_000);
            assert_eq!(health.deadline_misses as usize, total);
            assert_eq!(health.lanes, 2);
        }
        other => panic!("unexpected reply {other:?}"),
    }
    let stats = server.shutdown_and_join();
    assert_eq!(stats.deadline_misses as usize, total);
    assert_eq!(stats.queries as usize, total, "missed queries still count as served");
}

#[test]
fn frames_pipelined_past_shutdown_get_typed_reply_and_join_completes() {
    // A client that keeps pipelining frames never lets its reader observe
    // an idle read; the reader must notice the shutdown flag on the frame
    // path itself, answer the late frame with the typed error, and exit —
    // otherwise `Server::join` hangs on that reader forever.
    let pts = scenario::dense_uniform(29, 80);
    let index =
        build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
    let server = serve(
        index,
        &ephemeral(ServeConfig { coalesce_us: 100, threads: 2, ..Default::default() }),
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

    client.send_eps(1, &pts.slice(0, 1), 0.5).unwrap();
    match client.recv().unwrap() {
        Response::Hits { id, .. } => assert_eq!(id, 1),
        other => panic!("unexpected reply {other:?}"),
    }
    // Shutdown plus a trailing query in one pipelined burst: the trailing
    // frame is read after the flag flips.
    client.send_shutdown(2).unwrap();
    client.send_knn(3, &pts.slice(1, 2), 2).unwrap();
    assert_eq!(client.recv().unwrap(), Response::Bye { id: 2 });
    assert_eq!(
        client.recv().unwrap(),
        Response::Error { id: 3, code: ErrorCode::ShuttingDown },
        "late frame must get the typed shutting-down reply"
    );
    let stats = server.join();
    assert_eq!(stats.queries, 1, "the late query must not reach the batch path");
}

#[test]
fn shutdown_drains_in_flight_replies() {
    // Queries admitted before the shutdown frame must all be answered —
    // the huge window would otherwise sit on them for a second.
    let pts = scenario::dense_uniform(13, 150);
    let index =
        build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
    let server = serve(
        index,
        &ephemeral(ServeConfig {
            coalesce_us: 1_000_000,
            max_batch: 1024,
            threads: 2,
            ..Default::default()
        }),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    let sent = 20usize;
    for i in 0..sent {
        client.send_eps(i as u64, &pts.slice(i, i + 1), 0.4).unwrap();
    }
    // Give the reader time to admit all 20 before shutdown closes the
    // queue — admitted queries are what the drain guarantee covers.
    std::thread::sleep(std::time::Duration::from_millis(400));
    let mut other = Client::connect(&addr).unwrap();
    other.send_shutdown(500).unwrap();
    assert_eq!(other.recv().unwrap(), Response::Bye { id: 500 });

    let mut got = 0usize;
    for _ in 0..sent {
        match client.recv().unwrap() {
            Response::Hits { .. } => got += 1,
            other => panic!("in-flight query lost to shutdown: {other:?}"),
        }
    }
    assert_eq!(got, sent, "all admitted queries answered during drain");
    let stats = server.join();
    assert_eq!(stats.queries as usize, sent);
}
