//! Property-based suite over the testkit (DESIGN.md §6): cover-tree
//! invariants, the ghost rule (Lemma 1), partitioning bounds, wire
//! roundtrips and comm-layer exchange contents on random inputs.

use neargraph::covertree::{check_invariants, BuildParams, CoverTree};
use neargraph::data::synthetic;
use neargraph::dist::Bundle;
use neargraph::metric::Metric;
use neargraph::prelude::*;
use neargraph::testkit::{forall, Size};
use neargraph::voronoi;

#[test]
fn covertree_invariants_euclidean_random() {
    forall("covertree-euclid", 30, Size { n: 120, dim: 6 }, |rng, size| {
        let clusters = 1 + rng.below(5);
        let pts = synthetic::gaussian_mixture(rng, size.n, size.dim, clusters, 0.2);
        let leaf_size = 1 + rng.below(16);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
        check_invariants(&tree, &Euclidean);
    });
}

#[test]
fn covertree_invariants_with_duplicates() {
    forall("covertree-dup", 20, Size { n: 80, dim: 4 }, |rng, size| {
        let base = synthetic::uniform(rng, size.n.max(2), size.dim, 1.0);
        let pts = synthetic::with_duplicates(rng, &base, size.n / 2 + 1);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        check_invariants(&tree, &Euclidean);
    });
}

#[test]
fn covertree_invariants_hamming_and_edit() {
    forall("covertree-nm", 15, Size { n: 70, dim: 64 }, |rng, size| {
        let codes = synthetic::hamming_clusters(rng, size.n, size.dim.max(8), 3, 0.1);
        let tree = CoverTree::build(&codes, &Hamming, &BuildParams::default());
        check_invariants(&tree, &Hamming);

        let reads = synthetic::reads(rng, size.n.min(40), 16, 3, 0.08);
        let tree = CoverTree::build(&reads, &Levenshtein, &BuildParams { leaf_size: 2, root: 0 });
        check_invariants(&tree, &Levenshtein);
    });
}

#[test]
fn covertree_query_equals_linear_scan() {
    forall("query-vs-scan", 25, Size { n: 100, dim: 5 }, |rng, size| {
        let pts = synthetic::gaussian_mixture(rng, size.n, size.dim, 2, 0.3);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 1 + rng.below(8), root: 0 });
        let eps = rng.f64() * 1.5;
        let qi = rng.below(size.n);
        let mut got = tree.query_vec(&Euclidean, pts.row(qi), eps);
        got.sort_unstable();
        let want: Vec<u32> = (0..size.n)
            .filter(|&j| Euclidean.dist_ij(&pts, qi, j) <= eps)
            .map(|j| j as u32)
            .collect();
        assert_eq!(got, want, "eps={eps}");
    });
}

#[test]
fn ghost_rule_lemma1_is_sound() {
    // Lemma 1: if p ∈ V_j has an ε-neighbor in V_i (i≠j) then
    // d(p, c_i) ≤ d(p, C) + 2ε. Property: every brute-force cross-cell
    // neighbor pair is covered by the ghost candidate rule.
    forall("lemma1", 25, Size { n: 90, dim: 4 }, |rng, size| {
        let pts = synthetic::gaussian_mixture(rng, size.n, size.dim, 3, 0.25);
        let m = 1 + rng.below(10);
        let centers_idx = rng.sample_indices(size.n, m.min(size.n));
        let centers = pts.gather(&centers_idx);
        let assignment = voronoi::assign_to_centers(&pts, &centers, &Euclidean);
        let eps = rng.f64() * 0.8;
        for i in 0..size.n {
            for j in 0..size.n {
                if i == j || Euclidean.dist_ij(&pts, i, j) > eps {
                    continue;
                }
                let (ci, _) = assignment[i];
                let (cj, dj) = assignment[j];
                if ci == cj {
                    continue;
                }
                // j must qualify as a ghost for cell ci.
                let d_to_ci = Euclidean.dist_between(&pts, j, &centers, ci as usize);
                assert!(
                    d_to_ci <= dj + 2.0 * eps + 1e-9,
                    "Lemma 1 violated: d(p,c_i)={d_to_ci} > d(p,C)+2eps={}",
                    dj + 2.0 * eps
                );
            }
        }
    });
}

#[test]
fn multiway_partition_bound_random() {
    forall("lpt-bound", 50, Size { n: 40, dim: 1 }, |rng, size| {
        let m = 1 + rng.below(size.n.max(2));
        let sizes: Vec<u64> = (0..m).map(|_| rng.below(10_000) as u64).collect();
        let ranks = 1 + rng.below(12);
        let a = voronoi::multiway_partition(&sizes, ranks);
        assert_eq!(a.len(), m);
        assert!(a.iter().all(|&r| r < ranks));
        let mk = voronoi::partition_makespan(&sizes, &a, ranks);
        let total: u64 = sizes.iter().sum();
        let lb = ((total + ranks as u64 - 1) / ranks as u64)
            .max(sizes.iter().copied().max().unwrap_or(0));
        assert!(mk as f64 <= lb as f64 * 4.0 / 3.0 + 1.0, "LPT bound violated: {mk} vs LB {lb}");
    });
}

#[test]
fn wire_bundle_roundtrip_random() {
    forall("bundle-roundtrip", 30, Size { n: 50, dim: 8 }, |rng, size| {
        let pts = synthetic::uniform(rng, size.n, size.dim, 10.0);
        let with_meta = rng.bool(0.5);
        let b = Bundle {
            pts: pts.clone(),
            gids: (0..size.n as u32).map(|i| i * 7 + 3).collect(),
            cells: if with_meta { (0..size.n as u32).collect() } else { Vec::new() },
            dpc: if with_meta { (0..size.n).map(|i| i as f64 * 0.5).collect() } else { Vec::new() },
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    });
}

#[test]
fn weighted_wire_roundtrip_and_truncation_random() {
    forall("weighted-wire", 30, Size { n: 60, dim: 1 }, |rng, size| {
        let mut w = WeightedEdgeList::new();
        for _ in 0..size.n {
            let u = rng.below(500) as u32;
            let v = rng.below(500) as u32;
            w.push(u, v, rng.below(1000) as f64 * 0.01);
        }
        let bytes = w.to_bytes();
        let w2 = WeightedEdgeList::from_bytes(&bytes).expect("roundtrip");
        assert_eq!(w.edges(), w2.edges());
        // Any truncation is a typed error, never a panic.
        let cut = rng.below(bytes.len().max(1));
        if cut < bytes.len() {
            assert!(WeightedEdgeList::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // The canonical graph round-trips through the binary CSR format.
        let n = 500;
        let g = w.into_near_graph(n);
        let g2 = NearGraph::from_bytes(&g.to_bytes()).expect("graph roundtrip");
        assert_eq!(g, g2);
    });
}

#[test]
fn alltoallv_random_contents() {
    use neargraph::comm::{run_world, CostModel};
    forall("alltoallv", 10, Size { n: 6, dim: 1 }, |rng, size| {
        let ranks = 1 + size.n.min(8);
        let seed = rng.next_u64();
        let outs = run_world(ranks, CostModel::default(), move |c| {
            // Deterministic pseudo-random payload per (src, dst).
            let payload = |src: usize, dst: usize| -> Vec<u8> {
                let mut r = Rng::new(seed ^ ((src * 1000 + dst) as u64));
                (0..r.below(50)).map(|_| r.next_u64() as u8).collect()
            };
            let bufs: Vec<Vec<u8>> = (0..c.size()).map(|d| payload(c.rank(), d)).collect();
            let got = c.alltoallv(bufs);
            for (src, buf) in got.iter().enumerate() {
                assert_eq!(*buf, payload(src, c.rank()), "src={src} dst={}", c.rank());
            }
        });
        assert_eq!(outs.len(), ranks);
    });
}

#[test]
fn greedy_permutation_prefix_separation_random() {
    forall("greedy-net", 20, Size { n: 80, dim: 4 }, |rng, size| {
        let pts = synthetic::uniform(rng, size.n.max(3), size.dim, 1.0);
        let m = 2 + rng.below(10);
        let g = voronoi::greedy_permutation(&pts, &Euclidean, m, 0);
        // Coverage radius of the prefix.
        let mut cover = 0.0f64;
        for i in 0..pts.len() {
            let d =
                g.iter().map(|&c| Euclidean.dist_ij(&pts, i, c)).fold(f64::INFINITY, f64::min);
            cover = cover.max(d);
        }
        for a in 0..g.len() {
            for b in a + 1..g.len() {
                assert!(
                    Euclidean.dist_ij(&pts, g[a], g[b]) >= cover - 1e-9,
                    "prefix is not an r-net"
                );
            }
        }
    });
}

/// Adversarial metric: Euclidean, except distances inside a band come back
/// NaN — the shape of a broken user metric (overflow, 0/0 normalization).
#[derive(Clone)]
struct NanMetric;

impl Metric<DenseMatrix> for NanMetric {
    fn dist(&self, a: &[f32], b: &[f32]) -> f64 {
        let d = Euclidean.dist(a, b);
        if d > 0.35 && d < 0.45 {
            f64::NAN
        } else {
            d
        }
    }

    fn name(&self) -> &'static str {
        "nan-band"
    }
}

#[test]
fn nan_metric_never_panics_and_graphs_stay_nan_free() {
    // Every IndexKind and all three distributed ε algorithms must either
    // reject the configuration with a typed error (SNN: wrong metric type)
    // or produce a NaN-free weighted graph — never panic. NaN distances
    // fail every `d <= eps` accept, so they are dropped at the traversal,
    // and `WeightedEdgeList::push` skips (debug-asserts on) anything
    // non-finite that would slip past.
    use neargraph::dist::run_epsilon_graph;
    use neargraph::index::build_index;

    let pts = synthetic::gaussian_mixture(&mut Rng::new(950), 70, 3, 3, 0.25);
    let eps = 0.6; // wider than the NaN band, so real accepts exist around it
    for kind in IndexKind::ALL {
        match build_index(kind, &pts, NanMetric, &IndexParams::default()) {
            Err(e) => {
                // Typed rejection is acceptable (SNN requires Euclidean).
                assert!(!e.to_string().is_empty(), "{kind:?} error must render");
            }
            Ok(idx) => {
                let mut sink = WeightedEdgeList::new();
                idx.eps_self_join(eps, &mut sink);
                sink.canonicalize();
                assert!(
                    sink.edges().iter().all(|&(u, v, w)| w.is_finite() && w >= 0.0 && u < v),
                    "{kind:?} emitted a non-finite weight"
                );
                // The CSR build must also go through cleanly.
                let g = sink.into_near_graph(pts.len());
                assert!(g.edge_triples().all(|(_, _, w)| w.is_finite()));
                // Point queries and k-NN must not panic either (k-NN rows
                // may carry NaN tails — the heap order is total — but the
                // calls return).
                let mut hits = Vec::new();
                idx.eps_query(pts.row(0), eps, &mut hits);
                assert!(hits.iter().all(|&(_, d)| d.is_finite()));
                let _ = idx.knn(pts.row(0), 5);
            }
        }
    }
    for algorithm in Algorithm::ALL {
        for ranks in [1usize, 3] {
            let cfg = RunConfig { ranks, algorithm, ..Default::default() };
            let res = run_epsilon_graph(&pts, NanMetric, eps, &cfg);
            assert!(
                res.weighted.edges().iter().all(|&(_, _, w)| w.is_finite() && w >= 0.0),
                "{} ranks={ranks} emitted a non-finite weight",
                algorithm.name()
            );
            assert_eq!(res.graph.num_edges(), res.edges.edges().len());
        }
    }
}

#[test]
fn canonicalize_orders_finite_weights_like_total_cmp() {
    // Regression for the total_cmp sweep: `canonicalize()` keys duplicate
    // edges by `f32::to_bits`, which must order NaN-free, non-negative
    // weights exactly as `f32::total_cmp` — i.e. the sweep changed no
    // canonical ordering on valid data.
    forall("canon-totalcmp", 30, Size { n: 80, dim: 1 }, |rng, size| {
        let mut w = WeightedEdgeList::new();
        for _ in 0..size.n {
            let u = rng.below(20) as u32;
            let v = rng.below(20) as u32;
            w.push(u, v, rng.below(8) as f64 * 0.125); // few values ⇒ many duplicates
        }
        // Reference: sort the raw records by (u, v, total_cmp(w)), dedup
        // keep-first.
        let mut want: Vec<(u32, u32, f32)> = w.edges().to_vec();
        want.sort_by(|a, b| {
            (a.0, a.1).cmp(&(b.0, b.1)).then_with(|| a.2.total_cmp(&b.2))
        });
        want.dedup_by_key(|e| (e.0, e.1));
        w.canonicalize();
        assert_eq!(w.edges(), &want[..], "to_bits order diverged from total_cmp");
    });
}

#[test]
fn snn_query_equals_scan_random() {
    use neargraph::baseline::{Snn, SnnParams};
    forall("snn-vs-scan", 20, Size { n: 120, dim: 6 }, |rng, size| {
        let pts = synthetic::gaussian_mixture(rng, size.n, size.dim, 3, 0.2);
        let snn = Snn::build(&pts, &SnnParams::default());
        let eps = rng.f64() * 0.6;
        let qi = rng.below(size.n);
        let mut got = snn.query(pts.row(qi), eps);
        got.sort_unstable();
        // The window filter is exact up to matmul-form boundary noise;
        // compare against a scan using the same d² formulation.
        let norms = pts.row_sq_norms();
        let q = pts.row(qi);
        let qn: f32 = q.iter().map(|x| x * x).sum();
        let want: Vec<u32> = (0..size.n)
            .filter(|&j| {
                let dot: f32 = pts.row(j).iter().zip(q).map(|(a, b)| a * b).sum();
                (qn + norms[j] - 2.0 * dot).max(0.0) <= (eps * eps) as f32
            })
            .map(|j| j as u32)
            .collect();
        assert_eq!(got, want, "eps={eps} qi={qi}");
    });
}
