//! Conformance gate for distributed k-NN graph construction (DESIGN.md
//! §9): `dist::run_knn_graph` must reproduce single-rank brute force
//! **bit-for-bit** — exact neighbor id sets, bit-equal `f64` distances and
//! deterministic `(distance, id)` tie-breaks — over
//!
//!   {3 algorithms} × {dense / Hamming / Levenshtein / duplicate-heavy}
//!     × {1, 2, 4 ranks} × {1, 4 threads} × k ∈ {1, 5, 70},
//!
//! including datasets where k exceeds the point count (rows clamp to
//! `n − 1`) and duplicate-point datasets where every tie must resolve by
//! id. The facade's `knn_graph` is held to the identical result, and every
//! malformed `KnnBundle` byte pattern must decode to a typed `WireError`
//! (via the shared `testkit::wire` mutation harness), never a panic.

use neargraph::dist::{run_knn_graph, Algorithm, KnnBundle, RunConfig};
use neargraph::graph::KnnGraph;
use neargraph::index::{build_index, IndexKind, IndexParams};
use neargraph::prelude::*;
use neargraph::testkit::{brute_knn_rows, scenario, wire};

const RANKS: [usize; 3] = [1, 2, 4];
const THREADS: [usize; 2] = [1, 4];
const KS: [usize; 3] = [1, 5, 70];

/// Assert a constructed graph equals the reference rows bit-for-bit.
fn assert_rows_bit_equal(got: &KnnGraph, want: &[Vec<(u32, f64)>], ctx: &str) {
    assert_eq!(got.num_vertices(), want.len(), "{ctx}: vertex count");
    for (i, wrow) in want.iter().enumerate() {
        let grow = got.row(i);
        assert_eq!(grow.len(), wrow.len(), "{ctx}: row {i} length");
        for (g, w) in grow.iter().zip(wrow) {
            assert_eq!(g.0, w.0, "{ctx}: row {i} neighbor id");
            assert_eq!(
                g.1.to_bits(),
                w.1.to_bits(),
                "{ctx}: row {i} distance bits (got {}, want {})",
                g.1,
                w.1
            );
        }
    }
}

/// The full {algorithm × ranks × threads × k} sweep over one dataset.
fn sweep<P: PointSet, M: Metric<P>>(pts: &P, metric: M, what: &str) {
    for k in KS {
        let want = brute_knn_rows(pts, &metric, k);
        for algorithm in Algorithm::ALL {
            for ranks in RANKS {
                for threads in THREADS {
                    let cfg = RunConfig {
                        ranks,
                        algorithm,
                        threads: threads * ranks, // `threads` pool workers per rank
                        ..Default::default()
                    };
                    let got = run_knn_graph(pts, metric.clone(), k, &cfg);
                    assert_rows_bit_equal(
                        &got.knn,
                        &want,
                        &format!("{what}/{}/r{ranks}/t{threads}/k{k}", algorithm.name()),
                    );
                    // The undirected projection is the arc union.
                    assert_eq!(got.graph.num_vertices(), pts.len());
                }
            }
        }
    }
}

#[test]
fn dense_clusters_conformance() {
    let pts = scenario::dense_clusters(8101, 110);
    sweep(&pts, Euclidean, "dense");
}

#[test]
fn dense_duplicates_conformance() {
    // Duplicate-heavy: exact zero-distance ties everywhere; every row must
    // still resolve deterministically by id.
    let pts = scenario::dense_duplicates(8102, 60, 50);
    sweep(&pts, Euclidean, "dense+dups");
}

#[test]
fn hamming_conformance() {
    // Integer-valued distances: ties are the common case, not the edge
    // case.
    let codes = scenario::hamming_codes(8103, 90);
    sweep(&codes, Hamming, "hamming");
}

#[test]
fn levenshtein_conformance() {
    // k = 70 exceeds n − 1 = 59: every row clamps to full width.
    let reads = scenario::string_pool(8104, 60);
    sweep(&reads, Levenshtein, "levenshtein");
}

#[test]
fn facade_knn_graph_matches_distributed() {
    // The single-node facade entry point and the distributed driver must
    // agree bit-for-bit (and with brute force) on the same input.
    let pts = scenario::dense_clusters(8105, 130);
    let k = 7;
    let want = brute_knn_rows(&pts, &Euclidean, k);
    let pool = Pool::new(4);
    for kind in IndexKind::ALL {
        let index = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
        let got = index.knn_graph(k, &pool);
        assert_rows_bit_equal(&got, &want, &format!("facade/{}", kind.name()));
    }
    let cfg = RunConfig { ranks: 3, ..Default::default() };
    let dist = run_knn_graph(&pts, Euclidean, k, &cfg);
    assert_rows_bit_equal(&dist.knn, &want, "dist-vs-facade");
}

#[test]
fn knn_graph_wire_roundtrip() {
    // The NGK-KNN1 file format preserves the certified rows exactly.
    let pts = scenario::dense_clusters(8106, 50);
    let cfg = RunConfig { ranks: 2, ..Default::default() };
    let res = run_knn_graph(&pts, Euclidean, 4, &cfg);
    let decoded = KnnGraph::from_bytes(&res.knn.to_bytes()).expect("roundtrip");
    assert_eq!(decoded, res.knn);
}

#[test]
fn malformed_knn_bundles_are_typed_errors() {
    // Acceptance criterion: every truncation/extension of a KnnBundle is a
    // WireError and no byte mutation can panic the decoder. Exercise all
    // three wire shapes (circulating, request, reply).
    let pts = scenario::dense_clusters(8107, 6);
    let gids: Vec<u32> = (0..6).collect();
    let rows: Vec<Vec<(u32, f64)>> = (0..6)
        .map(|i| vec![((i as u32 + 1) % 6, 0.5 + i as f64), ((i as u32 + 2) % 6, 1.5 + i as f64)])
        .collect();
    let caps: Vec<f64> = rows.iter().map(|r| r.last().unwrap().1).collect();
    let dpc: Vec<f64> = (0..6).map(|i| i as f64 * 0.1).collect();

    let circulating =
        KnnBundle::from_rows(2, pts.clone(), gids.clone(), dpc, caps.clone(), &rows);
    wire::check_wire_decoder("knn-bundle/circulating", &circulating.to_bytes(), &|b| {
        KnnBundle::<DenseMatrix>::try_from_bytes(b)
    });

    let request = KnnBundle::from_rows(
        2,
        pts.clone(),
        gids.clone(),
        Vec::new(),
        caps,
        &vec![Vec::new(); 6],
    );
    wire::check_wire_decoder("knn-bundle/request", &request.to_bytes(), &|b| {
        KnnBundle::<DenseMatrix>::try_from_bytes(b)
    });

    let reply =
        KnnBundle::from_rows(2, DenseMatrix::new(5), gids, Vec::new(), Vec::new(), &rows);
    wire::check_wire_decoder("knn-bundle/reply", &reply.to_bytes(), &|b| {
        KnnBundle::<DenseMatrix>::try_from_bytes(b)
    });
}
