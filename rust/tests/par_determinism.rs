//! Determinism gate for the shared-memory parallel paths (DESIGN.md §7.1):
//!
//! * the hub-parallel cover-tree build must produce the **identical**
//!   node/children arrays as the sequential build at every pool size —
//!   and the parallel-built tree must satisfy every cover-tree invariant
//!   (`covertree::check_invariants`: nesting, covering, separating, leaf
//!   partition), so bit-equality is anchored to a *valid* structure, not
//!   just a reproducible one;
//! * the parallel ε self-join must emit the **identical** edge set;
//! * the dual-tree self-join (sequential and parallel) must emit the
//!   identical edge set and weight bits as the batched join, and the
//!   parallel form must be thread-count-independent;
//!
//! on all three metric families (dense Euclidean, bit-packed Hamming,
//! Levenshtein over strings), including duplicate-heavy inputs. Datasets
//! come from the shared `testkit::scenario` source.

use neargraph::covertree::{check_invariants, BuildParams, CoverTree};
use neargraph::metric::{Euclidean, Hamming, Levenshtein, Metric};
use neargraph::points::{DenseMatrix, PointSet};
use neargraph::testkit::scenario;
use neargraph::util::{Pool, Rng};

const POOL_SIZES: [usize; 4] = [1, 2, 4, 8];

fn check_parallel_paths<P, M>(pts: &P, metric: &M, eps: f64, leaf_size: usize, what: &str)
where
    P: PointSet,
    M: Metric<P>,
{
    let params = BuildParams { leaf_size, root: 0 };
    let seq = CoverTree::build(pts, metric, &params);
    // Edge set AND weight bits must be identical at every pool size.
    let mut seq_edges: Vec<(u32, u32, u64)> = Vec::new();
    seq.eps_self_join(metric, eps, |a, b, d| seq_edges.push((a, b, d.to_bits())));
    seq_edges.sort_unstable();

    for threads in POOL_SIZES {
        let pool = Pool::new(threads);
        let par = CoverTree::build_par(pts, metric, &params, &pool);
        assert_eq!(
            seq.structure(),
            par.structure(),
            "{what}: tree arrays differ at threads={threads} leaf={leaf_size}"
        );
        assert_eq!(seq.ids(), par.ids(), "{what}: ids differ at threads={threads}");
        // The parallel build must be a *valid* cover tree, not merely a
        // reproducible byte pattern (the invariant module historically
        // never ran against build_par).
        check_invariants(&par, metric);

        let mut par_edges: Vec<(u32, u32, u64)> = Vec::new();
        par.eps_self_join_par(metric, eps, &pool, |a, b, d| par_edges.push((a, b, d.to_bits())));
        par_edges.sort_unstable();
        assert_eq!(
            seq_edges, par_edges,
            "{what}: self-join edges differ at threads={threads} leaf={leaf_size}"
        );

        // Dual-tree conformance: same edge set and weight bits as the
        // batched join on both the sequential and the pooled traversal.
        let mut dual_edges: Vec<(u32, u32, u64)> = Vec::new();
        par.eps_self_join_dual_par(metric, eps, &pool, |a, b, d| {
            dual_edges.push((a, b, d.to_bits()))
        });
        dual_edges.sort_unstable();
        dual_edges.dedup();
        assert_eq!(
            seq_edges, dual_edges,
            "{what}: dual-tree join differs at threads={threads} leaf={leaf_size}"
        );
    }

    // Sequential dual-tree against the batched reference once per dataset.
    let mut dual_seq: Vec<(u32, u32, u64)> = Vec::new();
    seq.eps_self_join_dual(metric, eps, |a, b, d| dual_seq.push((a, b, d.to_bits())));
    dual_seq.sort_unstable();
    dual_seq.dedup();
    assert_eq!(seq_edges, dual_seq, "{what}: sequential dual-tree join differs");
}

#[test]
fn dense_euclidean_build_and_join_deterministic() {
    let pts = scenario::dense_clusters(900, 600);
    for leaf_size in [1usize, 8, 32] {
        check_parallel_paths(&pts, &Euclidean, 0.3, leaf_size, "dense");
    }
}

#[test]
fn dense_with_duplicates_deterministic() {
    let pts = scenario::dense_duplicates(901, 150, 100);
    check_parallel_paths(&pts, &Euclidean, 0.2, 8, "dense+dups");
    check_parallel_paths(&pts, &Euclidean, 0.0, 8, "dense+dups eps=0");
}

#[test]
fn hamming_build_and_join_deterministic() {
    let codes = scenario::hamming_codes(902, 300);
    for leaf_size in [2usize, 8] {
        check_parallel_paths(&codes, &Hamming, 14.0, leaf_size, "hamming");
    }
}

#[test]
fn levenshtein_build_and_join_deterministic() {
    let reads = scenario::string_pool(903, 120);
    for leaf_size in [2usize, 8] {
        check_parallel_paths(&reads, &Levenshtein, 4.0, leaf_size, "levenshtein");
    }
}

#[test]
fn tiny_and_degenerate_inputs_deterministic() {
    // Sizes around and below the leaf cutoff, where par_build delegates.
    for n in [0usize, 1, 2, 9, 17] {
        let mut pts = DenseMatrix::new(2);
        let mut rng = Rng::new(904 + n as u64);
        for _ in 0..n {
            pts.push(&[rng.normal_f32(), rng.normal_f32()]);
        }
        check_parallel_paths(&pts, &Euclidean, 0.5, 8, &format!("tiny n={n}"));
    }
}

#[test]
fn parallel_batch_query_matches_sequential_on_hamming() {
    // Cross-container check of the sharded batch path (> one chunk).
    let tree_codes = scenario::hamming_codes(905, 400);
    let query_codes = scenario::hamming_codes(906, 1500);
    let tree = CoverTree::build(&tree_codes, &Hamming, &BuildParams::default());
    let mut seq: Vec<(u32, u32, u64)> = Vec::new();
    tree.query_batch(&Hamming, &query_codes, 16.0, |q, id, d| {
        seq.push((q as u32, id, d.to_bits()));
    });
    seq.sort_unstable();
    for threads in POOL_SIZES {
        let pool = Pool::new(threads);
        let mut par: Vec<(u32, u32, u64)> = Vec::new();
        tree.query_batch_par(&Hamming, &query_codes, 16.0, &pool, |q, id, d| {
            par.push((q as u32, id, d.to_bits()));
        });
        par.sort_unstable();
        assert_eq!(seq, par, "hamming batch threads={threads}");
    }
}
