//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. cover-tree leaf size ζ (build vs query trade-off);
//! 2. random vs greedy landmark selection (cell balance + makespan) —
//!    the paper's §IV-D observation that random wins on skewed data;
//! 3. multiway (LPT) vs cyclic cell→rank assignment (load imbalance);
//! 4. native vs PJRT tile backend throughput on dense distance tiles;
//! 5. batch construction (Algorithms 1–2) vs classic consecutive
//!    insertion — the paper's §IV-A motivation;
//! 6. batched self-join vs dual-tree self-join (extension).
//!
//! `NEARGRAPH_BENCH_N` (default 3000).

use neargraph::bench::{fmt, timed, Table};
use neargraph::covertree::{BuildParams, CoverTree};
use neargraph::data::synthetic;
use neargraph::dist::{run_epsilon_graph, Algorithm, AssignStrategy, CenterStrategy, RunConfig};
use neargraph::graph::EdgeList;
use neargraph::metric::engine::{NativeBackend, TileBackend};
use neargraph::metric::Euclidean;
use neargraph::points::PointSet;
use neargraph::util::Rng;

fn main() {
    let n: usize = std::env::var("NEARGRAPH_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3000);
    let mut rng = Rng::new(77);
    let pts = synthetic::manifold_mixture(&mut rng, n, 32, 6, 12, 0.08);
    let eps = neargraph::data::calibrate_eps(&pts, &Euclidean, 40.0, 60_000, &mut rng);
    println!("workload: n={n}, dim=32, eps={eps:.4}");

    // ------------------------------------------------------- ζ leaf size
    let mut t1 = Table::new("Ablation 1: cover-tree leaf size ζ", &[
        "leaf_size", "build_s", "selfjoin_s", "total_s", "nodes",
    ]);
    for leaf_size in [1usize, 2, 4, 8, 16, 32, 128] {
        let params = BuildParams { leaf_size, root: 0 };
        let (tree, build_s) = timed(|| CoverTree::build(&pts, &Euclidean, &params));
        let (_e, join_s) = timed(|| {
            let mut e = EdgeList::new();
            tree.eps_self_join(&Euclidean, eps, |a, b, _d| e.push(a, b));
            e
        });
        t1.row(&[
            leaf_size.to_string(),
            format!("{build_s:.3}"),
            format!("{join_s:.3}"),
            format!("{:.3}", build_s + join_s),
            tree.num_nodes().to_string(),
        ]);
    }
    t1.print();
    t1.write_csv("ablation_leaf_size.csv").ok();

    // -------------------------------------- random vs greedy landmarks
    // Include a heavily duplicated dataset — the case the paper says
    // breaks greedy permutations.
    let dup_pts = synthetic::with_duplicates(&mut rng, &pts.slice(0, n / 2), n / 2);
    let mut t2 = Table::new("Ablation 2: landmark selection (makespan s, 8 ranks)", &[
        "dataset", "strategy", "makespan_s", "max_cell_share",
    ]);
    for (dname, data) in [("clustered", &pts), ("duplicated", &dup_pts)] {
        for (sname, strategy) in
            [("random", CenterStrategy::Random), ("greedy", CenterStrategy::Greedy)]
        {
            let cfg = RunConfig {
                ranks: 8,
                algorithm: Algorithm::LandmarkColl,
                centers: strategy,
                ..Default::default()
            };
            let res = run_epsilon_graph(data, Euclidean, eps, &cfg);
            // Cell-size skew proxy: the most loaded rank's share of points.
            let max_share = max_rank_share(data, &cfg);
            t2.row(&[
                dname.into(),
                sname.into(),
                format!("{:.4}", res.makespan),
                format!("{:.2}", max_share),
            ]);
            eprintln!("[ablation2] {dname}/{sname} done");
        }
    }
    t2.print();
    t2.write_csv("ablation_centers.csv").ok();

    // ------------------------------------ multiway vs cyclic assignment
    let mut t3 = Table::new("Ablation 3: cell→rank assignment (8 ranks)", &[
        "strategy", "makespan_s",
    ]);
    for (sname, strategy) in
        [("multiway(LPT)", AssignStrategy::Multiway), ("cyclic", AssignStrategy::Cyclic)]
    {
        let cfg = RunConfig {
            ranks: 8,
            algorithm: Algorithm::LandmarkColl,
            assignment: strategy,
            ..Default::default()
        };
        let res = run_epsilon_graph(&dup_pts, Euclidean, eps, &cfg);
        t3.row(&[sname.into(), format!("{:.4}", res.makespan)]);
    }
    t3.print();
    t3.write_csv("ablation_assignment.csv").ok();

    // --------------------------------------- native vs PJRT tile engine
    let mut t4 = Table::new("Ablation 4: dense tile backend (512x512x32d tiles)", &[
        "kernel", "backend", "tile_s", "Mdists/s",
    ]);
    let q = pts.slice(0, 512);
    let r = pts.slice(512, 1024);
    let (_, native_s) = timed(|| NativeBackend.euclidean_tile(&q, &r));
    t4.row(&[
        "euclidean".into(),
        "native".into(),
        format!("{native_s:.4}"),
        fmt(512.0 * 512.0 / native_s / 1e6),
    ]);
    let (_, l1_native_s) = timed(|| NativeBackend.manhattan_tile(&q, &r));
    t4.row(&[
        "manhattan".into(),
        "native".into(),
        format!("{l1_native_s:.4}"),
        fmt(512.0 * 512.0 / l1_native_s / 1e6),
    ]);
    match neargraph::runtime::PjrtEngine::load_default() {
        Some(engine) => {
            let _ = engine.euclidean_tile(&q, &r); // warm the compile cache
            let (_, pjrt_s) = timed(|| engine.euclidean_tile(&q, &r));
            t4.row(&[
                "euclidean".into(),
                "pjrt (interpret)".into(),
                format!("{pjrt_s:.4}"),
                fmt(512.0 * 512.0 / pjrt_s / 1e6),
            ]);
            let _ = engine.manhattan_tile(&q, &r);
            let (_, l1_pjrt_s) = timed(|| engine.manhattan_tile(&q, &r));
            t4.row(&[
                "manhattan".into(),
                "pjrt (interpret)".into(),
                format!("{l1_pjrt_s:.4}"),
                fmt(512.0 * 512.0 / l1_pjrt_s / 1e6),
            ]);
        }
        None => eprintln!("[ablation4] PJRT skipped: artifacts missing"),
    }
    t4.print();
    t4.write_csv("ablation_backend.csv").ok();

    // ------------------------- batch vs insertion construction (§IV-A)
    use neargraph::covertree::InsertCoverTree;
    use neargraph::metric::Counted;
    let mut t5 = Table::new("Ablation 5: batch vs consecutive-insertion cover tree", &[
        "builder", "build_s", "query50_s", "query_dists",
    ]);
    {
        let counted = Counted::new(Euclidean);
        let (batch, bs) = timed(|| {
            CoverTree::build(&pts, &counted, &BuildParams::default())
        });
        counted.counter().reset();
        let (_, qs) = timed(|| {
            let mut out = Vec::new();
            for qi in 0..50 {
                out.clear();
                batch.query(&counted, pts.row(qi), eps, &mut out);
            }
        });
        t5.row(&["batch (Alg 1-2)".into(), format!("{bs:.3}"), format!("{qs:.4}"),
                 counted.count().to_string()]);
    }
    {
        let counted = Counted::new(Euclidean);
        let (ins, bs) = timed(|| InsertCoverTree::build(&pts, &counted));
        counted.counter().reset();
        let (_, qs) = timed(|| {
            let mut out = Vec::new();
            for qi in 0..50 {
                out.clear();
                ins.query(&counted, pts.row(qi), eps, &mut out);
            }
        });
        t5.row(&["insertion (BKL'06)".into(), format!("{bs:.3}"), format!("{qs:.4}"),
                 counted.count().to_string()]);
    }
    t5.print();
    t5.write_csv("ablation_builder.csv").ok();

    // ----------------------------- batched vs dual-tree self-join
    let mut t6 = Table::new("Ablation 6: self-join strategy", &[
        "strategy", "selfjoin_s", "dists",
    ]);
    let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
    {
        let counted = Counted::new(Euclidean);
        let (_n, s) = timed(|| {
            let mut n = 0u64;
            tree.eps_self_join(&counted, eps, |_, _, _| n += 1);
            n
        });
        t6.row(&["batched queries".into(), format!("{s:.3}"), counted.count().to_string()]);
    }
    {
        let counted = Counted::new(Euclidean);
        let (_n, s) = timed(|| {
            let mut n = 0u64;
            tree.eps_self_join_dual(&counted, eps, |_, _, _| n += 1);
            n
        });
        t6.row(&["dual-tree".into(), format!("{s:.3}"), counted.count().to_string()]);
    }
    t6.print();
    t6.write_csv("ablation_selfjoin.csv").ok();
}

/// Share of all points landing on the most-loaded rank under the
/// config's landmark partitioning (recomputed sequentially for clarity).
fn max_rank_share(pts: &neargraph::points::DenseMatrix, cfg: &RunConfig) -> f64 {
    use neargraph::voronoi;
    let n = pts.len();
    let m = cfg.resolved_centers(n);
    let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
    let centers_idx = match cfg.centers {
        CenterStrategy::Random => rng.sample_indices(n, m),
        CenterStrategy::Greedy => voronoi::greedy_permutation(pts, &Euclidean, m, 0),
    };
    let centers = pts.gather(&centers_idx);
    let assignment = voronoi::assign_to_centers(pts, &centers, &Euclidean);
    let sizes = voronoi::cell_sizes(&assignment, centers.len());
    let f = voronoi::multiway_partition(&sizes, cfg.ranks);
    let mut loads = vec![0u64; cfg.ranks];
    for (c, &rank) in f.iter().enumerate() {
        loads[rank] += sizes[c];
    }
    *loads.iter().max().unwrap() as f64 / n as f64
}
