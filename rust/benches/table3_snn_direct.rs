//! Table III — direct single-process comparison against SNN.
//!
//! All seven Euclidean datasets, three ε each: SNN's batch self-join wall
//! time versus a single MPI rank running landmark-coll with m = 10 and
//! m = 60 Voronoi cells (the paper's exact configuration). Shape to match:
//! the cover-tree landmarking method is competitive with SNN sequentially —
//! winning on clustered/low-intrinsic-dimension data, losing where
//! Euclidean structure lets SNN's BLAS3 filter shine.
//!
//! `NEARGRAPH_BENCH_N` (default 2000).

use neargraph::baseline::{Snn, SnnParams};
use neargraph::bench::{build_workload, fmt, timed, Table, Workload};
use neargraph::data::registry::TABLE1;
use neargraph::dist::{run_epsilon_graph, Algorithm, RunConfig};
use neargraph::metric::Euclidean;

fn main() {
    let n: usize = std::env::var("NEARGRAPH_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);

    let mut table = Table::new(
        &format!("Table III analog: SNN direct comparison, 1 rank (n={n}, seconds)"),
        &["dataset", "eps", "snn_s", "m=10_s", "m=60_s"],
    );
    for spec in TABLE1.iter().filter(|s| s.metric == neargraph::data::MetricKind::Euclidean) {
        let w = build_workload(spec, n, 5);
        let Workload::Dense { pts, eps, .. } = &w else { unreachable!() };
        for &e in eps.iter() {
            let (_, snn_time) = timed(|| {
                let snn = Snn::build(pts, &SnnParams::default());
                snn.self_join(e)
            });
            let mut cells = vec![spec.name.to_string(), fmt(e), format!("{snn_time:.3}")];
            for m in [10usize, 60] {
                let cfg = RunConfig {
                    ranks: 1,
                    algorithm: Algorithm::LandmarkColl,
                    num_centers: m,
                    ..Default::default()
                };
                let res = run_epsilon_graph(pts, Euclidean, e, &cfg);
                cells.push(format!("{:.3}", res.makespan));
            }
            table.row(&cells);
        }
        eprintln!("[table3] {} done", spec.name);
    }
    table.print();
    table.write_csv("table3_snn_direct.csv").ok();
    println!("\nShape check: single-rank landmark-coll within the same order of");
    println!("magnitude as SNN, with the advantage flipping by dataset (as in Table III).");
}
