//! Figure 2 — strong scaling of the three algorithms across datasets.
//!
//! For every Table-I analog and the middle ε of its sweep, run all three
//! algorithms over a power-of-two rank sweep and report the simulated
//! makespan. Shapes to match the paper: all algorithms scale; landmark-coll
//! is strong at low/medium ranks but its alltoallv α·(P−1) term bends the
//! curve upward at high ranks; landmark-ring flattens that; systolic
//! catches up as P grows.
//!
//! Env knobs: `NEARGRAPH_BENCH_N` (default 4000 points),
//! `NEARGRAPH_BENCH_MAXRANKS` (default 128),
//! `NEARGRAPH_BENCH_DATASETS` (comma list; default all nine).

use neargraph::bench::{build_workload, rank_sweep, Table, Workload};
use neargraph::data::registry::TABLE1;
use neargraph::dist::{run_epsilon_graph, Algorithm, RunConfig};
use neargraph::metric::{Euclidean, Hamming};

fn main() {
    let n: usize = env_usize("NEARGRAPH_BENCH_N", 4000);
    let max_ranks: usize = env_usize("NEARGRAPH_BENCH_MAXRANKS", 128);
    let filter: Option<Vec<String>> = std::env::var("NEARGRAPH_BENCH_DATASETS")
        .ok()
        .map(|v| v.split(',').map(str::to_string).collect());

    let mut table = Table::new(
        &format!("Figure 2 analog: strong scaling (n={n}, makespan seconds)"),
        &["dataset", "eps", "ranks", "systolic-ring", "landmark-coll", "landmark-ring"],
    );
    for spec in &TABLE1 {
        if let Some(f) = &filter {
            if !f.iter().any(|x| x == spec.name) {
                continue;
            }
        }
        let w = build_workload(spec, n, 2);
        let eps = w.eps_sweep()[1];
        for ranks in rank_sweep(max_ranks) {
            let mut cells = vec![spec.name.to_string(), format!("{eps:.4}"), ranks.to_string()];
            for algorithm in Algorithm::ALL {
                let cfg = RunConfig { ranks, algorithm, ..Default::default() };
                let makespan = match &w {
                    Workload::Dense { pts, .. } => {
                        run_epsilon_graph(pts, Euclidean, eps, &cfg).makespan
                    }
                    Workload::Hamming { codes, .. } => {
                        run_epsilon_graph(codes, Hamming, eps, &cfg).makespan
                    }
                };
                cells.push(format!("{makespan:.6}"));
            }
            table.row(&cells);
            eprintln!("[fig2] {} ranks={ranks} done", spec.name);
        }
    }
    table.print();
    table.write_csv("fig2_strong_scaling.csv").ok();
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}
