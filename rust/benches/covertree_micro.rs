//! Cover-tree microbenchmark — the paper's §V-D claim that the
//! shared-memory batch cover tree is competitive with state-of-the-art
//! fixed-radius search.
//!
//! Reports, per dataset analog: batch build time, batch self-join query
//! time, distance evaluations per point for build and query, and the
//! distance-call saving versus brute force (the n²/2 floor).
//!
//! `NEARGRAPH_BENCH_N` (default 4000).

use neargraph::bench::{build_workload, fmt, timed, Table, Workload};
use neargraph::covertree::{BuildParams, CoverTree};
use neargraph::data::registry::TABLE1;
use neargraph::graph::EdgeList;
use neargraph::metric::{Counted, Euclidean, Hamming};
use neargraph::util::{Pool, Rng};

fn main() {
    let n: usize = std::env::var("NEARGRAPH_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);
    let params = BuildParams::default();

    let mut table = Table::new(
        &format!("Cover tree micro (n={n})"),
        &[
            "dataset", "eps", "build_s", "selfjoin_s", "build_dists/pt", "query_dists/pt",
            "brute_saving",
        ],
    );
    for spec in &TABLE1 {
        let w = build_workload(spec, n, 6);
        let eps = w.eps_sweep()[1];
        let (build_s, join_s, build_d, query_d) = match &w {
            Workload::Dense { pts, .. } => {
                let counted = Counted::new(Euclidean);
                let (tree, build_s) = timed(|| CoverTree::build(pts, &counted, &params));
                let build_d = counted.count();
                counted.counter().reset();
                let (_edges, join_s) = timed(|| {
                    let mut e = EdgeList::new();
                    tree.eps_self_join(&counted, eps, |a, b, _d| e.push(a, b));
                    e
                });
                (build_s, join_s, build_d, counted.count())
            }
            Workload::Hamming { codes, .. } => {
                let counted = Counted::new(Hamming);
                let (tree, build_s) = timed(|| CoverTree::build(codes, &counted, &params));
                let build_d = counted.count();
                counted.counter().reset();
                let (_edges, join_s) = timed(|| {
                    let mut e = EdgeList::new();
                    tree.eps_self_join(&counted, eps, |a, b, _d| e.push(a, b));
                    e
                });
                (build_s, join_s, build_d, counted.count())
            }
        };
        let total = build_d + query_d;
        let brute = (n as u64) * (n as u64 - 1) / 2;
        table.row(&[
            spec.name.into(),
            fmt(eps),
            format!("{build_s:.3}"),
            format!("{join_s:.3}"),
            format!("{:.1}", build_d as f64 / n as f64),
            format!("{:.1}", query_d as f64 / n as f64),
            format!("{:.1}x", brute as f64 / total as f64),
        ]);
        eprintln!("[covertree] {} done", spec.name);
    }
    table.print();
    table.write_csv("covertree_micro.csv").ok();

    // ------------------------------------------------------------------
    // Pool scaling: hub-parallel build + sharded self-join (bit-identical
    // to the sequential path; see tests/par_determinism.rs).
    // ------------------------------------------------------------------
    let mut scaling = Table::new(
        &format!("Cover tree pool scaling (gaussian mixture, n={n})"),
        &["threads", "build_s", "selfjoin_s", "total_s", "speedup"],
    );
    let pts = neargraph::data::synthetic::gaussian_mixture(&mut Rng::new(11), n, 8, 16, 0.05);
    let eps = neargraph::data::calibrate_eps(&pts, &Euclidean, 30.0, 50_000, &mut Rng::new(12));
    let mut seq_total = 0.0f64;
    for threads in [1usize, 2, 4] {
        let pool = Pool::new(threads);
        let (tree, build_s) = timed(|| CoverTree::build_par(&pts, &Euclidean, &params, &pool));
        let (_edges, join_s) = timed(|| {
            let mut e = EdgeList::new();
            tree.eps_self_join_par(&Euclidean, eps, &pool, |a, b, _d| e.push(a, b));
            e
        });
        let total = build_s + join_s;
        if threads == 1 {
            seq_total = total;
        }
        scaling.row(&[
            format!("{threads}"),
            format!("{build_s:.3}"),
            format!("{join_s:.3}"),
            format!("{total:.3}"),
            format!("{:.2}x", seq_total / total.max(1e-12)),
        ]);
        eprintln!("[covertree] pool threads={threads} done");
    }
    scaling.print();
    scaling.write_csv("covertree_pool_scaling.csv").ok();
}
