//! Table II — speedups over the sequential SNN baseline.
//!
//! For the covtype, twitter and sift analogs, three ε values each: SNN's
//! sequential runtime (measured on this CPU) and each distributed
//! algorithm's simulated makespan at a set of rank counts, reported as
//! speedups over SNN. Shapes to match the paper: the landmarking
//! algorithms lead at lower rank counts; systolic-ring closes the gap (or
//! wins) at the highest.
//!
//! The virtual makespan is the honest cluster analog on this one-core box
//! (see DESIGN.md §3); SNN is real wall time — both describe "time to the
//! full ε-graph".
//!
//! Env knobs: `NEARGRAPH_BENCH_N` (default 3000),
//! `NEARGRAPH_BENCH_RANKSETS` (default "1,32,256").

use neargraph::baseline::{Snn, SnnParams};
use neargraph::bench::{build_workload, fmt, timed, Table, Workload};
use neargraph::data::registry::DatasetSpec;
use neargraph::dist::{run_epsilon_graph, Algorithm, RunConfig};
use neargraph::metric::Euclidean;

fn main() {
    let n: usize = std::env::var("NEARGRAPH_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3000);
    let ranksets: Vec<usize> = std::env::var("NEARGRAPH_BENCH_RANKSETS")
        .unwrap_or_else(|_| "1,32,256".into())
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();

    let mut columns: Vec<String> = vec!["dataset".into(), "eps".into(), "snn_s".into()];
    for r in &ranksets {
        for a in Algorithm::ALL {
            columns.push(format!("{}@{r}", a.name()));
        }
    }
    let colrefs: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut table =
        Table::new(&format!("Table II analog: speedups over SNN (n={n})"), &colrefs);

    for name in ["covtype", "twitter", "sift"] {
        let spec = DatasetSpec::by_name(name).unwrap();
        let w = build_workload(spec, n, 4);
        let Workload::Dense { pts, eps, .. } = &w else { unreachable!() };
        for &e in eps.iter() {
            // Sequential SNN: index + batch self-join, wall time.
            let (_, snn_time) = timed(|| {
                let snn = Snn::build(pts, &SnnParams::default());
                snn.self_join(e)
            });
            let mut cells = vec![name.to_string(), fmt(e), format!("{snn_time:.3}")];
            for &ranks in &ranksets {
                for algorithm in Algorithm::ALL {
                    let cfg = RunConfig { ranks, algorithm, ..Default::default() };
                    let res = run_epsilon_graph(pts, Euclidean, e, &cfg);
                    cells.push(format!("{:.2}", snn_time / res.makespan.max(1e-12)));
                }
                eprintln!("[table2] {name} eps={e:.3} ranks={ranks} done");
            }
            table.row(&cells);
        }
    }
    table.print();
    table.write_csv("table2_speedups.csv").ok();
    println!("\nShape check: landmark speedups dominate at low/medium ranks;");
    println!("systolic-ring closes in at the highest rank count.");
}
