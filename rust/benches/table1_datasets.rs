//! Table I — datasets, ε sweep, edge counts, average neighbors.
//!
//! Regenerates the paper's Table I over the synthetic analogs: for each of
//! the nine datasets, three calibrated ε values sweeping sparse → dense,
//! with the resulting edge count and average degree. The *shape* to match:
//! the sweep should span roughly one to two orders of magnitude of average
//! degree per dataset, as in the paper.
//!
//! `NEARGRAPH_BENCH_N` overrides the per-dataset point count (default 1500).

use neargraph::bench::{build_workload, fmt, Table, Workload};
use neargraph::data::diagnostics::estimate_expansion_constant;
use neargraph::data::registry::TABLE1;
use neargraph::dist::{run_epsilon_graph, Algorithm, RunConfig};
use neargraph::metric::{Euclidean, Hamming};
use neargraph::util::Rng;

fn main() {
    let n: usize = std::env::var("NEARGRAPH_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1500);
    let cfg = RunConfig { ranks: 4, algorithm: Algorithm::LandmarkColl, ..Default::default() };

    let mut table = Table::new(
        &format!("Table I analog (n={n} per dataset)"),
        &["dataset", "metric", "dim", "points", "expansion~", "eps", "edges", "avg_neighbors", "paper_avg"],
    );
    for spec in &TABLE1 {
        let w = build_workload(spec, n, 1);
        // Intrinsic-difficulty diagnostic: the expansion-constant estimate
        // the paper's runtime bounds are parameterized by.
        let mut drng = Rng::new(1);
        let expansion = match &w {
            Workload::Dense { pts, .. } => {
                estimate_expansion_constant(pts, &Euclidean, 8, &mut drng)
            }
            Workload::Hamming { codes, .. } => {
                estimate_expansion_constant(codes, &Hamming, 8, &mut drng)
            }
        };
        for (k, &eps) in w.eps_sweep().iter().enumerate() {
            let (edges, avg) = match &w {
                Workload::Dense { pts, .. } => {
                    let r = run_epsilon_graph(pts, Euclidean, eps, &cfg);
                    (r.graph.num_edges(), r.graph.avg_degree())
                }
                Workload::Hamming { codes, .. } => {
                    let r = run_epsilon_graph(codes, Hamming, eps, &cfg);
                    (r.graph.num_edges(), r.graph.avg_degree())
                }
            };
            table.row(&[
                spec.name.into(),
                format!("{:?}", spec.metric).to_lowercase(),
                spec.dim.to_string(),
                n.to_string(),
                format!("{expansion:.1}"),
                fmt(eps),
                edges.to_string(),
                fmt(avg),
                fmt(spec.paper_avg_neighbors[k]),
            ]);
        }
        eprintln!("[table1] {} done", spec.name);
    }
    table.print();
    table.write_csv("table1_datasets.csv").ok();
    println!("\nShape check: each dataset's sweep should climb from ~15 to ~300 avg neighbors");
    println!("(the synthetic analogs calibrate ε to the paper's sparse→dense degree bands).");
}
