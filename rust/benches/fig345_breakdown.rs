//! Figures 3, 4, 5 — landmark algorithm phase breakdowns (covtype,
//! twitter, sift analogs).
//!
//! For each dataset and rank count, report per-phase compute and
//! communication time for landmark-coll (top rows of the paper's figures)
//! and landmark-ring (bottom rows). The shape to match: the ghost phase's
//! *communication* share grows with rank count under the collective
//! regime and stays flat under the ring regime. Also reports per-rank
//! imbalance (max/mean of total time), visible in the paper as ragged
//! bars.
//!
//! Env knobs: `NEARGRAPH_BENCH_N` (default 2500),
//! `NEARGRAPH_BENCH_RANKSETS` (default "8,32,128").

use neargraph::bench::{build_workload, Table, Workload};
use neargraph::data::registry::DatasetSpec;
use neargraph::dist::{run_epsilon_graph, Algorithm, RunConfig, RunResult};
use neargraph::metric::Euclidean;

const PHASES: [&str; 3] = ["partition", "tree", "ghost"];

fn main() {
    let n: usize = std::env::var("NEARGRAPH_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2500);
    let ranksets: Vec<usize> = std::env::var("NEARGRAPH_BENCH_RANKSETS")
        .unwrap_or_else(|_| "8,32,128".into())
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();

    let mut table = Table::new(
        &format!("Figures 3-5 analog: landmark phase breakdown (n={n}, seconds)"),
        &[
            "dataset",
            "algorithm",
            "ranks",
            "partition(comp+comm)",
            "tree(comp+comm)",
            "ghost(comp+comm)",
            "ghost_comm_share",
            "imbalance(max/mean)",
        ],
    );

    for name in ["covtype", "twitter", "sift"] {
        let spec = DatasetSpec::by_name(name).unwrap();
        let w = build_workload(spec, n, 3);
        let Workload::Dense { pts, eps, .. } = &w else { unreachable!() };
        let eps = eps[1];
        for &ranks in &ranksets {
            for algorithm in [Algorithm::LandmarkColl, Algorithm::LandmarkRing] {
                let cfg = RunConfig { ranks, algorithm, ..Default::default() };
                let res = run_epsilon_graph(pts, Euclidean, eps, &cfg);
                let mut cells =
                    vec![name.to_string(), algorithm.name().into(), ranks.to_string()];
                let mut ghost_comm = 0.0;
                let mut ghost_total = 0.0;
                for phase in PHASES {
                    let (c, m) = phase_avg(&res, phase);
                    cells.push(format!("{c:.4}+{m:.4}"));
                    if phase == "ghost" {
                        ghost_comm = m;
                        ghost_total = c + m;
                    }
                }
                cells.push(format!("{:.1}%", 100.0 * ghost_comm / ghost_total.max(1e-12)));
                cells.push(format!("{:.2}", imbalance(&res)));
                table.row(&cells);
                eprintln!("[fig345] {name} {} ranks={ranks} done", algorithm.name());
            }
        }
    }
    table.print();
    table.write_csv("fig345_breakdown.csv").ok();
    println!("\nShape check: ghost_comm_share grows with ranks for landmark-coll");
    println!("(the alltoallv α·(P−1) term) and stays flat for landmark-ring.");
}

/// Mean over ranks of a phase's (compute, comm).
fn phase_avg(res: &RunResult, phase: &str) -> (f64, f64) {
    let mut c = 0.0;
    let mut m = 0.0;
    for r in &res.ranks {
        if let Some(p) = r.stats.phases().get(phase) {
            c += p.compute;
            m += p.comm;
        }
    }
    let k = res.ranks.len() as f64;
    (c / k, m / k)
}

/// Max/mean of per-rank total virtual time (load imbalance).
fn imbalance(res: &RunResult) -> f64 {
    let times: Vec<f64> = res.ranks.iter().map(|r| r.virtual_time).collect();
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let max = times.iter().cloned().fold(0.0, f64::max);
    max / mean.max(1e-12)
}
