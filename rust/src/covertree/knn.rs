//! k-nearest-neighbor queries — the problem's second formulation from the
//! paper's introduction ("the k nearest neighbors of every point"),
//! provided as an extension so downstream users (UMAP/Isomap-style
//! pipelines) don't need a second index.
//!
//! Best-first branch-and-bound over the cover tree: nodes are visited in
//! order of their lower bound `max(d(q, p_v) − radius_v, 0)`; a node is
//! pruned once k candidates closer than its bound are known.

use super::CoverTree;
use crate::metric::Metric;
use crate::points::PointSet;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Max-heap entry of current k-best candidates.
#[derive(PartialEq)]
struct Cand {
    dist: f64,
    gid: u32,
}

impl Eq for Cand {}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by distance; ties by gid for determinism.
        self.dist.partial_cmp(&other.dist).unwrap().then(self.gid.cmp(&other.gid))
    }
}

/// Min-heap frontier entry (lower bound, node, exact distance to point).
#[derive(PartialEq)]
struct Frontier {
    bound: f64,
    node: u32,
    dist: f64,
}

impl Eq for Frontier {}

impl PartialOrd for Frontier {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Frontier {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on the bound.
        other.bound.partial_cmp(&self.bound).unwrap().then(other.node.cmp(&self.node))
    }
}

impl<P: PointSet> CoverTree<P> {
    /// The `k` nearest tree points to `query`, as `(global_id, distance)`
    /// sorted by ascending distance (ties by id). Returns fewer than `k`
    /// only when the tree holds fewer points. The query point itself is
    /// *not* excluded — callers joining a set against itself typically
    /// ask for `k + 1` and drop the self match.
    pub fn knn<M: Metric<P>>(&self, metric: &M, query: P::Point<'_>, k: usize) -> Vec<(u32, f64)> {
        if self.is_empty() || k == 0 {
            return Vec::new();
        }
        let mut best: BinaryHeap<Cand> = BinaryHeap::with_capacity(k + 1);
        let mut frontier: BinaryHeap<Frontier> = BinaryHeap::new();
        let root = self.node(self.root());
        let d = metric.dist(query, self.points().point(root.point as usize));
        frontier.push(Frontier { bound: (d - root.radius).max(0.0), node: self.root(), dist: d });

        while let Some(Frontier { bound, node, dist }) = frontier.pop() {
            // Prune: k candidates at least as close as this bound exist.
            if best.len() == k && bound >= best.peek().unwrap().dist {
                break; // the frontier is bound-ordered — nothing better left
            }
            let n = self.node(node);
            if n.is_leaf() {
                push_cand(&mut best, k, Cand { dist, gid: self.global_id(n.point as usize) });
                continue;
            }
            for &c in self.node_children(node) {
                let cn = self.node(c);
                // Nesting reuse: same point as parent ⇒ same distance.
                let dc = if cn.point == n.point {
                    dist
                } else {
                    metric.dist(query, self.points().point(cn.point as usize))
                };
                let cb = (dc - cn.radius).max(0.0);
                if best.len() < k || cb < best.peek().unwrap().dist {
                    frontier.push(Frontier { bound: cb, node: c, dist: dc });
                }
            }
        }
        let mut out: Vec<(u32, f64)> =
            best.into_sorted_vec().into_iter().map(|c| (c.gid, c.dist)).collect();
        // into_sorted_vec gives ascending by our Ord (distance, gid).
        out.truncate(k);
        out
    }
}

fn push_cand(best: &mut BinaryHeap<Cand>, k: usize, c: Cand) {
    if best.len() < k {
        best.push(c);
    } else if let Some(top) = best.peek() {
        if c.dist < top.dist || (c.dist == top.dist && c.gid < top.gid) {
            best.pop();
            best.push(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Counted, Euclidean, Hamming, Metric};
    use crate::points::{DenseMatrix, PointSet};
    use crate::util::Rng;

    fn brute_knn<P: PointSet, M: Metric<P>>(
        pts: &P,
        metric: &M,
        q: P::Point<'_>,
        k: usize,
    ) -> Vec<(u32, f64)> {
        let mut all: Vec<(u32, f64)> =
            (0..pts.len()).map(|i| (i as u32, metric.dist(q, pts.point(i)))).collect();
        all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    fn assert_knn_equal(got: &[(u32, f64)], want: &[(u32, f64)]) {
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            // Distances must match exactly; ids may differ only on exact ties.
            assert_eq!(g.1, w.1, "distance mismatch: {g:?} vs {w:?}");
        }
    }

    #[test]
    fn knn_matches_brute_force() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(150), 300, 5, 4, 0.2);
        let queries = crate::data::synthetic::uniform(&mut Rng::new(151), 15, 5, 1.0);
        for leaf in [1usize, 8] {
            let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: leaf, root: 0 });
            for k in [1usize, 5, 17] {
                for qi in 0..queries.len() {
                    let got = tree.knn(&Euclidean, queries.row(qi), k);
                    let want = brute_knn(&pts, &Euclidean, queries.row(qi), k);
                    assert_knn_equal(&got, &want);
                }
            }
        }
    }

    #[test]
    fn knn_hamming() {
        let codes = crate::data::synthetic::hamming_clusters(&mut Rng::new(152), 200, 64, 4, 0.1);
        let tree = CoverTree::build(&codes, &Hamming, &BuildParams::default());
        for qi in [0usize, 50, 199] {
            let got = tree.knn(&Hamming, codes.code(qi), 8);
            let want = brute_knn(&codes, &Hamming, codes.code(qi), 8);
            assert_knn_equal(&got, &want);
            assert_eq!(got[0].1, 0.0, "self must be the nearest");
        }
    }

    #[test]
    fn knn_edge_cases() {
        let pts = DenseMatrix::from_flat(1, vec![0.0, 1.0, 2.0]);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        assert!(tree.knn(&Euclidean, &[0.5], 0).is_empty());
        // k larger than the tree: everything returned, sorted.
        let all = tree.knn(&Euclidean, &[0.9], 10);
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].0, 1);
        // empty tree
        let empty = CoverTree::build(&DenseMatrix::new(1), &Euclidean, &BuildParams::default());
        assert!(empty.knn(&Euclidean, &[0.0], 3).is_empty());
    }

    #[test]
    fn knn_with_duplicates_returns_each_id() {
        let mut pts = DenseMatrix::new(1);
        pts.push(&[5.0]);
        pts.push(&[5.0]);
        pts.push(&[9.0]);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let got = tree.knn(&Euclidean, &[5.0], 2);
        let ids: Vec<u32> = got.iter().map(|&(g, _)| g).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn knn_prunes_versus_linear_scan() {
        let pts =
            crate::data::synthetic::gaussian_mixture(&mut Rng::new(153), 3000, 6, 15, 0.02);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 8, root: 0 });
        let counted = Counted::new(Euclidean);
        let got = tree.knn(&counted, pts.row(0), 10);
        assert_eq!(got.len(), 10);
        assert!(
            counted.count() < 3000 / 2,
            "knn used {} distance calls on clustered n=3000",
            counted.count()
        );
    }
}
