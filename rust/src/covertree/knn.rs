//! k-nearest-neighbor queries — the problem's second formulation from the
//! paper's introduction ("the k nearest neighbors of every point"),
//! provided as an extension so downstream users (UMAP/Isomap-style
//! pipelines) don't need a second index.
//!
//! Best-first branch-and-bound over the cover tree: nodes are visited in
//! order of their lower bound `max(d(q, p_v) − radius_v, 0)`; a node is
//! pruned once k candidates closer than its bound are known. Traversal
//! runs over the flat level-ordered layout ([`super::FlatTree`]) with both
//! heaps owned by a caller-provided [`QueryScratch`] — the distributed
//! refinement loops issue millions of bounded queries per rank and reuse
//! one scratch each, so the steady state allocates nothing per query.
//!
//! Heap ordering uses [`f64::total_cmp`] (see `scratch.rs`): a NaN
//! distance from a broken user metric sorts last instead of panicking
//! inside the heap the way `partial_cmp(..).unwrap()` did, and on real
//! distances the order is the documented `(distance, id)` policy bit for
//! bit. The pruning comparisons themselves stay native `f64` operators
//! and degrade cleanly under NaN: a NaN center distance yields a lower
//! bound of 0 (`(NaN − r).max(0.0)` is `0.0`), so such a subtree is
//! still *explored* — real candidates beneath one broken center pair are
//! not lost, at the price of pruning efficiency — while NaN candidate
//! distances fail the leaf accept (`d ≤ cap` is false for NaN) and never
//! enter a result. Point/bounded k-NN queries therefore never panic
//! under a NaN metric; note that full k-NN **graph** construction
//! (`KnnGraph::from_rows`) still asserts complete, finite rows and does
//! require a finite metric.
//!
//! Two properties the distributed radius-refinement loop (`dist::knn`,
//! DESIGN.md §9) depends on:
//!
//! * **bounded search** — [`CoverTree::knn_within`] additionally prunes
//!   every subtree whose lower bound exceeds a caller-supplied radius cap,
//!   so a remote rank refining a visiting point does work proportional to
//!   the point's *current* candidate radius, not its tree size;
//! * **tie-exact order** — results are the k smallest under the total
//!   order `(distance, id)`, including on exact distance ties (duplicate
//!   points). Pruning uses strict comparisons against the current k-th
//!   candidate so an equal-distance, smaller-id point behind an
//!   equal-to-bound subtree is never lost; this is what makes distributed
//!   merges bit-deterministic across rank and pool counts.
#![warn(clippy::unwrap_used)]

use super::scratch::{Cand, Frontier};
use super::{CoverTree, QueryScratch};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::fmax;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

impl<P: PointSet> CoverTree<P> {
    /// The `k` nearest tree points to `query`, as `(global_id, distance)`
    /// sorted ascending by `(distance, id)` — tie-exact. Returns fewer
    /// than `k` only when the tree holds fewer points. The query point
    /// itself is *not* excluded — callers joining a set against itself
    /// typically ask for `k + 1` and drop the self match.
    pub fn knn<M: Metric<P>>(&self, metric: &M, query: P::Point<'_>, k: usize) -> Vec<(u32, f64)> {
        self.knn_within(metric, query, k, f64::INFINITY)
    }

    /// The `k` nearest tree points to `query` **among those within
    /// distance `cap`**, ascending by `(distance, id)` — the bounded query
    /// of the distributed radius-refinement loop (DESIGN.md §9).
    ///
    /// Equivalent to filtering [`CoverTree::knn`]'s result to `d ≤ cap`,
    /// but prunes every subtree whose lower bound exceeds `cap`, so the
    /// work shrinks with the cap. May return fewer than `k` entries when
    /// fewer tree points lie within `cap`. A NaN or negative `cap` yields
    /// an empty result.
    // lint: cold
    pub fn knn_within<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        k: usize,
        cap: f64,
    ) -> Vec<(u32, f64)> {
        let mut scratch = QueryScratch::new();
        let mut out = Vec::new();
        self.knn_within_with(metric, query, k, cap, &mut scratch, &mut out);
        out
    }

    /// [`CoverTree::knn_within`] with caller-owned heaps and result
    /// buffer: `out` is cleared and filled with the ascending
    /// `(distance, id)`-ordered result. Callers issuing many bounded
    /// queries (the `dist::knn` refinement loops, the facade's pooled
    /// k-NN batches) hold one [`QueryScratch`] per worker and pay no
    /// per-query allocation once the buffers are warm.
    pub fn knn_within_with<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        k: usize,
        cap: f64,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        out.clear();
        if self.is_empty() || k == 0 || !(cap >= 0.0) {
            return;
        }
        let flat = self.flat();
        let QueryScratch { best, frontier, .. } = scratch;
        best.clear();
        frontier.clear();
        let root = flat.root();
        let d = metric.dist(query, self.points().point(flat.point(root) as usize));
        let rb = fmax(d - flat.radius(root), 0.0);
        if rb <= cap {
            frontier.push(Frontier { bound: rb, node: root, dist: d });
        }

        while let Some(Frontier { bound, node, dist }) = frontier.pop() {
            // Prune: k candidates *strictly* better than this bound exist.
            // On a tie (bound == current k-th distance) the subtree may
            // still hold an equal-distance point with a smaller id, which
            // outranks the current k-th under (distance, id) — keep going.
            if best.len() == k {
                if let Some(top) = best.peek() {
                    if bound > top.dist {
                        break; // frontier is bound-ordered — nothing better left
                    }
                }
            }
            if flat.is_leaf(node) {
                if dist <= cap {
                    let gid = self.global_id(flat.point(node) as usize);
                    push_cand(best, k, Cand { dist, gid });
                }
                continue;
            }
            let un_point = flat.point(node);
            for c in flat.children(node) {
                let cp = flat.point(c);
                // Nesting reuse: same point as parent ⇒ same distance.
                let dc = if cp == un_point {
                    dist
                } else {
                    metric.dist(query, self.points().point(cp as usize))
                };
                let cb = fmax(dc - flat.radius(c), 0.0);
                if cb > cap {
                    continue;
                }
                let admit = best.len() < k || matches!(best.peek(), Some(top) if cb <= top.dist);
                if admit {
                    frontier.push(Frontier { bound: cb, node: c, dist: dc });
                }
            }
        }
        // Drain the max-heap (descending pops) and reverse: ascending by
        // our Ord — the same sequence `into_sorted_vec` produced, without
        // consuming the heap's buffer.
        while let Some(c) = best.pop() {
            out.push((c.gid, c.dist));
        }
        out.reverse();
        out.truncate(k);
    }
}

/// k-bounded heap admission under the `(distance, id)` total order —
/// shared with the tombstone-aware epoch traversals ([`super::epoch`]).
pub(crate) fn push_cand(best: &mut BinaryHeap<Cand>, k: usize, c: Cand) {
    if best.len() < k {
        best.push(c);
    } else if let Some(top) = best.peek() {
        // Replace the current worst iff c outranks it under (distance, id).
        if c.cmp(top) == Ordering::Less {
            best.pop();
            best.push(c);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Counted, Euclidean, Hamming, Metric};
    use crate::points::{DenseMatrix, PointSet};
    use crate::util::Rng;

    fn brute_knn<P: PointSet, M: Metric<P>>(
        pts: &P,
        metric: &M,
        q: P::Point<'_>,
        k: usize,
    ) -> Vec<(u32, f64)> {
        let mut all: Vec<(u32, f64)> =
            (0..pts.len()).map(|i| (i as u32, metric.dist(q, pts.point(i)))).collect();
        all.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    fn assert_knn_equal(got: &[(u32, f64)], want: &[(u32, f64)]) {
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want) {
            // Distances must match exactly; ids may differ only on exact ties.
            assert_eq!(g.1, w.1, "distance mismatch: {g:?} vs {w:?}");
        }
    }

    #[test]
    fn knn_matches_brute_force() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(150), 300, 5, 4, 0.2);
        let queries = crate::data::synthetic::uniform(&mut Rng::new(151), 15, 5, 1.0);
        for leaf in [1usize, 8] {
            let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: leaf, root: 0 });
            for k in [1usize, 5, 17] {
                for qi in 0..queries.len() {
                    let got = tree.knn(&Euclidean, queries.row(qi), k);
                    let want = brute_knn(&pts, &Euclidean, queries.row(qi), k);
                    assert_knn_equal(&got, &want);
                }
            }
        }
    }

    #[test]
    fn knn_hamming() {
        let codes = crate::data::synthetic::hamming_clusters(&mut Rng::new(152), 200, 64, 4, 0.1);
        let tree = CoverTree::build(&codes, &Hamming, &BuildParams::default());
        for qi in [0usize, 50, 199] {
            let got = tree.knn(&Hamming, codes.code(qi), 8);
            let want = brute_knn(&codes, &Hamming, codes.code(qi), 8);
            assert_knn_equal(&got, &want);
            assert_eq!(got[0].1, 0.0, "self must be the nearest");
        }
    }

    #[test]
    fn knn_edge_cases() {
        let pts = DenseMatrix::from_flat(1, vec![0.0, 1.0, 2.0]);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        assert!(tree.knn(&Euclidean, &[0.5], 0).is_empty());
        // k larger than the tree: everything returned, sorted.
        let all = tree.knn(&Euclidean, &[0.9], 10);
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].0, 1);
        // empty tree
        let empty = CoverTree::build(&DenseMatrix::new(1), &Euclidean, &BuildParams::default());
        assert!(empty.knn(&Euclidean, &[0.0], 3).is_empty());
    }

    #[test]
    fn knn_with_duplicates_returns_each_id() {
        let mut pts = DenseMatrix::new(1);
        pts.push(&[5.0]);
        pts.push(&[5.0]);
        pts.push(&[9.0]);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let got = tree.knn(&Euclidean, &[5.0], 2);
        let ids: Vec<u32> = got.iter().map(|&(g, _)| g).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    fn brute_knn_within<P: PointSet, M: Metric<P>>(
        pts: &P,
        metric: &M,
        q: P::Point<'_>,
        k: usize,
        cap: f64,
    ) -> Vec<(u32, f64)> {
        let mut all: Vec<(u32, f64)> = (0..pts.len())
            .map(|i| (i as u32, metric.dist(q, pts.point(i))))
            .filter(|&(_, d)| d <= cap)
            .collect();
        all.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    #[test]
    fn knn_within_matches_filtered_brute_force() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(154), 250, 4, 4, 0.2);
        let queries = crate::data::synthetic::uniform(&mut Rng::new(155), 12, 4, 1.0);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        for k in [1usize, 6, 20] {
            for cap in [0.0f64, 0.1, 0.4, 2.0, f64::INFINITY] {
                for qi in 0..queries.len() {
                    let got = tree.knn_within(&Euclidean, queries.row(qi), k, cap);
                    let want = brute_knn_within(&pts, &Euclidean, queries.row(qi), k, cap);
                    // Ids AND distance bits: the bounded query is tie-exact.
                    assert_eq!(got, want, "k={k} cap={cap} qi={qi}");
                }
            }
        }
    }

    #[test]
    fn knn_scratch_reuse_matches_fresh_calls() {
        // One scratch across many bounded queries must reproduce the
        // one-shot wrapper bit for bit — the refinement-loop contract.
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(157), 300, 4, 5, 0.15);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let mut scratch = QueryScratch::new();
        let mut row = Vec::new();
        for qi in 0..40 {
            for (k, cap) in [(1usize, f64::INFINITY), (5, 0.3), (9, 0.0), (3, 1.5)] {
                tree.knn_within_with(&Euclidean, pts.row(qi), k, cap, &mut scratch, &mut row);
                let fresh = tree.knn_within(&Euclidean, pts.row(qi), k, cap);
                assert_eq!(row, fresh, "qi={qi} k={k} cap={cap}");
            }
        }
    }

    #[test]
    fn knn_within_tie_exact_on_duplicates() {
        // Many co-located points: the (distance, id) order must pick the
        // smallest ids, and a cap equal to the tie distance must include
        // the tied points.
        let mut pts = DenseMatrix::new(1);
        for _ in 0..6 {
            pts.push(&[2.0]);
        }
        pts.push(&[5.0]);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 2, root: 0 });
        let got = tree.knn_within(&Euclidean, &[1.0], 4, 1.0);
        assert_eq!(
            got,
            vec![(0, 1.0), (1, 1.0), (2, 1.0), (3, 1.0)],
            "smallest ids win exact ties at the cap boundary"
        );
        // Degenerate caps.
        assert!(tree.knn_within(&Euclidean, &[1.0], 4, f64::NAN).is_empty());
        assert!(tree.knn_within(&Euclidean, &[1.0], 4, -1.0).is_empty());
        assert!(tree.knn_within(&Euclidean, &[2.0], 0, 1.0).is_empty());
    }

    #[test]
    fn knn_within_small_cap_prunes_work() {
        let pts =
            crate::data::synthetic::gaussian_mixture(&mut Rng::new(156), 3000, 6, 15, 0.02);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 8, root: 0 });
        let wide = Counted::new(Euclidean);
        tree.knn_within(&wide, pts.row(0), 10, f64::INFINITY);
        let narrow = Counted::new(Euclidean);
        tree.knn_within(&narrow, pts.row(0), 10, 0.05);
        assert!(
            narrow.count() <= wide.count(),
            "bounded query did more work: {} > {}",
            narrow.count(),
            wide.count()
        );
    }

    #[test]
    fn knn_prunes_versus_linear_scan() {
        let pts =
            crate::data::synthetic::gaussian_mixture(&mut Rng::new(153), 3000, 6, 15, 0.02);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 8, root: 0 });
        let counted = Counted::new(Euclidean);
        let got = tree.knn(&counted, pts.row(0), 10);
        assert_eq!(got.len(), 10);
        assert!(
            counted.count() < 3000 / 2,
            "knn used {} distance calls on clustered n=3000",
            counted.count()
        );
    }

    #[test]
    fn nan_metric_knn_does_not_panic() {
        // A metric returning NaN must degrade cleanly (possibly odd
        // results, never a panic) — the total_cmp heap ordering gate.
        #[derive(Clone)]
        struct SometimesNan;
        impl Metric<DenseMatrix> for SometimesNan {
            fn dist(&self, a: &[f32], b: &[f32]) -> f64 {
                let d = Euclidean.dist(a, b);
                if (1.0..2.0).contains(&d) {
                    f64::NAN
                } else {
                    d
                }
            }
            fn name(&self) -> &'static str {
                "sometimes-nan"
            }
        }
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(158), 120, 3, 3, 0.4);
        let tree = CoverTree::build(&pts, &SometimesNan, &BuildParams { leaf_size: 4, root: 0 });
        for qi in 0..10 {
            let got = tree.knn(&SometimesNan, pts.row(qi), 5);
            assert!(got.len() <= 5);
        }
    }
}
