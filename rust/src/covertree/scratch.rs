//! Reusable query scratch state — the allocation story of the hot path.
//!
//! Every traversal in this crate needs the same few growable buffers: a
//! DFS stack, the batched query's active-query arena, and the k-NN
//! branch-and-bound heaps. Allocating them per call is invisible on a
//! single query and ruinous on the distributed inner loops, which issue
//! millions of bounded queries per rank. [`QueryScratch`] owns all of
//! them; callers that hold one across calls (one per pool worker, one per
//! incoming bundle on a rank) perform **zero steady-state heap
//! allocations** per query — every buffer is `clear()`ed, never dropped,
//! so capacity warms up once and stays. `examples/perf_driver.rs` gates
//! this with a counting global allocator.
//!
//! Constructing a [`QueryScratch`] is itself allocation-free (`Vec::new`
//! and `BinaryHeap::new` defer their first allocation), so one-shot
//! convenience wrappers can create a throwaway scratch without paying
//! anything the old code didn't already pay.
//!
//! The heap entry types live here (not in `knn.rs`) because the scratch
//! owns the heaps. Both order by [`f64::total_cmp`]: a NaN distance from
//! a broken user metric sorts after every real distance instead of
//! panicking inside `BinaryHeap` the way `partial_cmp(..).unwrap()` did —
//! and on the non-NaN distances every in-crate metric produces, the total
//! order coincides with the documented `(distance, id)` policy bit for
//! bit.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Max-heap entry of the current k-best candidates, ordered by
/// `(distance, gid)` under [`f64::total_cmp`].
#[derive(Debug, PartialEq)]
pub(crate) struct Cand {
    pub(crate) dist: f64,
    pub(crate) gid: u32,
}

impl Eq for Cand {}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by distance; ties by gid for determinism. `total_cmp`
        // never panics — NaN sorts last, see the module docs.
        self.dist.total_cmp(&other.dist).then(self.gid.cmp(&other.gid))
    }
}

/// Min-heap frontier entry (lower bound, node, exact distance to point).
#[derive(Debug, PartialEq)]
pub(crate) struct Frontier {
    pub(crate) bound: f64,
    pub(crate) node: u32,
    pub(crate) dist: f64,
}

impl Eq for Frontier {}

impl PartialOrd for Frontier {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Frontier {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for a min-heap on the bound; NaN-total like `Cand`.
        other.bound.total_cmp(&self.bound).then(other.node.cmp(&self.node))
    }
}

/// Reusable traversal state for every cover-tree query shape.
///
/// One scratch serves one thread at a time; the pooled batch paths keep
/// one per worker ([`crate::util::Pool::run_indexed_with`]) and the
/// distributed refinement loops keep one per rank, reused across incoming
/// bundles. All fields retain their capacity across calls.
#[derive(Debug, Default)]
pub struct QueryScratch {
    /// Single-query DFS stack: `(node, distance to the node's point)`.
    pub(crate) stack: Vec<(u32, f64)>,
    /// Batched-query DFS stack: `(node, arena start, arena len)`.
    pub(crate) range_stack: Vec<(u32, u32, u32)>,
    /// Batched-query emit arena: `(query index, carried distance)` ranges
    /// addressed by `range_stack`, reclaimed LIFO.
    pub(crate) arena: Vec<(u32, f64)>,
    /// Plain node stack for traversals that carry no distance (the
    /// insertion-tree query).
    pub(crate) nodes: Vec<u32>,
    /// k-NN current-best max-heap.
    pub(crate) best: BinaryHeap<Cand>,
    /// k-NN frontier min-heap.
    pub(crate) frontier: BinaryHeap<Frontier>,
    /// SoA gather tile + DP rows for the batched K-lane leaf kernels
    /// ([`crate::metric::kernel`]); lazily grown like every other field.
    pub(crate) tile: crate::metric::SoaTile,
    /// Dual-tree node-pair stack (`eps_self_join_dual_with`).
    pub(crate) pairs: Vec<(u32, u32)>,
}

impl QueryScratch {
    /// A fresh scratch. Allocation-free until first use.
    pub fn new() -> Self {
        Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cand_orders_by_distance_then_id() {
        let mut heap = BinaryHeap::new();
        heap.push(Cand { dist: 1.0, gid: 5 });
        heap.push(Cand { dist: 1.0, gid: 2 });
        heap.push(Cand { dist: 0.5, gid: 9 });
        // Max-heap: the largest (distance, id) pops first.
        assert_eq!(heap.pop(), Some(Cand { dist: 1.0, gid: 5 }));
        assert_eq!(heap.pop(), Some(Cand { dist: 1.0, gid: 2 }));
        assert_eq!(heap.pop(), Some(Cand { dist: 0.5, gid: 9 }));
    }

    #[test]
    fn nan_candidates_sort_last_without_panicking() {
        let mut heap = BinaryHeap::new();
        heap.push(Cand { dist: f64::NAN, gid: 0 });
        heap.push(Cand { dist: 2.0, gid: 1 });
        heap.push(Cand { dist: f64::INFINITY, gid: 2 });
        // NaN > +inf > finite under total_cmp.
        let first = heap.pop().expect("nonempty");
        assert!(first.dist.is_nan());
        assert_eq!(heap.pop().map(|c| c.gid), Some(2));
        assert_eq!(heap.pop().map(|c| c.gid), Some(1));
    }

    #[test]
    fn frontier_is_min_heap_on_bound() {
        let mut heap = BinaryHeap::new();
        heap.push(Frontier { bound: 3.0, node: 1, dist: 3.0 });
        heap.push(Frontier { bound: 0.5, node: 2, dist: 1.0 });
        heap.push(Frontier { bound: 0.5, node: 0, dist: 1.0 });
        assert_eq!(heap.pop().map(|f| f.node), Some(0)); // tie: smaller node
        assert_eq!(heap.pop().map(|f| f.node), Some(2));
        assert_eq!(heap.pop().map(|f| f.node), Some(1));
    }

    #[test]
    fn scratch_construction_is_lazy() {
        let s = QueryScratch::new();
        assert_eq!(s.stack.capacity(), 0);
        assert_eq!(s.arena.capacity(), 0);
        assert_eq!(s.range_stack.capacity(), 0);
        assert_eq!(s.nodes.capacity(), 0);
        assert_eq!(s.pairs.capacity(), 0);
    }
}
