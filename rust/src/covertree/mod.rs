//! Shared-memory cover tree with batch construction and batch fixed-radius
//! queries — Algorithms 1–3 of the paper.
//!
//! The tree is built top-down by repeatedly *splitting* vertex triples
//! `(H, π₁, r)` — a point subset `H`, its root point `π₁`, and the radius
//! `r = max_{q∈H} d(π₁, q)` — into child triples whose centers form an
//! `r/2`-net of `H` (covering + separating invariants of Algorithm 1).
//! Splitting proceeds level by level (Algorithm 2) until every triple is
//! smaller than the leaf-size parameter `ζ`, at which point its points are
//! attached as leaf vertices. Duplicate points (distance 0 from their
//! center) collapse into sibling leaves of a common parent, which keeps the
//! metric axiom (ii) escape hatch the paper describes.
//!
//! Queries (Algorithm 3) walk the tree with the triple radii as the pruning
//! bound (`d(q, v) ≤ radius(v) + ε` ⇒ descend), which is tighter than the
//! textbook `2^level` bound. Batch queries amortize traversal state across
//! a whole query set.
//!
//! Construction and the batched queries both have hub-/shard-parallel
//! variants on the in-crate task pool ([`crate::util::Pool`]):
//! [`CoverTree::build_par`], [`CoverTree::query_batch_par`] and
//! [`CoverTree::eps_self_join_par`]. All are *exact* — the parallel build
//! is bit-identical to the sequential one at every pool size, and the
//! parallel queries emit the same result multiset (DESIGN.md §7.1).

mod build;
mod dualtree;
mod epoch;
mod incremental;
mod invariants;
mod knn;
mod layout;
mod query;
mod scratch;
mod snapshot;

pub use build::BuildParams;
pub use epoch::{EpochParams, EpochTree};
pub use incremental::InsertCoverTree;
pub use invariants::check_invariants;
pub use layout::FlatTree;
pub use scratch::QueryScratch;
pub use snapshot::{peek_point_tag, point_tag, SnapshotError, SNAPSHOT_MAGIC};
pub(crate) use snapshot::fnv1a64;

use crate::metric::Metric;
use crate::points::PointSet;

/// Sentinel for "no node".
pub(crate) const NIL: u32 = u32::MAX;

/// A vertex of the cover tree.
#[derive(Clone, Copy, Debug)]
pub struct Node {
    /// Index of the associated point in the *owned* point set.
    pub point: u32,
    /// Upper bound on the distance from `point` to any descendant leaf
    /// (the vertex-triple radius; 0 for leaves).
    pub radius: f64,
    /// Tree level (root highest; each split decrements by one).
    pub level: i32,
    /// Offset into the child-index arena.
    pub(crate) child_off: u32,
    /// Number of children (0 ⇒ leaf).
    pub(crate) child_len: u32,
}

impl Node {
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.child_len == 0
    }
}

/// A cover tree over an owned point set.
///
/// The tree owns a copy of its points (`P: PointSet`), mirroring the
/// distributed setting where each rank builds trees over points it received
/// from other ranks. `ids` maps local point indices back to global vertex
/// ids so query results can be reported in graph coordinates.
#[derive(Clone, Debug)]
pub struct CoverTree<P: PointSet> {
    points: P,
    /// Global vertex id of each local point (identity when built standalone).
    ids: Vec<u32>,
    nodes: Vec<Node>,
    children: Vec<u32>,
    root: u32,
    /// Level-ordered SoA renumber of `(nodes, children, root)` — the hot
    /// query paths traverse this, not the build-order arena above. Derived
    /// deterministically at the end of every build ([`FlatTree`]).
    ///
    /// The legacy arena is deliberately kept alongside (≈2× topology
    /// memory): the invariant checker and the `*_legacy` comparators
    /// still walk it (the dual-tree join moved to the flat layout). If
    /// that cost ever matters at scale, gate the arena behind a feature
    /// and port those two consumers to the flat layout.
    flat: layout::FlatTree,
}

impl<P: PointSet> CoverTree<P> {
    /// Build over `points` with global ids `0..n`.
    pub fn build<M: Metric<P>>(points: &P, metric: &M, params: &BuildParams) -> Self {
        let ids = (0..points.len() as u32).collect();
        Self::build_with_ids(points.clone(), ids, metric, params)
    }

    /// Build over an owned point set whose `i`-th point has global id
    /// `ids[i]`.
    pub fn build_with_ids<M: Metric<P>>(
        points: P,
        ids: Vec<u32>,
        metric: &M,
        params: &BuildParams,
    ) -> Self {
        assert_eq!(points.len(), ids.len());
        build::build(points, ids, metric, params)
    }

    /// Hub-parallel [`CoverTree::build`] on `pool` — bit-identical output
    /// (same node array, children arena and numbering) at every pool size;
    /// a one-thread pool runs the sequential builder unchanged.
    pub fn build_par<M: Metric<P>>(
        points: &P,
        metric: &M,
        params: &BuildParams,
        pool: &crate::util::Pool,
    ) -> Self {
        let ids = (0..points.len() as u32).collect();
        Self::build_with_ids_par(points.clone(), ids, metric, params, pool)
    }

    /// Hub-parallel [`CoverTree::build_with_ids`] on `pool`.
    pub fn build_with_ids_par<M: Metric<P>>(
        points: P,
        ids: Vec<u32>,
        metric: &M,
        params: &BuildParams,
        pool: &crate::util::Pool,
    ) -> Self {
        assert_eq!(points.len(), ids.len());
        build::par_build(points, ids, metric, params, pool)
    }

    /// The owned point set.
    pub fn points(&self) -> &P {
        &self.points
    }

    /// Global id of local point `i`.
    #[inline]
    pub fn global_id(&self, i: usize) -> u32 {
        self.ids[i]
    }

    /// All global ids (parallel to `points()`).
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Number of points in the tree.
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    /// Number of tree vertices (internal + leaves).
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Root node index ([`NIL`] if the tree is empty).
    pub fn root(&self) -> u32 {
        self.root
    }

    pub fn is_empty(&self) -> bool {
        self.root == NIL
    }

    /// The level-ordered flat layout the hot query paths traverse.
    #[inline]
    pub fn flat(&self) -> &layout::FlatTree {
        &self.flat
    }

    /// Rebuild the flat layout from the legacy arena — the last step of
    /// every construction path.
    pub(crate) fn finish(mut self) -> Self {
        self.flat = layout::FlatTree::from_arena(&self.nodes, &self.children, self.root);
        self
    }

    /// The build-order node arena (legacy layout; tests and the
    /// invariant/ablation paths).
    #[cfg(test)]
    pub(crate) fn raw_nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// The build-order children arena.
    #[cfg(test)]
    pub(crate) fn raw_children(&self) -> &[u32] {
        &self.children
    }

    #[inline]
    pub(crate) fn node(&self, i: u32) -> &Node {
        &self.nodes[i as usize]
    }

    #[inline]
    pub(crate) fn node_children(&self, i: u32) -> &[u32] {
        let n = self.node(i);
        &self.children[n.child_off as usize..(n.child_off + n.child_len) as usize]
    }

    /// Iterate over all nodes (index, node).
    pub fn nodes(&self) -> impl Iterator<Item = (u32, &Node)> {
        self.nodes.iter().enumerate().map(|(i, n)| (i as u32, n))
    }

    /// Structural fingerprint for exact-equality checks (the determinism
    /// gate): `(root, nodes, children)` with each node flattened to
    /// `(point, radius_bits, level, child_off, child_len)`. Two trees with
    /// equal fingerprints (and equal `ids`/points) are interchangeable
    /// bit-for-bit.
    pub fn structure(&self) -> (u32, Vec<(u32, u64, i32, u32, u32)>, Vec<u32>) {
        let nodes = self
            .nodes
            .iter()
            .map(|n| (n.point, n.radius.to_bits(), n.level, n.child_off, n.child_len))
            .collect();
        (self.root, nodes, self.children.clone())
    }

    /// Depth of the tree (number of levels; 0 for empty).
    pub fn depth(&self) -> usize {
        if self.is_empty() {
            return 0;
        }
        let mut depth = 0usize;
        let mut stack = vec![(self.root, 1usize)];
        while let Some((u, d)) = stack.pop() {
            depth = depth.max(d);
            for &c in self.node_children(u) {
                stack.push((c, d + 1));
            }
        }
        depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::Euclidean;
    use crate::points::DenseMatrix;
    use crate::util::Rng;

    fn random_points(seed: u64, n: usize, d: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut m = DenseMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            m.push(&row);
        }
        m
    }

    #[test]
    fn empty_tree() {
        let pts = DenseMatrix::new(3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        assert!(t.is_empty());
        assert_eq!(t.num_nodes(), 0);
        assert_eq!(t.depth(), 0);
    }

    #[test]
    fn singleton_tree() {
        let pts = DenseMatrix::from_flat(2, vec![1.0, 2.0]);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        assert_eq!(t.num_points(), 1);
        assert!(!t.is_empty());
        let root = t.node(t.root());
        assert_eq!(root.radius, 0.0);
    }

    #[test]
    fn depth_reasonable_for_random_data() {
        let pts = random_points(31, 256, 4);
        let t =
            CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 1, ..Default::default() });
        // log-ish depth for low intrinsic dimension; generous bound.
        assert!(t.depth() <= 40, "depth {} too large", t.depth());
        assert!(t.num_nodes() >= 256);
    }
}
