//! Classic consecutive-insertion cover tree (Beygelzimer–Kakade–Langford
//! 2006) — the construction the paper's *batch* algorithm is designed to
//! avoid ("a batch construction algorithm that avoids making n
//! consecutive point insertions").
//!
//! Kept as a faithful comparator: the `ablation` bench builds both trees
//! on the same data and shows where batch construction wins. This
//! variant uses the textbook explicit `2^level` covers (children of a
//! level-`l` vertex lie within `2^l`; subtrees span at most `2^{l+1}`),
//! not the tighter triple radii of the batch tree, so its queries prune
//! less — one of the two effects the paper's design exploits (the other
//! being cache-friendly level-by-level partitioning).
//!
//! Since PR 9 the tree is also the crate's *mutable* structure
//! (DESIGN.md §13): points can be appended after build
//! ([`InsertCoverTree::insert_from`]) and removed by **tombstone**
//! ([`InsertCoverTree::delete`]) — a deleted point keeps its node, so the
//! covering invariants (and every other point's reachability) are
//! untouched, and the query paths simply skip tombstoned points at
//! emission. Reclaiming tombstones is the job of the epoch layer
//! ([`super::epoch`]), which rebuilds through the batch builder once the
//! dead fraction crosses a threshold.

use super::QueryScratch;
use crate::metric::Metric;
use crate::points::PointSet;

/// A node of the insertion-built tree.
#[derive(Clone, Debug)]
struct INode {
    point: u32,
    level: i32,
    children: Vec<u32>,
}

/// Cover tree built by consecutive single-point insertions, with
/// tombstone deletion (PR 9).
pub struct InsertCoverTree<P: PointSet> {
    points: P,
    nodes: Vec<INode>,
    root: Option<u32>,
    /// Tombstones, indexed by point id: a dead point keeps its node (the
    /// covering structure stays intact) but is skipped at query emission.
    dead: Vec<bool>,
    dead_count: usize,
}

impl<P: PointSet> InsertCoverTree<P> {
    /// Build by inserting `points` one at a time, in order.
    pub fn build<M: Metric<P>>(points: &P, metric: &M) -> Self {
        let mut t = InsertCoverTree {
            points: points.clone(),
            nodes: Vec::new(),
            root: None,
            dead: vec![false; points.len()],
            dead_count: 0,
        };
        for i in 0..points.len() {
            t.insert(metric, i as u32);
        }
        t
    }

    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Points that are not tombstoned.
    pub fn num_live(&self) -> usize {
        self.points.len() - self.dead_count
    }

    /// Tombstoned points (nodes still present in the covering structure).
    pub fn num_tombstones(&self) -> usize {
        self.dead_count
    }

    /// Whether point `id` exists and is not tombstoned.
    pub fn is_live(&self, id: u32) -> bool {
        (id as usize) < self.points.len() && !self.dead[id as usize]
    }

    /// The owned point set (insertion order; point index == id). Includes
    /// tombstoned points — liveness is [`InsertCoverTree::is_live`].
    pub fn points(&self) -> &P {
        &self.points
    }

    /// Append every point of `batch` (same shape) and insert each into
    /// the covering structure, in order. Returns the id range assigned —
    /// ids are insertion positions, continuing past the build-time set.
    pub fn insert_from<M: Metric<P>>(&mut self, metric: &M, batch: &P) -> std::ops::Range<u32> {
        let lo = self.points.len() as u32;
        self.points.extend_from(batch);
        self.dead.resize(self.points.len(), false);
        let hi = self.points.len() as u32;
        for i in lo..hi {
            self.insert(metric, i);
        }
        lo..hi
    }

    /// Tombstone point `id`: it stops being reported by queries but its
    /// node stays, so the covering invariants over the remaining points
    /// are untouched. Returns `false` if `id` is out of range or already
    /// tombstoned.
    pub fn delete(&mut self, id: u32) -> bool {
        match self.dead.get_mut(id as usize) {
            Some(d) if !*d => {
                *d = true;
                self.dead_count += 1;
                true
            }
            _ => false,
        }
    }

    fn push_node(&mut self, point: u32, level: i32) -> u32 {
        self.nodes.push(INode { point, level, children: Vec::new() });
        (self.nodes.len() - 1) as u32
    }

    /// Insert point `p` (index into the owned set).
    fn insert<M: Metric<P>>(&mut self, metric: &M, p: u32) {
        let Some(root) = self.root else {
            self.root = Some(self.push_node(p, 0));
            return;
        };
        let d_root = metric.dist_ij(&self.points, p as usize, self.nodes[root as usize].point as usize);
        if d_root == 0.0 {
            // Duplicate of the root point: attach directly beneath it.
            let lvl = self.nodes[root as usize].level - 1;
            let leaf = self.push_node(p, lvl);
            self.nodes[root as usize].children.push(leaf);
            return;
        }
        // Raise the root level until 2^level covers the new point.
        while pow2(self.nodes[root as usize].level) < d_root {
            let l = self.nodes[root as usize].level;
            self.nodes[root as usize].level = l + 1;
        }

        // Descend with candidate cover sets Q_i = {q : d(p, q) ≤ 2^i}.
        // Track the deepest level at which some candidate still covers p;
        // insert as a child there (textbook "any parent works").
        let mut level = self.nodes[root as usize].level;
        let mut cover: Vec<(u32, f64)> = vec![(root, d_root)];
        let mut parent: (u32, f64, i32) = (root, d_root, level); // last valid parent
        loop {
            // Children of the cover set at the next level down, including
            // the implicit self-children (the nodes themselves).
            let mut next: Vec<(u32, f64)> = Vec::new();
            let bound = pow2(level - 1);
            for &(q, dq) in &cover {
                if dq <= bound {
                    next.push((q, dq));
                }
                // Iterate the child list by index: the only mutation inside
                // the loop (the duplicate-attach push) returns immediately,
                // so the indices stay valid and no per-expansion clone of
                // the children Vec is needed (the PR 9 allocation fix —
                // the old `children.clone()` allocated on every cover-set
                // expansion of every insert).
                let child_count = self.nodes[q as usize].children.len();
                for ci in 0..child_count {
                    let c = self.nodes[q as usize].children[ci];
                    let cn = &self.nodes[c as usize];
                    if cn.level != level - 1 {
                        continue;
                    }
                    let dc = metric.dist_ij(&self.points, p as usize, cn.point as usize);
                    if dc == 0.0 {
                        // Duplicate point: attach beneath the twin.
                        let leaf = self.push_node(p, cn.level - 1);
                        self.nodes[c as usize].children.push(leaf);
                        return;
                    }
                    if dc <= bound {
                        next.push((c, dc));
                    }
                }
            }
            if next.is_empty() {
                break;
            }
            level -= 1;
            // The separation constraint needs d(p, parent) ≤ 2^{level};
            // every member of `next` qualifies. Prefer the closest.
            // (total_cmp: a NaN distance from a broken metric sorts last
            // instead of panicking mid-insert.)
            let &(best, bd) = next
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .expect("cover set nonempty");
            parent = (best, bd, level);
            cover = next;
        }
        // Attach p as a child of `parent` one level below it. The parent's
        // stored level may sit higher than the level we found it at (an
        // implicit self-chain); materialize at `found_level - 1`.
        let (q, _dq, found_level) = parent;
        let leaf = self.push_node(p, found_level - 1);
        self.nodes[q as usize].children.push(leaf);
    }

    /// Fixed-radius query (Algorithm 3 with the `2^{l+1}` subtree bound in
    /// place of the batch tree's measured triple radius), reporting
    /// `(point index, distance)` pairs.
    pub fn query_weighted<M: Metric<P>>(
        &self,
        metric: &M,
        q: P::Point<'_>,
        eps: f64,
        out: &mut Vec<(u32, f64)>,
    ) {
        let mut scratch = QueryScratch::new();
        self.query_weighted_with(metric, q, eps, &mut scratch, out);
    }

    /// [`InsertCoverTree::query_weighted`] with a caller-owned node stack
    /// (the comparator tree rides the same scratch-reuse scheme as the
    /// batch tree, so facade-level batching over it stays allocation-lean).
    pub fn query_weighted_with<M: Metric<P>>(
        &self,
        metric: &M,
        q: P::Point<'_>,
        eps: f64,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        let Some(root) = self.root else { return };
        let stack = &mut scratch.nodes;
        stack.clear();
        stack.push(root);
        while let Some(u) = stack.pop() {
            let n = &self.nodes[u as usize];
            let d = metric.dist(q, self.points.point(n.point as usize));
            if d <= eps && !self.dead[n.point as usize] {
                out.push((n.point, d));
            }
            // Descendants of a level-l node lie within 2^l + 2^{l-1} + …
            // < 2^{l+1} of it.
            if !n.children.is_empty() && d <= pow2(n.level + 1) + eps {
                stack.extend_from_slice(&n.children);
            }
        }
    }

    /// [`InsertCoverTree::query_weighted`] without the distances.
    pub fn query<M: Metric<P>>(&self, metric: &M, q: P::Point<'_>, eps: f64, out: &mut Vec<u32>) {
        let mut weighted = Vec::new();
        self.query_weighted(metric, q, eps, &mut weighted);
        out.extend(weighted.into_iter().map(|(i, _)| i));
    }

    /// Structural sanity: every point — tombstoned or not — appears
    /// exactly once; children obey the 2^level covering bound relative to
    /// their parent. Tombstones are emission-only state, so the covering
    /// checks run over the full structure.
    pub fn check_invariants<M: Metric<P>>(&self, metric: &M) {
        let Some(root) = self.root else {
            assert_eq!(self.points.len(), 0);
            return;
        };
        let mut seen = vec![false; self.points.len()];
        let mut stack = vec![root];
        while let Some(u) = stack.pop() {
            let n = &self.nodes[u as usize];
            assert!(!seen[n.point as usize], "point {} appears twice", n.point);
            seen[n.point as usize] = true;
            for &c in &n.children {
                let cn = &self.nodes[c as usize];
                assert!(cn.level < n.level, "child level must drop");
                let d = metric.dist_ij(&self.points, n.point as usize, cn.point as usize);
                assert!(
                    d <= pow2(cn.level + 1) + 1e-9,
                    "covering violated: child {} at distance {d} from parent (child level {})",
                    cn.point,
                    cn.level
                );
                stack.push(c);
            }
        }
        assert!(seen.into_iter().all(|s| s), "some point never inserted");
    }
}

#[inline]
fn pow2(l: i32) -> f64 {
    (l as f64).exp2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::{Counted, Euclidean, Hamming, Metric};
    use crate::points::{DenseMatrix, PointSet};
    use crate::util::Rng;

    fn brute<P: PointSet, M: Metric<P>>(pts: &P, metric: &M, q: P::Point<'_>, eps: f64) -> Vec<u32> {
        let mut out: Vec<u32> = (0..pts.len())
            .filter(|&i| metric.dist(q, pts.point(i)) <= eps)
            .map(|i| i as u32)
            .collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn insertion_tree_queries_match_brute_force() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(160), 200, 4, 4, 0.2);
        let t = InsertCoverTree::build(&pts, &Euclidean);
        t.check_invariants(&Euclidean);
        for eps in [0.05, 0.3, 1.0] {
            for qi in 0..15 {
                let mut got = Vec::new();
                t.query(&Euclidean, pts.row(qi), eps, &mut got);
                got.sort_unstable();
                assert_eq!(got, brute(&pts, &Euclidean, pts.row(qi), eps), "eps={eps} qi={qi}");
            }
        }
    }

    #[test]
    fn insertion_tree_handles_duplicates() {
        let mut rng = Rng::new(161);
        let base = crate::data::synthetic::uniform(&mut rng, 30, 2, 1.0);
        let pts = crate::data::synthetic::with_duplicates(&mut rng, &base, 25);
        let t = InsertCoverTree::build(&pts, &Euclidean);
        t.check_invariants(&Euclidean);
        let mut got = Vec::new();
        t.query(&Euclidean, pts.row(0), 0.0, &mut got);
        assert!(!got.is_empty());
    }

    #[test]
    fn insertion_tree_hamming() {
        let codes = crate::data::synthetic::hamming_clusters(&mut Rng::new(162), 120, 64, 3, 0.1);
        let t = InsertCoverTree::build(&codes, &Hamming);
        t.check_invariants(&Hamming);
        let mut got = Vec::new();
        t.query(&Hamming, codes.code(5), 12.0, &mut got);
        got.sort_unstable();
        assert_eq!(got, brute(&codes, &Hamming, codes.code(5), 12.0));
    }

    #[test]
    fn batch_tree_prunes_better_than_insertion_tree() {
        // The motivating comparison: the batch tree's measured triple
        // radii give tighter pruning than the insertion tree's 2^{l+1}
        // bound on the same query.
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(163), 1000, 5, 8, 0.05);
        let eps = 0.15;
        let ins = InsertCoverTree::build(&pts, &Euclidean);
        let batch = crate::covertree::CoverTree::build(
            &pts,
            &Euclidean,
            &crate::covertree::BuildParams::default(),
        );
        let ci = Counted::new(Euclidean);
        let cb = Counted::new(Euclidean);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for qi in 0..50 {
            ins.query(&ci, pts.row(qi), eps, &mut a);
            batch.query(&cb, pts.row(qi), eps, &mut b);
        }
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "result sets must agree");
        assert!(
            cb.count() < ci.count(),
            "batch tree ({}) should out-prune insertion tree ({})",
            cb.count(),
            ci.count()
        );
    }

    #[test]
    fn tombstone_delete_excludes_from_queries_but_keeps_structure() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(164), 150, 3, 3, 0.3);
        let mut t = InsertCoverTree::build(&pts, &Euclidean);
        // Tombstone every third point.
        let mut gone = Vec::new();
        for id in (0..pts.len() as u32).step_by(3) {
            assert!(t.delete(id));
            gone.push(id);
        }
        assert!(!t.delete(gone[0]), "double delete must report false");
        assert!(!t.delete(pts.len() as u32), "out-of-range delete must report false");
        assert_eq!(t.num_tombstones(), gone.len());
        assert_eq!(t.num_live(), pts.len() - gone.len());
        // Structure (including dead nodes) still satisfies the covering
        // invariants; queries report exactly the live brute-force set.
        t.check_invariants(&Euclidean);
        for eps in [0.1, 0.5, 2.0] {
            for qi in 0..10 {
                let mut got = Vec::new();
                t.query(&Euclidean, pts.row(qi), eps, &mut got);
                got.sort_unstable();
                let want: Vec<u32> = brute(&pts, &Euclidean, pts.row(qi), eps)
                    .into_iter()
                    .filter(|id| !gone.contains(id))
                    .collect();
                assert_eq!(got, want, "eps={eps} qi={qi}");
            }
        }
    }

    #[test]
    fn insert_from_appends_and_queries_match_brute_force() {
        let mut rng = Rng::new(165);
        let all = crate::data::synthetic::gaussian_mixture(&mut rng, 180, 4, 4, 0.25);
        let seed = all.slice(0, 100);
        let extra = all.slice(100, 180);
        let mut t = InsertCoverTree::build(&seed, &Euclidean);
        let assigned = t.insert_from(&Euclidean, &extra);
        assert_eq!(assigned, 100..180);
        assert_eq!(t.num_points(), 180);
        t.check_invariants(&Euclidean);
        // Ids continue past the seed set, so the tree over seed + extra
        // answers exactly like a build over the concatenation.
        for eps in [0.1, 0.4] {
            for qi in 0..12 {
                let mut got = Vec::new();
                t.query(&Euclidean, all.row(qi), eps, &mut got);
                got.sort_unstable();
                assert_eq!(got, brute(&all, &Euclidean, all.row(qi), eps), "eps={eps} qi={qi}");
            }
        }
        // Interleave: delete a few originals, insert their twins again.
        assert!(t.delete(5) && t.delete(6));
        let twins = all.slice(5, 7);
        let again = t.insert_from(&Euclidean, &twins);
        assert_eq!(again, 180..182);
        t.check_invariants(&Euclidean);
        let mut got = Vec::new();
        t.query(&Euclidean, all.row(5), 0.0, &mut got);
        assert!(got.contains(&180) && !got.contains(&5));
    }

    #[test]
    fn empty_and_single() {
        let empty = DenseMatrix::new(2);
        let t = InsertCoverTree::build(&empty, &Euclidean);
        t.check_invariants(&Euclidean);
        let mut out = Vec::new();
        t.query(&Euclidean, &[0.0, 0.0], 1.0, &mut out);
        assert!(out.is_empty());

        let one = DenseMatrix::from_flat(2, vec![3.0, 4.0]);
        let t1 = InsertCoverTree::build(&one, &Euclidean);
        t1.check_invariants(&Euclidean);
        t1.query(&Euclidean, &[3.0, 4.0], 0.1, &mut out);
        assert_eq!(out, vec![0]);
    }
}
