//! `NGI-IDX1` — the versioned, checksummed index snapshot format behind
//! the serve daemon's load-once entry point (DESIGN.md §10.4).
//!
//! A snapshot captures everything a built [`CoverTree`] owns: the point
//! set, the global-id map and the build-order node/children arena. The
//! level-ordered [`super::FlatTree`] the hot query paths traverse is *not*
//! stored — it is a pure permutation of the arena, so the loader derives
//! it with [`FlatTree::from_arena`] in O(n) with **zero metric
//! evaluations**, and a snapshot can never carry a flat layout that
//! disagrees with its arena.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    [8]  b"NGI-IDX1"
//! version  u64  1
//! checksum u64  FNV-1a 64 of the payload bytes
//! len      u64  payload byte count
//! payload:
//!   tag        u8   point container (1 dense, 2 hamming, 3 strings)
//!   root       u64  root node id (u32; 0xFFFF_FFFF ⇒ empty tree)
//!   points_len u64  + that many bytes of `PointSet::to_bytes`
//!   n          u64  + n × u32 global ids (n must equal the point count)
//!   n_nodes    u64  + n_nodes × (point u32, radius-bits u64, level i32,
//!                    child_off u32, child_len u32)   — 24 bytes each
//!   n_children u64  + n_children × u32
//! ```
//!
//! The decoder is length-checked end to end ([`WireError`] on truncation,
//! extension or any internal inconsistency) and *structurally* validated:
//! node point indices, child ranges and children entries are
//! bounds-checked, radii must be finite and non-negative, and the arena
//! must be exactly one tree (every node reachable from the root exactly
//! once — which also rules out cycles before `from_arena` walks it). The
//! checksum turns nearly every payload bit flip into a typed error;
//! `tests/wire_adversarial.rs` runs the full
//! [`crate::testkit::wire::check_wire_decoder`] battery over all three
//! point families.

use super::layout::FlatTree;
use super::{CoverTree, Node, NIL};
use crate::points::{
    le_i32, le_u32, le_u64, put_u64, try_get_u64, try_get_u8, try_take, DenseMatrix, HammingCodes,
    PointSet, StringSet, WireError,
};
use std::any::TypeId;

/// The 8-byte magic prefix of every snapshot.
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"NGI-IDX1";

const SNAPSHOT_VERSION: u64 = 1;

/// Per-node record width in the payload (see the module docs).
const NODE_BYTES: usize = 4 + 8 + 4 + 4 + 4;

/// Why a snapshot could not be *written* (reading fails with [`WireError`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SnapshotError {
    /// The tree's point container is not one of the three wire-tagged
    /// families (dense, hamming, strings).
    UnsupportedPointType { type_name: &'static str },
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::UnsupportedPointType { type_name } => {
                write!(f, "no NGI-IDX1 point tag for container type {type_name}")
            }
        }
    }
}

impl std::error::Error for SnapshotError {}

/// FNV-1a 64 — the snapshot checksum (std-only, byte-order independent).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The wire tag of point container `P`, or `None` for a container outside
/// the three built-in families.
pub fn point_tag<P: PointSet>() -> Option<u8> {
    let t = TypeId::of::<P>();
    if t == TypeId::of::<DenseMatrix>() {
        Some(1)
    } else if t == TypeId::of::<HammingCodes>() {
        Some(2)
    } else if t == TypeId::of::<StringSet>() {
        Some(3)
    } else {
        None
    }
}

/// Read the point tag of an encoded snapshot without decoding the payload —
/// how the CLI dispatches a snapshot file to the right monomorphization.
/// Verifies magic, version and the header lengths but not the checksum.
pub fn peek_point_tag(bytes: &[u8]) -> Result<u8, WireError> {
    let mut off = 0usize;
    let magic = try_take(bytes, &mut off, 8, "snapshot magic")?;
    if magic != SNAPSHOT_MAGIC {
        return Err(WireError::Corrupt { what: "bad snapshot magic (want NGI-IDX1)" });
    }
    let version = try_get_u64(bytes, &mut off, "snapshot version")?;
    if version != SNAPSHOT_VERSION {
        return Err(WireError::Corrupt { what: "unsupported snapshot version" });
    }
    let _checksum = try_get_u64(bytes, &mut off, "snapshot checksum")?;
    let len = try_get_u64(bytes, &mut off, "snapshot payload length")? as usize;
    let payload = try_take(bytes, &mut off, len, "snapshot payload")?;
    match payload.first() {
        Some(&tag) => Ok(tag),
        None => Err(WireError::Corrupt { what: "empty snapshot payload" }),
    }
}

impl<P: PointSet> CoverTree<P> {
    /// Encode the tree as an `NGI-IDX1` snapshot.
    ///
    /// Fails only when `P` is not one of the wire-tagged point families;
    /// every built tree of dense, Hamming or string points encodes.
    pub fn to_snapshot_bytes(&self) -> Result<Vec<u8>, SnapshotError> {
        let tag = point_tag::<P>().ok_or(SnapshotError::UnsupportedPointType {
            type_name: std::any::type_name::<P>(),
        })?;
        let points = self.points.to_bytes();
        let mut payload = Vec::with_capacity(
            1 + 8 + 8 + points.len() + 8 + self.ids.len() * 4 + 8
                + self.nodes.len() * NODE_BYTES
                + 8
                + self.children.len() * 4,
        );
        payload.push(tag);
        put_u64(&mut payload, self.root as u64);
        put_u64(&mut payload, points.len() as u64);
        payload.extend_from_slice(&points);
        put_u64(&mut payload, self.ids.len() as u64);
        for &id in &self.ids {
            payload.extend_from_slice(&id.to_le_bytes());
        }
        put_u64(&mut payload, self.nodes.len() as u64);
        for n in &self.nodes {
            payload.extend_from_slice(&n.point.to_le_bytes());
            payload.extend_from_slice(&n.radius.to_bits().to_le_bytes());
            payload.extend_from_slice(&n.level.to_le_bytes());
            payload.extend_from_slice(&n.child_off.to_le_bytes());
            payload.extend_from_slice(&n.child_len.to_le_bytes());
        }
        put_u64(&mut payload, self.children.len() as u64);
        for &c in &self.children {
            payload.extend_from_slice(&c.to_le_bytes());
        }

        let mut buf = Vec::with_capacity(32 + payload.len());
        buf.extend_from_slice(SNAPSHOT_MAGIC);
        put_u64(&mut buf, SNAPSHOT_VERSION);
        put_u64(&mut buf, fnv1a64(&payload));
        put_u64(&mut buf, payload.len() as u64);
        buf.extend_from_slice(&payload);
        Ok(buf)
    }

    /// Decode an `NGI-IDX1` snapshot back into a queryable tree.
    ///
    /// Length-checked and structurally validated (module docs); the flat
    /// traversal layout is re-derived from the decoded arena, so the
    /// loaded tree is query-for-query identical to the one that was saved.
    pub fn try_from_snapshot_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let magic = try_take(bytes, &mut off, 8, "snapshot magic")?;
        if magic != SNAPSHOT_MAGIC {
            return Err(WireError::Corrupt { what: "bad snapshot magic (want NGI-IDX1)" });
        }
        let version = try_get_u64(bytes, &mut off, "snapshot version")?;
        if version != SNAPSHOT_VERSION {
            return Err(WireError::Corrupt { what: "unsupported snapshot version" });
        }
        let checksum = try_get_u64(bytes, &mut off, "snapshot checksum")?;
        let len = try_get_u64(bytes, &mut off, "snapshot payload length")? as usize;
        let payload = try_take(bytes, &mut off, len, "snapshot payload")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after snapshot payload" });
        }
        if fnv1a64(payload) != checksum {
            return Err(WireError::Corrupt { what: "snapshot checksum mismatch" });
        }

        let mut off = 0usize;
        let tag = try_get_u8(payload, &mut off, "snapshot point tag")?;
        if point_tag::<P>() != Some(tag) {
            return Err(WireError::Corrupt { what: "snapshot point tag does not match container" });
        }
        let root64 = try_get_u64(payload, &mut off, "snapshot root")?;
        let points_len = try_get_u64(payload, &mut off, "snapshot points length")? as usize;
        let points = P::try_from_bytes(try_take(payload, &mut off, points_len, "snapshot points")?)?;
        let n = try_get_u64(payload, &mut off, "snapshot id count")? as usize;
        if n != points.len() {
            return Err(WireError::Corrupt { what: "snapshot id count != point count" });
        }
        let id_bytes = try_take(payload, &mut off, n.saturating_mul(4), "snapshot ids")?;
        let ids: Vec<u32> = id_bytes.chunks_exact(4).map(le_u32).collect();

        let n_nodes = try_get_u64(payload, &mut off, "snapshot node count")? as usize;
        let node_bytes =
            try_take(payload, &mut off, n_nodes.saturating_mul(NODE_BYTES), "snapshot nodes")?;
        let n_children = try_get_u64(payload, &mut off, "snapshot children count")? as usize;
        let child_bytes =
            try_take(payload, &mut off, n_children.saturating_mul(4), "snapshot children")?;
        if off != payload.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after snapshot children" });
        }

        let mut nodes = Vec::with_capacity(n_nodes);
        for rec in node_bytes.chunks_exact(NODE_BYTES) {
            let (point_b, rest) = rec.split_at(4);
            let (radius_b, rest) = rest.split_at(8);
            let (level_b, rest) = rest.split_at(4);
            let (child_off_b, child_len_b) = rest.split_at(4);
            let point = le_u32(point_b);
            let radius = f64::from_bits(le_u64(radius_b));
            let level = le_i32(level_b);
            let child_off = le_u32(child_off_b);
            let child_len = le_u32(child_len_b);
            if point as usize >= n {
                return Err(WireError::Corrupt { what: "snapshot node point out of range" });
            }
            if !radius.is_finite() || radius < 0.0 {
                return Err(WireError::Corrupt { what: "snapshot node radius not a distance" });
            }
            let end = (child_off as usize).saturating_add(child_len as usize);
            if end > n_children {
                return Err(WireError::Corrupt { what: "snapshot child range out of bounds" });
            }
            nodes.push(Node { point, radius, level, child_off, child_len });
        }
        let children: Vec<u32> = child_bytes.chunks_exact(4).map(le_u32).collect();
        for &c in &children {
            if c as usize >= n_nodes {
                return Err(WireError::Corrupt { what: "snapshot child id out of range" });
            }
        }

        // Root / emptiness consistency, then single-tree reachability: every
        // node visited exactly once from the root. This is what licenses the
        // `from_arena` walk below (a cycle or a shared child would otherwise
        // loop or silently drop nodes).
        let root = if root64 == NIL as u64 {
            if n_nodes != 0 || n != 0 || n_children != 0 {
                return Err(WireError::Corrupt { what: "snapshot empty root over non-empty tree" });
            }
            NIL
        } else {
            if root64 >= n_nodes as u64 {
                return Err(WireError::Corrupt { what: "snapshot root out of range" });
            }
            root64 as u32
        };
        if root != NIL {
            let mut seen = vec![false; n_nodes];
            let mut stack = vec![root];
            let mut visited = 0usize;
            while let Some(u) = stack.pop() {
                match seen.get_mut(u as usize) {
                    Some(s) if !*s => *s = true,
                    _ => return Err(WireError::Corrupt { what: "snapshot arena is not a tree" }),
                }
                visited += 1;
                // Child ranges were bounds-checked per node above; a range
                // that still fails to borrow drops its children and is then
                // caught by the visited-count check below.
                let (lo, len) = match nodes.get(u as usize) {
                    Some(nd) => (nd.child_off as usize, nd.child_len as usize),
                    None => {
                        return Err(WireError::Corrupt { what: "snapshot arena is not a tree" })
                    }
                };
                stack.extend_from_slice(children.get(lo..lo.saturating_add(len)).unwrap_or(&[]));
            }
            if visited != n_nodes {
                return Err(WireError::Corrupt { what: "snapshot has unreachable nodes" });
            }
        }

        let flat = FlatTree::from_arena(&nodes, &children, root);
        Ok(CoverTree { points, ids, nodes, children, root, flat })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Euclidean, Hamming, Levenshtein};
    use crate::testkit::scenario;
    use crate::util::Rng;

    fn dense_tree(n: usize) -> CoverTree<DenseMatrix> {
        let pts = scenario::dense_clusters(1234, n);
        CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 })
    }

    #[test]
    fn roundtrip_preserves_structure_and_answers() {
        let t = dense_tree(120);
        let bytes = t.to_snapshot_bytes().expect("dense encodes");
        let t2 = CoverTree::<DenseMatrix>::try_from_snapshot_bytes(&bytes).expect("decodes");
        assert_eq!(t.structure(), t2.structure());
        assert_eq!(t.ids(), t2.ids());
        assert_eq!(t.points(), t2.points());
        // Query-for-query identical through the re-derived flat layout.
        let q = t.points().row(3);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        t.query_weighted(&Euclidean, q, 0.6, &mut a);
        t2.query_weighted(&Euclidean, q, 0.6, &mut b);
        assert_eq!(a, b);
        assert_eq!(t.knn(&Euclidean, q, 7), t2.knn(&Euclidean, q, 7));
    }

    #[test]
    fn roundtrip_hamming_and_strings() {
        let codes = scenario::hamming_codes(77, 90);
        let t = CoverTree::build(&codes, &Hamming, &BuildParams { leaf_size: 4, root: 0 });
        let t2 = CoverTree::<HammingCodes>::try_from_snapshot_bytes(
            &t.to_snapshot_bytes().expect("hamming encodes"),
        )
        .expect("hamming decodes");
        assert_eq!(t.structure(), t2.structure());

        let mut rng = Rng::new(9);
        let reads = crate::data::synthetic::reads(&mut rng, 40, 12, 4, 0.1);
        let t = CoverTree::build(&reads, &Levenshtein, &BuildParams { leaf_size: 4, root: 0 });
        let t2 = CoverTree::<StringSet>::try_from_snapshot_bytes(
            &t.to_snapshot_bytes().expect("strings encode"),
        )
        .expect("strings decode");
        assert_eq!(t.structure(), t2.structure());
    }

    #[test]
    fn empty_and_singleton_roundtrip() {
        let empty = CoverTree::build(&DenseMatrix::new(3), &Euclidean, &BuildParams::default());
        let b = empty.to_snapshot_bytes().unwrap();
        let back = CoverTree::<DenseMatrix>::try_from_snapshot_bytes(&b).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.num_points(), 0);

        let one = CoverTree::build(
            &DenseMatrix::from_flat(2, vec![1.0, 2.0]),
            &Euclidean,
            &BuildParams::default(),
        );
        let back =
            CoverTree::<DenseMatrix>::try_from_snapshot_bytes(&one.to_snapshot_bytes().unwrap())
                .unwrap();
        assert_eq!(back.num_points(), 1);
        assert_eq!(back.structure(), one.structure());
    }

    #[test]
    fn wrong_container_tag_is_typed() {
        let t = dense_tree(30);
        let bytes = t.to_snapshot_bytes().unwrap();
        assert_eq!(peek_point_tag(&bytes), Ok(1));
        let err = CoverTree::<HammingCodes>::try_from_snapshot_bytes(&bytes).unwrap_err();
        assert!(matches!(err, WireError::Corrupt { .. }), "got {err:?}");
    }

    #[test]
    fn checksum_catches_payload_corruption() {
        let t = dense_tree(40);
        let mut bytes = t.to_snapshot_bytes().unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x10;
        let err = CoverTree::<DenseMatrix>::try_from_snapshot_bytes(&bytes).unwrap_err();
        assert_eq!(err, WireError::Corrupt { what: "snapshot checksum mismatch" });
    }

    #[test]
    fn cyclic_or_shared_arena_is_rejected_not_looped() {
        // Hand-build a payload whose "tree" has a node that is its own
        // child; the reachability check must reject it (a naive from_arena
        // walk would spin forever).
        let pts = DenseMatrix::from_flat(1, vec![0.0]);
        let points = pts.to_bytes();
        let mut payload = vec![1u8];
        put_u64(&mut payload, 0); // root = node 0
        put_u64(&mut payload, points.len() as u64);
        payload.extend_from_slice(&points);
        put_u64(&mut payload, 1); // one id
        payload.extend_from_slice(&0u32.to_le_bytes());
        put_u64(&mut payload, 1); // one node: child range [0,1) -> itself
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&0f64.to_bits().to_le_bytes());
        payload.extend_from_slice(&0i32.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes());
        put_u64(&mut payload, 1); // children = [0]
        payload.extend_from_slice(&0u32.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(SNAPSHOT_MAGIC);
        put_u64(&mut bytes, SNAPSHOT_VERSION);
        put_u64(&mut bytes, fnv1a64(&payload));
        put_u64(&mut bytes, payload.len() as u64);
        bytes.extend_from_slice(&payload);
        let err = CoverTree::<DenseMatrix>::try_from_snapshot_bytes(&bytes).unwrap_err();
        assert_eq!(err, WireError::Corrupt { what: "snapshot arena is not a tree" });
    }
}
