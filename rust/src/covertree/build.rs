//! Batch construction — Algorithms 1 (SplitVertex) and 2 (BuildLevel).

use super::{CoverTree, Node, NIL};
use crate::metric::Metric;
use crate::points::PointSet;

/// Construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct BuildParams {
    /// Leaf-size threshold ζ: a vertex triple with at most this many points
    /// stops splitting and attaches its points as leaves.
    pub leaf_size: usize,
    /// Index of the point used as the tree root (the paper selects one
    /// arbitrarily; fixed to 0 by default for determinism).
    pub root: usize,
}

impl Default for BuildParams {
    fn default() -> Self {
        BuildParams { leaf_size: 8, root: 0 }
    }
}

/// A vertex triple `(H, π₁, r)` awaiting a split, together with its distance
/// array `D[p] = d(p, π₁)` and the index (within `members`) of the farthest
/// point `π₂` — exactly the state Algorithm 1 requires on entry.
struct Hub {
    /// Tree node already created for π₁ at the parent level.
    node: u32,
    /// Local point indices of H. `members[0]` is always π₁.
    members: Vec<u32>,
    /// `dist[k] = d(members[k], π₁)`.
    dist: Vec<f64>,
    /// Index into `members` of the farthest point (argmax of `dist`).
    farthest: usize,
    /// Radius `r = dist[farthest]`.
    radius: f64,
    level: i32,
}

pub(super) fn build<P: PointSet, M: Metric<P>>(
    points: P,
    ids: Vec<u32>,
    metric: &M,
    params: &BuildParams,
) -> CoverTree<P> {
    let n = points.len();
    let mut tree = CoverTree { points, ids, nodes: Vec::new(), children: Vec::new(), root: NIL };
    if n == 0 {
        return tree;
    }
    assert!(params.root < n, "root index out of range");
    assert!(params.leaf_size >= 1, "leaf size must be ≥ 1");

    // Root triple: H = all points, π₁ = params.root.
    let root_pt = params.root as u32;
    let mut members: Vec<u32> = Vec::with_capacity(n);
    members.push(root_pt);
    members.extend((0..n as u32).filter(|&i| i != root_pt));
    let mut dist = vec![0.0f64; n];
    let mut farthest = 0usize;
    let mut radius = 0.0f64;
    for k in 1..n {
        let d = metric.dist_ij(&tree.points, members[k] as usize, root_pt as usize);
        dist[k] = d;
        if d > radius {
            radius = d;
            farthest = k;
        }
    }
    // Root level from the radius so that 2^level ≥ radius.
    let level = if radius > 0.0 { radius.log2().ceil() as i32 } else { 0 };
    let root_node = push_node(&mut tree, root_pt, radius, level);
    tree.root = root_node;

    let mut queue = vec![Hub { node: root_node, members, dist, farthest, radius, level }];

    // Level-by-level expansion (Algorithm 2). A simple LIFO worklist gives
    // the same tree as strict level order because hubs are independent.
    while let Some(hub) = queue.pop() {
        if hub.members.len() <= params.leaf_size || hub.radius == 0.0 {
            attach_leaves(&mut tree, &hub);
            continue;
        }
        split_vertex(&mut tree, metric, params, hub, &mut queue);
    }
    tree
}

fn push_node<P: PointSet>(tree: &mut CoverTree<P>, point: u32, radius: f64, level: i32) -> u32 {
    tree.nodes.push(Node { point, radius, level, child_off: 0, child_len: 0 });
    (tree.nodes.len() - 1) as u32
}

/// Attach every member of `hub` as a leaf child of `hub.node`.
///
/// This handles both the ζ cutoff and the duplicate-point case
/// (`radius == 0` with several members ⇒ all coincide with π₁): every point
/// becomes a `B(p, 0)` leaf so queries report each graph vertex separately.
fn attach_leaves<P: PointSet>(tree: &mut CoverTree<P>, hub: &Hub) {
    let off = tree.children.len() as u32;
    let node_pt = tree.nodes[hub.node as usize].point;
    let mut len = 0u32;
    for &p in &hub.members {
        // If the hub is a singleton of its own root point, the existing
        // vertex *is* the leaf — don't create a duplicate child.
        if hub.members.len() == 1 && p == node_pt {
            tree.nodes[hub.node as usize].radius = 0.0;
            return;
        }
        let leaf = push_node(tree, p, 0.0, hub.level - 1);
        tree.children.push(leaf);
        len += 1;
    }
    let node = &mut tree.nodes[hub.node as usize];
    node.child_off = off;
    node.child_len = len;
}

/// Algorithm 1: split `hub` into child triples whose centers form an
/// `r/2`-net of its members, then enqueue the children.
fn split_vertex<P: PointSet, M: Metric<P>>(
    tree: &mut CoverTree<P>,
    metric: &M,
    _params: &BuildParams,
    hub: Hub,
    queue: &mut Vec<Hub>,
) {
    let Hub { node, members, mut dist, mut farthest, radius, level } = hub;
    let m = members.len();
    // Center list; labels[k] = index into `centers` of the closest center.
    let mut centers: Vec<u32> = vec![members[0]];
    let mut labels: Vec<u32> = vec![0; m];

    // Greedy farthest-point selection until the members are covered by
    // balls of radius r/2 (covering invariant). Each chosen center was at
    // distance > r/2 from all previous ones (separating invariant).
    let half = radius / 2.0;
    let mut r_star = radius;
    while r_star > half {
        let c = members[farthest];
        let ci = centers.len() as u32;
        centers.push(c);
        // Update D and L against the new center; track the next farthest.
        r_star = 0.0;
        let mut next_far = 0usize;
        for k in 0..m {
            let d_new = metric.dist_ij(&tree.points, members[k] as usize, c as usize);
            if d_new < dist[k] {
                dist[k] = d_new;
                labels[k] = ci;
            }
            if dist[k] > r_star {
                r_star = dist[k];
                next_far = k;
            }
        }
        farthest = next_far;
    }

    // Partition members by label into child triples, tracking each child's
    // radius and farthest point (the π₂ of the next split).
    let nc = centers.len();
    let mut child_members: Vec<Vec<u32>> = vec![Vec::new(); nc];
    let mut child_dist: Vec<Vec<f64>> = vec![Vec::new(); nc];
    let mut child_far: Vec<usize> = vec![0; nc];
    let mut child_rad: Vec<f64> = vec![0.0; nc];
    // Seed each child with its center (distance 0) so members[0] == π₁.
    for (ci, &c) in centers.iter().enumerate() {
        child_members[ci].push(c);
        child_dist[ci].push(0.0);
    }
    for k in 0..m {
        let ci = labels[k] as usize;
        let p = members[k];
        if p == centers[ci] {
            continue; // already seeded
        }
        child_members[ci].push(p);
        child_dist[ci].push(dist[k]);
        if dist[k] > child_rad[ci] {
            child_rad[ci] = dist[k];
            child_far[ci] = child_members[ci].len() - 1;
        }
    }

    // Create the child vertices (nesting: centers[0] == the hub's own point)
    // and enqueue their triples.
    let off = tree.children.len() as u32;
    // Reserve the contiguous child slots first.
    for _ in 0..nc {
        tree.children.push(NIL);
    }
    for ci in 0..nc {
        let child_node = push_node(tree, centers[ci], child_rad[ci], level - 1);
        tree.children[(off as usize) + ci] = child_node;
        queue.push(Hub {
            node: child_node,
            members: std::mem::take(&mut child_members[ci]),
            dist: std::mem::take(&mut child_dist[ci]),
            farthest: child_far[ci],
            radius: child_rad[ci],
            level: level - 1,
        });
    }
    let nref = &mut tree.nodes[node as usize];
    nref.child_off = off;
    nref.child_len = nc as u32;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::check_invariants;
    use crate::metric::{Counted, Euclidean, Hamming, Levenshtein};
    use crate::points::{DenseMatrix, HammingCodes, StringSet};
    use crate::util::Rng;

    fn random_dense(seed: u64, n: usize, d: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut m = DenseMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            m.push(&row);
        }
        m
    }

    #[test]
    fn invariants_hold_across_leaf_sizes() {
        let pts = random_dense(40, 200, 3);
        for leaf_size in [1usize, 2, 8, 32, 500] {
            let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
            check_invariants(&t, &Euclidean);
        }
    }

    #[test]
    fn invariants_hold_with_duplicates() {
        let mut pts = random_dense(41, 50, 3);
        // Duplicate some rows heavily.
        let dup = pts.row(7).to_vec();
        for _ in 0..20 {
            pts.push(&dup);
        }
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        check_invariants(&t, &Euclidean);
        assert_eq!(t.num_points(), 70);
    }

    #[test]
    fn all_identical_points() {
        let mut pts = DenseMatrix::new(2);
        for _ in 0..10 {
            pts.push(&[1.0, 1.0]);
        }
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        check_invariants(&t, &Euclidean);
        // One internal vertex with 10 duplicate leaves.
        assert_eq!(t.node(t.root()).radius, 0.0);
    }

    #[test]
    fn invariants_hold_hamming() {
        let mut rng = Rng::new(42);
        let mut codes = HammingCodes::new(64);
        for _ in 0..120 {
            codes.push_bits(&(0..64).map(|_| rng.bool(0.5)).collect::<Vec<_>>());
        }
        let t = CoverTree::build(&codes, &Hamming, &BuildParams { leaf_size: 4, root: 0 });
        check_invariants(&t, &Hamming);
    }

    #[test]
    fn invariants_hold_edit_distance() {
        let mut rng = Rng::new(43);
        let alphabet = b"ACGT";
        let strs: Vec<Vec<u8>> = (0..60)
            .map(|_| (0..10 + rng.below(15)).map(|_| alphabet[rng.below(4)]).collect())
            .collect();
        let set = StringSet::from_strs(&strs);
        let t = CoverTree::build(&set, &Levenshtein, &BuildParams { leaf_size: 2, root: 0 });
        check_invariants(&t, &Levenshtein);
    }

    #[test]
    fn build_distance_calls_subquadratic_on_clustered_data() {
        // On well-clustered data the batch build should need far fewer than
        // n² distance calls.
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(44), 1000, 8, 10, 0.05);
        let counted = Counted::new(Euclidean);
        let _t = CoverTree::build(&pts, &counted, &BuildParams { leaf_size: 8, root: 0 });
        let n = 1000u64;
        assert!(
            counted.count() < n * n / 4,
            "build used {} distance calls (n²={})",
            counted.count(),
            n * n
        );
    }

    #[test]
    fn custom_root_respected() {
        let pts = random_dense(45, 30, 2);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 1, root: 17 });
        assert_eq!(t.node(t.root()).point, 17);
        check_invariants(&t, &Euclidean);
    }

    #[test]
    fn ids_mapping_preserved() {
        let pts = random_dense(46, 20, 2);
        let ids: Vec<u32> = (100..120).collect();
        let t = CoverTree::build_with_ids(pts, ids, &Euclidean, &BuildParams::default());
        assert_eq!(t.global_id(0), 100);
        assert_eq!(t.global_id(19), 119);
    }
}
