//! Batch construction — Algorithms 1 (SplitVertex) and 2 (BuildLevel) —
//! sequential and hub-parallel.
//!
//! The hub worklist is embarrassingly parallel: hubs are independent by
//! construction, so [`par_build`] lets pool workers split hubs
//! concurrently, records each hub's outcome (leaf attach or split) keyed
//! by a globally unique hub id, and then *replays* the sequential LIFO
//! worklist over the recorded structure to assign node numbers. Because
//! the per-hub math is shared ([`compute_split`]) and the replay walks
//! hubs in exactly the order the sequential builder would, the parallel
//! tree is **bit-identical** to [`build`]'s at every pool size (enforced
//! by `tests/par_determinism.rs`).

use super::{CoverTree, FlatTree, Node, NIL};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::Pool;
use std::sync::atomic::{AtomicU64, Ordering};

/// Construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct BuildParams {
    /// Leaf-size threshold ζ: a vertex triple with at most this many points
    /// stops splitting and attaches its points as leaves.
    pub leaf_size: usize,
    /// Index of the point used as the tree root (the paper selects one
    /// arbitrarily; fixed to 0 by default for determinism).
    pub root: usize,
}

impl Default for BuildParams {
    fn default() -> Self {
        BuildParams { leaf_size: 8, root: 0 }
    }
}

/// A vertex triple `(H, π₁, r)` awaiting a split, together with its distance
/// array `D[p] = d(p, π₁)` and the index (within `members`) of the farthest
/// point `π₂` — exactly the state Algorithm 1 requires on entry.
struct Hub {
    /// Tree node already created for π₁ at the parent level.
    node: u32,
    /// Local point indices of H. `members[0]` is always π₁.
    members: Vec<u32>,
    /// `dist[k] = d(members[k], π₁)`.
    dist: Vec<f64>,
    /// Index into `members` of the farthest point (argmax of `dist`).
    farthest: usize,
    /// Radius `r = dist[farthest]`.
    radius: f64,
    level: i32,
}

pub(super) fn build<P: PointSet, M: Metric<P>>(
    points: P,
    ids: Vec<u32>,
    metric: &M,
    params: &BuildParams,
) -> CoverTree<P> {
    let n = points.len();
    let mut tree = CoverTree {
        points,
        ids,
        nodes: Vec::new(),
        children: Vec::new(),
        root: NIL,
        flat: FlatTree::default(),
    };
    if n == 0 {
        return tree;
    }
    assert!(params.root < n, "root index out of range");
    assert!(params.leaf_size >= 1, "leaf size must be ≥ 1");

    // Root triple: H = all points, π₁ = params.root.
    let root_pt = params.root as u32;
    let mut members: Vec<u32> = Vec::with_capacity(n);
    members.push(root_pt);
    members.extend((0..n as u32).filter(|&i| i != root_pt));
    let mut dist = vec![0.0f64; n];
    let mut farthest = 0usize;
    let mut radius = 0.0f64;
    for k in 1..n {
        let d = metric.dist_ij(&tree.points, members[k] as usize, root_pt as usize);
        dist[k] = d;
        if d > radius {
            radius = d;
            farthest = k;
        }
    }
    // Root level from the radius so that 2^level ≥ radius.
    let level = if radius > 0.0 { radius.log2().ceil() as i32 } else { 0 };
    let root_node = push_node(&mut tree, root_pt, radius, level);
    tree.root = root_node;

    let mut queue = vec![Hub { node: root_node, members, dist, farthest, radius, level }];

    // Level-by-level expansion (Algorithm 2). A simple LIFO worklist gives
    // the same tree as strict level order because hubs are independent.
    while let Some(hub) = queue.pop() {
        if hub.members.len() <= params.leaf_size || hub.radius == 0.0 {
            attach_leaves(&mut tree, &hub);
            continue;
        }
        split_vertex(&mut tree, metric, params, hub, &mut queue);
    }
    tree.finish()
}

fn push_node<P: PointSet>(tree: &mut CoverTree<P>, point: u32, radius: f64, level: i32) -> u32 {
    tree.nodes.push(Node { point, radius, level, child_off: 0, child_len: 0 });
    (tree.nodes.len() - 1) as u32
}

/// Attach every member of `hub` as a leaf child of `hub.node`.
///
/// This handles both the ζ cutoff and the duplicate-point case
/// (`radius == 0` with several members ⇒ all coincide with π₁): every point
/// becomes a `B(p, 0)` leaf so queries report each graph vertex separately.
fn attach_leaves<P: PointSet>(tree: &mut CoverTree<P>, hub: &Hub) {
    let off = tree.children.len() as u32;
    let node_pt = tree.nodes[hub.node as usize].point;
    let mut len = 0u32;
    for &p in &hub.members {
        // If the hub is a singleton of its own root point, the existing
        // vertex *is* the leaf — don't create a duplicate child.
        if hub.members.len() == 1 && p == node_pt {
            tree.nodes[hub.node as usize].radius = 0.0;
            return;
        }
        let leaf = push_node(tree, p, 0.0, hub.level - 1);
        tree.children.push(leaf);
        len += 1;
    }
    let node = &mut tree.nodes[hub.node as usize];
    node.child_off = off;
    node.child_len = len;
}

/// One child triple produced by [`compute_split`], in center order.
struct SplitChild {
    /// The child's center π₁ (a local point index).
    point: u32,
    radius: f64,
    /// Members with `members[0] == point`.
    members: Vec<u32>,
    /// `dist[k] = d(members[k], point)`.
    dist: Vec<f64>,
    /// argmax of `dist` (the π₂ of the next split).
    farthest: usize,
}

/// Algorithm 1 on one hub's triple: greedy farthest-point selection until
/// the members are covered by balls of radius r/2 (covering invariant;
/// each chosen center was at distance > r/2 from all previous ones, the
/// separating invariant), then partition the members by nearest center.
///
/// Pure with respect to the tree — shared verbatim by the sequential and
/// parallel builders so both perform the identical floating-point work.
fn compute_split<P: PointSet, M: Metric<P>>(
    points: &P,
    metric: &M,
    members: Vec<u32>,
    mut dist: Vec<f64>,
    mut farthest: usize,
    radius: f64,
) -> Vec<SplitChild> {
    let m = members.len();
    // Center list; labels[k] = index into `centers` of the closest center.
    let mut centers: Vec<u32> = vec![members[0]];
    let mut labels: Vec<u32> = vec![0; m];

    let half = radius / 2.0;
    let mut r_star = radius;
    while r_star > half {
        let c = members[farthest];
        let ci = centers.len() as u32;
        centers.push(c);
        // Update D and L against the new center; track the next farthest.
        r_star = 0.0;
        let mut next_far = 0usize;
        for k in 0..m {
            let d_new = metric.dist_ij(points, members[k] as usize, c as usize);
            if d_new < dist[k] {
                dist[k] = d_new;
                labels[k] = ci;
            }
            if dist[k] > r_star {
                r_star = dist[k];
                next_far = k;
            }
        }
        farthest = next_far;
    }

    // Partition members by label into child triples, tracking each child's
    // radius and farthest point.
    let nc = centers.len();
    let mut child_members: Vec<Vec<u32>> = vec![Vec::new(); nc];
    let mut child_dist: Vec<Vec<f64>> = vec![Vec::new(); nc];
    let mut child_far: Vec<usize> = vec![0; nc];
    let mut child_rad: Vec<f64> = vec![0.0; nc];
    // Seed each child with its center (distance 0) so members[0] == π₁.
    for (ci, &c) in centers.iter().enumerate() {
        child_members[ci].push(c);
        child_dist[ci].push(0.0);
    }
    for k in 0..m {
        let ci = labels[k] as usize;
        let p = members[k];
        if p == centers[ci] {
            continue; // already seeded
        }
        child_members[ci].push(p);
        child_dist[ci].push(dist[k]);
        if dist[k] > child_rad[ci] {
            child_rad[ci] = dist[k];
            child_far[ci] = child_members[ci].len() - 1;
        }
    }
    (0..nc)
        .map(|ci| SplitChild {
            point: centers[ci],
            radius: child_rad[ci],
            members: std::mem::take(&mut child_members[ci]),
            dist: std::mem::take(&mut child_dist[ci]),
            farthest: child_far[ci],
        })
        .collect()
}

/// Algorithm 1: split `hub` into child triples whose centers form an
/// `r/2`-net of its members, then enqueue the children.
fn split_vertex<P: PointSet, M: Metric<P>>(
    tree: &mut CoverTree<P>,
    metric: &M,
    _params: &BuildParams,
    hub: Hub,
    queue: &mut Vec<Hub>,
) {
    let Hub { node, members, dist, farthest, radius, level } = hub;
    let kids = compute_split(&tree.points, metric, members, dist, farthest, radius);

    // Create the child vertices (nesting: centers[0] == the hub's own point)
    // and enqueue their triples.
    let nc = kids.len();
    let off = tree.children.len() as u32;
    // Reserve the contiguous child slots first.
    for _ in 0..nc {
        tree.children.push(NIL);
    }
    for (ci, kid) in kids.into_iter().enumerate() {
        let child_node = push_node(tree, kid.point, kid.radius, level - 1);
        tree.children[(off as usize) + ci] = child_node;
        queue.push(Hub {
            node: child_node,
            members: kid.members,
            dist: kid.dist,
            farthest: kid.farthest,
            radius: kid.radius,
            level: level - 1,
        });
    }
    let nref = &mut tree.nodes[node as usize];
    nref.child_off = off;
    nref.child_len = nc as u32;
}

// ----------------------------------------------------------------------
// hub-parallel build
// ----------------------------------------------------------------------

/// A hub awaiting a split on the shared worklist (always split-worthy:
/// leaf-case children are resolved inline by the splitting worker).
struct ParHub {
    /// Globally unique hub id (allocation order, *not* the final node
    /// number — the replay assigns those).
    id: u64,
    members: Vec<u32>,
    dist: Vec<f64>,
    farthest: usize,
    radius: f64,
}

/// A child vertex recorded at split time, in center (ci) order.
struct ChildDesc {
    id: u64,
    point: u32,
    radius: f64,
}

/// The recorded outcome of one hub.
enum DoneKind {
    /// ζ cutoff or zero radius: the members become leaf children
    /// (or, for a singleton of the hub's own point, no children at all).
    Leaves(Vec<u32>),
    /// Split into child triples.
    Split(Vec<ChildDesc>),
}

struct DoneHub {
    id: u64,
    kind: DoneKind,
}

/// Hub-parallel batch build on `pool`, bit-identical to [`build`].
///
/// Phase A expands hubs in arbitrary worker order, recording each hub's
/// outcome into per-worker arenas. Phase B replays the sequential LIFO
/// worklist over the recorded structure — processing a hub appends exactly
/// the nodes/children entries the sequential builder would at that point —
/// so node numbering and the children arena come out identical without any
/// further distance evaluations.
pub(super) fn par_build<P: PointSet, M: Metric<P>>(
    points: P,
    ids: Vec<u32>,
    metric: &M,
    params: &BuildParams,
    pool: &Pool,
) -> CoverTree<P> {
    let n = points.len();
    // The sequential path IS the spec; use it verbatim whenever there is
    // nothing to parallelize (one worker, or a root hub that attaches
    // leaves immediately). These checks precede the root triple so no
    // distance is ever evaluated twice — parallel and sequential builds
    // perform the identical number of metric calls (the perf driver
    // asserts this parity).
    if pool.threads() <= 1 || n == 0 || n <= params.leaf_size {
        return build(points, ids, metric, params);
    }
    assert!(params.root < n, "root index out of range");
    assert!(params.leaf_size >= 1, "leaf size must be ≥ 1");

    // Root triple — the same math as `build`.
    let root_pt = params.root as u32;
    let mut members: Vec<u32> = Vec::with_capacity(n);
    members.push(root_pt);
    members.extend((0..n as u32).filter(|&i| i != root_pt));
    let mut dist = vec![0.0f64; n];
    let mut farthest = 0usize;
    let mut radius = 0.0f64;
    for k in 1..n {
        let d = metric.dist_ij(&points, members[k] as usize, root_pt as usize);
        dist[k] = d;
        if d > radius {
            radius = d;
            farthest = k;
        }
    }
    let level = if radius > 0.0 { radius.log2().ceil() as i32 } else { 0 };

    if radius == 0.0 {
        // All points coincide with the root (n > leaf_size duplicates):
        // mirror `build`'s attach_leaves outcome directly instead of
        // delegating, which would recompute the n−1 root distances.
        let mut tree = CoverTree {
            points,
            ids,
            nodes: Vec::new(),
            children: Vec::new(),
            root: NIL,
            flat: FlatTree::default(),
        };
        let root_node = push_node(&mut tree, root_pt, radius, level);
        tree.root = root_node;
        // n ≥ 2 here, so this is the multi-leaf case of attach_leaves:
        // every member (the root's point included) becomes a B(p, 0) leaf.
        let off = tree.children.len() as u32;
        let mut len = 0u32;
        for p in members {
            let leaf = push_node(&mut tree, p, 0.0, level - 1);
            tree.children.push(leaf);
            len += 1;
        }
        let nref = &mut tree.nodes[root_node as usize];
        nref.child_off = off;
        nref.child_len = len;
        return tree.finish();
    }

    // Phase A: expand every hub, any order. Hub ids come from an atomic
    // allocator; id 0 is the root hub.
    let counter = AtomicU64::new(1);
    let leaf_size = params.leaf_size;
    let seed = vec![ParHub { id: 0, members, dist, farthest, radius }];
    let worker_out = {
        let (points, counter) = (&points, &counter);
        pool.run_worklist(
            seed,
            |_| Vec::new(),
            move |wl, out: &mut Vec<DoneHub>, hub: ParHub| {
                let kids =
                    compute_split(points, metric, hub.members, hub.dist, hub.farthest, hub.radius);
                let base = counter.fetch_add(kids.len() as u64, Ordering::Relaxed);
                let mut descs = Vec::with_capacity(kids.len());
                for (ci, kid) in kids.into_iter().enumerate() {
                    let id = base + ci as u64;
                    descs.push(ChildDesc { id, point: kid.point, radius: kid.radius });
                    if kid.members.len() <= leaf_size || kid.radius == 0.0 {
                        // Leaf-case children never touch the queue — record
                        // them here (identical outcome, less contention).
                        out.push(DoneHub { id, kind: DoneKind::Leaves(kid.members) });
                    } else {
                        wl.push(ParHub {
                            id,
                            members: kid.members,
                            dist: kid.dist,
                            farthest: kid.farthest,
                            radius: kid.radius,
                        });
                    }
                }
                out.push(DoneHub { id: hub.id, kind: DoneKind::Split(descs) });
            },
        )
    };

    // Index outcomes by hub id (ids are a contiguous 0..total block).
    let total = counter.load(Ordering::Relaxed) as usize;
    let mut done: Vec<Option<DoneKind>> = Vec::new();
    done.resize_with(total, || None);
    for out in worker_out {
        for h in out {
            done[h.id as usize] = Some(h.kind);
        }
    }

    // Phase B: replay the sequential worklist order to number the nodes.
    let mut tree = CoverTree {
        points,
        ids,
        nodes: Vec::new(),
        children: Vec::new(),
        root: NIL,
        flat: FlatTree::default(),
    };
    let root_node = push_node(&mut tree, root_pt, radius, level);
    tree.root = root_node;
    let mut stack: Vec<(u32, u64)> = vec![(root_node, 0)];
    while let Some((nid, hid)) = stack.pop() {
        let kind = done[hid as usize].take().expect("hub outcome missing");
        let lvl = tree.nodes[nid as usize].level;
        match kind {
            DoneKind::Leaves(members) => {
                // Mirror `attach_leaves`.
                let node_pt = tree.nodes[nid as usize].point;
                if members.len() == 1 && members[0] == node_pt {
                    tree.nodes[nid as usize].radius = 0.0;
                    continue;
                }
                let off = tree.children.len() as u32;
                let mut len = 0u32;
                for p in members {
                    let leaf = push_node(&mut tree, p, 0.0, lvl - 1);
                    tree.children.push(leaf);
                    len += 1;
                }
                let nref = &mut tree.nodes[nid as usize];
                nref.child_off = off;
                nref.child_len = len;
            }
            DoneKind::Split(descs) => {
                // Mirror `split_vertex`'s tree mutations.
                let off = tree.children.len() as u32;
                for _ in 0..descs.len() {
                    tree.children.push(NIL);
                }
                let mut kid_nodes = Vec::with_capacity(descs.len());
                for (ci, d) in descs.iter().enumerate() {
                    let child_node = push_node(&mut tree, d.point, d.radius, lvl - 1);
                    tree.children[(off as usize) + ci] = child_node;
                    kid_nodes.push(child_node);
                }
                {
                    let nref = &mut tree.nodes[nid as usize];
                    nref.child_off = off;
                    nref.child_len = descs.len() as u32;
                }
                // Push in ci order — popped in reverse, exactly like the
                // sequential LIFO queue.
                for (ci, d) in descs.iter().enumerate() {
                    stack.push((kid_nodes[ci], d.id));
                }
            }
        }
    }
    tree.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::check_invariants;
    use crate::metric::{Counted, Euclidean, Hamming, Levenshtein};
    use crate::points::{DenseMatrix, HammingCodes, StringSet};
    use crate::util::{Pool, Rng};

    fn random_dense(seed: u64, n: usize, d: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut m = DenseMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            m.push(&row);
        }
        m
    }

    #[test]
    fn invariants_hold_across_leaf_sizes() {
        let pts = random_dense(40, 200, 3);
        for leaf_size in [1usize, 2, 8, 32, 500] {
            let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
            check_invariants(&t, &Euclidean);
        }
    }

    #[test]
    fn invariants_hold_with_duplicates() {
        let mut pts = random_dense(41, 50, 3);
        // Duplicate some rows heavily.
        let dup = pts.row(7).to_vec();
        for _ in 0..20 {
            pts.push(&dup);
        }
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        check_invariants(&t, &Euclidean);
        assert_eq!(t.num_points(), 70);
    }

    #[test]
    fn all_identical_points() {
        let mut pts = DenseMatrix::new(2);
        for _ in 0..10 {
            pts.push(&[1.0, 1.0]);
        }
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        check_invariants(&t, &Euclidean);
        // One internal vertex with 10 duplicate leaves.
        assert_eq!(t.node(t.root()).radius, 0.0);
    }

    #[test]
    fn invariants_hold_hamming() {
        let mut rng = Rng::new(42);
        let mut codes = HammingCodes::new(64);
        for _ in 0..120 {
            codes.push_bits(&(0..64).map(|_| rng.bool(0.5)).collect::<Vec<_>>());
        }
        let t = CoverTree::build(&codes, &Hamming, &BuildParams { leaf_size: 4, root: 0 });
        check_invariants(&t, &Hamming);
    }

    #[test]
    fn invariants_hold_edit_distance() {
        let mut rng = Rng::new(43);
        let alphabet = b"ACGT";
        let strs: Vec<Vec<u8>> = (0..60)
            .map(|_| (0..10 + rng.below(15)).map(|_| alphabet[rng.below(4)]).collect())
            .collect();
        let set = StringSet::from_strs(&strs);
        let t = CoverTree::build(&set, &Levenshtein, &BuildParams { leaf_size: 2, root: 0 });
        check_invariants(&t, &Levenshtein);
    }

    #[test]
    fn build_distance_calls_subquadratic_on_clustered_data() {
        // On well-clustered data the batch build should need far fewer than
        // n² distance calls.
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(44), 1000, 8, 10, 0.05);
        let counted = Counted::new(Euclidean);
        let _t = CoverTree::build(&pts, &counted, &BuildParams { leaf_size: 8, root: 0 });
        let n = 1000u64;
        assert!(
            counted.count() < n * n / 4,
            "build used {} distance calls (n²={})",
            counted.count(),
            n * n
        );
    }

    #[test]
    fn par_build_bit_identical_across_pool_sizes() {
        let pts = random_dense(47, 300, 3);
        for leaf_size in [1usize, 8, 64] {
            let params = BuildParams { leaf_size, root: 0 };
            let seq = CoverTree::build(&pts, &Euclidean, &params);
            for threads in [1usize, 2, 4, 8] {
                let pool = Pool::new(threads);
                let par = CoverTree::build_par(&pts, &Euclidean, &params, &pool);
                assert_eq!(seq.structure(), par.structure(), "leaf={leaf_size} threads={threads}");
                assert_eq!(seq.ids(), par.ids());
            }
        }
    }

    #[test]
    fn par_build_handles_duplicates_and_degenerate_inputs() {
        let pool = Pool::new(4);
        // Heavy duplication.
        let mut pts = random_dense(48, 40, 2);
        let dup = pts.row(5).to_vec();
        for _ in 0..30 {
            pts.push(&dup);
        }
        let params = BuildParams::default();
        let seq = CoverTree::build(&pts, &Euclidean, &params);
        let par = CoverTree::build_par(&pts, &Euclidean, &params, &pool);
        assert_eq!(seq.structure(), par.structure());
        check_invariants(&par, &Euclidean);
        // All-identical points, singleton, empty.
        let mut same = DenseMatrix::new(2);
        for _ in 0..9 {
            same.push(&[2.0, 2.0]);
        }
        for set in [same, DenseMatrix::from_flat(2, vec![1.0, 2.0]), DenseMatrix::new(2)] {
            let seq = CoverTree::build(&set, &Euclidean, &params);
            let par = CoverTree::build_par(&set, &Euclidean, &params, &pool);
            assert_eq!(seq.structure(), par.structure(), "n={}", set.len());
        }
    }

    #[test]
    fn par_build_custom_root_and_ids() {
        let pts = random_dense(49, 60, 2);
        let params = BuildParams { leaf_size: 2, root: 23 };
        let ids: Vec<u32> = (500..560).collect();
        let pool = Pool::new(3);
        let seq = CoverTree::build_with_ids(pts.clone(), ids.clone(), &Euclidean, &params);
        let par = CoverTree::build_with_ids_par(pts, ids, &Euclidean, &params, &pool);
        assert_eq!(seq.structure(), par.structure());
        assert_eq!(par.global_id(0), 500);
        assert_eq!(par.node(par.root()).point, 23);
    }

    #[test]
    fn custom_root_respected() {
        let pts = random_dense(45, 30, 2);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 1, root: 17 });
        assert_eq!(t.node(t.root()).point, 17);
        check_invariants(&t, &Euclidean);
    }

    #[test]
    fn ids_mapping_preserved() {
        let pts = random_dense(46, 20, 2);
        let ids: Vec<u32> = (100..120).collect();
        let t = CoverTree::build_with_ids(pts, ids, &Euclidean, &BuildParams::default());
        assert_eq!(t.global_id(0), 100);
        assert_eq!(t.global_id(19), 119);
    }
}
