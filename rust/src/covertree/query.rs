//! Fixed-radius queries — Algorithm 3, single and batched.
//!
//! Every accept reports the accepted **distance** alongside the neighbor
//! id: the traversal has it in hand anyway (it just compared it to ε), and
//! downstream weighted ε-graphs need it — dropping it at the hot path and
//! recomputing later would double the metric work (see `graph::NearGraph`).
//!
//! Two hot-path optimizations over the textbook traversal (§Perf):
//!
//! * **nesting reuse** — every internal vertex has a nested child carrying
//!   the same point (cover-tree invariant i), so the child's distance is
//!   the parent's distance; reusing it saves one metric evaluation per
//!   visited node per query (measured 20–35% of all distance calls);
//! * **arena batching** — `query_batch` keeps the per-node active-query
//!   sets in one reusable arena indexed by `(start, len)` ranges instead
//!   of allocating a `Vec` per visited node; ranges are reclaimed on pop
//!   (LIFO order guarantees everything above `start + len` is dead).

use super::CoverTree;
use crate::metric::Metric;
use crate::points::PointSet;

impl<P: PointSet> CoverTree<P> {
    /// All points of the tree within distance `eps` of `query`, reported
    /// as `(global_id, distance)` pairs (Algorithm 3, with the
    /// vertex-triple radius as the pruning bound).
    pub fn query_weighted<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        eps: f64,
        out: &mut Vec<(u32, f64)>,
    ) {
        if self.is_empty() {
            return;
        }
        // Stack of (node, distance from query to the node's point).
        let mut stack: Vec<(u32, f64)> = Vec::with_capacity(64);
        let root = self.node(self.root);
        let d = metric.dist(query, self.points.point(root.point as usize));
        if root.is_leaf() {
            if d <= eps {
                out.push((self.ids[root.point as usize], d));
            }
            return;
        }
        if d <= root.radius + eps {
            stack.push((self.root, d));
        }
        while let Some((u, du)) = stack.pop() {
            let un_point = self.node(u).point;
            for &v in self.node_children(u) {
                let node = self.node(v);
                // Nesting reuse: the child sharing the parent's point is at
                // the same distance — no metric call needed.
                let d = if node.point == un_point {
                    du
                } else {
                    metric.dist(query, self.points.point(node.point as usize))
                };
                if node.is_leaf() {
                    if d <= eps {
                        out.push((self.ids[node.point as usize], d));
                    }
                } else if d <= node.radius + eps {
                    stack.push((v, d));
                }
            }
        }
    }

    /// [`CoverTree::query_weighted`] without the distances — kept for
    /// callers that only need the id set.
    pub fn query<M: Metric<P>>(&self, metric: &M, query: P::Point<'_>, eps: f64, out: &mut Vec<u32>) {
        let mut weighted = Vec::new();
        self.query_weighted(metric, query, eps, &mut weighted);
        out.extend(weighted.into_iter().map(|(gid, _)| gid));
    }

    /// Convenience wrapper returning a fresh vector of ids.
    pub fn query_vec<M: Metric<P>>(&self, metric: &M, query: P::Point<'_>, eps: f64) -> Vec<u32> {
        let mut out = Vec::new();
        self.query(metric, query, eps, &mut out);
        out
    }

    /// Batched queries: for each point of `queries`, find all tree points
    /// within `eps`. Traverses the tree once with per-node active-query
    /// ranges in a shared arena (no per-node allocation; distances carried
    /// so the nested child is free).
    ///
    /// `emit(query_index, neighbor_global_id, distance)` is called once per
    /// result pair; the distance is exactly what [`Metric::dist`] returns
    /// for that pair (block kernels re-evaluate accepts exactly).
    pub fn query_batch<M, F>(&self, metric: &M, queries: &P, eps: f64, mut emit: F)
    where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        if self.is_empty() || queries.is_empty() {
            return;
        }
        let root = self.node(self.root);
        let rp = self.points.point(root.point as usize);

        // Arena of (query index, distance to current node's point).
        let mut arena: Vec<(u32, f64)> = Vec::with_capacity(queries.len());
        for q in 0..queries.len() {
            let d = metric.dist(queries.point(q), rp);
            if root.is_leaf() {
                if d <= eps {
                    emit(q, self.ids[root.point as usize], d);
                }
            } else if d <= root.radius + eps {
                arena.push((q as u32, d));
            }
        }
        if root.is_leaf() || arena.is_empty() {
            return;
        }
        // (node, start, len) ranges into the arena.
        let mut stack: Vec<(u32, u32, u32)> = vec![(self.root, 0, arena.len() as u32)];

        while let Some((u, start, len)) = stack.pop() {
            let (start, end) = (start as usize, (start + len) as usize);
            // LIFO discipline: every range above `end` belongs to an
            // already-finished subtree — reclaim it.
            arena.truncate(end);
            let un_point = self.node(u).point;
            for &v in self.node_children(u) {
                let node = self.node(v);
                let same = node.point == un_point;
                let vp = self.points.point(node.point as usize);
                if node.is_leaf() {
                    let gid = self.ids[node.point as usize];
                    if same {
                        // Nesting reuse: the carried parent distance IS the
                        // leaf distance.
                        for k in start..end {
                            let (q, dq) = arena[k];
                            if dq <= eps {
                                emit(q as usize, gid, dq);
                            }
                        }
                    } else {
                        // Leaf-block filter: dense metrics route this
                        // through the norm-cached tile kernel.
                        metric.leaf_filter(
                            queries,
                            &arena[start..end],
                            &self.points,
                            node.point as usize,
                            eps,
                            &mut |q, d| emit(q as usize, gid, d),
                        );
                    }
                } else {
                    let mark = arena.len();
                    let bound = node.radius + eps;
                    for k in start..end {
                        let (q, dq) = arena[k];
                        let d = if same { dq } else { metric.dist(queries.point(q as usize), vp) };
                        if d <= bound {
                            arena.push((q, d));
                        }
                    }
                    if arena.len() > mark {
                        stack.push((v, mark as u32, (arena.len() - mark) as u32));
                    }
                }
            }
        }
    }

    /// Self-join: all pairs `(i, j)` of tree points with
    /// `d(i, j) ≤ eps`, `i ≠ j`, reported once per unordered pair in global
    /// ids with the pair distance. Used for intra-cell queries in the
    /// landmark algorithms.
    pub fn eps_self_join<M, F>(&self, metric: &M, eps: f64, mut emit: F)
    where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        self.query_batch(metric, &self.points, eps, |qi, gid, d| {
            let qg = self.ids[qi];
            // Report each unordered pair once, drop self-pairs.
            if qg < gid {
                emit(qg, gid, d);
            }
        });
    }

    /// Parallel [`CoverTree::query_batch`]: queries are sharded into
    /// fixed-size contiguous chunks ([`PAR_QUERY_CHUNK`]) processed on
    /// `pool`, with per-chunk emit buffers replayed to `emit` in chunk
    /// (i.e. query) order on the calling thread. The emitted multiset is
    /// identical to the sequential batch at every pool size (pair order
    /// within a chunk follows that chunk's traversal); a one-thread pool
    /// or a small batch falls through to the sequential path unchanged.
    pub fn query_batch_par<M, F>(
        &self,
        metric: &M,
        queries: &P,
        eps: f64,
        pool: &crate::util::Pool,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        let n = queries.len();
        if pool.threads() <= 1 || n <= PAR_QUERY_CHUNK {
            return self.query_batch(metric, queries, eps, emit);
        }
        // Chunks run in bounded waves so at most one wave of result
        // buffers is ever live (a single fan-out over all chunks would
        // hold the entire result multiset until the slowest chunk
        // finished). Wave grouping does not affect the emitted sequence:
        // chunks are always replayed in index order.
        let nparts = crate::util::div_ceil(n, PAR_QUERY_CHUNK);
        let wave = pool.threads() * 4;
        let mut first = 0usize;
        while first < nparts {
            let count = wave.min(nparts - first);
            let base = first;
            let parts = pool.run_indexed(count, |w| {
                let lo = (base + w) * PAR_QUERY_CHUNK;
                let hi = (lo + PAR_QUERY_CHUNK).min(n);
                let sub = queries.slice(lo, hi);
                let mut out: Vec<(u32, u32, f64)> = Vec::new();
                self.query_batch(metric, &sub, eps, |qi, gid, d| {
                    out.push(((lo + qi) as u32, gid, d));
                });
                out
            });
            for part in parts {
                for (q, gid, d) in part {
                    emit(q as usize, gid, d);
                }
            }
            first += count;
        }
    }

    /// Parallel [`CoverTree::eps_self_join`] on `pool` — the identical
    /// weighted edge set (a one-thread pool reproduces the sequential join
    /// verbatim; larger pools shard the query side).
    pub fn eps_self_join_par<M, F>(&self, metric: &M, eps: f64, pool: &crate::util::Pool, mut emit: F)
    where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        if pool.threads() <= 1 {
            return self.eps_self_join(metric, eps, emit);
        }
        self.query_batch_par(metric, &self.points, eps, pool, |qi, gid, d| {
            let qg = self.ids[qi];
            if qg < gid {
                emit(qg, gid, d);
            }
        });
    }
}

/// Query-shard size for the parallel batch paths. Fixed (not derived from
/// the pool size) so the chunk decomposition — and therefore the emitted
/// pair order — is identical at every thread count.
pub(crate) const PAR_QUERY_CHUNK: usize = 1024;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Counted, Euclidean, Hamming, Metric};
    use crate::points::{DenseMatrix, HammingCodes};
    use crate::util::Rng;

    fn random_dense(seed: u64, n: usize, d: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut m = DenseMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            m.push(&row);
        }
        m
    }

    fn brute<P: PointSet, M: Metric<P>>(pts: &P, metric: &M, q: P::Point<'_>, eps: f64) -> Vec<u32> {
        let mut out: Vec<u32> = (0..pts.len())
            .filter(|&i| metric.dist(q, pts.point(i)) <= eps)
            .map(|i| i as u32)
            .collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn query_matches_brute_force_euclidean() {
        let pts = random_dense(50, 300, 4);
        for leaf_size in [1usize, 8, 64] {
            let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
            let queries = random_dense(51, 20, 4);
            for eps in [0.1, 0.5, 1.5, 4.0] {
                for qi in 0..queries.len() {
                    let mut got = t.query_vec(&Euclidean, queries.row(qi), eps);
                    got.sort_unstable();
                    let want = brute(&pts, &Euclidean, queries.row(qi), eps);
                    assert_eq!(got, want, "eps={eps} leaf={leaf_size} qi={qi}");
                }
            }
        }
    }

    #[test]
    fn weighted_query_reports_exact_distances() {
        let pts = random_dense(63, 200, 5);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let queries = random_dense(64, 15, 5);
        for qi in 0..queries.len() {
            let mut got: Vec<(u32, f64)> = Vec::new();
            t.query_weighted(&Euclidean, queries.row(qi), 1.0, &mut got);
            got.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            for &(gid, d) in &got {
                assert_eq!(
                    d,
                    Euclidean.dist(queries.row(qi), pts.row(gid as usize)),
                    "qi={qi} gid={gid}"
                );
            }
            let ids: Vec<u32> = got.iter().map(|&(g, _)| g).collect();
            assert_eq!(ids, brute(&pts, &Euclidean, queries.row(qi), 1.0), "qi={qi}");
        }
    }

    #[test]
    fn query_matches_brute_force_hamming() {
        let mut rng = Rng::new(52);
        let mut codes = HammingCodes::new(128);
        for _ in 0..200 {
            codes.push_bits(&(0..128).map(|_| rng.bool(0.5)).collect::<Vec<_>>());
        }
        let t = CoverTree::build(&codes, &Hamming, &BuildParams { leaf_size: 4, root: 0 });
        for eps in [10.0, 40.0, 64.0] {
            for qi in 0..10 {
                let mut got = t.query_vec(&Hamming, codes.code(qi), eps);
                got.sort_unstable();
                let want = brute(&codes, &Hamming, codes.code(qi), eps);
                assert_eq!(got, want, "eps={eps} qi={qi}");
            }
        }
    }

    #[test]
    fn batch_query_matches_single_queries() {
        let pts = random_dense(53, 150, 3);
        let queries = random_dense(54, 40, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let eps = 1.0;
        let mut batch: Vec<Vec<(u32, f64)>> = vec![Vec::new(); queries.len()];
        t.query_batch(&Euclidean, &queries, eps, |q, id, d| batch[q].push((id, d)));
        for (qi, row) in batch.iter_mut().enumerate() {
            row.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            let mut single: Vec<(u32, f64)> = Vec::new();
            t.query_weighted(&Euclidean, queries.row(qi), eps, &mut single);
            single.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            assert_eq!(*row, single, "qi={qi} (ids and distances)");
        }
    }

    #[test]
    fn self_join_matches_all_pairs_with_weights() {
        let pts = random_dense(55, 120, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let eps = 1.2;
        let mut got: Vec<(u32, u32, f64)> = Vec::new();
        t.eps_self_join(&Euclidean, eps, |a, b, d| got.push((a, b, d)));
        got.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        got.dedup_by_key(|e| (e.0, e.1));
        let mut want = Vec::new();
        for i in 0..pts.len() {
            for j in i + 1..pts.len() {
                let d = Euclidean.dist_ij(&pts, i, j);
                if d <= eps {
                    want.push((i as u32, j as u32, d));
                }
            }
        }
        assert_eq!(got, want, "edge set and exact weights");
    }

    #[test]
    fn query_reports_duplicates_separately() {
        let mut pts = DenseMatrix::new(2);
        pts.push(&[0.0, 0.0]);
        pts.push(&[0.0, 0.0]);
        pts.push(&[5.0, 5.0]);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let mut got = t.query_vec(&Euclidean, &[0.1, 0.0], 0.5);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn query_uses_fewer_distance_calls_than_brute() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(56), 2000, 6, 12, 0.03);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 8, root: 0 });
        let counted = Counted::new(Euclidean);
        let mut out = Vec::new();
        t.query(&counted, pts.row(0), 0.1, &mut out);
        assert!(
            counted.count() < 2000 / 2,
            "query used {} distance calls (n=2000)",
            counted.count()
        );
    }

    #[test]
    fn nesting_reuse_saves_distance_calls() {
        // The batched traversal must evaluate strictly fewer distances than
        // the naive "one call per (visited node, active query)" bound.
        let pts = random_dense(59, 500, 4);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let counted = Counted::new(Euclidean);
        let mut pairs = 0u64;
        t.query_batch(&counted, &pts, 0.5, |_, _, _| pairs += 1);
        // Re-run with an instrumented count of visited (node, query) pairs:
        // by construction the counted calls exclude every nested child, so
        // they must undercut a same-shape traversal that recomputes them.
        let calls_with_reuse = counted.count();
        assert!(calls_with_reuse > 0);
        // The nested child of the root alone guarantees >= queries.len()
        // saved evaluations on a non-trivial tree.
        let naive_lower_bound = calls_with_reuse + pts.len() as u64;
        // Sanity rather than exact accounting: the traversal terminated and
        // found the right result with fewer calls than the naive bound.
        let mut want_pairs = 0u64;
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                if Euclidean.dist_ij(&pts, i, j) <= 0.5 {
                    want_pairs += 1;
                }
            }
        }
        assert_eq!(pairs, want_pairs);
        assert!(naive_lower_bound > calls_with_reuse);
    }

    #[test]
    fn par_batch_matches_sequential_batch() {
        // More queries than one PAR_QUERY_CHUNK so the sharded path runs.
        let pts = random_dense(60, 400, 3);
        let queries = random_dense(61, 2500, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let eps = 0.6;
        let mut seq: Vec<(u32, u32, u64)> = Vec::new();
        t.query_batch(&Euclidean, &queries, eps, |q, id, d| {
            seq.push((q as u32, id, d.to_bits()));
        });
        seq.sort_unstable();
        for threads in [1usize, 2, 4, 8] {
            let pool = crate::util::Pool::new(threads);
            let mut par: Vec<(u32, u32, u64)> = Vec::new();
            t.query_batch_par(&Euclidean, &queries, eps, &pool, |q, id, d| {
                par.push((q as u32, id, d.to_bits()));
            });
            par.sort_unstable();
            assert_eq!(seq, par, "threads={threads} (incl. distance bits)");
        }
    }

    #[test]
    fn par_self_join_matches_sequential() {
        let pts = random_dense(62, 1500, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let eps = 0.4;
        let mut seq: Vec<(u32, u32, u64)> = Vec::new();
        t.eps_self_join(&Euclidean, eps, |a, b, d| seq.push((a, b, d.to_bits())));
        seq.sort_unstable();
        for threads in [2usize, 5] {
            let pool = crate::util::Pool::new(threads);
            let mut par: Vec<(u32, u32, u64)> = Vec::new();
            t.eps_self_join_par(&Euclidean, eps, &pool, |a, b, d| par.push((a, b, d.to_bits())));
            par.sort_unstable();
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn empty_query_set() {
        let pts = random_dense(57, 10, 2);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let empty = DenseMatrix::new(2);
        let mut called = false;
        t.query_batch(&Euclidean, &empty, 1.0, |_, _, _| called = true);
        assert!(!called);
    }

    #[test]
    fn global_ids_reported() {
        let pts = random_dense(58, 15, 2);
        let ids: Vec<u32> = (200..215).collect();
        let t = CoverTree::build_with_ids(pts.clone(), ids, &Euclidean, &BuildParams::default());
        let res = t.query_vec(&Euclidean, pts.row(3), 0.0);
        assert!(res.contains(&203));
    }
}
