//! Fixed-radius queries — Algorithm 3, single and batched.
//!
//! Every accept reports the accepted **distance** alongside the neighbor
//! id: the traversal has it in hand anyway (it just compared it to ε), and
//! downstream weighted ε-graphs need it — dropping it at the hot path and
//! recomputing later would double the metric work (see `graph::NearGraph`).
//!
//! Three hot-path optimizations over the textbook traversal (§Perf):
//!
//! * **nesting reuse** — every internal vertex has a nested child carrying
//!   the same point (cover-tree invariant i), so the child's distance is
//!   the parent's distance; reusing it saves one metric evaluation per
//!   visited node per query (measured 20–35% of all distance calls);
//! * **arena batching** — `query_batch` keeps the per-node active-query
//!   sets in one reusable arena indexed by `(start, len)` ranges instead
//!   of allocating a `Vec` per visited node; ranges are reclaimed on pop
//!   (LIFO order guarantees everything above `start + len` is dead);
//! * **flat layout + scratch reuse** — traversal runs over the
//!   level-ordered [`FlatTree`](super::FlatTree) (children are contiguous
//!   id ranges; no child-arena chase) with all growable state owned by a
//!   caller-provided [`QueryScratch`], so steady-state batch queries
//!   perform zero heap allocations per query. The `*_legacy` methods keep
//!   the build-order traversal alive as a comparator (the perf driver
//!   times both; the `flat_matches_legacy_*` tests pin bit-identical
//!   emission order).

use super::{CoverTree, QueryScratch};
use crate::metric::Metric;
use crate::points::PointSet;

impl<P: PointSet> CoverTree<P> {
    /// All points of the tree within distance `eps` of `query`, reported
    /// as `(global_id, distance)` pairs (Algorithm 3, with the
    /// vertex-triple radius as the pruning bound). Convenience wrapper
    /// over [`CoverTree::query_weighted_with`] with a throwaway scratch.
    pub fn query_weighted<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        eps: f64,
        out: &mut Vec<(u32, f64)>,
    ) {
        let mut scratch = QueryScratch::new();
        self.query_weighted_with(metric, query, eps, &mut scratch, out);
    }

    /// [`CoverTree::query_weighted`] with caller-owned traversal state:
    /// callers issuing many queries hold one [`QueryScratch`] and pay no
    /// per-query allocation once its buffers are warm.
    pub fn query_weighted_with<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        eps: f64,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        if self.is_empty() {
            return;
        }
        let flat = self.flat();
        // Stack of (node, distance from query to the node's point).
        let stack = &mut scratch.stack;
        stack.clear();
        let root = flat.root();
        let root_pt = flat.point(root);
        let d = metric.dist(query, self.points().point(root_pt as usize));
        if flat.is_leaf(root) {
            if d <= eps {
                out.push((self.ids()[root_pt as usize], d));
            }
            return;
        }
        if d <= flat.radius(root) + eps {
            stack.push((root, d));
        }
        while let Some((u, du)) = stack.pop() {
            let un_point = flat.point(u);
            for v in flat.children(u) {
                let vp = flat.point(v);
                // Nesting reuse: the child sharing the parent's point is at
                // the same distance — no metric call needed.
                let d = if vp == un_point {
                    du
                } else {
                    metric.dist(query, self.points().point(vp as usize))
                };
                if flat.is_leaf(v) {
                    if d <= eps {
                        out.push((self.ids()[vp as usize], d));
                    }
                } else if d <= flat.radius(v) + eps {
                    stack.push((v, d));
                }
            }
        }
    }

    /// [`CoverTree::query_weighted`] without the distances — kept for
    /// callers that only need the id set.
    // lint: cold
    pub fn query<M: Metric<P>>(&self, metric: &M, query: P::Point<'_>, eps: f64, out: &mut Vec<u32>) {
        let mut weighted = Vec::new();
        self.query_weighted(metric, query, eps, &mut weighted);
        out.extend(weighted.into_iter().map(|(gid, _)| gid));
    }

    /// Convenience wrapper returning a fresh vector of ids.
    // lint: cold
    pub fn query_vec<M: Metric<P>>(&self, metric: &M, query: P::Point<'_>, eps: f64) -> Vec<u32> {
        let mut out = Vec::new();
        self.query(metric, query, eps, &mut out);
        out
    }

    /// Batched queries: for each point of `queries`, find all tree points
    /// within `eps`. Traverses the tree once with per-node active-query
    /// ranges in a shared arena (no per-node allocation; distances carried
    /// so the nested child is free). Convenience wrapper over
    /// [`CoverTree::query_batch_with`] with a throwaway scratch.
    ///
    /// `emit(query_index, neighbor_global_id, distance)` is called once per
    /// result pair; the distance is exactly what [`Metric::dist`] returns
    /// for that pair (block kernels re-evaluate accepts exactly).
    pub fn query_batch<M, F>(&self, metric: &M, queries: &P, eps: f64, emit: F)
    where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        let mut scratch = QueryScratch::new();
        self.query_batch_with(metric, queries, eps, &mut scratch, emit);
    }

    /// [`CoverTree::query_batch`] with caller-owned traversal state (the
    /// arena and the range stack live in `scratch` and keep their capacity
    /// across calls). The emitted sequence is identical to
    /// [`CoverTree::query_batch_legacy`] pair for pair — the flat renumber
    /// preserves per-node child order and the DFS discipline, so both
    /// traversals visit, prune and accept in the same order with the same
    /// metric evaluations.
    pub fn query_batch_with<M, F>(
        &self,
        metric: &M,
        queries: &P,
        eps: f64,
        scratch: &mut QueryScratch,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        if self.is_empty() || queries.is_empty() {
            return;
        }
        let flat = self.flat();
        let root = flat.root();
        let root_pt = flat.point(root);
        let rp = self.points().point(root_pt as usize);

        // Arena of (query index, distance to current node's point).
        let arena = &mut scratch.arena;
        let stack = &mut scratch.range_stack;
        let tile = &mut scratch.tile;
        arena.clear();
        stack.clear();
        let root_leaf = flat.is_leaf(root);
        let root_bound = flat.radius(root) + eps;
        for q in 0..queries.len() {
            let d = metric.dist(queries.point(q), rp);
            if root_leaf {
                if d <= eps {
                    emit(q, self.ids()[root_pt as usize], d);
                }
            } else if d <= root_bound {
                arena.push((q as u32, d));
            }
        }
        if root_leaf || arena.is_empty() {
            return;
        }
        // (node, start, len) ranges into the arena.
        stack.push((root, 0, arena.len() as u32));

        while let Some((u, start, len)) = stack.pop() {
            let (start, end) = (start as usize, (start + len) as usize);
            // LIFO discipline: every range above `end` belongs to an
            // already-finished subtree — reclaim it.
            arena.truncate(end);
            let un_point = flat.point(u);
            for v in flat.children(u) {
                let vp = flat.point(v);
                let same = vp == un_point;
                if flat.is_leaf(v) {
                    let gid = self.ids()[vp as usize];
                    if same {
                        // Nesting reuse: the carried parent distance IS the
                        // leaf distance.
                        for k in start..end {
                            let (q, dq) = arena[k];
                            if dq <= eps {
                                emit(q as usize, gid, dq);
                            }
                        }
                    } else {
                        // Leaf-block filter through the scratch-owned SoA
                        // tile: metrics with a K-lane kernel gather the
                        // block into lanes; the rest fall through to the
                        // scalar walk. Same decisions, same distance bits.
                        metric.leaf_filter_with(
                            queries,
                            &arena[start..end],
                            self.points(),
                            vp as usize,
                            eps,
                            tile,
                            &mut |q, d| emit(q as usize, gid, d),
                        );
                    }
                } else {
                    let mark = arena.len();
                    let bound = flat.radius(v) + eps;
                    let vpoint = self.points().point(vp as usize);
                    for k in start..end {
                        let (q, dq) = arena[k];
                        let d =
                            if same { dq } else { metric.dist(queries.point(q as usize), vpoint) };
                        if d <= bound {
                            arena.push((q, d));
                        }
                    }
                    if arena.len() > mark {
                        stack.push((v, mark as u32, (arena.len() - mark) as u32));
                    }
                }
            }
        }
    }

    /// Self-join: all pairs `(i, j)` of tree points with
    /// `d(i, j) ≤ eps`, `i ≠ j`, reported once per unordered pair in global
    /// ids with the pair distance. Used for intra-cell queries in the
    /// landmark algorithms.
    pub fn eps_self_join<M, F>(&self, metric: &M, eps: f64, emit: F)
    where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        let mut scratch = QueryScratch::new();
        self.eps_self_join_with(metric, eps, &mut scratch, emit);
    }

    /// [`CoverTree::eps_self_join`] with caller-owned traversal state.
    pub fn eps_self_join_with<M, F>(
        &self,
        metric: &M,
        eps: f64,
        scratch: &mut QueryScratch,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        self.query_batch_with(metric, self.points(), eps, scratch, |qi, gid, d| {
            let qg = self.ids()[qi];
            // Report each unordered pair once, drop self-pairs.
            if qg < gid {
                emit(qg, gid, d);
            }
        });
    }

    /// Parallel [`CoverTree::query_batch`]: queries are sharded into
    /// fixed-size contiguous chunks ([`PAR_QUERY_CHUNK`]) processed on
    /// `pool`, with per-chunk emit buffers replayed to `emit` in chunk
    /// (i.e. query) order on the calling thread. The emitted multiset is
    /// identical to the sequential batch at every pool size (pair order
    /// within a chunk follows that chunk's traversal); a one-thread pool
    /// or a small batch falls through to the sequential path unchanged.
    pub fn query_batch_par<M, F>(
        &self,
        metric: &M,
        queries: &P,
        eps: f64,
        pool: &crate::util::Pool,
        emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        let mut scratch = QueryScratch::new();
        self.query_batch_par_with(metric, queries, eps, pool, &mut scratch, emit);
    }

    /// [`CoverTree::query_batch_par`] with a caller-owned scratch for the
    /// sequential fall-through (single-thread pool or sub-chunk batch).
    /// The pooled path keeps **one scratch per worker**
    /// ([`crate::util::Pool::run_indexed_with`]) reused across every chunk
    /// that worker claims, so steady-state per-query allocations are zero
    /// on both routes.
    pub fn query_batch_par_with<M, F>(
        &self,
        metric: &M,
        queries: &P,
        eps: f64,
        pool: &crate::util::Pool,
        scratch: &mut QueryScratch,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        let n = queries.len();
        if pool.threads() <= 1 || n <= PAR_QUERY_CHUNK {
            return self.query_batch_with(metric, queries, eps, scratch, emit);
        }
        // Chunks run in bounded waves so at most one wave of result
        // buffers is ever live (a single fan-out over all chunks would
        // hold the entire result multiset until the slowest chunk
        // finished). Wave grouping does not affect the emitted sequence:
        // chunks are always replayed in index order.
        let nparts = crate::util::div_ceil(n, PAR_QUERY_CHUNK);
        let wave = pool.threads() * 4;
        let mut first = 0usize;
        while first < nparts {
            let count = wave.min(nparts - first);
            let base = first;
            let parts = pool.run_indexed_with(
                count,
                |_| QueryScratch::new(),
                |sc, w| {
                    let lo = (base + w) * PAR_QUERY_CHUNK;
                    let hi = (lo + PAR_QUERY_CHUNK).min(n);
                    let sub = queries.slice(lo, hi);
                    // lint: allow(no-alloc-hot-path) reason="per-chunk result buffer of one parallel wave, amortized over PAR_QUERY_CHUNK queries"
                    let mut out: Vec<(u32, u32, f64)> = Vec::new();
                    self.query_batch_with(metric, &sub, eps, sc, |qi, gid, d| {
                        out.push(((lo + qi) as u32, gid, d));
                    });
                    out
                },
            );
            for part in parts {
                for (q, gid, d) in part {
                    emit(q as usize, gid, d);
                }
            }
            first += count;
        }
    }

    /// Parallel [`CoverTree::eps_self_join`] on `pool` — the identical
    /// weighted edge set (a one-thread pool reproduces the sequential join
    /// verbatim; larger pools shard the query side).
    pub fn eps_self_join_par<M, F>(&self, metric: &M, eps: f64, pool: &crate::util::Pool, emit: F)
    where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        let mut scratch = QueryScratch::new();
        self.eps_self_join_par_with(metric, eps, pool, &mut scratch, emit);
    }

    /// [`CoverTree::eps_self_join_par`] with a caller-owned scratch for
    /// the sequential fall-through (see
    /// [`CoverTree::query_batch_par_with`]).
    pub fn eps_self_join_par_with<M, F>(
        &self,
        metric: &M,
        eps: f64,
        pool: &crate::util::Pool,
        scratch: &mut QueryScratch,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        if pool.threads() <= 1 {
            return self.eps_self_join_with(metric, eps, scratch, emit);
        }
        self.query_batch_par_with(metric, self.points(), eps, pool, scratch, |qi, gid, d| {
            let qg = self.ids()[qi];
            if qg < gid {
                emit(qg, gid, d);
            }
        });
    }

    // ------------------------------------------------------------------
    // legacy build-order traversals — the comparator the flat layout is
    // measured against (perf_driver's traversal section) and the oracle
    // the flat_matches_legacy_* tests pin emission order to.
    // ------------------------------------------------------------------

    /// [`CoverTree::query_weighted`] over the build-order node arena (the
    /// pre-flat traversal, allocating its stack per call). Same results in
    /// the same order; kept as a perf/equivalence comparator.
    pub fn query_weighted_legacy<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        eps: f64,
        out: &mut Vec<(u32, f64)>,
    ) {
        if self.is_empty() {
            return;
        }
        let mut stack: Vec<(u32, f64)> = Vec::with_capacity(64);
        let root = self.node(self.root());
        let d = metric.dist(query, self.points().point(root.point as usize));
        if root.is_leaf() {
            if d <= eps {
                out.push((self.ids()[root.point as usize], d));
            }
            return;
        }
        if d <= root.radius + eps {
            stack.push((self.root(), d));
        }
        while let Some((u, du)) = stack.pop() {
            let un_point = self.node(u).point;
            for &v in self.node_children(u) {
                let node = self.node(v);
                let d = if node.point == un_point {
                    du
                } else {
                    metric.dist(query, self.points().point(node.point as usize))
                };
                if node.is_leaf() {
                    if d <= eps {
                        out.push((self.ids()[node.point as usize], d));
                    }
                } else if d <= node.radius + eps {
                    stack.push((v, d));
                }
            }
        }
    }

    /// [`CoverTree::query_batch`] over the build-order node arena (the
    /// pre-flat traversal, allocating its arena and stack per call). Same
    /// emitted sequence; kept as a perf/equivalence comparator.
    // lint: cold
    pub fn query_batch_legacy<M, F>(&self, metric: &M, queries: &P, eps: f64, mut emit: F)
    where
        M: Metric<P>,
        F: FnMut(usize, u32, f64),
    {
        if self.is_empty() || queries.is_empty() {
            return;
        }
        let root = self.node(self.root());
        let rp = self.points().point(root.point as usize);

        let mut arena: Vec<(u32, f64)> = Vec::with_capacity(queries.len());
        for q in 0..queries.len() {
            let d = metric.dist(queries.point(q), rp);
            if root.is_leaf() {
                if d <= eps {
                    emit(q, self.ids()[root.point as usize], d);
                }
            } else if d <= root.radius + eps {
                arena.push((q as u32, d));
            }
        }
        if root.is_leaf() || arena.is_empty() {
            return;
        }
        let mut stack: Vec<(u32, u32, u32)> = vec![(self.root(), 0, arena.len() as u32)];

        while let Some((u, start, len)) = stack.pop() {
            let (start, end) = (start as usize, (start + len) as usize);
            arena.truncate(end);
            let un_point = self.node(u).point;
            for &v in self.node_children(u) {
                let node = self.node(v);
                let same = node.point == un_point;
                let vp = self.points().point(node.point as usize);
                if node.is_leaf() {
                    let gid = self.ids()[node.point as usize];
                    if same {
                        for k in start..end {
                            let (q, dq) = arena[k];
                            if dq <= eps {
                                emit(q as usize, gid, dq);
                            }
                        }
                    } else {
                        metric.leaf_filter(
                            queries,
                            &arena[start..end],
                            self.points(),
                            node.point as usize,
                            eps,
                            &mut |q, d| emit(q as usize, gid, d),
                        );
                    }
                } else {
                    let mark = arena.len();
                    let bound = node.radius + eps;
                    for k in start..end {
                        let (q, dq) = arena[k];
                        let d = if same { dq } else { metric.dist(queries.point(q as usize), vp) };
                        if d <= bound {
                            arena.push((q, d));
                        }
                    }
                    if arena.len() > mark {
                        stack.push((v, mark as u32, (arena.len() - mark) as u32));
                    }
                }
            }
        }
    }
}

/// Query-shard size for the parallel batch paths. Fixed (not derived from
/// the pool size) so the chunk decomposition — and therefore the emitted
/// pair order — is identical at every thread count.
pub(crate) const PAR_QUERY_CHUNK: usize = 1024;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Counted, Euclidean, Hamming, Metric};
    use crate::points::{DenseMatrix, HammingCodes};
    use crate::util::Rng;

    fn random_dense(seed: u64, n: usize, d: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut m = DenseMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            m.push(&row);
        }
        m
    }

    fn brute<P: PointSet, M: Metric<P>>(pts: &P, metric: &M, q: P::Point<'_>, eps: f64) -> Vec<u32> {
        let mut out: Vec<u32> = (0..pts.len())
            .filter(|&i| metric.dist(q, pts.point(i)) <= eps)
            .map(|i| i as u32)
            .collect();
        out.sort_unstable();
        out
    }

    #[test]
    fn query_matches_brute_force_euclidean() {
        let pts = random_dense(50, 300, 4);
        for leaf_size in [1usize, 8, 64] {
            let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
            let queries = random_dense(51, 20, 4);
            for eps in [0.1, 0.5, 1.5, 4.0] {
                for qi in 0..queries.len() {
                    let mut got = t.query_vec(&Euclidean, queries.row(qi), eps);
                    got.sort_unstable();
                    let want = brute(&pts, &Euclidean, queries.row(qi), eps);
                    assert_eq!(got, want, "eps={eps} leaf={leaf_size} qi={qi}");
                }
            }
        }
    }

    #[test]
    fn weighted_query_reports_exact_distances() {
        let pts = random_dense(63, 200, 5);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let queries = random_dense(64, 15, 5);
        for qi in 0..queries.len() {
            let mut got: Vec<(u32, f64)> = Vec::new();
            t.query_weighted(&Euclidean, queries.row(qi), 1.0, &mut got);
            got.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            for &(gid, d) in &got {
                assert_eq!(
                    d,
                    Euclidean.dist(queries.row(qi), pts.row(gid as usize)),
                    "qi={qi} gid={gid}"
                );
            }
            let ids: Vec<u32> = got.iter().map(|&(g, _)| g).collect();
            assert_eq!(ids, brute(&pts, &Euclidean, queries.row(qi), 1.0), "qi={qi}");
        }
    }

    #[test]
    fn query_matches_brute_force_hamming() {
        let mut rng = Rng::new(52);
        let mut codes = HammingCodes::new(128);
        for _ in 0..200 {
            codes.push_bits(&(0..128).map(|_| rng.bool(0.5)).collect::<Vec<_>>());
        }
        let t = CoverTree::build(&codes, &Hamming, &BuildParams { leaf_size: 4, root: 0 });
        for eps in [10.0, 40.0, 64.0] {
            for qi in 0..10 {
                let mut got = t.query_vec(&Hamming, codes.code(qi), eps);
                got.sort_unstable();
                let want = brute(&codes, &Hamming, codes.code(qi), eps);
                assert_eq!(got, want, "eps={eps} qi={qi}");
            }
        }
    }

    #[test]
    fn batch_query_matches_single_queries() {
        let pts = random_dense(53, 150, 3);
        let queries = random_dense(54, 40, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let eps = 1.0;
        let mut batch: Vec<Vec<(u32, f64)>> = vec![Vec::new(); queries.len()];
        t.query_batch(&Euclidean, &queries, eps, |q, id, d| batch[q].push((id, d)));
        for (qi, row) in batch.iter_mut().enumerate() {
            row.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            let mut single: Vec<(u32, f64)> = Vec::new();
            t.query_weighted(&Euclidean, queries.row(qi), eps, &mut single);
            single.sort_unstable_by(|a, b| a.0.cmp(&b.0));
            assert_eq!(*row, single, "qi={qi} (ids and distances)");
        }
    }

    #[test]
    fn flat_matches_legacy_batch_emission_order() {
        // The strongest layout gate: the flat traversal must reproduce the
        // legacy build-order traversal's emitted sequence EXACTLY — same
        // pairs, same distance bits, same order — across metrics, leaf
        // sizes and ε scales.
        let pts = random_dense(70, 400, 4);
        let queries = random_dense(71, 60, 4);
        for leaf_size in [1usize, 4, 32] {
            let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
            for eps in [0.0, 0.3, 1.0, 3.0] {
                let mut legacy: Vec<(usize, u32, u64)> = Vec::new();
                t.query_batch_legacy(&Euclidean, &queries, eps, |q, g, d| {
                    legacy.push((q, g, d.to_bits()));
                });
                let mut flat: Vec<(usize, u32, u64)> = Vec::new();
                t.query_batch(&Euclidean, &queries, eps, |q, g, d| {
                    flat.push((q, g, d.to_bits()));
                });
                assert_eq!(flat, legacy, "leaf={leaf_size} eps={eps}");
            }
        }
    }

    #[test]
    fn flat_matches_legacy_single_query_order() {
        let pts = random_dense(72, 250, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 2, root: 0 });
        let queries = random_dense(73, 25, 3);
        for qi in 0..queries.len() {
            let mut legacy: Vec<(u32, f64)> = Vec::new();
            t.query_weighted_legacy(&Euclidean, queries.row(qi), 0.8, &mut legacy);
            let mut flat: Vec<(u32, f64)> = Vec::new();
            t.query_weighted(&Euclidean, queries.row(qi), 0.8, &mut flat);
            assert_eq!(flat, legacy, "qi={qi} (order-sensitive)");
        }
    }

    #[test]
    fn flat_and_legacy_make_identical_distance_calls() {
        let pts = random_dense(74, 500, 4);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 8, root: 0 });
        let queries = random_dense(75, 80, 4);
        let counted_legacy = Counted::new(Euclidean);
        t.query_batch_legacy(&counted_legacy, &queries, 0.6, |_, _, _| {});
        let counted_flat = Counted::new(Euclidean);
        t.query_batch(&counted_flat, &queries, 0.6, |_, _, _| {});
        assert_eq!(counted_flat.count(), counted_legacy.count());
    }

    #[test]
    fn scratch_reuse_is_stable_across_calls() {
        // The same scratch must serve different batches back to back with
        // no cross-talk.
        let pts = random_dense(76, 200, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let qa = random_dense(77, 30, 3);
        let qb = random_dense(78, 50, 3);
        let mut scratch = QueryScratch::new();
        for round in 0..3 {
            for (tag, queries) in [("a", &qa), ("b", &qb)] {
                let mut fresh: Vec<(usize, u32, u64)> = Vec::new();
                t.query_batch(&Euclidean, queries, 0.9, |q, g, d| {
                    fresh.push((q, g, d.to_bits()));
                });
                let mut reused: Vec<(usize, u32, u64)> = Vec::new();
                t.query_batch_with(&Euclidean, queries, 0.9, &mut scratch, |q, g, d| {
                    reused.push((q, g, d.to_bits()));
                });
                assert_eq!(reused, fresh, "round={round} batch={tag}");
            }
        }
    }

    #[test]
    fn self_join_matches_all_pairs_with_weights() {
        let pts = random_dense(55, 120, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let eps = 1.2;
        let mut got: Vec<(u32, u32, f64)> = Vec::new();
        t.eps_self_join(&Euclidean, eps, |a, b, d| got.push((a, b, d)));
        got.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        got.dedup_by_key(|e| (e.0, e.1));
        let mut want = Vec::new();
        for i in 0..pts.len() {
            for j in i + 1..pts.len() {
                let d = Euclidean.dist_ij(&pts, i, j);
                if d <= eps {
                    want.push((i as u32, j as u32, d));
                }
            }
        }
        assert_eq!(got, want, "edge set and exact weights");
    }

    #[test]
    fn query_reports_duplicates_separately() {
        let mut pts = DenseMatrix::new(2);
        pts.push(&[0.0, 0.0]);
        pts.push(&[0.0, 0.0]);
        pts.push(&[5.0, 5.0]);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let mut got = t.query_vec(&Euclidean, &[0.1, 0.0], 0.5);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn query_uses_fewer_distance_calls_than_brute() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(56), 2000, 6, 12, 0.03);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 8, root: 0 });
        let counted = Counted::new(Euclidean);
        let mut out = Vec::new();
        t.query(&counted, pts.row(0), 0.1, &mut out);
        assert!(
            counted.count() < 2000 / 2,
            "query used {} distance calls (n=2000)",
            counted.count()
        );
    }

    #[test]
    fn nesting_reuse_saves_distance_calls() {
        // The batched traversal must evaluate strictly fewer distances than
        // the naive "one call per (visited node, active query)" bound.
        let pts = random_dense(59, 500, 4);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let counted = Counted::new(Euclidean);
        let mut pairs = 0u64;
        t.query_batch(&counted, &pts, 0.5, |_, _, _| pairs += 1);
        let calls_with_reuse = counted.count();
        assert!(calls_with_reuse > 0);
        let naive_lower_bound = calls_with_reuse + pts.len() as u64;
        let mut want_pairs = 0u64;
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                if Euclidean.dist_ij(&pts, i, j) <= 0.5 {
                    want_pairs += 1;
                }
            }
        }
        assert_eq!(pairs, want_pairs);
        assert!(naive_lower_bound > calls_with_reuse);
    }

    #[test]
    fn par_batch_matches_sequential_batch() {
        // More queries than one PAR_QUERY_CHUNK so the sharded path runs.
        let pts = random_dense(60, 400, 3);
        let queries = random_dense(61, 2500, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let eps = 0.6;
        let mut seq: Vec<(u32, u32, u64)> = Vec::new();
        t.query_batch(&Euclidean, &queries, eps, |q, id, d| {
            seq.push((q as u32, id, d.to_bits()));
        });
        seq.sort_unstable();
        for threads in [1usize, 2, 4, 8] {
            let pool = crate::util::Pool::new(threads);
            let mut par: Vec<(u32, u32, u64)> = Vec::new();
            t.query_batch_par(&Euclidean, &queries, eps, &pool, |q, id, d| {
                par.push((q as u32, id, d.to_bits()));
            });
            par.sort_unstable();
            assert_eq!(seq, par, "threads={threads} (incl. distance bits)");
        }
    }

    #[test]
    fn par_self_join_matches_sequential() {
        let pts = random_dense(62, 1500, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let eps = 0.4;
        let mut seq: Vec<(u32, u32, u64)> = Vec::new();
        t.eps_self_join(&Euclidean, eps, |a, b, d| seq.push((a, b, d.to_bits())));
        seq.sort_unstable();
        for threads in [2usize, 5] {
            let pool = crate::util::Pool::new(threads);
            let mut par: Vec<(u32, u32, u64)> = Vec::new();
            t.eps_self_join_par(&Euclidean, eps, &pool, |a, b, d| par.push((a, b, d.to_bits())));
            par.sort_unstable();
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn empty_query_set() {
        let pts = random_dense(57, 10, 2);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams::default());
        let empty = DenseMatrix::new(2);
        let mut called = false;
        t.query_batch(&Euclidean, &empty, 1.0, |_, _, _| called = true);
        assert!(!called);
    }

    #[test]
    fn global_ids_reported() {
        let pts = random_dense(58, 15, 2);
        let ids: Vec<u32> = (200..215).collect();
        let t = CoverTree::build_with_ids(pts.clone(), ids, &Euclidean, &BuildParams::default());
        let res = t.query_vec(&Euclidean, pts.row(3), 0.0);
        assert!(res.contains(&203));
    }
}
