//! Level-ordered structure-of-arrays tree layout — the traversal-side
//! counterpart of the batch builder.
//!
//! The builder's node arena is laid out in *construction* order (the LIFO
//! hub worklist), so a query descending the tree hops around the arena and
//! chases a separate children array. [`FlatTree`] renumbers the vertices
//! **breadth-first** once per build:
//!
//! * node `0` is the root and every BFS layer occupies one contiguous id
//!   range ([`FlatTree::level`]), so wide traversals sweep forward through
//!   memory — the compressed-cover-tree / metric-skip-list layout insight;
//! * because children are appended to the BFS order exactly when their
//!   parent is visited, **the children of any node form a contiguous id
//!   range** `first_child[u] .. first_child[u] + child_len[u]`. The child
//!   arena disappears entirely: descending is an indexed range scan over
//!   four parallel arrays (`point`, `radius`, `first_child`, `child_len`)
//!   instead of a pointer chase;
//! * the renumber is a *pure permutation* decided only by the legacy
//!   arrays, and it preserves the per-node child order. A DFS over the
//!   flat layout therefore pushes, pops, prunes and emits in **exactly**
//!   the order the legacy traversal did — same metric evaluations, same
//!   accept sequence, bit-identical outputs (gated by the
//!   `flat_matches_legacy_*` tests in `query.rs` and the cross-layout
//!   section of `examples/perf_driver.rs`).
//!
//! Radii stay `f64` (they are compared against `d + ε` sums); ids are
//! `u32` throughout, matching the rest of the crate.

use super::{Node, NIL};
use std::ops::Range;

/// The level-ordered SoA layout of one built cover tree. Constructed by
/// [`FlatTree::from_arena`] at the end of every build (sequential,
/// parallel — which replays to the identical arena — and empty).
#[derive(Clone, Debug, Default)]
pub struct FlatTree {
    /// Point index (into the owning tree's point set) of each node.
    point: Vec<u32>,
    /// Vertex-triple radius of each node (0 for leaves).
    radius: Vec<f64>,
    /// First child id of each node; children are the contiguous range
    /// `first_child[u] .. first_child[u] + child_len[u]` (empty for
    /// leaves, where the start value is meaningless).
    first_child: Vec<u32>,
    /// Child count of each node (0 ⇒ leaf).
    child_len: Vec<u32>,
    /// BFS layer boundaries: layer `d` is `level_off[d] .. level_off[d+1]`.
    level_off: Vec<u32>,
}

impl FlatTree {
    /// Deterministic BFS renumber of the legacy `(nodes, children, root)`
    /// arena (also reachable as `FlatTree::default()` for the empty
    /// layout). Every node must be reachable from `root` (true for every
    /// builder output); an empty tree (`root == NIL`) yields the empty
    /// layout.
    // lint: cold
    pub(crate) fn from_arena(nodes: &[Node], children: &[u32], root: u32) -> Self {
        if root == NIL || nodes.is_empty() {
            return FlatTree::default();
        }
        let n = nodes.len();
        let mut point = Vec::with_capacity(n);
        let mut radius = Vec::with_capacity(n);
        let mut first_child = Vec::with_capacity(n);
        let mut child_len = Vec::with_capacity(n);
        // `order[new_id] = legacy_id`; processing in push order IS the BFS,
        // and children are appended when their parent is processed, so each
        // node's children get consecutive new ids starting at the queue
        // length observed at that moment.
        let mut order: Vec<u32> = Vec::with_capacity(n);
        order.push(root);
        let mut i = 0usize;
        while i < order.len() {
            let nd = &nodes[order[i] as usize];
            point.push(nd.point);
            radius.push(nd.radius);
            child_len.push(nd.child_len);
            first_child.push(order.len() as u32);
            let lo = nd.child_off as usize;
            order.extend_from_slice(&children[lo..lo + nd.child_len as usize]);
            i += 1;
        }
        debug_assert_eq!(order.len(), n, "unreachable nodes in the build arena");
        // Layer boundaries: the children of layer [lo, hi) are exactly the
        // next `sum(child_len[lo..hi])` ids.
        let mut level_off: Vec<u32> = vec![0, 1];
        loop {
            let m = level_off.len();
            let (lo, hi) = (level_off[m - 2] as usize, level_off[m - 1] as usize);
            if hi >= order.len() {
                break;
            }
            let kids: u32 = child_len[lo..hi].iter().sum();
            level_off.push(hi as u32 + kids);
        }
        FlatTree { point, radius, first_child, child_len, level_off }
    }

    /// Number of nodes.
    #[inline]
    pub fn len(&self) -> usize {
        self.point.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.point.is_empty()
    }

    /// The root node id (0). Only meaningful when the tree is non-empty.
    #[inline]
    pub fn root(&self) -> u32 {
        0
    }

    /// Point index of node `u`.
    #[inline]
    pub(crate) fn point(&self, u: u32) -> u32 {
        self.point[u as usize]
    }

    /// Triple radius of node `u`.
    #[inline]
    pub(crate) fn radius(&self, u: u32) -> f64 {
        self.radius[u as usize]
    }

    #[inline]
    pub(crate) fn is_leaf(&self, u: u32) -> bool {
        self.child_len[u as usize] == 0
    }

    /// Children of node `u` as a contiguous id range (empty for leaves).
    #[inline]
    pub(crate) fn children(&self, u: u32) -> Range<u32> {
        let first = self.first_child[u as usize];
        first..first + self.child_len[u as usize]
    }

    /// Number of BFS layers (0 for the empty tree).
    pub fn num_levels(&self) -> usize {
        self.level_off.len().saturating_sub(1)
    }

    /// The contiguous id range of BFS layer `d` (root layer is 0).
    pub fn level(&self, d: usize) -> Range<usize> {
        self.level_off[d] as usize..self.level_off[d + 1] as usize
    }

    /// Structural self-check against the legacy arena: same node count,
    /// and for every flat node the `(point, radius bits, child count)`
    /// triple matches its legacy counterpart under the BFS permutation,
    /// with children preserved in order. Test-only gate; O(n).
    #[cfg(test)]
    pub(crate) fn verify_against(&self, nodes: &[Node], children: &[u32], root: u32) {
        if root == NIL {
            assert!(self.is_empty(), "flat layout non-empty for an empty tree");
            return;
        }
        assert_eq!(self.len(), nodes.len(), "flat layout lost nodes");
        // Recompute the permutation by the same BFS and compare fields.
        let mut order: Vec<u32> = Vec::with_capacity(nodes.len());
        order.push(root);
        let mut i = 0usize;
        while i < order.len() {
            let nd = &nodes[order[i] as usize];
            assert_eq!(self.point[i], nd.point, "point mismatch at flat id {i}");
            assert_eq!(
                self.radius[i].to_bits(),
                nd.radius.to_bits(),
                "radius bits mismatch at flat id {i}"
            );
            assert_eq!(self.child_len[i], nd.child_len, "child count mismatch at flat id {i}");
            assert_eq!(
                self.first_child[i] as usize,
                order.len(),
                "children of flat id {i} not contiguous at the BFS frontier"
            );
            let lo = nd.child_off as usize;
            order.extend_from_slice(&children[lo..lo + nd.child_len as usize]);
            i += 1;
        }
        // Layer ranges tile [0, n) in order.
        assert_eq!(self.level_off.first(), Some(&0));
        assert_eq!(*self.level_off.last().expect("nonempty offsets") as usize, self.len());
        for w in self.level_off.windows(2) {
            assert!(w[0] < w[1], "empty or inverted BFS layer");
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::covertree::{BuildParams, CoverTree};
    use crate::metric::{Euclidean, Hamming};
    use crate::points::{DenseMatrix, HammingCodes};
    use crate::util::Rng;

    fn random_dense(seed: u64, n: usize, d: usize) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut m = DenseMatrix::new(d);
        for _ in 0..n {
            let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            m.push(&row);
        }
        m
    }

    #[test]
    fn flat_layout_matches_arena_across_leaf_sizes() {
        let pts = random_dense(900, 300, 4);
        for leaf_size in [1usize, 4, 16, 64] {
            let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size, root: 0 });
            assert_eq!(t.flat().len(), t.num_nodes(), "leaf={leaf_size}");
            let (root, _, _) = t.structure();
            t.flat().verify_against(t.raw_nodes(), t.raw_children(), root);
        }
    }

    #[test]
    fn flat_layout_levels_partition_the_nodes() {
        let pts = random_dense(901, 200, 3);
        let t = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 2, root: 0 });
        let flat = t.flat();
        let mut covered = 0usize;
        for d in 0..flat.num_levels() {
            let r = flat.level(d);
            assert_eq!(r.start, covered, "layer {d} not contiguous");
            covered = r.end;
        }
        assert_eq!(covered, flat.len());
        assert_eq!(flat.level(0), 0..1, "root layer is node 0");
    }

    #[test]
    fn flat_layout_handles_degenerate_trees() {
        // Empty.
        let empty = CoverTree::build(&DenseMatrix::new(2), &Euclidean, &BuildParams::default());
        assert!(empty.flat().is_empty());
        assert_eq!(empty.flat().num_levels(), 0);
        // Singleton: one node, one layer.
        let one = CoverTree::build(
            &DenseMatrix::from_flat(2, vec![1.0, 2.0]),
            &Euclidean,
            &BuildParams::default(),
        );
        assert_eq!(one.flat().len(), 1);
        assert_eq!(one.flat().num_levels(), 1);
        // All-duplicate points: root + n leaves in two layers.
        let mut dup = DenseMatrix::new(2);
        for _ in 0..7 {
            dup.push(&[3.0, 3.0]);
        }
        let t = CoverTree::build(&dup, &Euclidean, &BuildParams::default());
        assert_eq!(t.flat().num_levels(), 2);
        assert_eq!(t.flat().level(1).len(), 7);
    }

    #[test]
    fn flat_layout_identical_for_par_builds() {
        let pts = random_dense(902, 250, 3);
        let params = BuildParams { leaf_size: 4, root: 0 };
        let seq = CoverTree::build(&pts, &Euclidean, &params);
        for threads in [2usize, 4] {
            let pool = crate::util::Pool::new(threads);
            let par = CoverTree::build_par(&pts, &Euclidean, &params, &pool);
            // structure() equality already implies this, but check the
            // derived layout directly too.
            let (root, _, _) = par.structure();
            par.flat().verify_against(seq.raw_nodes(), seq.raw_children(), root);
        }
    }

    #[test]
    fn flat_layout_hamming() {
        let mut rng = Rng::new(903);
        let mut codes = HammingCodes::new(64);
        for _ in 0..150 {
            codes.push_bits(&(0..64).map(|_| rng.bool(0.5)).collect::<Vec<_>>());
        }
        let t = CoverTree::build(&codes, &Hamming, &BuildParams { leaf_size: 4, root: 0 });
        let (root, _, _) = t.structure();
        t.flat().verify_against(t.raw_nodes(), t.raw_children(), root);
    }
}
