//! Epoch-snapshot mutation layer (DESIGN.md §13): a batch-built base tree
//! that only changes at **compaction**, a bounded insert-tree delta, and
//! tombstone bitsets — the structure behind the facade's mutable
//! `insert-cover-tree` backend and the serve daemon's `--mutable` mode.
//!
//! The concurrency contract is *writer-publishes, readers-swap*:
//!
//! * **Readers** take the core read-lock for the duration of one query and
//!   traverse the immutable [`FlatTree`](super::FlatTree) of the current
//!   base epoch plus the (capacity-capped) delta tree, both through a
//!   caller-owned [`QueryScratch`] — the steady-state read path performs
//!   **zero heap allocations** (perf_driver keeps `steady_state_allocs ==
//!   0` armed over this path).
//! * **Writers** serialize on a dedicated mutex. Point mutations (insert
//!   into the delta, tombstone in either layer) hold the core write-lock
//!   only for the O(log n) marking itself. Compaction — triggered once the
//!   delta reaches `delta_cap` points or tombstones exceed `compact_frac`
//!   of the base — gathers the live points under a *read* lock, rebuilds a
//!   fresh base through the batch builder ([`CoverTree::build_with_ids`])
//!   with **no lock held**, then publishes the new epoch with one brief
//!   write-lock swap. Readers keep answering on the previous epoch for the
//!   whole rebuild: read throughput is independent of writer progress
//!   (the SOLANET-style snapshot discipline, PAPERS.md).
//!
//! Ids are global and permanent: the build-time points get `0..n`, every
//! insert gets the next id, and compaction *preserves* ids while dropping
//! tombstoned points entirely — which is also why a snapshot saved through
//! [`EpochTree::snapshot_bytes`] (compact-then-encode) carries no
//! tombstones and round-trips through the ordinary `NGI-IDX1` codec.
//!
//! Conformance gate (`tests/mutation_conformance.rs`): after every prefix
//! of a seeded insert/delete/query schedule, ε and k-NN answers are
//! bit-equal to a brute-force rebuild over the live `(id, point)` set —
//! across metrics, thread counts and compaction points.

use super::incremental::InsertCoverTree;
use super::knn::push_cand;
use super::scratch::{Cand, Frontier};
use super::snapshot::SnapshotError;
use super::{BuildParams, CoverTree, QueryScratch};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::fmax;
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Compaction policy of an [`EpochTree`].
#[derive(Clone, Copy, Debug)]
pub struct EpochParams {
    /// Compact once the delta tree holds this many points (inserts since
    /// the last epoch). Also bounds the linear part of every query.
    pub delta_cap: usize,
    /// Compact once tombstones exceed this fraction of the base size.
    pub compact_frac: f64,
}

impl Default for EpochParams {
    fn default() -> Self {
        EpochParams { delta_cap: 256, compact_frac: 0.25 }
    }
}

/// One epoch of index state — everything a reader needs for one query.
struct Core<P: PointSet> {
    /// Monotone epoch counter, bumped by each compaction.
    epoch: u64,
    /// The batch-built base; immutable within an epoch. `Arc` so the
    /// snapshot writer can encode it outside the lock.
    base: Arc<CoverTree<P>>,
    /// Base tombstones, by base-local point index.
    base_dead: Vec<bool>,
    base_dead_count: usize,
    /// Whether `base.ids()` is ascending (always true for built or
    /// compacted trees; a hand-crafted snapshot may disagree) — picks
    /// binary vs. linear id lookup on delete.
    base_sorted: bool,
    /// Inserts since the last compaction; carries its own tombstones.
    delta: InsertCoverTree<P>,
    /// Global id of each delta-local point (ascending by construction).
    delta_gids: Vec<u32>,
    /// Next id to assign.
    next_id: u32,
    /// Live (non-tombstoned) points across both layers.
    live: usize,
}

/// A mutable near-neighbor structure with epoch-snapshot reads — see the
/// module docs for the concurrency contract. Metrics are passed per call
/// (the crate's trees store no metric), so one `EpochTree` serves any
/// metric its callers keep fixed.
pub struct EpochTree<P: PointSet> {
    build_params: BuildParams,
    params: EpochParams,
    /// Serializes all mutation (insert/delete/compact/save) so compaction
    /// can rebuild outside the core lock without the world shifting.
    writer: Mutex<()>,
    core: RwLock<Core<P>>,
}

/// Poison-recovering lock helpers: a panicking writer leaves per-query
/// state consistent (mutations mark-then-count under one guard), so the
/// readers keep serving rather than cascading the panic — the same
/// recovery idiom as the serve outbox.
fn read_core<P: PointSet>(l: &RwLock<Core<P>>) -> RwLockReadGuard<'_, Core<P>> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn write_core<P: PointSet>(l: &RwLock<Core<P>>) -> RwLockWriteGuard<'_, Core<P>> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn lock_writer(l: &Mutex<()>) -> MutexGuard<'_, ()> {
    match l.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn is_ascending(ids: &[u32]) -> bool {
    ids.windows(2).all(|w| w[0] < w[1])
}

impl<P: PointSet> EpochTree<P> {
    /// Build epoch 0 over `points` with global ids `0..n` through the
    /// batch builder.
    // lint: cold
    pub fn build<M: Metric<P>>(
        points: &P,
        metric: &M,
        build_params: &BuildParams,
        params: EpochParams,
    ) -> Self {
        let n = points.len() as u32;
        let ids: Vec<u32> = (0..n).collect();
        let base = CoverTree::build_with_ids(points.clone(), ids, metric, build_params);
        Self::from_tree(base, metric, build_params, params)
    }

    /// Wrap an already-built (e.g. snapshot-loaded) tree as epoch 0. Ids
    /// are taken as-is; the next insert gets `max(id) + 1`.
    // lint: cold
    pub fn from_tree<M: Metric<P>>(
        tree: CoverTree<P>,
        metric: &M,
        build_params: &BuildParams,
        params: EpochParams,
    ) -> Self {
        let next_id = tree.ids().iter().copied().max().map_or(0, |m| m + 1);
        let live = tree.num_points();
        let base_sorted = is_ascending(tree.ids());
        let delta = InsertCoverTree::build(&tree.points().empty_like(), metric);
        EpochTree {
            build_params: *build_params,
            params,
            writer: Mutex::new(()),
            core: RwLock::new(Core {
                epoch: 0,
                base_dead: vec![false; live],
                base_dead_count: 0,
                base_sorted,
                base: Arc::new(tree),
                delta,
                delta_gids: Vec::new(),
                next_id,
                live,
            }),
        }
    }

    /// Current epoch (compaction count since construction).
    pub fn epoch(&self) -> u64 {
        read_core(&self.core).epoch
    }

    /// Live (queryable) points.
    pub fn live(&self) -> usize {
        read_core(&self.core).live
    }

    /// Tombstoned points awaiting compaction, across base and delta.
    pub fn tombstones(&self) -> usize {
        let g = read_core(&self.core);
        g.base_dead_count + g.delta.num_tombstones()
    }

    /// The id the next insert will be assigned.
    pub fn next_id(&self) -> u32 {
        read_core(&self.core).next_id
    }

    /// Insert every point of `batch` (same shape as the indexed points),
    /// returning the contiguous global-id range assigned. May trigger a
    /// compaction (after the inserts are visible to readers).
    // lint: cold
    pub fn insert_from<M: Metric<P>>(&self, metric: &M, batch: &P) -> std::ops::Range<u32> {
        let _w = lock_writer(&self.writer);
        let range = {
            let mut g = write_core(&self.core);
            g.delta.insert_from(metric, batch);
            let lo = g.next_id;
            let count = batch.len() as u32;
            for off in 0..count {
                let gid = lo + off;
                g.delta_gids.push(gid);
            }
            g.next_id = lo + count;
            g.live += batch.len();
            lo..lo + count
        };
        self.maybe_compact(metric);
        range
    }

    /// Tombstone global id `gid`. Returns `false` when the id was never
    /// assigned, was already tombstoned, or was dropped by a compaction.
    /// May trigger a compaction once the dead fraction crosses the
    /// threshold.
    // lint: cold
    pub fn delete<M: Metric<P>>(&self, metric: &M, gid: u32) -> bool {
        let _w = lock_writer(&self.writer);
        let deleted = {
            let mut g = write_core(&self.core);
            let base_pos = if g.base_sorted {
                g.base.ids().binary_search(&gid).ok()
            } else {
                g.base.ids().iter().position(|&x| x == gid)
            };
            if let Some(pos) = base_pos {
                if g.base_dead[pos] {
                    false
                } else {
                    g.base_dead[pos] = true;
                    g.base_dead_count += 1;
                    g.live -= 1;
                    true
                }
            } else if let Ok(j) = g.delta_gids.binary_search(&gid) {
                if g.delta.delete(j as u32) {
                    g.live -= 1;
                    true
                } else {
                    false
                }
            } else {
                false
            }
        };
        if deleted {
            self.maybe_compact(metric);
        }
        deleted
    }

    /// Force a compaction: rebuild the base over exactly the live points
    /// (ids preserved, tombstones dropped), clear the delta, and publish
    /// the next epoch. Returns the new epoch number.
    // lint: cold
    pub fn compact<M: Metric<P>>(&self, metric: &M) -> u64 {
        let _w = lock_writer(&self.writer);
        self.compact_locked(metric)
    }

    /// Compact-then-encode: the saved `NGI-IDX1` snapshot holds exactly
    /// the live points under their global ids — tombstones are elided by
    /// construction, and the bytes load through the ordinary
    /// [`CoverTree::try_from_snapshot_bytes`] /
    /// [`EpochTree::from_tree`] path.
    // lint: cold
    pub fn snapshot_bytes<M: Metric<P>>(&self, metric: &M) -> Result<Vec<u8>, SnapshotError> {
        let _w = lock_writer(&self.writer);
        let dirty = {
            let g = read_core(&self.core);
            g.base_dead_count > 0 || g.delta.num_points() > 0
        };
        if dirty {
            self.compact_locked(metric);
        }
        let base = {
            let g = read_core(&self.core);
            Arc::clone(&g.base)
        };
        base.to_snapshot_bytes()
    }

    // lint: cold
    fn maybe_compact<M: Metric<P>>(&self, metric: &M) {
        // Caller holds the writer mutex.
        let (delta_n, dead, base_n) = {
            let g = read_core(&self.core);
            let dead = g.base_dead_count + g.delta.num_tombstones();
            (g.delta.num_points(), dead, g.base.num_points())
        };
        let delta_full = delta_n >= self.params.delta_cap;
        let too_dead = dead > 0 && (dead as f64) > self.params.compact_frac * (base_n as f64);
        if delta_full || too_dead {
            self.compact_locked(metric);
        }
    }

    /// The compaction body; caller holds the writer mutex, which is what
    /// licenses gathering under a read lock and rebuilding with no lock:
    /// no other writer can move the world underneath the rebuild, and
    /// readers keep serving the old epoch until the final swap.
    // lint: cold
    fn compact_locked<M: Metric<P>>(&self, metric: &M) -> u64 {
        let (points, ids, next_epoch) = {
            let g = read_core(&self.core);
            let mut locals: Vec<usize> = Vec::with_capacity(g.live);
            for i in 0..g.base.num_points() {
                if !g.base_dead[i] {
                    locals.push(i);
                }
            }
            let mut pts = g.base.points().gather(&locals);
            let mut ids: Vec<u32> = Vec::with_capacity(g.live);
            for &i in &locals {
                ids.push(g.base.ids()[i]);
            }
            locals.clear();
            for j in 0..g.delta.num_points() {
                if g.delta.is_live(j as u32) {
                    locals.push(j);
                }
            }
            pts.extend_from(&g.delta.points().gather(&locals));
            for &j in &locals {
                ids.push(g.delta_gids[j]);
            }
            (pts, ids, g.epoch + 1)
        };
        let tree = CoverTree::build_with_ids(points, ids, metric, &self.build_params);
        let fresh_delta = InsertCoverTree::build(&tree.points().empty_like(), metric);
        let n_live = tree.num_points();
        let base_sorted = is_ascending(tree.ids());
        let mut g = write_core(&self.core);
        debug_assert_eq!(n_live, g.live, "compaction must keep exactly the live points");
        g.base = Arc::new(tree);
        g.base_dead.clear();
        g.base_dead.resize(n_live, false);
        g.base_dead_count = 0;
        g.base_sorted = base_sorted;
        g.delta = fresh_delta;
        g.delta_gids.clear();
        g.epoch = next_epoch;
        g.epoch
    }

    /// ε-query over the live points: base traversal with tombstoned
    /// points skipped at emission, then the delta tree (which skips its
    /// own tombstones), with delta-local ids mapped to global ids in
    /// place. Appends `(global_id, distance)` pairs; allocation-free once
    /// `scratch` and `out` are warm.
    pub fn eps_query_with<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        eps: f64,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        let g = read_core(&self.core);
        if !g.base.is_empty() {
            let flat = g.base.flat();
            let stack = &mut scratch.stack;
            stack.clear();
            let root = flat.root();
            let root_pt = flat.point(root);
            let d = metric.dist(query, g.base.points().point(root_pt as usize));
            if flat.is_leaf(root) {
                if d <= eps && !g.base_dead[root_pt as usize] {
                    out.push((g.base.ids()[root_pt as usize], d));
                }
            } else {
                if d <= flat.radius(root) + eps {
                    stack.push((root, d));
                }
                while let Some((u, du)) = stack.pop() {
                    let un_point = flat.point(u);
                    for v in flat.children(u) {
                        let vp = flat.point(v);
                        // Nesting reuse: the child sharing the parent's
                        // point is at the same distance.
                        let dv = if vp == un_point {
                            du
                        } else {
                            metric.dist(query, g.base.points().point(vp as usize))
                        };
                        if flat.is_leaf(v) {
                            if dv <= eps && !g.base_dead[vp as usize] {
                                out.push((g.base.ids()[vp as usize], dv));
                            }
                        } else if dv <= flat.radius(v) + eps {
                            stack.push((v, dv));
                        }
                    }
                }
            }
        }
        let before = out.len();
        g.delta.query_weighted_with(metric, query, eps, scratch, out);
        for pair in out[before..].iter_mut() {
            pair.0 = g.delta_gids[pair.0 as usize];
        }
    }

    /// Tie-exact k-NN over the live points: a tombstone-aware mirror of
    /// [`CoverTree::knn_within_with`]'s best-first traversal (dead leaves
    /// never enter the candidate heap, so they cannot evict live
    /// candidates), then the bounded delta folded into the same heap.
    /// `out` is cleared and filled ascending by `(distance, id)` — the
    /// same total order as every other k-NN path, so a brute-force
    /// rebuild reproduces it bit for bit.
    pub fn knn_with<M: Metric<P>>(
        &self,
        metric: &M,
        query: P::Point<'_>,
        k: usize,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        out.clear();
        if k == 0 {
            return;
        }
        let g = read_core(&self.core);
        let QueryScratch { best, frontier, .. } = scratch;
        best.clear();
        frontier.clear();
        if !g.base.is_empty() {
            let flat = g.base.flat();
            let root = flat.root();
            let d = metric.dist(query, g.base.points().point(flat.point(root) as usize));
            let bound = fmax(d - flat.radius(root), 0.0);
            frontier.push(Frontier { bound, node: root, dist: d });
            while let Some(Frontier { bound, node, dist }) = frontier.pop() {
                if best.len() == k {
                    if let Some(top) = best.peek() {
                        if bound > top.dist {
                            break;
                        }
                    }
                }
                if flat.is_leaf(node) {
                    let lp = flat.point(node) as usize;
                    if !g.base_dead[lp] {
                        push_cand(best, k, Cand { dist, gid: g.base.ids()[lp] });
                    }
                    continue;
                }
                let un_point = flat.point(node);
                for c in flat.children(node) {
                    let cp = flat.point(c);
                    let dc = if cp == un_point {
                        dist
                    } else {
                        metric.dist(query, g.base.points().point(cp as usize))
                    };
                    let cb = fmax(dc - flat.radius(c), 0.0);
                    let admit =
                        best.len() < k || matches!(best.peek(), Some(top) if cb <= top.dist);
                    if admit {
                        frontier.push(Frontier { bound: cb, node: c, dist: dc });
                    }
                }
            }
        }
        // The delta holds at most `delta_cap` points: a live linear scan
        // through the same k-bounded admission keeps the merged result
        // tie-exact without a second traversal structure.
        for j in 0..g.delta.num_points() {
            if !g.delta.is_live(j as u32) {
                continue;
            }
            let dj = metric.dist(query, g.delta.points().point(j));
            push_cand(best, k, Cand { dist: dj, gid: g.delta_gids[j] });
        }
        while let Some(c) = best.pop() {
            out.push((c.gid, c.dist));
        }
        out.reverse();
        out.truncate(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::{Euclidean, Hamming};
    use crate::points::DenseMatrix;
    use crate::util::Rng;

    fn brute_eps(
        live: &[(u32, Vec<f32>)],
        q: &[f32],
        eps: f64,
    ) -> Vec<(u32, f64)> {
        let mut out: Vec<(u32, f64)> = live
            .iter()
            .map(|(gid, p)| (*gid, crate::metric::Metric::dist(&Euclidean, q, &p[..])))
            .filter(|&(_, d)| d <= eps)
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    fn brute_knn(live: &[(u32, Vec<f32>)], q: &[f32], k: usize) -> Vec<(u32, f64)> {
        let mut all: Vec<(u32, f64)> = live
            .iter()
            .map(|(gid, p)| (*gid, crate::metric::Metric::dist(&Euclidean, q, &p[..])))
            .collect();
        all.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    fn eps_sorted(t: &EpochTree<DenseMatrix>, q: &[f32], eps: f64) -> Vec<(u32, f64)> {
        let mut scratch = QueryScratch::new();
        let mut out = Vec::new();
        t.eps_query_with(&Euclidean, q, eps, &mut scratch, &mut out);
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    #[test]
    fn mutations_track_brute_force_through_compactions() {
        let mut rng = Rng::new(900);
        let all = crate::data::synthetic::gaussian_mixture(&mut rng, 400, 4, 4, 0.25);
        let seed = all.slice(0, 120);
        let params = EpochParams { delta_cap: 16, compact_frac: 0.2 };
        let t = EpochTree::build(&seed, &Euclidean, &BuildParams { leaf_size: 4, root: 0 }, params);
        let mut live: Vec<(u32, Vec<f32>)> =
            (0..120).map(|i| (i as u32, seed.row(i).to_vec())).collect();
        let mut next = 120usize;
        let mut scratch = QueryScratch::new();
        let mut out = Vec::new();
        for step in 0..200 {
            let coin = rng.next_u64() % 10;
            if coin < 4 && next < all.len() {
                // Insert one point from the reserve.
                let batch = all.slice(next, next + 1);
                let r = t.insert_from(&Euclidean, &batch);
                live.push((r.start, all.row(next).to_vec()));
                next += 1;
            } else if coin < 7 && !live.is_empty() {
                let victim = live[(rng.next_u64() as usize) % live.len()].0;
                assert!(t.delete(&Euclidean, victim), "live id must delete");
                live.retain(|&(gid, _)| gid != victim);
                assert!(!t.delete(&Euclidean, victim), "second delete must be false");
            } else if coin == 7 {
                t.compact(&Euclidean);
            }
            assert_eq!(t.live(), live.len(), "step {step}");
            // Every prefix bit-equal to brute force over the live set.
            let q = all.row((step * 7) % all.len());
            for eps in [0.15, 0.6] {
                let want = brute_eps(&live, q, eps);
                assert_eq!(eps_sorted(&t, q, eps), want, "step {step} eps {eps}");
            }
            t.knn_with(&Euclidean, q, 5, &mut scratch, &mut out);
            assert_eq!(out, brute_knn(&live, q, 5), "step {step} knn");
        }
        assert!(t.epoch() > 0, "the schedule must have compacted at least once");
    }

    #[test]
    fn delete_of_unknown_or_compacted_ids_is_false() {
        let pts = crate::data::synthetic::uniform(&mut Rng::new(901), 40, 3, 1.0);
        let t = EpochTree::build(
            &pts,
            &Euclidean,
            &BuildParams::default(),
            EpochParams::default(),
        );
        assert!(!t.delete(&Euclidean, 40), "never-assigned id");
        assert!(t.delete(&Euclidean, 7));
        t.compact(&Euclidean);
        assert!(!t.delete(&Euclidean, 7), "compacted-away id");
        assert_eq!(t.live(), 39);
        assert_eq!(t.tombstones(), 0);
    }

    #[test]
    fn snapshot_elides_tombstones_and_roundtrips() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(902), 90, 3, 3, 0.3);
        let params = EpochParams { delta_cap: 64, compact_frac: 0.9 };
        let bp = BuildParams { leaf_size: 4, root: 0 };
        let t = EpochTree::build(&pts, &Euclidean, &bp, params);
        for gid in [3u32, 4, 5, 50] {
            assert!(t.delete(&Euclidean, gid));
        }
        let extra = pts.slice(0, 5);
        let r = t.insert_from(&Euclidean, &extra);
        assert_eq!(r, 90..95);
        let bytes = t.snapshot_bytes(&Euclidean).expect("dense encodes");
        let back = CoverTree::<DenseMatrix>::try_from_snapshot_bytes(&bytes).expect("decodes");
        assert_eq!(back.num_points(), 90 - 4 + 5, "tombstones elided, inserts kept");
        assert!(!back.ids().contains(&3), "dead ids dropped from the snapshot");
        assert!(back.ids().contains(&94));
        // Reload as a mutable tree: ids and answers carry over, and the
        // next insert continues past the highest surviving id.
        let t2 = EpochTree::from_tree(back, &Euclidean, &bp, params);
        assert_eq!(t2.next_id(), 95);
        assert_eq!(t2.live(), 91);
        let q = pts.row(10);
        assert_eq!(eps_sorted(&t2, q, 0.5), eps_sorted(&t, q, 0.5));
    }

    #[test]
    fn hamming_epoch_tree_matches_brute_force() {
        let codes = crate::data::synthetic::hamming_clusters(&mut Rng::new(903), 100, 64, 3, 0.1);
        let t = EpochTree::build(
            &codes,
            &Hamming,
            &BuildParams { leaf_size: 4, root: 0 },
            EpochParams { delta_cap: 8, compact_frac: 0.25 },
        );
        for gid in 0..30u32 {
            assert!(t.delete(&Hamming, gid * 3));
        }
        let extra = codes.slice(0, 10);
        t.insert_from(&Hamming, &extra);
        let mut scratch = QueryScratch::new();
        let mut out = Vec::new();
        for qi in [0usize, 13, 99] {
            out.clear();
            t.eps_query_with(&Hamming, codes.code(qi), 12.0, &mut scratch, &mut out);
            out.sort_by(|a, b| a.0.cmp(&b.0));
            let mut want = Vec::new();
            for i in 0..codes.len() as u32 {
                if i < 90 && i % 3 == 0 && i / 3 < 30 {
                    continue; // deleted
                }
                let d = Metric::dist(&Hamming, codes.code(qi), codes.code(i as usize));
                if d <= 12.0 {
                    want.push((i, d));
                }
            }
            for (j, i) in (0..10u32).enumerate() {
                let d =
                    crate::metric::Metric::dist(&Hamming, codes.code(qi), codes.code(i as usize));
                if d <= 12.0 {
                    want.push((100 + j as u32, d));
                }
            }
            want.sort_by(|a, b| a.0.cmp(&b.0));
            assert_eq!(out, want, "qi={qi}");
        }
    }

    #[test]
    fn readers_keep_answering_while_a_writer_churns() {
        // Read-while-write smoke: reader threads hammer queries while one
        // writer inserts/deletes through several compactions; every read
        // must come back internally consistent (no panic, every reported
        // distance is within eps).
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(904), 300, 4, 4, 0.2);
        let t = EpochTree::build(
            &pts,
            &Euclidean,
            &BuildParams { leaf_size: 4, root: 0 },
            EpochParams { delta_cap: 8, compact_frac: 0.1 },
        );
        std::thread::scope(|s| {
            for r in 0..3usize {
                let t = &t;
                let pts = &pts;
                s.spawn(move || {
                    let mut scratch = QueryScratch::new();
                    let mut out = Vec::new();
                    for i in 0..400usize {
                        let q = pts.row((i * (r + 2)) % pts.len());
                        out.clear();
                        t.eps_query_with(&Euclidean, q, 0.4, &mut scratch, &mut out);
                        for &(_, d) in &out {
                            assert!(d <= 0.4);
                        }
                        t.knn_with(&Euclidean, q, 3, &mut scratch, &mut out);
                        assert!(out.len() <= 3);
                    }
                });
            }
            let writer = &t;
            let pts = &pts;
            s.spawn(move || {
                let mut rng = Rng::new(905);
                for i in 0..150usize {
                    if i % 3 == 0 {
                        let j = (rng.next_u64() as usize) % pts.len();
                        writer.insert_from(&Euclidean, &pts.slice(j, j + 1));
                    } else {
                        let gid = (rng.next_u64() % writer.next_id() as u64) as u32;
                        writer.delete(&Euclidean, gid);
                    }
                }
            });
        });
        assert!(t.epoch() > 0);
    }
}
