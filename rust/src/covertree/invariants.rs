//! Cover-tree invariant checker, used by unit tests and the property suite.
//!
//! Checks, for every vertex:
//! 1. **Nesting** — every internal vertex has a child associated with the
//!    same point;
//! 2. **Covering (triple form)** — every descendant leaf point lies within
//!    `radius(v)` of `point(v)` (this is the bound queries prune with, so it
//!    is the invariant correctness actually depends on);
//! 3. **Separating** — siblings under a parent with radius `r` are pairwise
//!    more than `r/2` apart (vacuous for leaf-only sibling groups created by
//!    the ζ cutoff and duplicate collapse, matching the relaxed definition);
//! 4. **Leaf partition** — the multiset of leaf points equals the input
//!    point multiset (every point appears in exactly one leaf).

use super::CoverTree;
use crate::metric::Metric;
use crate::points::PointSet;

/// Panic with a descriptive message if any invariant is violated.
pub fn check_invariants<P: PointSet, M: Metric<P>>(tree: &CoverTree<P>, metric: &M) {
    if tree.is_empty() {
        assert_eq!(tree.num_points(), 0, "non-empty point set but empty tree");
        return;
    }
    let slack = 1e-9;
    let mut leaf_points: Vec<u32> = Vec::new();
    let mut stack = vec![tree.root()];
    while let Some(u) = stack.pop() {
        let node = tree.node(u);
        let children = tree.node_children(u);
        if node.is_leaf() {
            leaf_points.push(node.point);
            assert_eq!(node.radius, 0.0, "leaf {u} has nonzero radius");
            continue;
        }

        // (1) nesting: some child shares the parent's point, unless all
        // children are leaves (the ζ cutoff attaches every member of the
        // hub, including the center, as leaves — nesting still holds
        // because the center appears among them).
        assert!(
            children.iter().any(|&c| tree.node(c).point == node.point),
            "nesting violated at node {u}"
        );

        // (2) covering: every descendant leaf within radius of this point.
        let p = tree.points().point(node.point as usize);
        let mut sub = vec![u];
        while let Some(w) = sub.pop() {
            let wn = tree.node(w);
            if wn.is_leaf() {
                let d = metric.dist(p, tree.points().point(wn.point as usize));
                assert!(
                    d <= node.radius + slack + 1e-6 * node.radius.abs(),
                    "covering violated: leaf point {} at distance {d} > radius {} of node {u}",
                    wn.point,
                    node.radius
                );
            } else {
                sub.extend_from_slice(tree.node_children(w));
            }
        }

        // (3) separating: internal siblings pairwise > r/2 apart.
        let internal: Vec<u32> =
            children.iter().copied().filter(|&c| !tree.node(c).is_leaf()).collect();
        // The separating bound applies to the centers chosen by SplitVertex;
        // all children (internal or since-collapsed leaves of singleton
        // hubs) were centers, but ζ-cutoff leaf fans were *members*, not
        // centers. Distinguish: a leaf fan exists iff every child is a leaf.
        let all_leaves = children.iter().all(|&c| tree.node(c).is_leaf());
        if !all_leaves {
            let r = node.radius;
            let pts: Vec<u32> = if internal.len() == children.len() {
                children.iter().map(|&c| tree.node(c).point).collect()
            } else {
                // Mixed fan: centers are exactly the children (each child
                // was created by SplitVertex as a center; singleton hubs
                // collapse to leaves but were still centers).
                children.iter().map(|&c| tree.node(c).point).collect()
            };
            for i in 0..pts.len() {
                for j in i + 1..pts.len() {
                    if pts[i] == pts[j] {
                        continue; // duplicate points can both be centers only via nesting
                    }
                    let d = metric.dist_ij(tree.points(), pts[i] as usize, pts[j] as usize);
                    assert!(
                        d > r / 2.0 - slack - 1e-6 * r.abs(),
                        "separating violated under node {u}: centers {} and {} at distance {d} ≤ r/2 = {}",
                        pts[i],
                        pts[j],
                        r / 2.0
                    );
                }
            }
        }

        stack.extend_from_slice(children);
    }

    // (4) leaf partition = input multiset.
    leaf_points.sort_unstable();
    let mut want: Vec<u32> = (0..tree.num_points() as u32).collect();
    want.sort_unstable();
    assert_eq!(
        leaf_points, want,
        "leaf points do not partition the input (every point must appear in exactly one leaf)"
    );
}
