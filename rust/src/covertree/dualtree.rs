//! Dual-tree ε self-join — an extension beyond the paper's batched
//! single-point queries (Algorithm 3): traverse *pairs* of cover-tree
//! nodes and prune whole subtree pairs at once with
//! `d(p_u, p_v) > r_u + r_v + ε`.
//!
//! For self-joins this does strictly less work than querying every point
//! against the tree whenever sibling subtrees are far apart (the
//! compressed-cover-tree analysis: pruning subtree *pairs* is where exact
//! general-metric search wins); the `ablation` bench and the perf driver
//! compare it against the batched self-join. The distributed algorithms
//! keep the paper-faithful batched form as their default;
//! `--dualtree` / `index.dualtree` opts the self-join path in through the
//! facade ([`crate::index::NearIndex::eps_self_join`]).
//!
//! The traversal runs over the level-ordered [`FlatTree`] (contiguous
//! child ranges, no arena chase) with the pair stack owned by
//! [`QueryScratch`], so steady-state joins allocate nothing. Emitted
//! weights are [`Metric::dist`] values — bit-identical to the batched
//! self-join's weights, which the conformance gates
//! (`tests/index_equivalence.rs`) pin edge-for-edge.
//!
//! The parallel form is deterministic by construction: a sequential
//! breadth-first expansion (with pruning) grows the pair frontier on the
//! calling thread until there is enough independent work, then each
//! frontier seed's subtree-pair traversal runs on the pool and the
//! per-seed buffers are replayed in frontier order. The emitted sequence
//! therefore depends only on the tree and ε, never on the thread count.

use super::{CoverTree, FlatTree, QueryScratch};
use crate::metric::Metric;
use crate::points::PointSet;

impl<P: PointSet> CoverTree<P> {
    /// One dual-traversal step on the node pair `(u, v)`: emit (leaf-leaf
    /// within ε), prune (`d > r_u + r_v + ε`), or push the expanded child
    /// pairs. Shared by the sequential DFS and the parallel frontier
    /// expansion, so both visit the identical pair tree.
    #[inline]
    fn dual_step<M, F, G>(
        &self,
        flat: &FlatTree,
        metric: &M,
        eps: f64,
        u: u32,
        v: u32,
        push: &mut G,
        emit: &mut F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
        G: FnMut(u32, u32),
    {
        if u == v {
            // Self pair: every unordered child pair, including (a, a) —
            // the recursion that eventually pairs points *within* the
            // subtree.
            if flat.is_leaf(u) {
                return; // one point, no pair
            }
            let ch = flat.children(u);
            let (start, end) = (ch.start, ch.end);
            for a in start..end {
                for b in a..end {
                    push(a, b);
                }
            }
            return;
        }
        let (pu, pv) = (flat.point(u), flat.point(v));
        let (ru, rv) = (flat.radius(u), flat.radius(v));
        let d = metric.dist(self.points().point(pu as usize), self.points().point(pv as usize));
        // Prune: no descendant pair can be within eps.
        if d > ru + rv + eps {
            return;
        }
        match (flat.is_leaf(u), flat.is_leaf(v)) {
            (true, true) => {
                if d <= eps {
                    let ga = self.global_id(pu as usize);
                    let gb = self.global_id(pv as usize);
                    if ga < gb {
                        emit(ga, gb, d);
                    } else if gb < ga {
                        emit(gb, ga, d);
                    }
                    // ga == gb impossible: distinct leaves have distinct
                    // local points, and ids are unique per point.
                }
            }
            (false, true) => {
                for c in flat.children(u) {
                    push(c, v);
                }
            }
            (true, false) => {
                for c in flat.children(v) {
                    push(u, c);
                }
            }
            (false, false) => {
                // Expand the larger-radius side (standard dual-tree
                // heuristic: shrinks the pruning bound fastest).
                if ru >= rv {
                    for c in flat.children(u) {
                        push(c, v);
                    }
                } else {
                    for c in flat.children(v) {
                        push(u, c);
                    }
                }
            }
        }
    }

    /// Depth-first dual traversal of the whole subtree-pair tree rooted at
    /// `seed`, over a caller-owned pair stack.
    fn dual_traverse_from<M, F>(
        &self,
        flat: &FlatTree,
        metric: &M,
        eps: f64,
        seed: (u32, u32),
        stack: &mut Vec<(u32, u32)>,
        emit: &mut F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        stack.clear();
        stack.push(seed);
        while let Some((u, v)) = stack.pop() {
            self.dual_step(flat, metric, eps, u, v, &mut |a, b| stack.push((a, b)), emit);
        }
    }

    /// All unordered pairs of tree points within `eps`, via dual-tree
    /// traversal. Emits `(gid_a, gid_b, d)` with `gid_a < gid_b` exactly
    /// once per pair; `d` is exactly [`Metric::dist`] for the pair — the
    /// same weight bits as [`CoverTree::eps_self_join`]. Convenience
    /// wrapper over [`CoverTree::eps_self_join_dual_with`] with a
    /// throwaway scratch.
    pub fn eps_self_join_dual<M, F>(&self, metric: &M, eps: f64, emit: F)
    where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        let mut scratch = QueryScratch::new();
        self.eps_self_join_dual_with(metric, eps, &mut scratch, emit);
    }

    /// [`CoverTree::eps_self_join_dual`] with caller-owned traversal state
    /// (the pair stack lives in `scratch` and keeps its capacity across
    /// calls).
    pub fn eps_self_join_dual_with<M, F>(
        &self,
        metric: &M,
        eps: f64,
        scratch: &mut QueryScratch,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        if self.is_empty() {
            return;
        }
        let flat = self.flat();
        let seed = (flat.root(), flat.root());
        self.dual_traverse_from(flat, metric, eps, seed, &mut scratch.pairs, &mut emit);
    }

    /// Parallel [`CoverTree::eps_self_join_dual`] on `pool` — the
    /// identical weighted edge set, with an emission order that depends
    /// only on the tree and ε (never the thread count ≥ 2; a one-thread
    /// pool reproduces the sequential traversal verbatim). Convenience
    /// wrapper over [`CoverTree::eps_self_join_dual_par_with`].
    pub fn eps_self_join_dual_par<M, F>(
        &self,
        metric: &M,
        eps: f64,
        pool: &crate::util::Pool,
        emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        let mut scratch = QueryScratch::new();
        self.eps_self_join_dual_par_with(metric, eps, pool, &mut scratch, emit);
    }

    /// [`CoverTree::eps_self_join_dual_par`] with a caller-owned scratch
    /// for the sequential fall-through. The parallel route expands the
    /// pair frontier breadth-first (with pruning; terminal leaf-leaf
    /// pairs emit immediately) on the calling thread until it holds at
    /// least `threads × 4` independent seeds, then runs each seed's
    /// subtree-pair traversal on the pool in bounded waves and replays
    /// the per-seed buffers in frontier order.
    pub fn eps_self_join_dual_par_with<M, F>(
        &self,
        metric: &M,
        eps: f64,
        pool: &crate::util::Pool,
        scratch: &mut QueryScratch,
        mut emit: F,
    ) where
        M: Metric<P>,
        F: FnMut(u32, u32, f64),
    {
        if pool.threads() <= 1 {
            return self.eps_self_join_dual_with(metric, eps, scratch, emit);
        }
        if self.is_empty() {
            return;
        }
        let flat = self.flat();
        let target = pool.threads() * 4;
        // Frontier expansion runs once per join over node pairs, not per
        // point pair; the two ping-pong buffers are amortized across the
        // whole traversal the way the batch path's wave buffers are.
        // lint: allow(no-alloc-hot-path) reason="one frontier buffer per parallel join, amortized over the whole pair traversal"
        let mut frontier: Vec<(u32, u32)> = vec![(flat.root(), flat.root())];
        // lint: allow(no-alloc-hot-path) reason="one frontier buffer per parallel join, amortized over the whole pair traversal"
        let mut next: Vec<(u32, u32)> = Vec::new();
        while !frontier.is_empty() && frontier.len() < target {
            next.clear();
            for i in 0..frontier.len() {
                let (u, v) = frontier[i];
                self.dual_step(flat, metric, eps, u, v, &mut |a, b| next.push((a, b)), &mut emit);
            }
            std::mem::swap(&mut frontier, &mut next);
        }
        // Each expansion step strictly deepens every surviving pair, so
        // the loop terminates: either the frontier reaches the target
        // width or the whole join finished sequentially above.
        let wave = pool.threads() * 4;
        let mut first = 0usize;
        while first < frontier.len() {
            let count = wave.min(frontier.len() - first);
            let base = first;
            let parts = pool.run_indexed_with(
                count,
                |_| QueryScratch::new(),
                |sc, w| {
                    let seed = frontier[base + w];
                    // lint: allow(no-alloc-hot-path) reason="per-seed result buffer of one parallel wave, amortized over the seed's subtree pairs"
                    let mut out: Vec<(u32, u32, f64)> = Vec::new();
                    self.dual_traverse_from(flat, metric, eps, seed, &mut sc.pairs, &mut |a, b, d| {
                        out.push((a, b, d));
                    });
                    out
                },
            );
            for part in parts {
                for (a, b, d) in part {
                    emit(a, b, d);
                }
            }
            first += count;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Counted, Euclidean, Hamming, Levenshtein, Metric};
    use crate::points::{DenseMatrix, PointSet};
    use crate::util::{Pool, Rng};

    fn check_matches_batched<P: PointSet, M: Metric<P>>(pts: &P, metric: &M, eps: f64, leaf: usize) {
        let tree = CoverTree::build(pts, metric, &BuildParams { leaf_size: leaf, root: 0 });
        let mut dual: Vec<(u32, u32, u64)> = Vec::new();
        tree.eps_self_join_dual(metric, eps, |a, b, d| dual.push((a, b, d.to_bits())));
        dual.sort_unstable();
        dual.dedup();
        let mut batched: Vec<(u32, u32, u64)> = Vec::new();
        tree.eps_self_join(metric, eps, |a, b, d| batched.push((a, b, d.to_bits())));
        batched.sort_unstable();
        batched.dedup();
        assert_eq!(dual, batched, "eps={eps} leaf={leaf} (edges AND weight bits)");
        // The parallel dual join reproduces the same weighted edge set at
        // every pool size.
        for threads in [1usize, 3, 8] {
            let pool = Pool::new(threads);
            let mut par: Vec<(u32, u32, u64)> = Vec::new();
            tree.eps_self_join_dual_par(metric, eps, &pool, |a, b, d| {
                par.push((a, b, d.to_bits()));
            });
            par.sort_unstable();
            par.dedup();
            assert_eq!(par, batched, "eps={eps} leaf={leaf} threads={threads}");
        }
    }

    #[test]
    fn dual_matches_batched_euclidean() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(140), 250, 4, 5, 0.15);
        for leaf in [1usize, 4, 16] {
            for eps in [0.05, 0.3, 1.0] {
                check_matches_batched(&pts, &Euclidean, eps, leaf);
            }
        }
    }

    #[test]
    fn dual_matches_batched_hamming_and_edit() {
        let codes = crate::data::synthetic::hamming_clusters(&mut Rng::new(141), 150, 64, 3, 0.08);
        check_matches_batched(&codes, &Hamming, 12.0, 4);
        let reads = crate::data::synthetic::reads(&mut Rng::new(142), 80, 24, 4, 0.05);
        check_matches_batched(&reads, &Levenshtein, 4.0, 2);
    }

    #[test]
    fn dual_handles_duplicates() {
        let mut rng = Rng::new(143);
        let base = crate::data::synthetic::uniform(&mut rng, 40, 2, 1.0);
        let pts = crate::data::synthetic::with_duplicates(&mut rng, &base, 30);
        check_matches_batched(&pts, &Euclidean, 0.2, 8);
        check_matches_batched(&pts, &Euclidean, 0.0, 8); // dup-only pairs
    }

    #[test]
    fn dual_prunes_on_separated_clusters() {
        // Two far-apart blobs: the dual traversal should evaluate far
        // fewer distances than the batched per-point queries.
        let mut pts = DenseMatrix::new(2);
        let mut rng = Rng::new(144);
        for _ in 0..200 {
            pts.push(&[rng.normal_f32() * 0.1, rng.normal_f32() * 0.1]);
        }
        for _ in 0..200 {
            pts.push(&[100.0 + rng.normal_f32() * 0.1, rng.normal_f32() * 0.1]);
        }
        let eps = 0.15;
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());

        let dual_counted = Counted::new(Euclidean);
        let mut n_dual = 0u64;
        tree.eps_self_join_dual(&dual_counted, eps, |_, _, _| n_dual += 1);

        let batch_counted = Counted::new(Euclidean);
        let mut n_batch = 0u64;
        tree.eps_self_join(&batch_counted, eps, |_, _, _| n_batch += 1);

        assert_eq!(n_dual, n_batch, "result sets must agree");
        assert!(
            dual_counted.count() < batch_counted.count(),
            "dual ({}) should beat batched ({}) on separated clusters",
            dual_counted.count(),
            batch_counted.count()
        );
    }

    #[test]
    fn dual_scratch_reuse_is_stable_across_calls() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(145), 180, 3, 4, 0.2);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let mut scratch = QueryScratch::new();
        for round in 0..3 {
            for eps in [0.1, 0.4] {
                let mut fresh: Vec<(u32, u32, u64)> = Vec::new();
                tree.eps_self_join_dual(&Euclidean, eps, |a, b, d| {
                    fresh.push((a, b, d.to_bits()));
                });
                let mut reused: Vec<(u32, u32, u64)> = Vec::new();
                tree.eps_self_join_dual_with(&Euclidean, eps, &mut scratch, |a, b, d| {
                    reused.push((a, b, d.to_bits()));
                });
                assert_eq!(reused, fresh, "round={round} eps={eps} (order-sensitive)");
            }
        }
    }

    #[test]
    fn dual_par_emission_is_thread_count_independent() {
        // The parallel join's emitted SEQUENCE (not just the sorted set)
        // must be identical at every thread count ≥ 2: frontier expansion
        // and replay order are decided on the calling thread.
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(146), 300, 4, 5, 0.12);
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams { leaf_size: 4, root: 0 });
        let eps = 0.3;
        let mut want: Vec<(u32, u32, u64)> = Vec::new();
        let pool2 = Pool::new(2);
        tree.eps_self_join_dual_par(&Euclidean, eps, &pool2, |a, b, d| {
            want.push((a, b, d.to_bits()));
        });
        for threads in [3usize, 4, 8] {
            let pool = Pool::new(threads);
            let mut got: Vec<(u32, u32, u64)> = Vec::new();
            tree.eps_self_join_dual_par(&Euclidean, eps, &pool, |a, b, d| {
                got.push((a, b, d.to_bits()));
            });
            assert_eq!(got, want, "threads={threads} (sequence-sensitive)");
        }
    }

    #[test]
    fn dual_empty_and_singleton() {
        let empty = DenseMatrix::new(2);
        let t = CoverTree::build(&empty, &Euclidean, &BuildParams::default());
        let mut called = false;
        t.eps_self_join_dual(&Euclidean, 1.0, |_, _, _| called = true);
        let pool = Pool::new(4);
        t.eps_self_join_dual_par(&Euclidean, 1.0, &pool, |_, _, _| called = true);
        assert!(!called);

        let one = DenseMatrix::from_flat(2, vec![1.0, 1.0]);
        let t1 = CoverTree::build(&one, &Euclidean, &BuildParams::default());
        t1.eps_self_join_dual(&Euclidean, 1.0, |_, _, _| called = true);
        t1.eps_self_join_dual_par(&Euclidean, 1.0, &pool, |_, _, _| called = true);
        assert!(!called);
    }
}
