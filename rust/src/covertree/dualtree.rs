//! Dual-tree ε self-join — an extension beyond the paper's batched
//! single-point queries (Algorithm 3): traverse *pairs* of cover-tree
//! nodes and prune whole subtree pairs at once with
//! `d(p_u, p_v) > r_u + r_v + ε`.
//!
//! For self-joins this does strictly less work than querying every point
//! against the tree whenever sibling subtrees are far apart; the
//! `ablation` bench compares it against the batched self-join. The
//! distributed algorithms keep the paper-faithful batched form as their
//! default; `eps_self_join_dual` is opt-in.

use super::CoverTree;
use crate::metric::Metric;
use crate::points::PointSet;

impl<P: PointSet> CoverTree<P> {
    /// All unordered pairs of tree points within `eps`, via dual-tree
    /// traversal. Emits `(gid_a, gid_b)` with `gid_a < gid_b` exactly
    /// once per pair.
    pub fn eps_self_join_dual<M, F>(&self, metric: &M, eps: f64, mut emit: F)
    where
        M: Metric<P>,
        F: FnMut(u32, u32),
    {
        if self.is_empty() {
            return;
        }
        // Work stack of node pairs (u ≤ v by construction for self pairs).
        let mut stack: Vec<(u32, u32)> = vec![(self.root(), self.root())];
        while let Some((u, v)) = stack.pop() {
            let (nu, nv) = (self.node(u), self.node(v));
            if u == v {
                // Self pair: all unordered child pairs + leaf handling.
                if nu.is_leaf() {
                    continue; // one point, no pair
                }
                let children = self.node_children(u);
                for (i, &a) in children.iter().enumerate() {
                    for &b in &children[i..] {
                        stack.push((a, b));
                    }
                }
                continue;
            }
            let pu = self.points().point(nu.point as usize);
            let pv = self.points().point(nv.point as usize);
            let d = metric.dist(pu, pv);
            // Prune: no descendant pair can be within eps.
            if d > nu.radius + nv.radius + eps {
                continue;
            }
            match (nu.is_leaf(), nv.is_leaf()) {
                (true, true) => {
                    if d <= eps {
                        let (ga, gb) = (self.global_id(nu.point as usize), self.global_id(nv.point as usize));
                        if ga < gb {
                            emit(ga, gb);
                        } else if gb < ga {
                            emit(gb, ga);
                        }
                        // ga == gb impossible: distinct leaves have distinct
                        // local points, and ids are unique per point.
                    }
                }
                (false, true) => {
                    for &c in self.node_children(u) {
                        stack.push((c, v));
                    }
                }
                (true, false) => {
                    for &c in self.node_children(v) {
                        stack.push((u, c));
                    }
                }
                (false, false) => {
                    // Expand the larger-radius side (standard dual-tree
                    // heuristic: shrinks the pruning bound fastest).
                    if nu.radius >= nv.radius {
                        for &c in self.node_children(u) {
                            stack.push((c, v));
                        }
                    } else {
                        for &c in self.node_children(v) {
                            stack.push((u, c));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::BuildParams;
    use crate::metric::{Counted, Euclidean, Hamming, Levenshtein, Metric};
    use crate::points::{DenseMatrix, PointSet};
    use crate::util::Rng;

    fn check_matches_batched<P: PointSet, M: Metric<P>>(pts: &P, metric: &M, eps: f64, leaf: usize) {
        let tree = CoverTree::build(pts, metric, &BuildParams { leaf_size: leaf, root: 0 });
        let mut dual: Vec<(u32, u32)> = Vec::new();
        tree.eps_self_join_dual(metric, eps, |a, b| dual.push((a, b)));
        dual.sort_unstable();
        dual.dedup();
        let mut batched: Vec<(u32, u32)> = Vec::new();
        tree.eps_self_join(metric, eps, |a, b, _d| batched.push((a, b)));
        batched.sort_unstable();
        batched.dedup();
        assert_eq!(dual, batched, "eps={eps} leaf={leaf}");
    }

    #[test]
    fn dual_matches_batched_euclidean() {
        let pts = crate::data::synthetic::gaussian_mixture(&mut Rng::new(140), 250, 4, 5, 0.15);
        for leaf in [1usize, 4, 16] {
            for eps in [0.05, 0.3, 1.0] {
                check_matches_batched(&pts, &Euclidean, eps, leaf);
            }
        }
    }

    #[test]
    fn dual_matches_batched_hamming_and_edit() {
        let codes = crate::data::synthetic::hamming_clusters(&mut Rng::new(141), 150, 64, 3, 0.08);
        check_matches_batched(&codes, &Hamming, 12.0, 4);
        let reads = crate::data::synthetic::reads(&mut Rng::new(142), 80, 24, 4, 0.05);
        check_matches_batched(&reads, &Levenshtein, 4.0, 2);
    }

    #[test]
    fn dual_handles_duplicates() {
        let mut rng = Rng::new(143);
        let base = crate::data::synthetic::uniform(&mut rng, 40, 2, 1.0);
        let pts = crate::data::synthetic::with_duplicates(&mut rng, &base, 30);
        check_matches_batched(&pts, &Euclidean, 0.2, 8);
        check_matches_batched(&pts, &Euclidean, 0.0, 8); // dup-only pairs
    }

    #[test]
    fn dual_prunes_on_separated_clusters() {
        // Two far-apart blobs: the dual traversal should evaluate far
        // fewer distances than the batched per-point queries.
        let mut pts = DenseMatrix::new(2);
        let mut rng = Rng::new(144);
        for _ in 0..200 {
            pts.push(&[rng.normal_f32() * 0.1, rng.normal_f32() * 0.1]);
        }
        for _ in 0..200 {
            pts.push(&[100.0 + rng.normal_f32() * 0.1, rng.normal_f32() * 0.1]);
        }
        let eps = 0.15;
        let tree = CoverTree::build(&pts, &Euclidean, &BuildParams::default());

        let dual_counted = Counted::new(Euclidean);
        let mut n_dual = 0u64;
        tree.eps_self_join_dual(&dual_counted, eps, |_, _| n_dual += 1);

        let batch_counted = Counted::new(Euclidean);
        let mut n_batch = 0u64;
        tree.eps_self_join(&batch_counted, eps, |_, _, _| n_batch += 1);

        assert_eq!(n_dual, n_batch, "result sets must agree");
        assert!(
            dual_counted.count() < batch_counted.count(),
            "dual ({}) should beat batched ({}) on separated clusters",
            dual_counted.count(),
            batch_counted.count()
        );
    }

    #[test]
    fn dual_empty_and_singleton() {
        let empty = DenseMatrix::new(2);
        let t = CoverTree::build(&empty, &Euclidean, &BuildParams::default());
        let mut called = false;
        t.eps_self_join_dual(&Euclidean, 1.0, |_, _| called = true);
        assert!(!called);

        let one = DenseMatrix::from_flat(2, vec![1.0, 1.0]);
        let t1 = CoverTree::build(&one, &Euclidean, &BuildParams::default());
        t1.eps_self_join_dual(&Euclidean, 1.0, |_, _| called = true);
        assert!(!called);
    }
}
