//! Dataset acquisition: synthetic Table-I analogs, ε calibration, and
//! loaders for the standard `fvecs`/`bvecs`/`ivecs` interchange formats.
//!
//! The paper evaluates on nine datasets (Table I) that we cannot ship
//! (NERSC-scale downloads); `registry` generates synthetic analogs with the
//! same *dimension, metric and clustered structure* — the properties that
//! actually control the algorithms' behaviour (intrinsic dimensionality /
//! expansion constant and output sparsity). `calibrate_eps` then picks ε
//! values hitting the paper's average-degree bands. Users with the real
//! files can load them through [`loaders`].

pub mod diagnostics;
pub mod loaders;
pub mod registry;
pub mod synthetic;

pub use registry::{DatasetSpec, MetricKind, TABLE1};

use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::Rng;

/// Estimate the ε that yields an expected average degree of
/// `target_avg_degree` by sampling `samples` random pairs and taking the
/// matching quantile of their distance distribution:
/// `E[degree] = (n−1)·P(d ≤ ε)  ⇒  ε = quantile(target / (n−1))`.
pub fn calibrate_eps<P: PointSet, M: Metric<P>>(
    pts: &P,
    metric: &M,
    target_avg_degree: f64,
    samples: usize,
    rng: &mut Rng,
) -> f64 {
    let n = pts.len();
    assert!(n >= 2, "need at least two points to calibrate");
    let mut dists: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let i = rng.below(n);
        let mut j = rng.below(n - 1);
        if j >= i {
            j += 1;
        }
        dists.push(metric.dist_ij(pts, i, j));
    }
    dists.sort_by(f64::total_cmp);
    let q = (target_avg_degree / (n as f64 - 1.0)).clamp(0.0, 1.0);
    let idx = ((dists.len() as f64 - 1.0) * q).round() as usize;
    dists[idx].max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::Euclidean;

    #[test]
    fn calibrated_eps_hits_degree_band() {
        let mut rng = Rng::new(70);
        let pts = synthetic::gaussian_mixture(&mut rng, 400, 6, 5, 0.15);
        let target = 20.0;
        let eps = calibrate_eps(&pts, &Euclidean, target, 20_000, &mut rng);
        // Measure the true average degree at that eps.
        let mut edges = 0usize;
        for i in 0..pts.len() {
            for j in i + 1..pts.len() {
                if Euclidean.dist_ij(&pts, i, j) <= eps {
                    edges += 1;
                }
            }
        }
        let avg = 2.0 * edges as f64 / pts.len() as f64;
        assert!(
            avg > target * 0.5 && avg < target * 2.0,
            "calibration off: target {target}, got {avg} (eps={eps})"
        );
    }

    #[test]
    fn calibrate_monotone_in_target() {
        let mut rng = Rng::new(71);
        let pts = synthetic::uniform(&mut rng, 300, 4, 1.0);
        let e_small = calibrate_eps(&pts, &Euclidean, 5.0, 10_000, &mut rng.fork(1));
        let e_large = calibrate_eps(&pts, &Euclidean, 50.0, 10_000, &mut rng.fork(1));
        assert!(e_small < e_large);
    }
}
