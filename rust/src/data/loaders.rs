//! Loaders for the standard nearest-neighbor benchmark interchange formats:
//! `.fvecs` (f32 vectors), `.bvecs` (u8 vectors), `.ivecs` (i32 vectors),
//! and a simple whitespace-delimited ASCII matrix. Users with the real
//! Table-I files (sift, deep, ...) can run the full-size experiments.
//!
//! Format: each vector is `[d: i32 little-endian][d elements]`, repeated.

use crate::points::DenseMatrix;
use std::fs::File;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

/// Read an `.fvecs` file into a [`DenseMatrix`]. `limit` truncates (None =
/// all vectors).
pub fn read_fvecs(path: &Path, limit: Option<usize>) -> std::io::Result<DenseMatrix> {
    let mut f = BufReader::new(File::open(path)?);
    read_fvecs_from(&mut f, limit)
}

/// Reader-based variant (unit-testable without touching the filesystem).
pub fn read_fvecs_from<R: Read>(r: &mut R, limit: Option<usize>) -> std::io::Result<DenseMatrix> {
    let mut out: Option<DenseMatrix> = None;
    let mut count = 0usize;
    loop {
        if let Some(l) = limit {
            if count >= l {
                break;
            }
        }
        let mut dim_buf = [0u8; 4];
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let d = i32::from_le_bytes(dim_buf);
        if d <= 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad fvecs dimension {d}"),
            ));
        }
        let d = d as usize;
        let mut payload = vec![0u8; d * 4];
        r.read_exact(&mut payload)?;
        let row: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let m = out.get_or_insert_with(|| DenseMatrix::new(d));
        if m.dim() != d {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("inconsistent fvecs dimension: {} then {d}", m.dim()),
            ));
        }
        m.push(&row);
        count += 1;
    }
    Ok(out.unwrap_or_else(|| DenseMatrix::new(1)))
}

/// Read a `.bvecs` file (u8 elements) into f32s.
pub fn read_bvecs(path: &Path, limit: Option<usize>) -> std::io::Result<DenseMatrix> {
    let mut f = BufReader::new(File::open(path)?);
    read_bvecs_from(&mut f, limit)
}

pub fn read_bvecs_from<R: Read>(r: &mut R, limit: Option<usize>) -> std::io::Result<DenseMatrix> {
    let mut out: Option<DenseMatrix> = None;
    let mut count = 0usize;
    loop {
        if let Some(l) = limit {
            if count >= l {
                break;
            }
        }
        let mut dim_buf = [0u8; 4];
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let d = i32::from_le_bytes(dim_buf);
        if d <= 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad bvecs dimension {d}"),
            ));
        }
        let d = d as usize;
        let mut payload = vec![0u8; d];
        r.read_exact(&mut payload)?;
        let row: Vec<f32> = payload.iter().map(|&b| b as f32).collect();
        let m = out.get_or_insert_with(|| DenseMatrix::new(d));
        if m.dim() != d {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "inconsistent bvecs dimension",
            ));
        }
        m.push(&row);
        count += 1;
    }
    Ok(out.unwrap_or_else(|| DenseMatrix::new(1)))
}

/// Whitespace-delimited ASCII matrix (one point per line).
pub fn read_ascii(path: &Path, limit: Option<usize>) -> std::io::Result<DenseMatrix> {
    let f = BufReader::new(File::open(path)?);
    let mut out: Option<DenseMatrix> = None;
    for (ln, line) in f.lines().enumerate() {
        if let Some(l) = limit {
            if ln >= l {
                break;
            }
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let row: Result<Vec<f32>, _> = line.split_whitespace().map(str::parse::<f32>).collect();
        let row = row.map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {ln}: {e}"))
        })?;
        let m = out.get_or_insert_with(|| DenseMatrix::new(row.len()));
        if m.dim() != row.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {ln}: inconsistent dimension"),
            ));
        }
        m.push(&row);
    }
    Ok(out.unwrap_or_else(|| DenseMatrix::new(1)))
}

/// Write a [`DenseMatrix`] in fvecs format (round-trip/testing helper).
pub fn write_fvecs_to(m: &DenseMatrix, w: &mut impl std::io::Write) -> std::io::Result<()> {
    use crate::points::PointSet;
    for i in 0..m.len() {
        w.write_all(&(m.dim() as i32).to_le_bytes())?;
        for &x in m.row(i) {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::PointSet;

    #[test]
    fn fvecs_roundtrip() {
        let m = DenseMatrix::from_flat(3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut buf = Vec::new();
        write_fvecs_to(&m, &mut buf).unwrap();
        let m2 = read_fvecs_from(&mut buf.as_slice(), None).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn fvecs_limit_respected() {
        let m = DenseMatrix::from_flat(2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut buf = Vec::new();
        write_fvecs_to(&m, &mut buf).unwrap();
        let m2 = read_fvecs_from(&mut buf.as_slice(), Some(2)).unwrap();
        assert_eq!(m2.len(), 2);
    }

    #[test]
    fn fvecs_rejects_bad_dim() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(-1i32).to_le_bytes());
        assert!(read_fvecs_from(&mut buf.as_slice(), None).is_err());
    }

    #[test]
    fn fvecs_rejects_inconsistent_dim() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&2i32.to_le_bytes());
        buf.extend_from_slice(&1.0f32.to_le_bytes());
        buf.extend_from_slice(&2.0f32.to_le_bytes());
        buf.extend_from_slice(&3i32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 12]);
        assert!(read_fvecs_from(&mut buf.as_slice(), None).is_err());
    }

    #[test]
    fn bvecs_reads_bytes_as_f32() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&3i32.to_le_bytes());
        buf.extend_from_slice(&[10u8, 20, 255]);
        let m = read_bvecs_from(&mut buf.as_slice(), None).unwrap();
        assert_eq!(m.row(0), &[10.0, 20.0, 255.0]);
    }

    #[test]
    fn ascii_loader() {
        let dir = std::env::temp_dir().join("neargraph_test_ascii");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts.txt");
        std::fs::write(&path, "1.0 2.0\n3.5 -4.0\n\n5 6\n").unwrap();
        let m = read_ascii(&path, None).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.row(1), &[3.5, -4.0]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_input_gives_empty_matrix() {
        let m = read_fvecs_from(&mut (&[] as &[u8]), None).unwrap();
        assert_eq!(m.len(), 0);
    }
}
