//! Dataset diagnostics: estimators for the quantities the paper's theory
//! is parameterized by — the **spread** Δ(P) (max/min pairwise distance)
//! and the **expansion constant** c (smallest c ≥ 2 with
//! `|B(p, 2r)| ≤ c·|B(p, r)|` for all p, r — we follow the KR'02
//! doubling form; the paper's displayed inequality is the growth bound).
//!
//! Exact computation is Θ(n²·log) — fine at bench scale; both estimators
//! also take a sample size for larger inputs. Benches use these to report
//! the intrinsic difficulty of each Table-I analog.

use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::Rng;

/// Estimate the spread Δ(P) from `samples` random pairs (exact when
/// `samples ≥ n(n−1)/2`, in which case all pairs are scanned).
pub fn estimate_spread<P: PointSet, M: Metric<P>>(
    pts: &P,
    metric: &M,
    samples: usize,
    rng: &mut Rng,
) -> f64 {
    let n = pts.len();
    if n < 2 {
        return 1.0;
    }
    let all_pairs = n * (n - 1) / 2;
    let mut min_d = f64::INFINITY;
    let mut max_d: f64 = 0.0;
    let mut saw_zero = false;
    let mut consider = |d: f64| {
        if d > 0.0 {
            min_d = min_d.min(d);
        } else {
            saw_zero = true; // duplicate pair ⇒ unbounded spread
        }
        max_d = max_d.max(d);
    };
    if samples >= all_pairs {
        for i in 0..n {
            for j in i + 1..n {
                consider(metric.dist_ij(pts, i, j));
            }
        }
    } else {
        for _ in 0..samples {
            let i = rng.below(n);
            let mut j = rng.below(n - 1);
            if j >= i {
                j += 1;
            }
            consider(metric.dist_ij(pts, i, j));
        }
    }
    if saw_zero || !min_d.is_finite() {
        return f64::INFINITY; // duplicates present (or no finite pair)
    }
    max_d / min_d
}

/// Estimate the expansion (doubling growth) constant: sample anchor points
/// and radii, measure `|B(p, 2r)| / |B(p, r)|`, report the maximum over
/// samples (a lower bound on the true constant).
pub fn estimate_expansion_constant<P: PointSet, M: Metric<P>>(
    pts: &P,
    metric: &M,
    anchors: usize,
    rng: &mut Rng,
) -> f64 {
    let n = pts.len();
    if n < 4 {
        return 2.0;
    }
    let mut worst: f64 = 2.0;
    let mut dists: Vec<f64> = Vec::with_capacity(n);
    for _ in 0..anchors {
        let p = rng.below(n);
        dists.clear();
        for j in 0..n {
            dists.push(metric.dist_ij(pts, p, j));
        }
        dists.sort_by(f64::total_cmp);
        // Radii at a few quantiles of the anchor's distance distribution.
        for q in [0.05f64, 0.1, 0.25, 0.5] {
            let r = dists[((n as f64 - 1.0) * q) as usize];
            if r <= 0.0 {
                continue;
            }
            let inner = dists.partition_point(|&d| d <= r);
            let outer = dists.partition_point(|&d| d <= 2.0 * r);
            if inner > 0 {
                worst = worst.max(outer as f64 / inner as f64);
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::Euclidean;
    use crate::points::DenseMatrix;

    #[test]
    fn spread_exact_on_small_sets() {
        let pts = DenseMatrix::from_flat(1, vec![0.0, 1.0, 10.0]);
        let mut rng = Rng::new(180);
        let s = estimate_spread(&pts, &Euclidean, 1_000_000, &mut rng);
        assert!((s - 10.0).abs() < 1e-9);
    }

    #[test]
    fn spread_infinite_with_duplicates() {
        let pts = DenseMatrix::from_flat(1, vec![0.0, 0.0, 5.0]);
        let mut rng = Rng::new(181);
        let s = estimate_spread(&pts, &Euclidean, 1_000_000, &mut rng);
        assert!(s.is_infinite());
    }

    #[test]
    fn expansion_low_for_uniform_line_high_for_clusters() {
        let mut rng = Rng::new(182);
        // 1-D uniform grid: doubling a radius roughly doubles the ball.
        let line = DenseMatrix::from_flat(1, (0..400).map(|i| i as f32).collect());
        let c_line = estimate_expansion_constant(&line, &Euclidean, 12, &mut rng);
        assert!((2.0..=4.0).contains(&c_line), "line expansion {c_line}");

        // Tight, well-separated clusters: at r ≈ cluster scale, 2r jumps
        // across clusters ⇒ large growth ratio.
        let clustered = crate::data::synthetic::gaussian_mixture(&mut rng, 400, 2, 4, 0.005);
        let c_cl = estimate_expansion_constant(&clustered, &Euclidean, 12, &mut rng);
        assert!(c_cl > c_line, "clusters ({c_cl}) should exceed line ({c_line})");
    }

    #[test]
    fn degenerate_inputs() {
        let empty = DenseMatrix::new(2);
        let mut rng = Rng::new(183);
        assert_eq!(estimate_spread(&empty, &Euclidean, 10, &mut rng), 1.0);
        assert_eq!(estimate_expansion_constant(&empty, &Euclidean, 4, &mut rng), 2.0);
    }
}
