//! Table-I dataset registry: each paper dataset mapped to a synthetic
//! analog with the same metric, ambient dimension and a matching sparsity
//! sweep (three ε values spanning sparse → dense average degree).
//!
//! Sizes default to a laptop-scale fraction of the paper's (controlled by
//! `scale`); benches can request larger instances.

use super::synthetic;
use crate::points::{DenseMatrix, HammingCodes};
use crate::util::Rng;

/// Which metric family a dataset uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Euclidean,
    Hamming,
}

/// Three target average degrees, mirroring the paper's sparse→dense sweep
/// for each dataset (Table I's "Avg. neighbors" column).
pub const DEGREE_SWEEP: [f64; 3] = [15.0, 70.0, 300.0];

/// A Table-I dataset analog.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// Paper dataset name.
    pub name: &'static str,
    pub metric: MetricKind,
    /// Ambient dimension (bits for Hamming).
    pub dim: usize,
    /// Paper's point count.
    pub paper_points: usize,
    /// Intrinsic (latent) dimension used by the generator.
    pub intrinsic: usize,
    /// Number of generator clusters.
    pub clusters: usize,
    /// Cluster noise level.
    pub sigma: f64,
    /// Paper's three ε values (for EXPERIMENTS.md cross-reference only;
    /// synthetic runs calibrate their own ε from [`DEGREE_SWEEP`]).
    pub paper_eps: [f64; 3],
    /// Paper's three average-degree figures.
    pub paper_avg_neighbors: [f64; 3],
}

/// All nine Table-I datasets.
pub const TABLE1: [DatasetSpec; 9] = [
    DatasetSpec {
        name: "faces",
        metric: MetricKind::Euclidean,
        dim: 20,
        paper_points: 10_304,
        intrinsic: 5,
        clusters: 20,
        sigma: 0.08,
        paper_eps: [50.0, 100.0, 150.0],
        paper_avg_neighbors: [30.34, 436.09, 1666.84],
    },
    DatasetSpec {
        name: "artificial40",
        metric: MetricKind::Euclidean,
        dim: 40,
        paper_points: 10_000,
        intrinsic: 8,
        clusters: 10,
        sigma: 0.1,
        paper_eps: [6.0, 7.0, 8.0],
        paper_avg_neighbors: [11.26, 254.59, 1880.145],
    },
    DatasetSpec {
        name: "corel",
        metric: MetricKind::Euclidean,
        dim: 32,
        paper_points: 68_040,
        intrinsic: 6,
        clusters: 30,
        sigma: 0.08,
        paper_eps: [0.1, 0.125, 0.15],
        paper_avg_neighbors: [24.04, 57.37, 132.44],
    },
    DatasetSpec {
        name: "deep",
        metric: MetricKind::Euclidean,
        dim: 96,
        paper_points: 10_000,
        intrinsic: 10,
        clusters: 15,
        sigma: 0.1,
        paper_eps: [0.8, 1.0, 1.2],
        paper_avg_neighbors: [16.41, 136.74, 962.09],
    },
    DatasetSpec {
        name: "covtype",
        metric: MetricKind::Euclidean,
        dim: 55,
        paper_points: 581_012,
        intrinsic: 8,
        clusters: 40,
        sigma: 0.06,
        paper_eps: [150.0, 200.0, 250.0],
        paper_avg_neighbors: [96.70, 270.85, 641.845],
    },
    DatasetSpec {
        name: "twitter",
        metric: MetricKind::Euclidean,
        dim: 78,
        paper_points: 583_250,
        intrinsic: 10,
        clusters: 60,
        sigma: 0.05,
        paper_eps: [2.0, 4.0, 6.0],
        paper_avg_neighbors: [6.73, 59.29, 436.04],
    },
    DatasetSpec {
        name: "sift",
        metric: MetricKind::Euclidean,
        dim: 128,
        paper_points: 1_000_000,
        intrinsic: 12,
        clusters: 50,
        sigma: 0.07,
        paper_eps: [125.0, 175.0, 225.0],
        paper_avg_neighbors: [10.24, 71.41, 479.86],
    },
    DatasetSpec {
        name: "sift-hamming",
        metric: MetricKind::Hamming,
        dim: 256,
        paper_points: 988_258,
        intrinsic: 0, // unused for Hamming
        clusters: 50,
        sigma: 0.04, // bit-flip probability
        paper_eps: [20.0, 30.0, 40.0],
        paper_avg_neighbors: [26.77, 164.92, 656.29],
    },
    DatasetSpec {
        name: "word2bits",
        metric: MetricKind::Hamming,
        dim: 800,
        paper_points: 399_000,
        intrinsic: 0,
        clusters: 40,
        sigma: 0.05,
        paper_eps: [200.0, 250.0, 300.0],
        paper_avg_neighbors: [19.38, 320.68, 5186.16],
    },
];

/// Materialized analog data (one of the two containers).
pub enum Generated {
    Dense(DenseMatrix),
    Hamming(HammingCodes),
}

impl DatasetSpec {
    /// Look up a spec by paper name.
    pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
        TABLE1.iter().find(|s| s.name == name)
    }

    /// Number of points at a given scale factor (≥ 16 regardless).
    pub fn scaled_points(&self, scale: f64) -> usize {
        ((self.paper_points as f64 * scale) as usize).max(16)
    }

    /// Generate the synthetic analog with `n` points.
    pub fn generate(&self, n: usize, seed: u64) -> Generated {
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        match self.metric {
            MetricKind::Euclidean => Generated::Dense(synthetic::manifold_mixture(
                &mut rng,
                n,
                self.dim,
                self.intrinsic.max(2),
                self.clusters,
                self.sigma,
            )),
            MetricKind::Hamming => Generated::Hamming(synthetic::hamming_clusters(
                &mut rng,
                n,
                self.dim,
                self.clusters,
                self.sigma,
            )),
        }
    }
}

/// Tiny FNV-style string hash so each dataset gets an independent stream
/// from the same user seed.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::PointSet;

    #[test]
    fn registry_complete() {
        assert_eq!(TABLE1.len(), 9);
        for spec in &TABLE1 {
            assert!(spec.dim > 0);
            assert!(spec.paper_points > 0);
            assert!(spec.paper_eps[0] < spec.paper_eps[2]);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(DatasetSpec::by_name("sift").is_some());
        assert!(DatasetSpec::by_name("word2bits").is_some());
        assert!(DatasetSpec::by_name("nonexistent").is_none());
    }

    #[test]
    fn generation_matches_spec() {
        for spec in &TABLE1 {
            let n = 64;
            match spec.generate(n, 7) {
                Generated::Dense(m) => {
                    assert_eq!(m.len(), n, "{}", spec.name);
                    assert_eq!(m.dim(), spec.dim, "{}", spec.name);
                    assert_eq!(spec.metric, MetricKind::Euclidean);
                }
                Generated::Hamming(h) => {
                    assert_eq!(h.len(), n, "{}", spec.name);
                    assert_eq!(h.bits(), spec.dim, "{}", spec.name);
                    assert_eq!(spec.metric, MetricKind::Hamming);
                }
            }
        }
    }

    #[test]
    fn scaled_points_floor() {
        let s = DatasetSpec::by_name("sift").unwrap();
        assert_eq!(s.scaled_points(1e-9), 16);
        assert_eq!(s.scaled_points(0.01), 10_000);
    }

    #[test]
    fn seeds_give_distinct_datasets() {
        let s = DatasetSpec::by_name("faces").unwrap();
        let (a, b) = match (s.generate(32, 1), s.generate(32, 2)) {
            (Generated::Dense(a), Generated::Dense(b)) => (a, b),
            _ => unreachable!(),
        };
        assert_ne!(a, b);
    }
}
