//! Synthetic point-cloud generators.
//!
//! Each generator controls the property that drives the paper's algorithms:
//! *intrinsic* dimensionality (via a low-dimensional latent space embedded
//! in the ambient space) and cluster structure (which determines how well
//! landmark/Voronoi partitioning localizes neighbors).

use crate::points::{DenseMatrix, HammingCodes, PointSet, StringSet};
use crate::util::Rng;

/// `k` isotropic Gaussian clusters in `dim` dimensions. Cluster centers are
/// uniform in `[0,1]^dim`; points get noise `N(0, sigma²)` per coordinate.
pub fn gaussian_mixture(rng: &mut Rng, n: usize, dim: usize, k: usize, sigma: f64) -> DenseMatrix {
    assert!(k >= 1);
    let centers: Vec<Vec<f32>> =
        (0..k).map(|_| (0..dim).map(|_| rng.f32()).collect()).collect();
    let mut m = DenseMatrix::with_capacity(dim, n);
    let mut row = vec![0.0f32; dim];
    for _ in 0..n {
        let c = &centers[rng.below(k)];
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = c[j] + (rng.normal() * sigma) as f32;
        }
        m.push(&row);
    }
    m
}

/// Clustered data with *intrinsic* dimension `intrinsic` embedded in
/// `ambient` dimensions by a fixed random linear map — the "data manifold"
/// hypothesis that makes the output graph sparse and the cover tree
/// effective. This is the generator used for the high-dimensional Table-I
/// analogs (deep/96, sift/128, twitter/78...).
pub fn manifold_mixture(
    rng: &mut Rng,
    n: usize,
    ambient: usize,
    intrinsic: usize,
    k: usize,
    sigma: f64,
) -> DenseMatrix {
    assert!(intrinsic <= ambient);
    // Random embedding matrix (ambient × intrinsic), entries N(0, 1/√intrinsic).
    let scale = 1.0 / (intrinsic as f64).sqrt();
    let embed: Vec<f32> =
        (0..ambient * intrinsic).map(|_| (rng.normal() * scale) as f32).collect();
    let latent = gaussian_mixture(rng, n, intrinsic, k, sigma);
    let mut m = DenseMatrix::with_capacity(ambient, n);
    let mut row = vec![0.0f32; ambient];
    for i in 0..n {
        let z = latent.row(i);
        for a in 0..ambient {
            let mut acc = 0.0f32;
            for b in 0..intrinsic {
                acc += embed[a * intrinsic + b] * z[b];
            }
            // tiny ambient noise so points are not exactly on the manifold
            row[a] = acc + (rng.normal() * sigma * 0.01) as f32;
        }
        m.push(&row);
    }
    m
}

/// Uniform points in `[0, scale]^dim` — the worst case for landmarking
/// (no cluster structure to exploit).
pub fn uniform(rng: &mut Rng, n: usize, dim: usize, scale: f64) -> DenseMatrix {
    let mut m = DenseMatrix::with_capacity(dim, n);
    let mut row = vec![0.0f32; dim];
    for _ in 0..n {
        for slot in row.iter_mut() {
            *slot = (rng.f64() * scale) as f32;
        }
        m.push(&row);
    }
    m
}

/// Copy of `base` with `extra` additional rows duplicated from random
/// existing rows — stresses the duplicate-point handling in the cover tree
/// (metric axiom (ii) relaxation) and skews Voronoi cell sizes.
pub fn with_duplicates(rng: &mut Rng, base: &DenseMatrix, extra: usize) -> DenseMatrix {
    let mut m = base.clone();
    for _ in 0..extra {
        let i = rng.below(base.len());
        m.push(base.row(i));
    }
    m
}

/// `k` Hamming-space clusters: random ancestor codes, descendants flip each
/// bit with probability `flip_p` (binary-symmetric-channel noise). Analog of
/// sift-hamming / word2bits.
pub fn hamming_clusters(rng: &mut Rng, n: usize, bits: usize, k: usize, flip_p: f64) -> HammingCodes {
    assert!(k >= 1);
    let ancestors: Vec<Vec<bool>> =
        (0..k).map(|_| (0..bits).map(|_| rng.bool(0.5)).collect()).collect();
    let mut codes = HammingCodes::new(bits);
    let mut buf = vec![false; bits];
    for _ in 0..n {
        let a = &ancestors[rng.below(k)];
        for (j, slot) in buf.iter_mut().enumerate() {
            *slot = a[j] ^ rng.bool(flip_p);
        }
        codes.push_bits(&buf);
    }
    codes
}

/// Synthetic sequencing reads: `k` random ancestor strings over ACGT of
/// length `len`, descendants mutated with per-base substitution/indel rate
/// `mutation_rate`. The edit-distance workload from the paper's intro.
pub fn reads(rng: &mut Rng, n: usize, len: usize, k: usize, mutation_rate: f64) -> StringSet {
    const ALPHABET: &[u8; 4] = b"ACGT";
    let ancestors: Vec<Vec<u8>> = (0..k)
        .map(|_| (0..len).map(|_| ALPHABET[rng.below(4)]).collect())
        .collect();
    let mut set = StringSet::new();
    let mut buf: Vec<u8> = Vec::with_capacity(len + 8);
    for _ in 0..n {
        let a = &ancestors[rng.below(k)];
        buf.clear();
        for &base in a {
            if rng.bool(mutation_rate) {
                match rng.below(3) {
                    0 => buf.push(ALPHABET[rng.below(4)]), // substitute
                    1 => {}                                // delete
                    _ => {
                        // insert then keep
                        buf.push(ALPHABET[rng.below(4)]);
                        buf.push(base);
                    }
                }
            } else {
                buf.push(base);
            }
        }
        set.push(&buf);
    }
    set
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::{Euclidean, Hamming, Metric};
    use crate::points::PointSet;

    #[test]
    fn gaussian_mixture_shape() {
        let mut rng = Rng::new(80);
        let m = gaussian_mixture(&mut rng, 100, 5, 3, 0.1);
        assert_eq!(m.len(), 100);
        assert_eq!(m.dim(), 5);
    }

    #[test]
    fn mixture_is_clustered() {
        // With tiny sigma, within-cluster distances should be much smaller
        // than the typical between-cluster distance.
        let mut rng = Rng::new(81);
        let m = gaussian_mixture(&mut rng, 200, 4, 4, 0.01);
        let mut small = 0usize;
        let mut pairs = 0usize;
        for i in 0..50 {
            for j in i + 1..50 {
                pairs += 1;
                if Euclidean.dist_ij(&m, i, j) < 0.1 {
                    small += 1;
                }
            }
        }
        // Roughly 1/4 of pairs share a cluster.
        assert!(small > pairs / 10, "not clustered: {small}/{pairs}");
    }

    #[test]
    fn manifold_mixture_shape_and_rank() {
        let mut rng = Rng::new(82);
        let m = manifold_mixture(&mut rng, 150, 32, 4, 5, 0.1);
        assert_eq!(m.dim(), 32);
        assert_eq!(m.len(), 150);
        // Points should not be degenerate (nonzero spread).
        let d = Euclidean.dist_ij(&m, 0, 1);
        assert!(d.is_finite());
    }

    #[test]
    fn uniform_in_bounds() {
        let mut rng = Rng::new(83);
        let m = uniform(&mut rng, 100, 3, 2.0);
        for r in m.rows() {
            for &x in r {
                assert!((0.0..=2.0).contains(&x));
            }
        }
    }

    #[test]
    fn duplicates_added() {
        let mut rng = Rng::new(84);
        let base = uniform(&mut rng, 20, 2, 1.0);
        let d = with_duplicates(&mut rng, &base, 15);
        assert_eq!(d.len(), 35);
        // Each extra row matches some base row exactly.
        for i in 20..35 {
            assert!((0..20).any(|j| d.row(i) == base.row(j)));
        }
    }

    #[test]
    fn hamming_clusters_are_clustered() {
        let mut rng = Rng::new(85);
        let codes = hamming_clusters(&mut rng, 100, 128, 2, 0.02);
        assert_eq!(codes.len(), 100);
        // Distances should be bimodal: ~2·0.02·128 ≈ 5 within, ~64 between.
        let mut within = 0;
        let mut between = 0;
        for i in 0..40 {
            for j in i + 1..40 {
                let d = Hamming.dist_ij(&codes, i, j);
                if d < 20.0 {
                    within += 1;
                } else if d > 40.0 {
                    between += 1;
                }
            }
        }
        assert!(within > 0 && between > 0, "within={within} between={between}");
    }

    #[test]
    fn reads_have_plausible_lengths() {
        let mut rng = Rng::new(86);
        let set = reads(&mut rng, 50, 40, 3, 0.05);
        assert_eq!(set.len(), 50);
        for i in 0..set.len() {
            let l = set.str_len(i);
            assert!((25..=55).contains(&l), "read length {l} out of band");
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = gaussian_mixture(&mut Rng::new(99), 50, 4, 3, 0.1);
        let b = gaussian_mixture(&mut Rng::new(99), 50, 4, 3, 0.1);
        assert_eq!(a, b);
    }
}
