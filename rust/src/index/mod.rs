//! One query facade over every search structure: the object-safe
//! [`NearIndex`] trait, the [`IndexKind`] selector and the
//! [`build_index`] constructor.
//!
//! The four in-crate search structures historically exposed four bespoke
//! APIs (`query`/`query_batch(emit)`/`eps_self_join(emit)`/`knn`/
//! `self_join -> EdgeList`), all of which dropped the pair distance at the
//! hot path. The facade unifies them behind one trait whose every result
//! carries the distance — the edge weight of the [`crate::graph::NearGraph`]
//! downstream analyses consume — and makes each structure an
//! interchangeable backend:
//!
//! | [`IndexKind`]      | structure                              | scope |
//! |--------------------|----------------------------------------|-------|
//! | `BruteForce`       | linear scan (the trait's default impls)| any metric |
//! | `CoverTree`        | batch cover tree (Algorithms 1–3)      | any metric |
//! | `InsertCoverTree`  | mutable epoch tree (batch base + BKL-2006 insert delta) | any metric |
//! | `Snn`              | sort-based SNN (Chen & Güttel 2024)    | dense × Euclidean only |
//!
//! Contracts every backend upholds (enforced by
//! `tests/index_equivalence.rs`):
//!
//! * **identical edge sets** — accept/reject decisions equal the scalar
//!   [`Metric::dist`] comparison bit-for-bit, whatever kernel screens the
//!   candidates;
//! * **identical weights** — the reported distance is exactly what
//!   `Metric::dist` returns for that pair (see
//!   [`crate::graph::WEIGHT_TOL`] for the storage tolerance);
//! * **identity ids** — a facade index is built over the full point set,
//!   so reported ids are positions in the input.
//!
//! The pooled `*_par` variants are default-implemented on
//! [`crate::util::Pool`] with the fixed-chunk shard-and-replay scheme of
//! the cover tree's parallel queries, so any backend — including a future
//! one-file plug-in — gets deterministic parallel batching for free.

use crate::baseline::{Snn, SnnParams};
use crate::covertree::{BuildParams, CoverTree, EpochParams, EpochTree, QueryScratch};
use crate::graph::{GraphSink, KnnGraph, NearGraph, WeightedEdgeList};
use crate::metric::{Euclidean, Metric};
use crate::points::{DenseMatrix, PointSet};
use crate::util::Pool;
use std::any::Any;

/// The search structure behind a [`NearIndex`] — mirrors
/// [`crate::dist::Algorithm`] for config/CLI selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    /// Linear scan over every point — the reference backend (and the
    /// trait's default implementations, verbatim).
    BruteForce,
    /// The paper's batch-built cover tree (Algorithms 1–3).
    CoverTree,
    /// The classic consecutive-insertion cover tree (BKL 2006).
    InsertCoverTree,
    /// Sort-based SNN (Chen & Güttel 2024); dense Euclidean data only.
    Snn,
}

impl IndexKind {
    /// All kinds, reference first.
    pub const ALL: [IndexKind; 4] =
        [IndexKind::BruteForce, IndexKind::CoverTree, IndexKind::InsertCoverTree, IndexKind::Snn];

    /// The CLI / config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            IndexKind::BruteForce => "brute-force",
            IndexKind::CoverTree => "cover-tree",
            IndexKind::InsertCoverTree => "insert-cover-tree",
            IndexKind::Snn => "snn",
        }
    }

    /// Inverse of [`IndexKind::name`].
    pub fn parse(s: &str) -> Option<IndexKind> {
        match s {
            "brute-force" => Some(IndexKind::BruteForce),
            "cover-tree" => Some(IndexKind::CoverTree),
            "insert-cover-tree" => Some(IndexKind::InsertCoverTree),
            "snn" => Some(IndexKind::Snn),
            _ => None,
        }
    }
}

/// Build-time parameters shared by every backend (each uses what applies).
#[derive(Clone, Debug)]
pub struct IndexParams {
    /// Cover-tree leaf size ζ.
    pub leaf_size: usize,
    /// SNN power-iteration parameters.
    pub snn: SnnParams,
    /// Compaction policy of the mutable backend
    /// ([`IndexKind::InsertCoverTree`]; the others ignore it).
    pub epoch: EpochParams,
    /// Route the cover-tree self-join through the dual-tree traversal
    /// ([`CoverTree::eps_self_join_dual`]) instead of the batched queries.
    /// Same edge set and weight bits, different pruning strategy; only
    /// [`IndexKind::CoverTree`] consults it.
    pub dualtree: bool,
}

impl Default for IndexParams {
    fn default() -> Self {
        IndexParams {
            leaf_size: 8,
            snn: SnnParams::default(),
            epoch: EpochParams::default(),
            dualtree: false,
        }
    }
}

/// Typed failure of [`build_index`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IndexError {
    /// The backend cannot serve this point-set/metric combination (e.g.
    /// SNN outside dense Euclidean data).
    Unsupported {
        kind: IndexKind,
        /// `Metric::name` of the requested metric.
        metric: &'static str,
        /// What the backend requires instead.
        requires: &'static str,
    },
}

impl std::fmt::Display for IndexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IndexError::Unsupported { kind, metric, requires } => write!(
                f,
                "index backend {:?} does not support metric {metric:?}: requires {requires}",
                kind.name()
            ),
        }
    }
}

impl std::error::Error for IndexError {}

/// Query-shard size of the pooled default implementations (fixed, so the
/// chunk decomposition — and the replayed emission order — is identical at
/// every thread count).
const PAR_CHUNK: usize = 1024;

/// A built near-neighbor index over an owned point set with identity ids.
///
/// Object-safe: `Box<dyn NearIndex<P, M>>` is the facade's working type,
/// which is why the batch emitters take `&mut dyn FnMut` / `&mut dyn
/// GraphSink` rather than generic closures. Every method has a default
/// implementation in terms of [`NearIndex::points`] /
/// [`NearIndex::metric`] — a linear scan, which **is** the brute-force
/// reference backend — so a new backend only overrides its fast paths.
pub trait NearIndex<P: PointSet, M: Metric<P>>: Send + Sync {
    /// Which backend this is.
    fn kind(&self) -> IndexKind;

    /// The indexed points (input order; point index == reported id).
    fn points(&self) -> &P;

    /// The metric captured at build time.
    fn metric(&self) -> &M;

    /// Number of indexed points.
    fn num_points(&self) -> usize {
        self.points().len()
    }

    /// All indexed points within `eps` of `query`, as `(id, distance)`
    /// pairs appended to `out` (order unspecified).
    fn eps_query(&self, query: P::Point<'_>, eps: f64, out: &mut Vec<(u32, f64)>) {
        let pts = self.points();
        let metric = self.metric();
        for i in 0..pts.len() {
            let d = metric.dist(query, pts.point(i));
            if d <= eps {
                out.push((i as u32, d));
            }
        }
    }

    /// [`NearIndex::eps_query`] threading a caller-owned
    /// [`QueryScratch`]: appends the same `(id, distance)` pairs in the
    /// same order, but a backend with a scratch-aware traversal (the
    /// cover tree) reuses the scratch's warmed buffers instead of
    /// allocating per call. This is the serve daemon's per-lane entry
    /// point — one long-lived scratch per pool worker keeps the coalesced
    /// steady state allocation-free. The default ignores the scratch.
    fn eps_query_with(
        &self,
        query: P::Point<'_>,
        eps: f64,
        _scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        self.eps_query(query, eps, out);
    }

    /// [`NearIndex::knn`] threading a caller-owned [`QueryScratch`] and an
    /// output buffer (cleared, then filled ascending by `(distance, id)`)
    /// — same rows as [`NearIndex::knn`], without its per-call `Vec`. The
    /// default ignores the scratch.
    fn knn_with(
        &self,
        query: P::Point<'_>,
        k: usize,
        _scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        out.clear();
        out.extend(self.knn(query, k));
    }

    /// Batched [`NearIndex::eps_query`]: `emit(query_index, id, distance)`
    /// once per result pair (pair order unspecified; pairs unique).
    fn eps_batch(&self, queries: &P, eps: f64, emit: &mut dyn FnMut(u32, u32, f64)) {
        let mut out = Vec::new();
        for q in 0..queries.len() {
            out.clear();
            self.eps_query(queries.point(q), eps, &mut out);
            for &(gid, d) in &out {
                emit(q as u32, gid, d);
            }
        }
    }

    /// Weighted ε-self-join: every unordered pair of indexed points within
    /// `eps`, fed to `sink` once per pair.
    fn eps_self_join(&self, eps: f64, sink: &mut dyn GraphSink) {
        self.eps_batch(self.points(), eps, &mut |q, gid, d| {
            if q < gid {
                sink.accept(q, gid, d);
            }
        });
    }

    /// The `k` nearest indexed points to `query`, as `(id, distance)`
    /// ascending by `(distance, id)`. Fewer than `k` only when the index
    /// holds fewer points; the query point is not excluded if indexed.
    fn knn(&self, query: P::Point<'_>, k: usize) -> Vec<(u32, f64)> {
        if k == 0 {
            return Vec::new();
        }
        let pts = self.points();
        let metric = self.metric();
        let mut all: Vec<(u32, f64)> =
            (0..pts.len()).map(|i| (i as u32, metric.dist(query, pts.point(i)))).collect();
        // total_cmp: a NaN distance from a broken metric sorts last
        // instead of panicking, preserving the (distance, id) policy on
        // every real distance.
        all.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    /// [`NearIndex::knn`] for every point of `queries`, in query order.
    fn knn_batch(&self, queries: &P, k: usize) -> Vec<Vec<(u32, f64)>> {
        (0..queries.len()).map(|q| self.knn(queries.point(q), k)).collect()
    }

    /// Pooled [`NearIndex::eps_batch`]: fixed-size query shards
    /// ([`PAR_CHUNK`]) on `pool`, per-shard buffers replayed in shard
    /// order — the emitted multiset is identical at every pool size.
    fn eps_batch_par(
        &self,
        queries: &P,
        eps: f64,
        pool: &Pool,
        emit: &mut dyn FnMut(u32, u32, f64),
    ) {
        let n = queries.len();
        if pool.threads() <= 1 || n <= PAR_CHUNK {
            return self.eps_batch(queries, eps, emit);
        }
        // Bounded waves keep at most one wave of result buffers live (the
        // same scheme as the cover tree's parallel batch).
        let nparts = crate::util::div_ceil(n, PAR_CHUNK);
        let wave = pool.threads() * 4;
        let mut first = 0usize;
        while first < nparts {
            let count = wave.min(nparts - first);
            let base = first;
            let parts = pool.run_indexed(count, |w| {
                let lo = (base + w) * PAR_CHUNK;
                let hi = (lo + PAR_CHUNK).min(n);
                let sub = queries.slice(lo, hi);
                let mut out: Vec<(u32, u32, f64)> = Vec::new();
                self.eps_batch(&sub, eps, &mut |qi, gid, d| {
                    out.push((lo as u32 + qi, gid, d));
                });
                out
            });
            for part in parts {
                for (q, gid, d) in part {
                    emit(q, gid, d);
                }
            }
            first += count;
        }
    }

    /// Pooled [`NearIndex::eps_self_join`] — the identical weighted edge
    /// set at every pool size. The sequential/small-input path delegates
    /// to [`NearIndex::eps_self_join`] so a backend's specialized
    /// self-join (e.g. SNN's forward-only sorted sweep) is what actually
    /// runs there.
    fn eps_self_join_par(&self, eps: f64, pool: &Pool, sink: &mut dyn GraphSink) {
        if pool.threads() <= 1 || self.num_points() <= PAR_CHUNK {
            return self.eps_self_join(eps, sink);
        }
        self.eps_batch_par(self.points(), eps, pool, &mut |q, gid, d| {
            if q < gid {
                sink.accept(q, gid, d);
            }
        });
    }

    /// Pooled [`NearIndex::knn_batch`], in query order at every pool size.
    fn knn_batch_par(&self, queries: &P, k: usize, pool: &Pool) -> Vec<Vec<(u32, f64)>> {
        let n = queries.len();
        if pool.threads() <= 1 || n <= PAR_CHUNK {
            return self.knn_batch(queries, k);
        }
        let nparts = crate::util::div_ceil(n, PAR_CHUNK);
        let parts = pool.run_indexed(nparts, |w| {
            let lo = w * PAR_CHUNK;
            let hi = (lo + PAR_CHUNK).min(n);
            self.knn_batch(&queries.slice(lo, hi), k)
        });
        parts.into_iter().flatten().collect()
    }

    /// The mutation interface, when this backend supports in-place
    /// insert/delete/compact ([`MutableOps`]). `None` — the default — means
    /// the index is immutable once built; the serve daemon maps that to a
    /// `read-only` protocol error instead of a panic.
    fn mutable(&self) -> Option<&dyn MutableOps<P>> {
        None
    }

    /// The exact directed k-NN graph of the indexed points: row `i` holds
    /// the `min(k, n − 1)` nearest *other* points of `i`, ascending by
    /// `(distance, id)` — the single-node counterpart of
    /// `dist::run_knn_graph`, identical at every pool size. Implemented on
    /// [`NearIndex::knn_batch_par`] with `k + 1` and the self match
    /// dropped, so every backend serves it through its own k-NN path.
    fn knn_graph(&self, k: usize, pool: &Pool) -> KnnGraph {
        let pts = self.points();
        let n = pts.len();
        let want = k.min(n.saturating_sub(1));
        let rows: Vec<Vec<(u32, f64)>> = self
            .knn_batch_par(pts, k.saturating_add(1), pool)
            .into_iter()
            .enumerate()
            .map(|(i, row)| {
                let mut row: Vec<(u32, f64)> =
                    row.into_iter().filter(|&(g, _)| g as usize != i).collect();
                row.truncate(want);
                row
            })
            .collect();
        KnnGraph::from_rows(n, k, rows)
    }
}

/// In-place mutation of a built index (PR 9, DESIGN.md §13). Ids are
/// global and permanent: the build-time points own `0..n`, every insert
/// gets the next id, and a delete retires its id forever — queries after
/// any prefix of mutations are bit-equal to a brute-force rebuild over
/// the live `(id, point)` set (`tests/mutation_conformance.rs`).
///
/// All methods take `&self`: the backend serializes writers internally and
/// readers never block on a rebuild (the epoch-snapshot scheme of
/// [`EpochTree`]).
pub trait MutableOps<P: PointSet>: Send + Sync {
    /// Insert every point of `batch` (same shape as the indexed points);
    /// returns the contiguous id range assigned.
    fn insert(&self, batch: &P) -> std::ops::Range<u32>;

    /// Tombstone one id. `false` when the id was never assigned or is
    /// already gone.
    fn delete(&self, id: u32) -> bool;

    /// Force a compaction (rebuild over the live points, dropping
    /// tombstones); returns the new epoch number.
    fn compact(&self) -> u64;

    /// Compactions since build (0 until the first).
    fn epoch(&self) -> u64;

    /// Live (queryable) points.
    fn live(&self) -> usize;

    /// Tombstoned points awaiting compaction.
    fn tombstones(&self) -> usize;

    /// Compact, then encode the live points as an `NGI-IDX1` snapshot —
    /// the saved bytes carry no tombstones and reload through the same
    /// checksummed path as an immutable index.
    fn snapshot_bytes(&self) -> Result<Vec<u8>, crate::covertree::SnapshotError>;
}

/// The ε-graph of an index's points: pooled weighted self-join,
/// canonicalized into a [`NearGraph`].
pub fn epsilon_graph<P: PointSet, M: Metric<P>>(
    index: &dyn NearIndex<P, M>,
    eps: f64,
    pool: &Pool,
) -> NearGraph {
    let mut sink = WeightedEdgeList::new();
    index.eps_self_join_par(eps, pool, &mut sink);
    sink.into_near_graph(index.num_points())
}

/// Linear-scan reference backend: the trait's default implementations,
/// unmodified.
pub struct BruteForceIndex<P: PointSet, M: Metric<P>> {
    pts: P,
    metric: M,
}

impl<P: PointSet, M: Metric<P>> NearIndex<P, M> for BruteForceIndex<P, M> {
    fn kind(&self) -> IndexKind {
        IndexKind::BruteForce
    }

    fn points(&self) -> &P {
        &self.pts
    }

    fn metric(&self) -> &M {
        &self.metric
    }
}

/// Batch cover tree behind the facade.
pub struct CoverTreeIndex<P: PointSet, M: Metric<P>> {
    tree: CoverTree<P>,
    metric: M,
    /// Self-join strategy: `true` routes [`NearIndex::eps_self_join`] (and
    /// the `_par` form) through the dual-tree traversal. Conformance-gated
    /// to emit the same edge set and weight bits as the batched join.
    dualtree: bool,
}

impl<P: PointSet, M: Metric<P>> CoverTreeIndex<P, M> {
    /// The wrapped tree (for structure inspection / direct-path benches).
    pub fn tree(&self) -> &CoverTree<P> {
        &self.tree
    }

    /// Wrap an already-built tree — the snapshot load path and the tests
    /// that build trees with non-default [`BuildParams`].
    pub fn from_tree(tree: CoverTree<P>, metric: M) -> Self {
        CoverTreeIndex { tree, metric, dualtree: false }
    }

    /// Select the self-join strategy ([`IndexParams::dualtree`]); builder
    /// form so the snapshot/`from_tree` paths stay untouched.
    pub fn with_dualtree(mut self, on: bool) -> Self {
        self.dualtree = on;
        self
    }

    /// Encode the underlying tree as an `NGI-IDX1` snapshot
    /// ([`CoverTree::to_snapshot_bytes`]).
    pub fn to_snapshot_bytes(&self) -> Result<Vec<u8>, crate::covertree::SnapshotError> {
        self.tree.to_snapshot_bytes()
    }

    /// Load an `NGI-IDX1` snapshot into a serving-ready index — the
    /// daemon's load-once entry point. No metric evaluations: the snapshot
    /// carries the built structure and the flat traversal layout is a pure
    /// permutation of it.
    pub fn from_snapshot_bytes(
        bytes: &[u8],
        metric: M,
    ) -> Result<Self, crate::points::WireError> {
        Ok(CoverTreeIndex {
            tree: CoverTree::try_from_snapshot_bytes(bytes)?,
            metric,
            dualtree: false,
        })
    }
}

impl<P: PointSet, M: Metric<P>> NearIndex<P, M> for CoverTreeIndex<P, M> {
    fn kind(&self) -> IndexKind {
        IndexKind::CoverTree
    }

    fn points(&self) -> &P {
        self.tree.points()
    }

    fn metric(&self) -> &M {
        &self.metric
    }

    fn eps_query(&self, query: P::Point<'_>, eps: f64, out: &mut Vec<(u32, f64)>) {
        self.tree.query_weighted(&self.metric, query, eps, out);
    }

    fn eps_query_with(
        &self,
        query: P::Point<'_>,
        eps: f64,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        // Same traversal as `eps_query` (which wraps this with a throwaway
        // scratch), so results are bit-identical — only the allocations go.
        self.tree.query_weighted_with(&self.metric, query, eps, scratch, out);
    }

    fn knn_with(
        &self,
        query: P::Point<'_>,
        k: usize,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        self.tree.knn_within_with(&self.metric, query, k, f64::INFINITY, scratch, out);
    }

    fn eps_batch(&self, queries: &P, eps: f64, emit: &mut dyn FnMut(u32, u32, f64)) {
        self.tree.query_batch(&self.metric, queries, eps, |qi, gid, d| {
            emit(qi as u32, gid, d);
        });
    }

    fn eps_self_join(&self, eps: f64, sink: &mut dyn GraphSink) {
        if self.dualtree {
            self.tree.eps_self_join_dual(&self.metric, eps, |a, b, d| sink.accept(a, b, d));
        } else {
            self.tree.eps_self_join(&self.metric, eps, |a, b, d| sink.accept(a, b, d));
        }
    }

    fn knn(&self, query: P::Point<'_>, k: usize) -> Vec<(u32, f64)> {
        self.tree.knn(&self.metric, query, k)
    }

    /// One scratch across the whole batch: the bounded branch-and-bound
    /// reuses its heaps per query instead of reallocating them.
    fn knn_batch(&self, queries: &P, k: usize) -> Vec<Vec<(u32, f64)>> {
        let mut scratch = QueryScratch::new();
        (0..queries.len())
            .map(|q| {
                let mut row = Vec::new();
                self.tree.knn_within_with(
                    &self.metric,
                    queries.point(q),
                    k,
                    f64::INFINITY,
                    &mut scratch,
                    &mut row,
                );
                row
            })
            .collect()
    }

    /// Fixed chunks with **one scratch per pool worker** (the worker's
    /// scratch follows it across every chunk it claims) — identical rows
    /// to [`NearIndex::knn_batch`] at every pool size.
    fn knn_batch_par(&self, queries: &P, k: usize, pool: &Pool) -> Vec<Vec<(u32, f64)>> {
        let n = queries.len();
        if pool.threads() <= 1 || n <= PAR_CHUNK {
            return self.knn_batch(queries, k);
        }
        let nparts = crate::util::div_ceil(n, PAR_CHUNK);
        let parts = pool.run_indexed_with(
            nparts,
            |_| QueryScratch::new(),
            |scratch, w| {
                let lo = w * PAR_CHUNK;
                let hi = (lo + PAR_CHUNK).min(n);
                let sub = queries.slice(lo, hi);
                (0..sub.len())
                    .map(|q| {
                        let mut row = Vec::new();
                        self.tree.knn_within_with(
                            &self.metric,
                            sub.point(q),
                            k,
                            f64::INFINITY,
                            scratch,
                            &mut row,
                        );
                        row
                    })
                    .collect::<Vec<_>>()
            },
        );
        parts.into_iter().flatten().collect()
    }

    fn eps_batch_par(
        &self,
        queries: &P,
        eps: f64,
        pool: &Pool,
        emit: &mut dyn FnMut(u32, u32, f64),
    ) {
        self.tree.query_batch_par(&self.metric, queries, eps, pool, |qi, gid, d| {
            emit(qi as u32, gid, d);
        });
    }

    fn eps_self_join_par(&self, eps: f64, pool: &Pool, sink: &mut dyn GraphSink) {
        if self.dualtree {
            self.tree.eps_self_join_dual_par(&self.metric, eps, pool, |a, b, d| {
                sink.accept(a, b, d)
            });
        } else {
            self.tree.eps_self_join_par(&self.metric, eps, pool, |a, b, d| sink.accept(a, b, d));
        }
    }
}

/// The mutable backend: an [`EpochTree`] — batch-built base snapshots, an
/// insertion-tree delta ([`crate::covertree::InsertCoverTree`]), tombstone deletes and
/// epoch-publishing compaction (PR 9, DESIGN.md §13). The only facade
/// backend whose [`NearIndex::mutable`] is `Some`.
///
/// [`NearIndex::points`] reports the *build-time* point set (identity
/// ids), which is also what batch defaults and the serve daemon's shape
/// checks consult; the live set — build-time points minus deletes plus
/// inserts — lives inside the epoch tree and is what every query answers
/// over ([`NearIndex::num_points`] counts it).
pub struct InsertCoverTreeIndex<P: PointSet, M: Metric<P>> {
    seed: P,
    epoch: EpochTree<P>,
    metric: M,
}

impl<P: PointSet, M: Metric<P>> InsertCoverTreeIndex<P, M> {
    /// Build epoch 0 over `pts` with identity ids.
    pub fn build(pts: &P, metric: M, params: &IndexParams) -> Self {
        let build = BuildParams { leaf_size: params.leaf_size.max(1), root: 0 };
        let epoch = EpochTree::build(pts, &metric, &build, params.epoch);
        InsertCoverTreeIndex { seed: pts.clone(), epoch, metric }
    }

    /// Wrap an already-built tree (the snapshot load path). Ids carry
    /// over; the next insert continues past the highest surviving id.
    pub fn from_tree(tree: CoverTree<P>, metric: M, params: &IndexParams) -> Self {
        let build = BuildParams { leaf_size: params.leaf_size.max(1), root: 0 };
        let seed = tree.points().clone();
        let epoch = EpochTree::from_tree(tree, &metric, &build, params.epoch);
        InsertCoverTreeIndex { seed, epoch, metric }
    }

    /// Load an `NGI-IDX1` snapshot into a serving-ready *mutable* index —
    /// same checksummed format as [`CoverTreeIndex::from_snapshot_bytes`].
    pub fn from_snapshot_bytes(
        bytes: &[u8],
        metric: M,
        params: &IndexParams,
    ) -> Result<Self, crate::points::WireError> {
        Ok(Self::from_tree(CoverTree::try_from_snapshot_bytes(bytes)?, metric, params))
    }

    /// The epoch tree itself (tests and direct-path benches).
    pub fn epoch_tree(&self) -> &EpochTree<P> {
        &self.epoch
    }
}

impl<P: PointSet, M: Metric<P>> MutableOps<P> for InsertCoverTreeIndex<P, M> {
    fn insert(&self, batch: &P) -> std::ops::Range<u32> {
        self.epoch.insert_from(&self.metric, batch)
    }

    fn delete(&self, id: u32) -> bool {
        self.epoch.delete(&self.metric, id)
    }

    fn compact(&self) -> u64 {
        self.epoch.compact(&self.metric)
    }

    fn epoch(&self) -> u64 {
        self.epoch.epoch()
    }

    fn live(&self) -> usize {
        self.epoch.live()
    }

    fn tombstones(&self) -> usize {
        self.epoch.tombstones()
    }

    fn snapshot_bytes(&self) -> Result<Vec<u8>, crate::covertree::SnapshotError> {
        self.epoch.snapshot_bytes(&self.metric)
    }
}

impl<P: PointSet, M: Metric<P>> NearIndex<P, M> for InsertCoverTreeIndex<P, M> {
    fn kind(&self) -> IndexKind {
        IndexKind::InsertCoverTree
    }

    fn points(&self) -> &P {
        &self.seed
    }

    fn metric(&self) -> &M {
        &self.metric
    }

    fn num_points(&self) -> usize {
        self.epoch.live()
    }

    fn mutable(&self) -> Option<&dyn MutableOps<P>> {
        Some(self)
    }

    fn eps_query(&self, query: P::Point<'_>, eps: f64, out: &mut Vec<(u32, f64)>) {
        let mut scratch = QueryScratch::new();
        self.epoch.eps_query_with(&self.metric, query, eps, &mut scratch, out);
    }

    fn eps_query_with(
        &self,
        query: P::Point<'_>,
        eps: f64,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        self.epoch.eps_query_with(&self.metric, query, eps, scratch, out);
    }

    fn knn(&self, query: P::Point<'_>, k: usize) -> Vec<(u32, f64)> {
        let mut scratch = QueryScratch::new();
        let mut out = Vec::new();
        self.epoch.knn_with(&self.metric, query, k, &mut scratch, &mut out);
        out
    }

    fn knn_with(
        &self,
        query: P::Point<'_>,
        k: usize,
        scratch: &mut QueryScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        self.epoch.knn_with(&self.metric, query, k, scratch, out);
    }
}

/// SNN behind the facade (dense Euclidean only; [`build_index`] rejects
/// anything else with [`IndexError::Unsupported`]).
pub struct SnnIndex {
    snn: Snn,
    /// Input-order copy (the SNN core keeps a score-sorted copy).
    pts: DenseMatrix,
    metric: Euclidean,
}

impl NearIndex<DenseMatrix, Euclidean> for SnnIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Snn
    }

    fn points(&self) -> &DenseMatrix {
        &self.pts
    }

    fn metric(&self) -> &Euclidean {
        &self.metric
    }

    fn eps_query(&self, query: &[f32], eps: f64, out: &mut Vec<(u32, f64)>) {
        out.extend(self.snn.query_weighted(query, eps));
    }

    fn eps_self_join(&self, eps: f64, sink: &mut dyn GraphSink) {
        self.snn.self_join_weighted(eps, |u, v, d| sink.accept(u, v, d));
    }
}

/// Build the selected index over `pts` under `metric`.
///
/// Backends are single-threaded here; see [`build_index_par`] for the
/// pool-accelerated cover-tree build.
pub fn build_index<P: PointSet, M: Metric<P>>(
    kind: IndexKind,
    pts: &P,
    metric: M,
    params: &IndexParams,
) -> Result<Box<dyn NearIndex<P, M>>, IndexError> {
    build_impl(kind, pts, metric, params, None)
}

/// [`build_index`] with a hub-parallel cover-tree construction on `pool`
/// (bit-identical structure to the sequential build; other backends build
/// identically and ignore the pool).
pub fn build_index_par<P: PointSet, M: Metric<P>>(
    kind: IndexKind,
    pts: &P,
    metric: M,
    params: &IndexParams,
    pool: &Pool,
) -> Result<Box<dyn NearIndex<P, M>>, IndexError> {
    build_impl(kind, pts, metric, params, Some(pool))
}

fn build_impl<P: PointSet, M: Metric<P>>(
    kind: IndexKind,
    pts: &P,
    metric: M,
    params: &IndexParams,
    pool: Option<&Pool>,
) -> Result<Box<dyn NearIndex<P, M>>, IndexError> {
    match kind {
        IndexKind::BruteForce => Ok(Box::new(BruteForceIndex { pts: pts.clone(), metric })),
        IndexKind::CoverTree => {
            let build = BuildParams { leaf_size: params.leaf_size.max(1), root: 0 };
            let tree = match pool {
                Some(pool) => CoverTree::build_par(pts, &metric, &build, pool),
                None => CoverTree::build(pts, &metric, &build),
            };
            Ok(Box::new(CoverTreeIndex::from_tree(tree, metric).with_dualtree(params.dualtree)))
        }
        IndexKind::InsertCoverTree => {
            Ok(Box::new(InsertCoverTreeIndex::build(pts, metric, params)))
        }
        IndexKind::Snn => {
            // SNN needs dense rows and Euclidean geometry; everything else
            // gets a typed error instead of a panic. The downcast dance is
            // how a generic signature meets a monomorphic backend: when the
            // runtime types match, `Box<dyn NearIndex<DenseMatrix,
            // Euclidean>>` IS `Box<dyn NearIndex<P, M>>`.
            let (Some(dense), Some(_)) = (
                (pts as &dyn Any).downcast_ref::<DenseMatrix>(),
                (&metric as &dyn Any).downcast_ref::<Euclidean>(),
            ) else {
                return Err(IndexError::Unsupported {
                    kind: IndexKind::Snn,
                    metric: metric.name(),
                    requires: "dense f32 rows under the Euclidean metric",
                });
            };
            let idx: Box<dyn NearIndex<DenseMatrix, Euclidean>> = Box::new(SnnIndex {
                snn: Snn::build(dense, &params.snn),
                pts: dense.clone(),
                metric: Euclidean,
            });
            let any_box: Box<dyn Any> = Box::new(idx);
            Ok(*any_box
                .downcast::<Box<dyn NearIndex<P, M>>>()
                .expect("type ids matched the dense Euclidean case"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::metric::{Hamming, Levenshtein};
    use crate::util::Rng;

    #[test]
    fn kind_names_roundtrip() {
        for k in IndexKind::ALL {
            assert_eq!(IndexKind::parse(k.name()), Some(k));
        }
        assert_eq!(IndexKind::parse("quantum"), None);
    }

    #[test]
    fn snn_unsupported_is_typed_not_panic() {
        let mut rng = Rng::new(800);
        let codes = synthetic::hamming_clusters(&mut rng, 30, 64, 2, 0.1);
        let err = build_index(IndexKind::Snn, &codes, Hamming, &IndexParams::default())
            .err()
            .expect("hamming SNN must be rejected");
        assert_eq!(
            err,
            IndexError::Unsupported {
                kind: IndexKind::Snn,
                metric: "hamming",
                requires: "dense f32 rows under the Euclidean metric",
            }
        );
        assert!(err.to_string().contains("snn"));

        let reads = synthetic::reads(&mut rng, 20, 16, 4, 0.05);
        assert!(build_index(IndexKind::Snn, &reads, Levenshtein, &IndexParams::default()).is_err());
    }

    #[test]
    fn snn_supported_on_dense_euclidean() {
        let mut rng = Rng::new(801);
        let pts = synthetic::gaussian_mixture(&mut rng, 60, 4, 3, 0.2);
        let idx = build_index(IndexKind::Snn, &pts, Euclidean, &IndexParams::default()).unwrap();
        assert_eq!(idx.kind(), IndexKind::Snn);
        assert_eq!(idx.num_points(), 60);
        let mut out = Vec::new();
        idx.eps_query(pts.row(0), 0.0, &mut out);
        assert!(out.iter().any(|&(i, d)| i == 0 && d == 0.0));
    }

    #[test]
    fn all_kinds_build_on_dense() {
        let mut rng = Rng::new(802);
        let pts = synthetic::gaussian_mixture(&mut rng, 50, 3, 3, 0.2);
        for kind in IndexKind::ALL {
            let idx = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
            assert_eq!(idx.kind(), kind);
            assert_eq!(idx.num_points(), pts.len());
        }
    }

    #[test]
    fn facade_self_join_matches_brute_force_weighted() {
        let mut rng = Rng::new(803);
        let pts = synthetic::gaussian_mixture(&mut rng, 90, 4, 3, 0.2);
        let eps = 0.4;
        let want = crate::baseline::brute_force_weighted(&pts, &Euclidean, eps);
        for kind in IndexKind::ALL {
            let idx = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
            let mut got = WeightedEdgeList::new();
            idx.eps_self_join(eps, &mut got);
            crate::graph::assert_same_weighted_graph(
                got,
                want.clone(),
                crate::graph::WEIGHT_TOL,
                kind.name(),
            );
        }
    }

    #[test]
    fn epsilon_graph_builds_near_graph() {
        let mut rng = Rng::new(804);
        let pts = synthetic::gaussian_mixture(&mut rng, 80, 3, 3, 0.2);
        let idx = build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default())
            .unwrap();
        let pool = Pool::new(2);
        let g = epsilon_graph(idx.as_ref(), 0.5, &pool);
        assert_eq!(g.num_vertices(), 80);
        let want = crate::baseline::brute_force_edges(&pts, &Euclidean, 0.5);
        assert_eq!(g.num_edges(), want.edges().len());
    }

    #[test]
    fn knn_default_matches_covertree_backend() {
        let mut rng = Rng::new(805);
        let pts = synthetic::gaussian_mixture(&mut rng, 120, 5, 4, 0.15);
        let queries = synthetic::uniform(&mut rng, 10, 5, 1.0);
        let brute =
            build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default()).unwrap();
        let tree =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        for qi in 0..queries.len() {
            let a = brute.knn(queries.row(qi), 7);
            let b = tree.knn(queries.row(qi), 7);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.1, y.1, "distance mismatch at qi={qi}");
            }
        }
    }

    #[test]
    fn par_defaults_match_sequential() {
        let mut rng = Rng::new(806);
        let pts = synthetic::gaussian_mixture(&mut rng, 1500, 3, 4, 0.1);
        let eps = 0.25;
        let idx =
            build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default()).unwrap();
        let mut seq = WeightedEdgeList::new();
        idx.eps_self_join(eps, &mut seq);
        seq.canonicalize();
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let mut par = WeightedEdgeList::new();
            idx.eps_self_join_par(eps, &pool, &mut par);
            par.canonicalize();
            assert_eq!(seq, par, "threads={threads}");
        }
        // knn_batch_par in query order.
        let k = 5;
        let a = idx.knn_batch(&pts, k);
        let pool = Pool::new(4);
        let b = idx.knn_batch_par(&pts, k, &pool);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn scratch_threaded_queries_match_plain_on_every_backend() {
        let mut rng = Rng::new(808);
        let pts = synthetic::gaussian_mixture(&mut rng, 150, 4, 4, 0.15);
        let mut scratch = QueryScratch::new();
        for kind in IndexKind::ALL {
            let idx = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
            for qi in [0usize, 7, 42] {
                let mut plain = Vec::new();
                idx.eps_query(pts.row(qi), 0.4, &mut plain);
                let mut with = Vec::new();
                idx.eps_query_with(pts.row(qi), 0.4, &mut scratch, &mut with);
                assert_eq!(plain, with, "{} eps qi={qi}", kind.name());
                let want = idx.knn(pts.row(qi), 6);
                let mut got = vec![(99u32, 9.9f64)]; // stale: knn_with must clear
                idx.knn_with(pts.row(qi), 6, &mut scratch, &mut got);
                assert_eq!(want, got, "{} knn qi={qi}", kind.name());
            }
        }
    }

    #[test]
    fn knn_graph_identical_across_backends_and_pools() {
        let mut rng = Rng::new(807);
        let base = synthetic::uniform(&mut rng, 70, 3, 1.0);
        let pts = synthetic::with_duplicates(&mut rng, &base, 40); // exact ties
        let reference = build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default())
            .unwrap()
            .knn_graph(6, &Pool::new(1));
        assert_eq!(reference.num_vertices(), pts.len());
        assert_eq!(reference.k(), 6);
        for kind in IndexKind::ALL {
            let idx = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
            for threads in [1usize, 4] {
                let got = idx.knn_graph(6, &Pool::new(threads));
                assert_eq!(got, reference, "{} threads={threads}", kind.name());
            }
        }
        // k beyond the point count yields full rows of n-1.
        let tiny = synthetic::uniform(&mut rng, 5, 2, 1.0);
        let idx = build_index(IndexKind::CoverTree, &tiny, Euclidean, &IndexParams::default())
            .unwrap();
        let g = idx.knn_graph(99, &Pool::new(2));
        assert_eq!(g.num_arcs(), 5 * 4);
    }

    #[test]
    fn only_the_insert_backend_is_mutable() {
        let mut rng = Rng::new(809);
        let pts = synthetic::gaussian_mixture(&mut rng, 80, 3, 3, 0.2);
        for kind in IndexKind::ALL {
            let idx = build_index(kind, &pts, Euclidean, &IndexParams::default()).unwrap();
            assert_eq!(
                idx.mutable().is_some(),
                kind == IndexKind::InsertCoverTree,
                "{}",
                kind.name()
            );
        }
    }

    #[test]
    fn facade_mutations_flow_through_queries_and_snapshots() {
        let mut rng = Rng::new(810);
        let all = synthetic::gaussian_mixture(&mut rng, 120, 4, 3, 0.2);
        let seed = all.slice(0, 100);
        let idx =
            build_index(IndexKind::InsertCoverTree, &seed, Euclidean, &IndexParams::default())
                .unwrap();
        let m = idx.mutable().expect("insert backend is mutable");
        assert_eq!(m.insert(&all.slice(100, 120)), 100..120);
        assert!(m.delete(17));
        assert!(!m.delete(17), "double delete");
        assert_eq!(m.live(), 119);
        assert_eq!(idx.num_points(), 119);
        assert_eq!(m.tombstones(), 1);
        // Queries see the mutated live set, with and without a scratch.
        let mut out = Vec::new();
        idx.eps_query(all.row(17), 0.0, &mut out);
        assert!(out.iter().all(|&(gid, _)| gid != 17));
        let knn = idx.knn(all.row(110), 3);
        assert_eq!(knn.len(), 3);
        assert!(knn.iter().any(|&(gid, d)| gid == 110 && d == 0.0));
        // Snapshot: compacts (tombstones elided), reloads mutable, and the
        // reloaded index answers identically.
        let bytes = m.snapshot_bytes().expect("dense snapshot");
        assert_eq!(m.tombstones(), 0, "save compacts first");
        let back = InsertCoverTreeIndex::from_snapshot_bytes(
            &bytes,
            Euclidean,
            &IndexParams::default(),
        )
        .expect("snapshot reloads");
        assert_eq!(back.num_points(), 119);
        assert_eq!(back.knn(all.row(110), 3), knn);
        let bm = NearIndex::mutable(&back).expect("reload stays mutable");
        assert_eq!(bm.insert(&all.slice(0, 1)), 120..121);
    }

    #[test]
    fn empty_index_is_harmless() {
        let empty = DenseMatrix::new(3);
        for kind in IndexKind::ALL {
            let idx = build_index(kind, &empty, Euclidean, &IndexParams::default()).unwrap();
            assert_eq!(idx.num_points(), 0);
            let mut out = Vec::new();
            idx.eps_query(&[0.0, 0.0, 0.0], 1.0, &mut out);
            assert!(out.is_empty());
            assert!(idx.knn(&[0.0, 0.0, 0.0], 3).is_empty());
            let mut sink = WeightedEdgeList::new();
            idx.eps_self_join(1.0, &mut sink);
            assert!(sink.is_empty());
        }
    }
}
