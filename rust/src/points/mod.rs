//! Point-set containers for the three families of metric data the paper
//! evaluates: dense real vectors (Euclidean & friends), bit-packed binary
//! codes (Hamming), and byte strings (edit distance).
//!
//! All containers expose the same minimal interface the algorithms need:
//! a length, O(1) access to a point by index, `gather` to build a subset,
//! and a flat (de)serialization used by the simulated MPI layer to move
//! points between ranks.

mod dense;
mod hamming;
mod strings;

pub use dense::DenseMatrix;
pub use hamming::HammingCodes;
pub use strings::StringSet;

/// A set of points movable between ranks and sliceable into subsets.
pub trait PointSet: Clone + Send + Sync + 'static {
    /// Borrowed view of a single point.
    type Point<'a>: Copy
    where
        Self: 'a;

    /// Number of points.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow point `i`.
    fn point(&self, i: usize) -> Self::Point<'_>;

    /// New set containing `ids` (in order, duplicates allowed).
    fn gather(&self, ids: &[usize]) -> Self;

    /// New set containing the contiguous range `[lo, hi)`.
    fn slice(&self, lo: usize, hi: usize) -> Self;

    /// Append all points of `other` onto `self`.
    fn extend_from(&mut self, other: &Self);

    /// Append points `[lo, hi)` of `other` onto `self` — the range form of
    /// [`PointSet::extend_from`], implemented without a temporary
    /// container so the serve coalescer's max-batch split stays
    /// allocation-free once buffers are warm.
    fn extend_from_range(&mut self, other: &Self, lo: usize, hi: usize);

    /// Keep only the first `n` points, retaining buffer capacity (a no-op
    /// when `n >= len`). Together with [`PointSet::extend_from_range`]
    /// this lets a caller move a tail of points between two warmed
    /// containers without allocating.
    fn truncate(&mut self, n: usize);

    /// Remove every point, keeping the per-point shape **and the buffer
    /// capacity**. `clear()` + `extend_from` is the steady-state reuse
    /// cycle of the serve coalescer's batch double-buffer: once warmed,
    /// the cycle performs no heap allocation.
    fn clear(&mut self);

    /// Whether `other`'s points could be appended onto `self` — same
    /// dimension for dense rows, same bit width for Hamming codes (byte
    /// strings always match). [`PointSet::extend_from`] asserts this;
    /// wire-facing callers (the serve daemon) check it first so a client
    /// sending a wrong-shape point gets a typed reply, not a panic.
    fn shape_matches(&self, other: &Self) -> bool;

    /// An empty set with the same per-point shape (dimension etc.).
    fn empty_like(&self) -> Self;

    /// Serialize into a byte buffer (used by the comm layer).
    fn to_bytes(&self) -> Vec<u8>;

    /// Length-checked deserialization from [`PointSet::to_bytes`] output:
    /// truncated, oversized or internally inconsistent bytes yield a typed
    /// [`WireError`], never a panic. This is the decoder every wire-facing
    /// container (`Bundle`, `KnnBundle`) routes through, so a corrupt
    /// point payload surfaces as an error at the message boundary.
    fn try_from_bytes(bytes: &[u8]) -> Result<Self, WireError>;

    /// Deserialize from [`PointSet::to_bytes`] output, panicking (with the
    /// decode diagnostic) on malformed bytes — for in-process callers
    /// whose bytes never left the address space.
    fn from_bytes(bytes: &[u8]) -> Self {
        match Self::try_from_bytes(bytes) {
            Ok(v) => v,
            Err(e) => panic!("point-set decode failed: {e}"),
        }
    }

    /// In-memory footprint of the payload in bytes (for the α-β comm model).
    fn payload_bytes(&self) -> u64;
}

/// Number of interleaved candidates in one SoA lane group — the K of the
/// K-lane distance kernels in [`crate::metric::kernel`]. Eight f32 lanes
/// fill one AVX2 register; eight u64 popcount lanes fill one cache line.
pub const LANES: usize = 8;

/// One coordinate's eight f32 lanes, padded to a cache line so every lane
/// group in a gathered tile starts 64-byte aligned (the K-lane inner loops
/// load each group as one unit; alignment keeps those loads from
/// straddling lines). The padding doubles the gather buffer — fine for a
/// tile that lives in L1 and is bounded by the point dimension.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C, align(64))]
pub struct F32Lanes(pub [f32; LANES]);

/// One code word's eight u64 lanes — exactly one 64-byte cache line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
#[repr(C, align(64))]
pub struct U64Lanes(pub [u64; LANES]);

/// Little-endian framing helpers shared by the serializers.
pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn get_u64(bytes: &[u8], off: &mut usize) -> u64 {
    let v = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap());
    *off += 8;
    v
}

/// Decode failure of a wire payload (truncated or internally inconsistent
/// bytes). Wire decoders that face bytes from outside the process — edge
/// lists, weighted graphs, point bundles — return this instead of panicking
/// on a blind slice.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before `need` more bytes of `what` could be read.
    Truncated { what: &'static str, need: usize, have: usize },
    /// Lengths/values decoded fine but contradict each other.
    Corrupt { what: &'static str },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { what, need, have } => {
                write!(f, "truncated wire payload: {what} needs {need} more bytes, {have} left")
            }
            WireError::Corrupt { what } => write!(f, "corrupt wire payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Little-endian reads over already-length-validated subslices. These are
/// the panic-free building blocks `WireError` decoders use in place of the
/// `try_into().unwrap()` idiom: a short slice is a caller bug surfaced by
/// the debug assertion, and release builds zero-fill the missing high bytes
/// instead of panicking — the downstream checksum/invariant checks then
/// reject the value as corrupt.
pub(crate) fn le_u64(b: &[u8]) -> u64 {
    debug_assert!(b.len() >= 8, "le_u64 needs 8 bytes");
    b.iter().take(8).rev().fold(0u64, |acc, &x| (acc << 8) | u64::from(x))
}

pub(crate) fn le_u32(b: &[u8]) -> u32 {
    debug_assert!(b.len() >= 4, "le_u32 needs 4 bytes");
    b.iter().take(4).rev().fold(0u32, |acc, &x| (acc << 8) | u32::from(x))
}

pub(crate) fn le_i32(b: &[u8]) -> i32 {
    le_u32(b) as i32
}

pub(crate) fn le_f64(b: &[u8]) -> f64 {
    f64::from_bits(le_u64(b))
}

pub(crate) fn le_f32(b: &[u8]) -> f32 {
    f32::from_bits(le_u32(b))
}

/// Length-checked [`get_u64`].
pub(crate) fn try_get_u64(
    bytes: &[u8],
    off: &mut usize,
    what: &'static str,
) -> Result<u64, WireError> {
    let b = try_take(bytes, off, 8, what)?;
    Ok(le_u64(b))
}

/// Length-checked single-byte read (wire tags and flags).
pub(crate) fn try_get_u8(
    bytes: &[u8],
    off: &mut usize,
    what: &'static str,
) -> Result<u8, WireError> {
    match bytes.get(*off) {
        Some(&v) => {
            *off += 1;
            Ok(v)
        }
        None => Err(WireError::Truncated { what, need: 1, have: 0 }),
    }
}

/// Borrow the next `len` bytes of `bytes`, or report how short the buffer
/// falls.
pub(crate) fn try_take<'a>(
    bytes: &'a [u8],
    off: &mut usize,
    len: usize,
    what: &'static str,
) -> Result<&'a [u8], WireError> {
    let have = bytes.len().saturating_sub(*off);
    if have < len {
        return Err(WireError::Truncated { what, need: len, have });
    }
    match bytes.get(*off..off.saturating_add(len)) {
        Some(out) => {
            *off += len;
            Ok(out)
        }
        None => Err(WireError::Corrupt { what }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framing_roundtrip() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 0);
        put_u64(&mut buf, u64::MAX);
        put_u64(&mut buf, 123456789);
        let mut off = 0;
        assert_eq!(get_u64(&buf, &mut off), 0);
        assert_eq!(get_u64(&buf, &mut off), u64::MAX);
        assert_eq!(get_u64(&buf, &mut off), 123456789);
        assert_eq!(off, buf.len());
    }

    #[test]
    fn le_readers_match_std() {
        let v64 = 0x0123_4567_89AB_CDEFu64;
        let v32 = 0xDEAD_BEEFu32;
        let vf = -1234.5678f64;
        assert_eq!(le_u64(&v64.to_le_bytes()), v64);
        assert_eq!(le_u32(&v32.to_le_bytes()), v32);
        assert_eq!(le_i32(&(-7i32).to_le_bytes()), -7);
        assert_eq!(le_f64(&vf.to_le_bytes()).to_bits(), vf.to_bits());
        // Longer slices read only their prefix (chunks_exact callers pass
        // exactly-sized chunks; offset callers pass the tail).
        let mut long = v32.to_le_bytes().to_vec();
        long.extend_from_slice(&[0xFF; 4]);
        assert_eq!(le_u32(&long), v32);
    }

    #[test]
    fn try_get_u8_reports_truncation() {
        let mut off = 0;
        assert_eq!(try_get_u8(&[7], &mut off, "tag"), Ok(7));
        assert!(matches!(
            try_get_u8(&[7], &mut off, "tag"),
            Err(WireError::Truncated { what: "tag", .. })
        ));
    }
}
