//! Variable-length byte strings for edit-distance (Levenshtein) workloads —
//! the "genomic reads" use case the paper's introduction motivates for
//! non-Euclidean metrics.

use super::{put_u64, PointSet};

/// A set of byte strings stored contiguously with an offsets array (the same
/// layout as an Arrow string column).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct StringSet {
    offsets: Vec<usize>, // len n+1, offsets[0] == 0
    bytes: Vec<u8>,
}

impl StringSet {
    pub fn new() -> Self {
        StringSet { offsets: vec![0], bytes: Vec::new() }
    }

    pub fn from_strs<S: AsRef<[u8]>>(items: &[S]) -> Self {
        let mut s = StringSet::new();
        for it in items {
            s.push(it.as_ref());
        }
        s
    }

    pub fn push(&mut self, s: &[u8]) {
        self.bytes.extend_from_slice(s);
        self.offsets.push(self.bytes.len());
    }

    /// Borrow string `i`.
    #[inline]
    pub fn get(&self, i: usize) -> &[u8] {
        &self.bytes[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Length of string `i` without borrowing it.
    #[inline]
    pub fn str_len(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }
}

impl PointSet for StringSet {
    type Point<'a> = &'a [u8];

    #[inline]
    fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    fn point(&self, i: usize) -> &[u8] {
        self.get(i)
    }

    fn gather(&self, ids: &[usize]) -> Self {
        let mut out = StringSet::new();
        for &i in ids {
            out.push(self.get(i));
        }
        out
    }

    fn slice(&self, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi && hi <= self.len());
        let mut out = StringSet::new();
        for i in lo..hi {
            out.push(self.get(i));
        }
        out
    }

    fn extend_from(&mut self, other: &Self) {
        for i in 0..other.len() {
            self.push(other.get(i));
        }
    }

    fn extend_from_range(&mut self, other: &Self, lo: usize, hi: usize) {
        assert!(lo <= hi && hi <= other.len());
        for i in lo..hi {
            self.push(other.get(i));
        }
    }

    fn truncate(&mut self, n: usize) {
        if n < self.len() {
            self.bytes.truncate(self.offsets[n]);
            self.offsets.truncate(n + 1);
        }
    }

    fn clear(&mut self) {
        self.offsets.clear();
        self.offsets.push(0);
        self.bytes.clear();
    }

    fn shape_matches(&self, _other: &Self) -> bool {
        true // variable-length strings have no fixed per-point shape
    }

    fn empty_like(&self) -> Self {
        StringSet::new()
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(16 + self.offsets.len() * 8 + self.bytes.len());
        put_u64(&mut buf, self.len() as u64);
        for i in 0..self.len() {
            put_u64(&mut buf, self.str_len(i) as u64);
        }
        buf.extend_from_slice(&self.bytes);
        buf
    }

    fn try_from_bytes(bytes: &[u8]) -> Result<Self, super::WireError> {
        use super::{le_u64, try_get_u64, try_take, WireError};
        let mut off = 0usize;
        let n = try_get_u64(bytes, &mut off, "string count")? as usize;
        let len_bytes = try_take(bytes, &mut off, n.saturating_mul(8), "string lengths")?;
        let lens: Vec<usize> =
            len_bytes.chunks_exact(8).map(|c| le_u64(c) as usize).collect();
        let mut out = StringSet::new();
        for l in lens {
            out.push(try_take(bytes, &mut off, l, "string bytes")?);
        }
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after string payload" });
        }
        Ok(out)
    }

    fn payload_bytes(&self) -> u64 {
        (self.bytes.len() + self.offsets.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StringSet {
        StringSet::from_strs(&["ACGT", "", "AAA", "TTTTTTTT"])
    }

    #[test]
    fn basic_access() {
        let s = sample();
        assert_eq!(s.len(), 4);
        assert_eq!(s.get(0), b"ACGT");
        assert_eq!(s.get(1), b"");
        assert_eq!(s.str_len(3), 8);
    }

    #[test]
    fn gather_and_slice() {
        let s = sample();
        let g = s.gather(&[3, 0]);
        assert_eq!(g.get(0), b"TTTTTTTT");
        assert_eq!(g.get(1), b"ACGT");
        let sl = s.slice(1, 3);
        assert_eq!(sl.len(), 2);
        assert_eq!(sl.get(1), b"AAA");
    }

    #[test]
    fn serialization_roundtrip_with_empty_strings() {
        let s = sample();
        let s2 = StringSet::from_bytes(&s.to_bytes());
        assert_eq!(s, s2);
    }

    #[test]
    fn extend_from_works() {
        let mut a = sample();
        let b = StringSet::from_strs(&["XY"]);
        a.extend_from(&b);
        assert_eq!(a.len(), 5);
        assert_eq!(a.get(4), b"XY");
    }

    #[test]
    fn empty_set_roundtrip() {
        let e = StringSet::new();
        assert!(e.is_empty());
        assert_eq!(StringSet::from_bytes(&e.to_bytes()).len(), 0);
    }

    #[test]
    fn extend_from_range_and_truncate_respect_offsets() {
        let s = sample();
        let mut dst = StringSet::new();
        dst.extend_from_range(&s, 1, 4);
        assert_eq!(dst.len(), 3);
        assert_eq!(dst.get(0), b"");
        assert_eq!(dst.get(2), b"TTTTTTTT");
        let mut t = sample();
        t.truncate(2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(0), b"ACGT");
        assert_eq!(t.get(1), b"");
        t.push(b"ZZ");
        assert_eq!(t.get(2), b"ZZ");
        t.truncate(9);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn clear_resets_to_valid_empty() {
        let mut s = sample();
        s.clear();
        assert_eq!(s.len(), 0);
        s.push(b"GG");
        assert_eq!(s.get(0), b"GG");
        assert!(s.shape_matches(&StringSet::new()));
    }
}
