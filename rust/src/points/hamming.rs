//! Bit-packed binary codes for Hamming-metric datasets (sift-hamming 256-bit,
//! word2bits 800-bit in Table I). Each point is `words_per_point` u64 words;
//! distance is a popcount over XOR-ed words.

use super::{put_u64, PointSet};

/// `n` binary codes of `bits` bits each, packed little-endian into u64 words.
#[derive(Clone, Debug, PartialEq)]
pub struct HammingCodes {
    bits: usize,
    words_per_point: usize,
    data: Vec<u64>,
}

impl HammingCodes {
    /// Empty set of `bits`-bit codes.
    pub fn new(bits: usize) -> Self {
        assert!(bits > 0);
        HammingCodes { bits, words_per_point: (bits + 63) / 64, data: Vec::new() }
    }

    /// From packed words (length must be a multiple of words-per-point).
    pub fn from_words(bits: usize, data: Vec<u64>) -> Self {
        let wpp = (bits + 63) / 64;
        assert_eq!(data.len() % wpp, 0);
        HammingCodes { bits, words_per_point: wpp, data }
    }

    /// Number of bits per code.
    #[inline]
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// u64 words per code.
    #[inline]
    pub fn words_per_point(&self) -> usize {
        self.words_per_point
    }

    /// Append a code given as a bool slice of length `bits`.
    pub fn push_bits(&mut self, bits: &[bool]) {
        assert_eq!(bits.len(), self.bits);
        let base = self.data.len();
        self.data.resize(base + self.words_per_point, 0);
        for (i, &b) in bits.iter().enumerate() {
            if b {
                self.data[base + i / 64] |= 1u64 << (i % 64);
            }
        }
    }

    /// Append a pre-packed code.
    pub fn push_words(&mut self, words: &[u64]) {
        assert_eq!(words.len(), self.words_per_point);
        self.data.extend_from_slice(words);
    }

    /// Borrow code `i` as packed words.
    #[inline]
    pub fn code(&self, i: usize) -> &[u64] {
        &self.data[i * self.words_per_point..(i + 1) * self.words_per_point]
    }

    /// Hamming weight (number of set bits) of code `i` — the `‖x‖₁` term of
    /// the matmul-form Hamming distance used by the PJRT tile engine.
    pub fn weight(&self, i: usize) -> u32 {
        self.code(i).iter().map(|w| w.count_ones()).sum()
    }

    /// Gather up to [`LANES`](super::LANES) codes into the lane-major
    /// (word-major, lane-minor) SoA layout of the K-lane popcount kernel:
    /// after the call, `out[w].0[l] == self.code(idx[l])[w]`. Unused lanes
    /// are zero-filled and never emitted from. `out` is caller-owned
    /// scratch; steady state performs no allocation.
    #[inline]
    pub fn gather_lanes(&self, idx: &[u32], out: &mut Vec<super::U64Lanes>) {
        debug_assert!(idx.len() <= super::LANES);
        out.clear();
        out.resize(self.words_per_point, super::U64Lanes::default());
        for (l, &i) in idx.iter().enumerate() {
            for (lanes, &w) in out.iter_mut().zip(self.code(i as usize)) {
                lanes.0[l] = w;
            }
        }
    }

    /// Unpack code `i` into ±0/1 f32s — the encoding the dense tile engine
    /// (L1 Pallas kernel) consumes.
    pub fn unpack_f32(&self, i: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.bits);
        let code = self.code(i);
        for b in 0..self.bits {
            out.push(((code[b / 64] >> (b % 64)) & 1) as f32);
        }
        out
    }
}

impl PointSet for HammingCodes {
    type Point<'a> = &'a [u64];

    #[inline]
    fn len(&self) -> usize {
        if self.data.is_empty() {
            0
        } else {
            self.data.len() / self.words_per_point
        }
    }

    #[inline]
    fn point(&self, i: usize) -> &[u64] {
        self.code(i)
    }

    fn gather(&self, ids: &[usize]) -> Self {
        let mut out = HammingCodes::new(self.bits);
        out.data.reserve(ids.len() * self.words_per_point);
        for &i in ids {
            out.data.extend_from_slice(self.code(i));
        }
        out
    }

    fn slice(&self, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi && hi <= self.len());
        HammingCodes {
            bits: self.bits,
            words_per_point: self.words_per_point,
            data: self.data[lo * self.words_per_point..hi * self.words_per_point].to_vec(),
        }
    }

    fn extend_from(&mut self, other: &Self) {
        assert_eq!(self.bits, other.bits);
        self.data.extend_from_slice(&other.data);
    }

    fn extend_from_range(&mut self, other: &Self, lo: usize, hi: usize) {
        assert_eq!(self.bits, other.bits);
        assert!(lo <= hi && hi <= other.len());
        self.data
            .extend_from_slice(&other.data[lo * self.words_per_point..hi * self.words_per_point]);
    }

    fn truncate(&mut self, n: usize) {
        if n < self.len() {
            self.data.truncate(n * self.words_per_point);
        }
    }

    fn clear(&mut self) {
        self.data.clear();
    }

    fn shape_matches(&self, other: &Self) -> bool {
        self.bits == other.bits
    }

    fn empty_like(&self) -> Self {
        HammingCodes::new(self.bits)
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(16 + self.data.len() * 8);
        put_u64(&mut buf, self.bits as u64);
        put_u64(&mut buf, self.len() as u64);
        for &w in &self.data {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        buf
    }

    fn try_from_bytes(bytes: &[u8]) -> Result<Self, super::WireError> {
        use super::{le_u64, try_get_u64, try_take, WireError};
        let mut off = 0usize;
        let bits = try_get_u64(bytes, &mut off, "hamming bits")? as usize;
        let n = try_get_u64(bytes, &mut off, "hamming code count")? as usize;
        if bits == 0 {
            return Err(WireError::Corrupt { what: "hamming bits must be positive" });
        }
        let wpp = bits.saturating_add(63) / 64;
        let payload =
            try_take(bytes, &mut off, n.saturating_mul(wpp).saturating_mul(8), "hamming words")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after hamming words" });
        }
        let data: Vec<u64> = payload.chunks_exact(8).map(le_u64).collect();
        Ok(HammingCodes { bits, words_per_point: wpp, data })
    }

    fn payload_bytes(&self) -> u64 {
        (self.data.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> HammingCodes {
        let mut h = HammingCodes::new(100); // 2 words per point
        let mut a = vec![false; 100];
        a[0] = true;
        a[64] = true;
        a[99] = true;
        h.push_bits(&a);
        let b = vec![true; 100];
        h.push_bits(&b);
        h
    }

    #[test]
    fn packing_and_weight() {
        let h = sample();
        assert_eq!(h.len(), 2);
        assert_eq!(h.words_per_point(), 2);
        assert_eq!(h.weight(0), 3);
        assert_eq!(h.weight(1), 100);
    }

    #[test]
    fn unpack_roundtrip() {
        let h = sample();
        let f = h.unpack_f32(0);
        assert_eq!(f.len(), 100);
        assert_eq!(f[0], 1.0);
        assert_eq!(f[1], 0.0);
        assert_eq!(f[64], 1.0);
        assert_eq!(f[99], 1.0);
        assert_eq!(f.iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn gather_slice_extend() {
        let h = sample();
        let g = h.gather(&[1, 0]);
        assert_eq!(g.weight(0), 100);
        assert_eq!(g.weight(1), 3);
        let mut s = h.slice(0, 1);
        assert_eq!(s.len(), 1);
        s.extend_from(&h.slice(1, 2));
        assert_eq!(s.len(), 2);
        assert_eq!(s.weight(1), 100);
    }

    #[test]
    fn serialization_roundtrip() {
        let h = sample();
        let h2 = HammingCodes::from_bytes(&h.to_bytes());
        assert_eq!(h, h2);
    }

    #[test]
    fn empty_set() {
        let e = sample().empty_like();
        assert_eq!(e.len(), 0);
        assert!(e.is_empty());
        assert_eq!(HammingCodes::from_bytes(&e.to_bytes()).len(), 0);
    }

    #[test]
    fn extend_from_range_and_truncate_on_packed_words() {
        let h = sample();
        let mut dst = h.empty_like();
        dst.extend_from_range(&h, 1, 2);
        assert_eq!(dst.len(), 1);
        assert_eq!(dst.weight(0), 100);
        let mut t = sample();
        t.truncate(1);
        assert_eq!(t.len(), 1);
        assert_eq!(t.weight(0), 3);
        t.truncate(4);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn clear_keeps_shape_and_capacity() {
        let mut h = sample();
        let cap = h.data.capacity();
        h.clear();
        assert_eq!(h.len(), 0);
        assert_eq!(h.bits(), 100);
        assert!(h.data.capacity() >= cap);
        h.extend_from(&sample());
        assert_eq!(h.len(), 2);
        assert!(h.shape_matches(&sample()));
        assert!(!h.shape_matches(&HammingCodes::new(64)));
    }
}
