//! Dense `f32` row-major point matrix — the container for every Euclidean
//! dataset in Table I (faces, artificial40, corel, deep, covtype, twitter,
//! sift) and their synthetic analogs.

use super::{put_u64, PointSet};

/// Row-major `n × d` matrix of `f32` coordinates.
///
/// Every matrix carries a cache of the squared L2 norm of each row,
/// maintained by all mutation paths. The cache feeds the matmul-form
/// distance kernels (`‖x‖² + ‖y‖² − 2⟨x,y⟩`): the SNN baseline, the dense
/// tile engine, and the cover tree's batched leaf filtering (DESIGN.md
/// §7.1). Norms are always computed by the same summation
/// ([`row_sq_norm`]), so equal row data yields bit-equal cached norms.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    dim: usize,
    data: Vec<f32>,
    norms: Vec<f32>,
}

/// Squared L2 norm of one row — the canonical summation used for every
/// cached norm (sequential f32 accumulation).
#[inline]
pub fn row_sq_norm(row: &[f32]) -> f32 {
    row.iter().map(|x| x * x).sum()
}

impl DenseMatrix {
    /// Create from a flat row-major buffer. `data.len()` must be a multiple
    /// of `dim` (or zero when `dim == 0` is disallowed).
    pub fn from_flat(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0, "dimension must be positive");
        assert_eq!(data.len() % dim, 0, "flat buffer not a multiple of dim");
        let norms = data.chunks_exact(dim).map(row_sq_norm).collect();
        DenseMatrix { dim, data, norms }
    }

    /// An empty matrix of points with dimension `dim`.
    pub fn new(dim: usize) -> Self {
        Self::from_flat(dim, Vec::new())
    }

    /// With pre-reserved capacity for `n` points.
    pub fn with_capacity(dim: usize, n: usize) -> Self {
        assert!(dim > 0);
        DenseMatrix { dim, data: Vec::with_capacity(dim * n), norms: Vec::with_capacity(n) }
    }

    /// Point dimensionality.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Borrow the flat row-major data.
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    /// Push one point (must have length `dim`).
    pub fn push(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim);
        self.data.extend_from_slice(row);
        self.norms.push(row_sq_norm(row));
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Iterator over rows.
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.dim)
    }

    /// Cached squared L2 norm of row `i`.
    #[inline]
    pub fn sq_norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    /// Cached squared L2 norms of all rows (parallel to the rows).
    #[inline]
    pub fn sq_norms(&self) -> &[f32] {
        &self.norms
    }

    /// Squared L2 norm of every row, as an owned vector (a copy of the
    /// cache; kept for callers that need ownership).
    pub fn row_sq_norms(&self) -> Vec<f32> {
        self.norms.clone()
    }

    /// Gather up to [`LANES`](super::LANES) rows into the lane-major
    /// (coordinate-major, lane-minor) SoA layout the K-lane kernels
    /// consume: after the call, `out[c].0[l] == self.row(idx[l])[c]`.
    /// Unused lanes (`idx.len() < LANES`) are zero-filled — the kernels
    /// never emit from them, so the padding value is never observed.
    /// `out` is caller-owned scratch; its capacity warms up to `dim` once
    /// and the steady state performs no allocation.
    #[inline]
    pub fn gather_lanes(&self, idx: &[u32], out: &mut Vec<super::F32Lanes>) {
        debug_assert!(idx.len() <= super::LANES);
        out.clear();
        out.resize(self.dim, super::F32Lanes::default());
        for (l, &i) in idx.iter().enumerate() {
            for (lanes, &x) in out.iter_mut().zip(self.row(i as usize)) {
                lanes.0[l] = x;
            }
        }
    }
}

impl PointSet for DenseMatrix {
    type Point<'a> = &'a [f32];

    #[inline]
    fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    #[inline]
    fn point(&self, i: usize) -> &[f32] {
        self.row(i)
    }

    fn gather(&self, ids: &[usize]) -> Self {
        let mut out = DenseMatrix::with_capacity(self.dim, ids.len());
        for &i in ids {
            out.data.extend_from_slice(self.row(i));
            out.norms.push(self.norms[i]);
        }
        out
    }

    fn slice(&self, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi && hi <= self.len());
        DenseMatrix {
            dim: self.dim,
            data: self.data[lo * self.dim..hi * self.dim].to_vec(),
            norms: self.norms[lo..hi].to_vec(),
        }
    }

    fn extend_from(&mut self, other: &Self) {
        assert_eq!(self.dim, other.dim, "dimension mismatch");
        self.data.extend_from_slice(&other.data);
        self.norms.extend_from_slice(&other.norms);
    }

    fn extend_from_range(&mut self, other: &Self, lo: usize, hi: usize) {
        assert_eq!(self.dim, other.dim, "dimension mismatch");
        assert!(lo <= hi && hi <= other.len());
        self.data.extend_from_slice(&other.data[lo * self.dim..hi * self.dim]);
        self.norms.extend_from_slice(&other.norms[lo..hi]);
    }

    fn truncate(&mut self, n: usize) {
        if n < self.len() {
            self.data.truncate(n * self.dim);
            self.norms.truncate(n);
        }
    }

    fn clear(&mut self) {
        self.data.clear();
        self.norms.clear();
    }

    fn shape_matches(&self, other: &Self) -> bool {
        self.dim == other.dim
    }

    fn empty_like(&self) -> Self {
        DenseMatrix::new(self.dim)
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(16 + self.data.len() * 4);
        put_u64(&mut buf, self.dim as u64);
        put_u64(&mut buf, self.len() as u64);
        for &x in &self.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        buf
    }

    fn try_from_bytes(bytes: &[u8]) -> Result<Self, super::WireError> {
        use super::{le_f32, try_get_u64, try_take, WireError};
        let mut off = 0usize;
        let dim = try_get_u64(bytes, &mut off, "dense dim")? as usize;
        let n = try_get_u64(bytes, &mut off, "dense point count")? as usize;
        if dim == 0 {
            return Err(WireError::Corrupt { what: "dense dim must be positive" });
        }
        let payload =
            try_take(bytes, &mut off, n.saturating_mul(dim).saturating_mul(4), "dense rows")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after dense rows" });
        }
        let data: Vec<f32> = payload.chunks_exact(4).map(le_f32).collect();
        Ok(DenseMatrix::from_flat(dim, data))
    }

    fn payload_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DenseMatrix {
        DenseMatrix::from_flat(3, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0])
    }

    #[test]
    fn len_and_rows() {
        let m = sample();
        assert_eq!(m.len(), 3);
        assert_eq!(m.dim(), 3);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.rows().count(), 3);
    }

    #[test]
    fn gather_orders_and_duplicates() {
        let m = sample();
        let g = m.gather(&[2, 0, 2]);
        assert_eq!(g.len(), 3);
        assert_eq!(g.row(0), &[6.0, 7.0, 8.0]);
        assert_eq!(g.row(1), &[0.0, 1.0, 2.0]);
        assert_eq!(g.row(2), &[6.0, 7.0, 8.0]);
    }

    #[test]
    fn slice_and_extend() {
        let m = sample();
        let mut s = m.slice(1, 3);
        assert_eq!(s.len(), 2);
        s.extend_from(&m.slice(0, 1));
        assert_eq!(s.len(), 3);
        assert_eq!(s.row(2), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn serialization_roundtrip() {
        let m = sample();
        let b = m.to_bytes();
        let m2 = DenseMatrix::from_bytes(&b);
        assert_eq!(m, m2);
        assert_eq!(m.payload_bytes(), 36);
    }

    #[test]
    fn empty_roundtrip() {
        let e = sample().empty_like();
        assert_eq!(e.len(), 0);
        let e2 = DenseMatrix::from_bytes(&e.to_bytes());
        assert_eq!(e, e2);
    }

    #[test]
    fn sq_norms() {
        let m = sample();
        let norms = m.row_sq_norms();
        assert_eq!(norms, vec![5.0, 50.0, 149.0]);
        assert_eq!(m.sq_norms(), &[5.0, 50.0, 149.0]);
        assert_eq!(m.sq_norm(1), 50.0);
    }

    #[test]
    fn norm_cache_tracks_every_mutation() {
        let m = sample();
        let expect = |mm: &DenseMatrix| {
            let want: Vec<f32> = mm.rows().map(row_sq_norm).collect();
            assert_eq!(mm.sq_norms(), &want[..]);
        };
        expect(&m.gather(&[2, 0, 2]));
        expect(&m.slice(1, 3));
        let mut s = m.slice(0, 2);
        s.extend_from(&m.slice(2, 3));
        expect(&s);
        s.push(&[1.0, 1.0, 1.0]);
        expect(&s);
        expect(&DenseMatrix::from_bytes(&s.to_bytes()));
    }

    #[test]
    fn extend_from_range_and_truncate_move_tails_exactly() {
        let m = sample();
        let mut dst = m.empty_like();
        dst.extend_from_range(&m, 1, 3);
        assert_eq!(dst.len(), 2);
        assert_eq!(dst.row(0), m.row(1));
        assert_eq!(dst.row(1), m.row(2));
        assert_eq!(dst.sq_norms(), &m.sq_norms()[1..3]);
        let mut t = sample();
        t.truncate(1);
        assert_eq!(t.len(), 1);
        assert_eq!(t.row(0), m.row(0));
        t.truncate(5); // no-op past the end
        assert_eq!(t.len(), 1);
        // The coalescer split cycle: tail out, truncate, both stay valid.
        let mut a = sample();
        let mut b = a.empty_like();
        b.extend_from_range(&a, 2, 3);
        a.truncate(2);
        assert_eq!(a.len(), 2);
        assert_eq!(b.row(0), m.row(2));
    }

    #[test]
    #[should_panic]
    fn push_wrong_dim_panics() {
        let mut m = sample();
        m.push(&[1.0]);
    }

    #[test]
    fn clear_keeps_shape_and_capacity() {
        let mut m = sample();
        let cap = m.data.capacity();
        m.clear();
        assert_eq!(m.len(), 0);
        assert_eq!(m.dim(), 3);
        assert!(m.data.capacity() >= cap, "clear must not shrink the buffer");
        m.extend_from(&sample());
        assert_eq!(m.len(), 3);
        assert!(m.shape_matches(&sample()));
        assert!(!m.shape_matches(&DenseMatrix::new(5)));
    }
}
