//! Cell → rank assignment.
//!
//! Coalescing Voronoi cells onto ranks is a multiway number partitioning
//! problem on the cell sizes. The paper uses Graham's LPT rule
//! (longest-processing-time-first), a 4/3-approximation computable in
//! O(m log m); a cyclic assignment is kept as the ablation baseline.

/// Cyclic (round-robin) assignment: cell `i` → rank `i mod ranks`.
pub fn cyclic_assignment(cell_sizes: &[u64], ranks: usize) -> Vec<usize> {
    (0..cell_sizes.len()).map(|i| i % ranks).collect()
}

/// Graham's LPT multiway number partitioning: sort cells by decreasing
/// size, repeatedly give the largest unassigned cell to the least-loaded
/// rank. Returns `assignment[cell] = rank`.
pub fn multiway_partition(cell_sizes: &[u64], ranks: usize) -> Vec<usize> {
    assert!(ranks > 0);
    let mut order: Vec<usize> = (0..cell_sizes.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(cell_sizes[i]));
    // Min-heap of (load, rank) via BinaryHeap<Reverse<..>>.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        (0..ranks).map(|r| Reverse((0u64, r))).collect();
    let mut assignment = vec![0usize; cell_sizes.len()];
    for i in order {
        let Reverse((load, r)) = heap.pop().unwrap();
        assignment[i] = r;
        heap.push(Reverse((load + cell_sizes[i], r)));
    }
    assignment
}

/// Maximum per-rank load under an assignment (the quantity LPT minimizes).
pub fn partition_makespan(cell_sizes: &[u64], assignment: &[usize], ranks: usize) -> u64 {
    let mut loads = vec![0u64; ranks];
    for (i, &r) in assignment.iter().enumerate() {
        loads[r] += cell_sizes[i];
    }
    loads.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn cyclic_is_round_robin() {
        let a = cyclic_assignment(&[1, 2, 3, 4, 5], 2);
        assert_eq!(a, vec![0, 1, 0, 1, 0]);
    }

    #[test]
    fn lpt_assignment_valid() {
        let sizes = [10u64, 7, 7, 6, 4, 4, 2];
        let a = multiway_partition(&sizes, 3);
        assert_eq!(a.len(), sizes.len());
        assert!(a.iter().all(|&r| r < 3));
    }

    #[test]
    fn lpt_beats_or_ties_cyclic_on_skewed_sizes() {
        let mut rng = Rng::new(65);
        for trial in 0..20 {
            // Heavily skewed cell sizes (the duplicated/clustered case).
            let m = 16 + rng.below(32);
            let sizes: Vec<u64> =
                (0..m).map(|_| if rng.bool(0.2) { 1000 + rng.below(5000) as u64 } else { rng.below(100) as u64 }).collect();
            let ranks = 4;
            let lpt = partition_makespan(&sizes, &multiway_partition(&sizes, ranks), ranks);
            let cyc = partition_makespan(&sizes, &cyclic_assignment(&sizes, ranks), ranks);
            assert!(lpt <= cyc, "trial {trial}: LPT {lpt} worse than cyclic {cyc}");
        }
    }

    #[test]
    fn lpt_within_4_3_of_lower_bound() {
        let mut rng = Rng::new(66);
        for _ in 0..20 {
            let m = 8 + rng.below(24);
            let sizes: Vec<u64> = (0..m).map(|_| 1 + rng.below(1000) as u64).collect();
            let ranks = 1 + rng.below(6);
            let a = multiway_partition(&sizes, ranks);
            let mk = partition_makespan(&sizes, &a, ranks);
            let total: u64 = sizes.iter().sum();
            let lb = (total as f64 / ranks as f64).ceil().max(*sizes.iter().max().unwrap() as f64);
            assert!(
                (mk as f64) <= lb * 4.0 / 3.0 + 1.0,
                "makespan {mk} exceeds 4/3 · LB {lb}"
            );
        }
    }

    #[test]
    fn perfect_split_found_when_trivial() {
        // Equal sizes divide evenly.
        let sizes = vec![5u64; 8];
        let a = multiway_partition(&sizes, 4);
        assert_eq!(partition_makespan(&sizes, &a, 4), 10);
    }

    #[test]
    fn more_ranks_than_cells() {
        let sizes = [3u64, 1];
        let a = multiway_partition(&sizes, 8);
        assert_eq!(partition_makespan(&sizes, &a, 8), 3);
    }

    #[test]
    fn empty_cells() {
        let a = multiway_partition(&[], 4);
        assert!(a.is_empty());
        assert_eq!(partition_makespan(&[], &a, 4), 0);
    }
}
