//! Landmark (Voronoi site) selection strategies.
//!
//! The paper compares the greedy permutation (Gonzalez farthest-point,
//! which yields an r-net prefix) against uniform-random selection and finds
//! random more robust on skewed/duplicated data; both are provided and the
//! ablation bench compares them.

use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::Rng;

/// `m` distinct uniform-random indices — the paper's default strategy.
pub fn random_centers(rng: &mut Rng, n: usize, m: usize) -> Vec<usize> {
    rng.sample_indices(n, m.min(n))
}

/// Length-`m` prefix of the greedy (farthest-point / Gonzalez) permutation
/// starting from `start`. The prefix is an r-net for r = its coverage
/// radius. O(n·m) distance evaluations.
pub fn greedy_permutation<P: PointSet, M: Metric<P>>(
    pts: &P,
    metric: &M,
    m: usize,
    start: usize,
) -> Vec<usize> {
    let n = pts.len();
    if n == 0 || m == 0 {
        return Vec::new();
    }
    assert!(start < n);
    let m = m.min(n);
    let mut chosen = Vec::with_capacity(m);
    chosen.push(start);
    let mut dist: Vec<f64> = (0..n).map(|i| metric.dist_ij(pts, i, start)).collect();
    while chosen.len() < m {
        // Farthest point from the chosen set.
        // total_cmp: NaN distances (broken metric) sort last instead of
        // panicking the selection loop.
        let (far, &d) = dist
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .expect("nonempty distance array");
        if d == 0.0 {
            break; // every remaining point duplicates a chosen one
        }
        chosen.push(far);
        for i in 0..n {
            let nd = metric.dist_ij(pts, i, far);
            if nd < dist[i] {
                dist[i] = nd;
            }
        }
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::{Euclidean, Metric};
    use crate::points::DenseMatrix;

    #[test]
    fn random_centers_distinct() {
        let mut rng = Rng::new(60);
        let c = random_centers(&mut rng, 100, 10);
        assert_eq!(c.len(), 10);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn random_centers_clamped_to_n() {
        let mut rng = Rng::new(61);
        let c = random_centers(&mut rng, 5, 10);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn greedy_permutation_maximizes_separation() {
        // 1-D points: greedy from 0.0 must pick the extremes first.
        let pts = DenseMatrix::from_flat(1, vec![0.0, 1.0, 2.0, 3.0, 10.0]);
        let g = greedy_permutation(&pts, &Euclidean, 3, 0);
        assert_eq!(g[0], 0);
        assert_eq!(g[1], 4); // farthest from 0.0 is 10.0
        // next farthest from {0, 10} is 3.0 (dist 3) vs 2.0 (dist 2) vs 1.0
        assert_eq!(g[2], 3);
    }

    #[test]
    fn greedy_prefix_is_net() {
        // Separation property: pairwise distances among the prefix are ≥
        // the coverage radius of the prefix.
        let pts = crate::data::synthetic::uniform(&mut Rng::new(62), 200, 3, 1.0);
        let g = greedy_permutation(&pts, &Euclidean, 12, 0);
        // coverage radius
        let mut cover = 0.0f64;
        for i in 0..200 {
            let d = g
                .iter()
                .map(|&c| Euclidean.dist_ij(&pts, i, c))
                .fold(f64::INFINITY, f64::min);
            cover = cover.max(d);
        }
        for i in 0..g.len() {
            for j in i + 1..g.len() {
                let d = Euclidean.dist_ij(&pts, g[i], g[j]);
                assert!(
                    d >= cover - 1e-9,
                    "separation {d} < coverage {cover} for pair ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn greedy_stops_on_duplicates() {
        let mut pts = DenseMatrix::new(1);
        pts.push(&[1.0]);
        pts.push(&[1.0]);
        pts.push(&[2.0]);
        let g = greedy_permutation(&pts, &Euclidean, 3, 0);
        assert_eq!(g.len(), 2, "only two distinct points exist");
    }

    #[test]
    fn empty_inputs() {
        let pts = DenseMatrix::new(1);
        assert!(greedy_permutation(&pts, &Euclidean, 5, 0).is_empty() || pts.len() > 0);
        let mut rng = Rng::new(63);
        assert!(random_centers(&mut rng, 10, 0).is_empty());
    }
}
