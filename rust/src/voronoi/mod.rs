//! Landmark (Voronoi) machinery for the landmarking algorithms:
//! center selection (random / greedy permutation), Voronoi cell assignment,
//! and the multiway-number-partitioning cell→rank assignment.

mod assign;
mod centers;

pub use assign::{cyclic_assignment, multiway_partition, partition_makespan};
pub use centers::{greedy_permutation, random_centers};

use crate::metric::Metric;
use crate::points::PointSet;

/// Voronoi assignment of a batch of points against a center set:
/// for each point, the index of the nearest center and the distance to it
/// (`d(p, C)`). Ties break toward the lower center index, which implements
/// the paper's "assign one of the points to avoid double counting".
pub fn assign_to_centers<P: PointSet, M: Metric<P>>(
    pts: &P,
    centers: &P,
    metric: &M,
) -> Vec<(u32, f64)> {
    let m = centers.len();
    assert!(m > 0, "need at least one center");
    let mut out = Vec::with_capacity(pts.len());
    for i in 0..pts.len() {
        let mut best = 0u32;
        let mut best_d = metric.dist_between(pts, i, centers, 0);
        for c in 1..m {
            let d = metric.dist_between(pts, i, centers, c);
            if d < best_d {
                best_d = d;
                best = c as u32;
            }
        }
        out.push((best, best_d));
    }
    out
}

/// Per-cell radius `r_i = max_{p ∈ V_i} d(p, c_i)` from an assignment.
pub fn cell_radii(assignment: &[(u32, f64)], m: usize) -> Vec<f64> {
    let mut radii = vec![0.0f64; m];
    for &(c, d) in assignment {
        if d > radii[c as usize] {
            radii[c as usize] = d;
        }
    }
    radii
}

/// Per-cell population counts from an assignment.
pub fn cell_sizes(assignment: &[(u32, f64)], m: usize) -> Vec<u64> {
    let mut sizes = vec![0u64; m];
    for &(c, _) in assignment {
        sizes[c as usize] += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::Euclidean;
    use crate::points::DenseMatrix;

    fn grid() -> DenseMatrix {
        // Four obvious clusters at the unit-square corners.
        let mut m = DenseMatrix::new(2);
        for (cx, cy) in [(0.0, 0.0), (10.0, 0.0), (0.0, 10.0), (10.0, 10.0)] {
            for k in 0..5 {
                m.push(&[cx + 0.1 * k as f32, cy]);
            }
        }
        m
    }

    #[test]
    fn assignment_picks_nearest() {
        let pts = grid();
        let centers = DenseMatrix::from_flat(
            2,
            vec![0.0, 0.0, 10.0, 0.0, 0.0, 10.0, 10.0, 10.0],
        );
        let asg = assign_to_centers(&pts, &centers, &Euclidean);
        for (i, &(c, d)) in asg.iter().enumerate() {
            assert_eq!(c as usize, i / 5, "point {i}");
            assert!(d <= 0.5);
        }
    }

    #[test]
    fn radii_and_sizes() {
        let pts = grid();
        let centers = DenseMatrix::from_flat(2, vec![0.0, 0.0, 10.0, 10.0]);
        let asg = assign_to_centers(&pts, &centers, &Euclidean);
        let radii = cell_radii(&asg, 2);
        let sizes = cell_sizes(&asg, 2);
        assert_eq!(sizes.iter().sum::<u64>(), 20);
        assert!(radii[0] > 0.0 && radii[1] > 0.0);
        // Farthest member of cell 0 is the (10,0)/(0,10) clusters' nearest...
        // both clusters at distance 10-ish get split between the two centers.
        assert!(radii[0] <= 10.5 && radii[1] <= 10.5);
    }

    #[test]
    fn tie_breaks_to_lower_index() {
        let pts = DenseMatrix::from_flat(1, vec![5.0]);
        let centers = DenseMatrix::from_flat(1, vec![0.0, 10.0]);
        let asg = assign_to_centers(&pts, &centers, &Euclidean);
        assert_eq!(asg[0].0, 0);
    }
}
