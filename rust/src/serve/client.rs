//! A blocking protocol client — the building block of the simulated-client
//! test harness ([`crate::testkit::serve_sim`]), the CLI `query`
//! subcommand, and the perf driver's load generator.
//!
//! The client pipelines freely: send any number of requests, then match
//! replies to requests by the echoed id (the daemon may answer pipelined
//! requests in any order). An optional read deadline ([`Client::set_timeout`])
//! turns a silent daemon into a typed `TimedOut` error instead of a hang.

use super::protocol::{self, FrameRead, Request, Response};
use crate::points::PointSet;
use crate::util::Rng;
use std::io::{self, ErrorKind};
use std::net::TcpStream;
use std::time::Duration;

/// One blocking connection to a serve daemon.
pub struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
    /// Whether a read deadline is armed — [`Client::recv`] maps idle and
    /// mid-frame stalls to `TimedOut` only when it is.
    timed: bool,
}

impl Client {
    /// Connect once.
    pub fn connect(addr: &str) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, buf: Vec::new(), timed: false })
    }

    /// Connect with retries (for scripts that race daemon startup):
    /// `attempts` tries before giving up, backing off exponentially from
    /// `delay` (doubling, capped at 16×) with seeded jitter so a herd of
    /// clients racing the same startup de-synchronises — deterministically,
    /// like everything else in this crate.
    pub fn connect_retry(addr: &str, attempts: usize, delay: Duration) -> io::Result<Client> {
        let mut rng = Rng::new(0xB0FF);
        let mut last = None;
        for i in 0..attempts.max(1) {
            if i > 0 {
                let step = delay.saturating_mul(1u32 << (i - 1).min(4));
                std::thread::sleep(step.mul_f64(0.75 + 0.5 * rng.f64()));
            }
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| io::Error::other("no connect attempts")))
    }

    /// Arm (or with `None` disarm) a per-read deadline: a [`Client::recv`]
    /// that waits longer than `timeout` for a reply returns
    /// `ErrorKind::TimedOut` instead of blocking forever.
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(timeout)?;
        self.timed = timeout.is_some();
        Ok(())
    }

    /// Send an ε-query for the single point held by `point`.
    pub fn send_eps<P: PointSet>(&mut self, id: u64, point: &P, eps: f64) -> io::Result<()> {
        self.send_request(&Request::Eps { id, eps, point: point.clone() })
    }

    /// Send a k-NN query for the single point held by `point`.
    pub fn send_knn<P: PointSet>(&mut self, id: u64, point: &P, k: usize) -> io::Result<()> {
        self.send_request(&Request::Knn { id, k, point: point.clone() })
    }

    /// Send a mutation: insert every point of `inserts` (zero or more)
    /// and tombstone-delete each id in `deletes`. A `--mutable` daemon
    /// answers `Mutated` with the assigned id range; a read-only daemon
    /// answers the typed `read-only` error.
    pub fn send_mutate<P: PointSet>(
        &mut self,
        id: u64,
        inserts: &P,
        deletes: &[u32],
    ) -> io::Result<()> {
        self.send_request(&Request::Mutate {
            id,
            inserts: inserts.clone(),
            deletes: deletes.to_vec(),
        })
    }

    /// Ask for the daemon's health counters (answered out-of-band on the
    /// reader thread — works even when the query queue is full).
    pub fn send_health(&mut self, id: u64) -> io::Result<()> {
        self.send_request::<crate::points::DenseMatrix>(&Request::Health { id })
    }

    /// Ask the daemon to drain and exit (answered with `Bye`).
    pub fn send_shutdown(&mut self, id: u64) -> io::Result<()> {
        self.send_request::<crate::points::DenseMatrix>(&Request::Shutdown { id })
    }

    fn send_request<P: PointSet>(&mut self, req: &Request<P>) -> io::Result<()> {
        protocol::write_frame(&mut self.stream, &req.to_bytes())
    }

    /// Block for the next response frame (bounded by the deadline when one
    /// is armed via [`Client::set_timeout`]).
    pub fn recv(&mut self) -> io::Result<Response> {
        // With a deadline armed, a mid-frame stall must abort after one
        // timeout period rather than retrying forever.
        let timed = self.timed;
        match protocol::read_frame(&mut self.stream, &mut self.buf, &|| timed)? {
            FrameRead::Frame => Response::try_from_bytes(&self.buf)
                .map_err(|e| io::Error::new(ErrorKind::InvalidData, format!("{e}"))),
            FrameRead::Eof => {
                Err(io::Error::new(ErrorKind::UnexpectedEof, "daemon closed the connection"))
            }
            // Only reachable with a read timeout armed: nothing arrived
            // within the deadline.
            FrameRead::Idle => Err(io::Error::new(
                ErrorKind::TimedOut,
                "read deadline elapsed waiting for a reply",
            )),
        }
    }
}
