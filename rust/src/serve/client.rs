//! A blocking protocol client — the building block of the simulated-client
//! test harness ([`crate::testkit::serve_sim`]), the CLI `query`
//! subcommand, and the perf driver's load generator.
//!
//! The client pipelines freely: send any number of requests, then match
//! replies to requests by the echoed id (the daemon may answer pipelined
//! requests in any order).

use super::protocol::{self, FrameRead, Request, Response};
use crate::points::PointSet;
use std::io::{self, ErrorKind};
use std::net::TcpStream;
use std::time::Duration;

/// One blocking connection to a serve daemon.
pub struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    /// Connect once.
    pub fn connect(addr: &str) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, buf: Vec::new() })
    }

    /// Connect with retries (for scripts that race daemon startup):
    /// `attempts` tries spaced `delay` apart before giving up.
    pub fn connect_retry(addr: &str, attempts: usize, delay: Duration) -> io::Result<Client> {
        let mut last = None;
        for i in 0..attempts.max(1) {
            if i > 0 {
                std::thread::sleep(delay);
            }
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| io::Error::other("no connect attempts")))
    }

    /// Send an ε-query for the single point held by `point`.
    pub fn send_eps<P: PointSet>(&mut self, id: u64, point: &P, eps: f64) -> io::Result<()> {
        self.send_request(&Request::Eps { id, eps, point: point.clone() })
    }

    /// Send a k-NN query for the single point held by `point`.
    pub fn send_knn<P: PointSet>(&mut self, id: u64, point: &P, k: usize) -> io::Result<()> {
        self.send_request(&Request::Knn { id, k, point: point.clone() })
    }

    /// Ask the daemon to drain and exit (answered with `Bye`).
    pub fn send_shutdown(&mut self, id: u64) -> io::Result<()> {
        self.send_request::<crate::points::DenseMatrix>(&Request::Shutdown { id })
    }

    fn send_request<P: PointSet>(&mut self, req: &Request<P>) -> io::Result<()> {
        protocol::write_frame(&mut self.stream, &req.to_bytes())
    }

    /// Block for the next response frame.
    pub fn recv(&mut self) -> io::Result<Response> {
        match protocol::read_frame(&mut self.stream, &mut self.buf, &|| false)? {
            FrameRead::Frame => Response::try_from_bytes(&self.buf)
                .map_err(|e| io::Error::new(ErrorKind::InvalidData, format!("{e}"))),
            FrameRead::Eof => {
                Err(io::Error::new(ErrorKind::UnexpectedEof, "daemon closed the connection"))
            }
            FrameRead::Idle => unreachable!("no read timeout set on client sockets"),
        }
    }
}
