//! The TCP daemon: listener, per-connection readers, and the one batch
//! dispatcher (DESIGN.md §10.4).
//!
//! Thread shape — all `std`, nothing detached:
//!
//! * **control** — owns the listener; accepts connections and spawns one
//!   reader per connection; after shutdown it joins every reader, closes
//!   the coalescer, and joins the dispatcher.
//! * **readers** (one per connection) — decode request frames, admit
//!   queries into the [`Coalescer`], and answer protocol errors/overload
//!   with typed replies on the spot. Reads poll with a short timeout so a
//!   quiet connection notices shutdown promptly.
//! * **dispatcher** (exactly one) — loops [`Coalescer::next_batch`] →
//!   [`ServeEngine::execute`] → reply per ticket, using double-buffered
//!   batch/output/reply buffers so the warmed loop allocates nothing.
//!
//! Shutdown (client `Shutdown` frame or [`Server::shutdown`]): `Bye` is
//! sent immediately as the acknowledgement, the flag flips, the accept
//! loop is woken by a self-connection, readers finish their current frame
//! and exit, the coalescer closes, and the dispatcher drains every
//! admitted query before exiting — an admitted query always gets its
//! reply, though those replies may arrive **after** `Bye` (clients match
//! on the echoed id, not on arrival order). A frame that arrives after
//! the flag flips is answered with the typed `shutting-down` error and
//! the connection closes — a pipelining client cannot pin a reader (and
//! the join) past shutdown. Replies are written under a per-connection
//! mutex, so a reply is never torn mid-frame.

use super::coalesce::{Admit, CoalesceParams, Coalescer, PendingBatch, ReplySink, Ticket};
use super::engine::{BatchOutput, QueryOp, ServeEngine};
use super::protocol::{self, ErrorCode, FrameRead, Request};
use super::{ServeConfig, ServeError};
use crate::index::NearIndex;
use crate::metric::Metric;
use crate::points::PointSet;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How often an idle reader wakes to poll the shutdown flag.
const READ_POLL: Duration = Duration::from_millis(25);

/// Pause before retrying a failed `accept` (fd exhaustion and friends
/// must not busy-spin a core).
const ACCEPT_RETRY: Duration = Duration::from_millis(25);

#[derive(Debug, Default)]
struct Stats {
    queries: AtomicU64,
    batches: AtomicU64,
    overloads: AtomicU64,
    bad_frames: AtomicU64,
    connections: AtomicU64,
    max_batch: AtomicU64,
    deadline_misses: AtomicU64,
    mutations: AtomicU64,
}

/// Counters observed over a daemon's lifetime (or so far, via
/// [`Server::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// Queries answered through the batch path.
    pub queries: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Typed overload replies sent.
    pub overloads: u64,
    /// Frames that failed to decode (answered with `bad-frame`).
    pub bad_frames: u64,
    /// Connections accepted.
    pub connections: u64,
    /// Largest batch dispatched.
    pub max_batch: u64,
    /// Queries answered with the typed `deadline-exceeded` error.
    pub deadline_misses: u64,
    /// Mutate frames applied (a `--mutable` daemon only; read-only
    /// daemons answer `read-only` and never bump this).
    pub mutations: u64,
}

impl StatsSnapshot {
    /// Mean queries per dispatched batch (0 when nothing ran) — the
    /// direct measure of how much the window actually coalesced.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.queries as f64 / self.batches as f64
        }
    }
}

impl Stats {
    fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            queries: self.queries.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            overloads: self.overloads.load(Ordering::Relaxed),
            bad_frames: self.bad_frames.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            max_batch: self.max_batch.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            mutations: self.mutations.load(Ordering::Relaxed),
        }
    }
}

/// A client connection's reply side: framed writes under a mutex so the
/// dispatcher and the connection's reader never interleave bytes.
struct Outbox {
    stream: Mutex<TcpStream>,
}

impl ReplySink for Outbox {
    fn send(&self, payload: &[u8]) {
        // Recover the stream from a poisoned lock rather than panicking:
        // a writer that panicked mid-frame already torched the connection,
        // and the reader-side EOF handling cleans it up.
        let mut s = self.stream.lock().unwrap_or_else(|p| p.into_inner());
        // A vanished client makes the write fail; the reader sees EOF and
        // cleans the connection up — nothing to do here.
        let _ = protocol::write_frame(&mut *s, payload);
    }
}

/// A running daemon. Dropping the handle shuts it down and joins every
/// thread (no detached threads survive the handle).
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<Stats>,
    control: Option<JoinHandle<()>>,
}

impl Server {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counters so far.
    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Request shutdown without waiting (idempotent).
    pub fn shutdown(&self) {
        request_shutdown(&self.shutdown, self.addr);
    }

    /// Wait until the daemon has fully exited (all threads joined) and
    /// return the final counters. Does **not** request shutdown itself —
    /// use this after a client sent the shutdown frame.
    pub fn join(mut self) -> StatsSnapshot {
        if let Some(h) = self.control.take() {
            let _ = h.join();
        }
        self.stats.snapshot()
    }

    /// [`Server::shutdown`] then [`Server::join`].
    pub fn shutdown_and_join(self) -> StatsSnapshot {
        self.shutdown();
        self.join()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if let Some(h) = self.control.take() {
            request_shutdown(&self.shutdown, self.addr);
            let _ = h.join();
        }
    }
}

fn request_shutdown(flag: &AtomicBool, addr: SocketAddr) {
    flag.store(true, Ordering::SeqCst);
    // Wake the blocking accept with a throwaway self-connection.
    let _ = TcpStream::connect(addr);
}

/// Start serving `index` per `cfg`. Binds immediately (so `:0` callers can
/// read the ephemeral port from [`Server::local_addr`]) and returns; the
/// daemon runs on background threads until a shutdown frame arrives or
/// [`Server::shutdown`] is called.
pub fn serve<P: PointSet, M: Metric<P>>(
    index: Box<dyn NearIndex<P, M>>,
    cfg: &ServeConfig,
) -> Result<Server, ServeError> {
    let addr: SocketAddr = cfg
        .addr
        .parse()
        .map_err(|_| ServeError::BadAddr { addr: cfg.addr.clone() })?;
    let listener = TcpListener::bind(addr)
        .map_err(|e| ServeError::Bind { addr: cfg.addr.clone(), error: e.to_string() })?;
    let addr = listener.local_addr().map_err(|e| ServeError::Bind {
        addr: cfg.addr.clone(),
        error: e.to_string(),
    })?;

    let engine = Arc::new(ServeEngine::new(index, cfg.threads));
    let coalescer = Arc::new(Coalescer::new(
        engine.index().points(),
        CoalesceParams {
            window: Duration::from_micros(cfg.coalesce_us),
            max_batch: cfg.max_batch,
            queue_cap: cfg.queue_cap,
        },
    ));
    let shutdown = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(Stats::default());

    let dispatcher = {
        let engine = engine.clone();
        let coalescer = coalescer.clone();
        let stats = stats.clone();
        let deadline = Duration::from_micros(cfg.deadline_us);
        std::thread::spawn(move || dispatch_loop(&engine, &coalescer, &stats, deadline))
    };

    // Mutations are double-gated: the operator must opt in (`--mutable`)
    // AND the resident index must actually expose `MutableOps` — either
    // missing makes every Mutate frame a typed `read-only` reply.
    let accept_mutations = cfg.mutable;

    let control = {
        let shutdown = shutdown.clone();
        let stats = stats.clone();
        std::thread::spawn(move || {
            let mut readers: Vec<JoinHandle<()>> = Vec::new();
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        // Reap finished readers so a long-lived daemon
                        // serving many short connections does not grow
                        // the handle vector (and retained thread
                        // resources) without bound.
                        let mut i = 0;
                        while i < readers.len() {
                            if readers[i].is_finished() {
                                let _ = readers.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        stats.connections.fetch_add(1, Ordering::Relaxed);
                        let engine = engine.clone();
                        let coalescer = coalescer.clone();
                        let shutdown = shutdown.clone();
                        let stats = stats.clone();
                        readers.push(std::thread::spawn(move || {
                            reader_loop(
                                stream,
                                addr,
                                &engine,
                                &coalescer,
                                &shutdown,
                                &stats,
                                accept_mutations,
                            )
                        }));
                    }
                    Err(_) => {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        std::thread::sleep(ACCEPT_RETRY);
                    }
                }
            }
            for h in readers {
                let _ = h.join();
            }
            // No reader can admit anymore; drain what remains.
            coalescer.close();
            let _ = dispatcher.join();
        })
    };

    Ok(Server { addr, shutdown, stats, control: Some(control) })
}

fn dispatch_loop<P: PointSet, M: Metric<P>>(
    engine: &ServeEngine<P, M>,
    coalescer: &Coalescer<P>,
    stats: &Stats,
    deadline: Duration,
) {
    let mut work = PendingBatch::new_like(engine.index().points());
    let mut out = BatchOutput::new();
    let mut reply = Vec::new();
    while coalescer.next_batch(&mut work) {
        engine.execute(&work.batch, &mut out);
        for (q, ticket) in work.tickets.iter().enumerate() {
            // The deadline is measured from admission, so queue wait counts:
            // under overload a stale answer degrades to the typed error
            // rather than arriving arbitrarily late.
            if !deadline.is_zero() && ticket.admit.elapsed() > deadline {
                stats.deadline_misses.fetch_add(1, Ordering::Relaxed);
                protocol::encode_error_into(&mut reply, ticket.id, ErrorCode::DeadlineExceeded);
            } else {
                protocol::encode_hits_into(&mut reply, ticket.id, out.hits_of(q));
            }
            ticket.sink.send(&reply);
        }
        let n = work.len() as u64;
        stats.queries.fetch_add(n, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.max_batch.fetch_max(n, Ordering::Relaxed);
        work.clear();
    }
}

#[allow(clippy::too_many_arguments)]
fn reader_loop<P: PointSet, M: Metric<P>>(
    stream: TcpStream,
    addr: SocketAddr,
    engine: &ServeEngine<P, M>,
    coalescer: &Coalescer<P>,
    shutdown: &Arc<AtomicBool>,
    stats: &Stats,
    accept_mutations: bool,
) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_nodelay(true);
    let outbox: Arc<dyn ReplySink> = match stream.try_clone() {
        Ok(write_half) => Arc::new(Outbox { stream: Mutex::new(write_half) }),
        Err(_) => return,
    };
    let mut stream = stream;
    let mut frame = Vec::new();
    let mut reply = Vec::new();
    // A started frame is read to completion under normal operation, but a
    // client that stalls mid-frame must not pin the reader past shutdown.
    let abort = || shutdown.load(Ordering::SeqCst);
    loop {
        match protocol::read_frame(&mut stream, &mut frame, &abort) {
            Ok(FrameRead::Eof) | Err(_) => break,
            Ok(FrameRead::Idle) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Ok(FrameRead::Frame) => {
                if shutdown.load(Ordering::SeqCst) {
                    // A pipelining client can keep frames coming forever,
                    // and `Idle` — the only other flag poll — never fires
                    // then. Answer the late frame with the typed error and
                    // stop reading, so the control thread's join cannot
                    // hang on this reader.
                    protocol::encode_error_into(
                        &mut reply,
                        protocol::peek_request_id(&frame),
                        ErrorCode::ShuttingDown,
                    );
                    outbox.send(&reply);
                    break;
                }
                handle_frame(
                    &frame,
                    &outbox,
                    addr,
                    engine,
                    coalescer,
                    shutdown,
                    stats,
                    accept_mutations,
                    &mut reply,
                )
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_frame<P: PointSet, M: Metric<P>>(
    frame: &[u8],
    outbox: &Arc<dyn ReplySink>,
    addr: SocketAddr,
    engine: &ServeEngine<P, M>,
    coalescer: &Coalescer<P>,
    shutdown: &Arc<AtomicBool>,
    stats: &Stats,
    accept_mutations: bool,
    reply: &mut Vec<u8>,
) {
    let (id, point, op) = match Request::<P>::try_from_bytes(frame) {
        Err(_) => {
            stats.bad_frames.fetch_add(1, Ordering::Relaxed);
            protocol::encode_error_into(reply, protocol::peek_request_id(frame), ErrorCode::BadFrame);
            outbox.send(reply);
            return;
        }
        Ok(Request::Shutdown { id }) => {
            protocol::encode_bye_into(reply, id);
            outbox.send(reply);
            request_shutdown(shutdown, addr);
            return;
        }
        Ok(Request::Health { id }) => {
            // Answered on the reader thread, bypassing the batch queue: a
            // health probe must work precisely when the queue is full.
            let health = protocol::Health {
                queue_depth: coalescer.pending_len() as u64,
                lanes: engine.threads() as u64,
                queries: stats.queries.load(Ordering::Relaxed),
                batches: stats.batches.load(Ordering::Relaxed),
                overloads: stats.overloads.load(Ordering::Relaxed),
                bad_frames: stats.bad_frames.load(Ordering::Relaxed),
                deadline_misses: stats.deadline_misses.load(Ordering::Relaxed),
            };
            protocol::encode_health_into(reply, id, &health);
            outbox.send(reply);
            return;
        }
        Ok(Request::Mutate { id, inserts, deletes }) => {
            // Applied on the reader thread: the epoch tree serialises
            // writers internally and readers traverse the previous
            // snapshot, so in-flight query batches keep answering while
            // this applies (DESIGN.md §13). Never touches the batch queue.
            let mutable = if accept_mutations { engine.index().mutable() } else { None };
            let Some(m) = mutable else {
                protocol::encode_error_into(reply, id, ErrorCode::ReadOnly);
                outbox.send(reply);
                return;
            };
            if !inserts.is_empty() && !engine.shape_ok(&inserts) {
                protocol::encode_error_into(reply, id, ErrorCode::BadQuery);
                outbox.send(reply);
                return;
            }
            let range = if inserts.is_empty() { 0..0 } else { m.insert(&inserts) };
            let mut deleted = 0u64;
            for gid in &deletes {
                if m.delete(*gid) {
                    deleted += 1;
                }
            }
            let outcome = protocol::MutateOutcome {
                first_gid: range.start as u64,
                inserted: (range.end - range.start) as u64,
                deleted,
                epoch: m.epoch(),
                live: m.live() as u64,
            };
            stats.mutations.fetch_add(1, Ordering::Relaxed);
            protocol::encode_mutated_into(reply, id, &outcome);
            outbox.send(reply);
            return;
        }
        Ok(Request::Eps { id, eps, point }) => (id, point, QueryOp::Eps(eps)),
        Ok(Request::Knn { id, k, point }) => (id, point, QueryOp::Knn(k)),
    };
    if !engine.shape_ok(&point) {
        protocol::encode_error_into(reply, id, ErrorCode::BadQuery);
        outbox.send(reply);
        return;
    }
    match coalescer.submit(&point, op, Ticket { sink: outbox.clone(), id, admit: Instant::now() }) {
        Admit::Accepted => {}
        Admit::Overloaded => {
            stats.overloads.fetch_add(1, Ordering::Relaxed);
            protocol::encode_error_into(reply, id, ErrorCode::Overloaded);
            outbox.send(reply);
        }
        Admit::Closed => {
            // Unreachable under the current teardown order (the coalescer
            // closes only after every reader joined; late frames are
            // answered in `reader_loop` before reaching here) — kept so a
            // future teardown reordering still yields the typed reply.
            protocol::encode_error_into(reply, id, ErrorCode::ShuttingDown);
            outbox.send(reply);
        }
    }
}
