//! Request coalescing: the bounded admission queue between connection
//! readers and the batch dispatcher (DESIGN.md §10.2).
//!
//! Readers [`Coalescer::submit`] single queries; the dispatcher blocks in
//! [`Coalescer::next_batch`] until a batch is *ripe* and then takes **at
//! most `max_batch`** pending queries, oldest first, by swapping the
//! pending buffer against its own spare and handing any excess straight
//! back (a double-buffer plus a tail split: all sides keep their warmed
//! capacity, so the steady-state cycle allocates nothing). Queries beyond
//! `max_batch` — the queue can legally hold up to `queue_cap` of them —
//! stay pending with their original admission time, so their window
//! accounting (and their deadline clocks) never reset. A pending batch
//! ripens when
//!
//! * it reaches `max_batch` queries, **or**
//! * `window` has elapsed since its *first* admission (a lone query waits
//!   at most one window; the timer is not reset by later arrivals), **or**
//! * the coalescer is closed (shutdown drains immediately, still in
//!   `max_batch`-sized chunks).
//!
//! Backpressure is explicit and bounded: once `queue_cap` queries are
//! pending, `submit` returns [`Admit::Overloaded`] and the reader sends
//! the typed overload reply — the daemon never buffers unboundedly and
//! never silently drops an admitted query. After [`Coalescer::close`],
//! `next_batch` keeps returning batches until the queue is empty (no
//! admitted query loses its reply to shutdown) and only then reports
//! exhaustion.

use super::engine::{QueryBatch, QueryOp};
use crate::points::PointSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Where a query's reply goes: one sink per client connection, shared by
/// every ticket of that connection. `send` must be safe to call from the
/// dispatcher thread concurrently with reader-side error replies.
pub trait ReplySink: Send + Sync {
    /// Deliver one encoded response payload (the sink adds the frame
    /// length prefix). Delivery to a vanished client may be dropped
    /// silently; it must never block shutdown indefinitely or panic.
    fn send(&self, payload: &[u8]);
}

/// The reply address of one admitted query.
pub struct Ticket {
    /// The connection's reply sink.
    pub sink: Arc<dyn ReplySink>,
    /// Client-chosen request id, echoed in the response.
    pub id: u64,
    /// When the query was admitted — the dispatcher measures the
    /// per-request deadline from this instant (queue wait included).
    pub admit: Instant,
}

/// A pending batch plus the reply address of each query (parallel to the
/// batch positions).
pub struct PendingBatch<P: PointSet> {
    pub batch: QueryBatch<P>,
    pub tickets: Vec<Ticket>,
}

impl<P: PointSet> PendingBatch<P> {
    /// An empty pending batch shaped like `proto`.
    pub fn new_like(proto: &P) -> Self {
        PendingBatch { batch: QueryBatch::new_like(proto), tickets: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    /// Drop all queries, keep capacity (the double-buffer reuse cycle).
    pub fn clear(&mut self) {
        self.batch.clear();
        self.tickets.clear();
    }
}

/// Admission verdict of [`Coalescer::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Queued; the reply will arrive via the ticket's sink.
    Accepted,
    /// The admission queue is at `queue_cap` — the caller must send the
    /// typed overload reply itself.
    Overloaded,
    /// The coalescer is closed (shutting down); no new queries.
    Closed,
}

/// Tuning knobs (validated `serve.*` config keys).
#[derive(Clone, Copy, Debug)]
pub struct CoalesceParams {
    /// Longest a pending batch may wait for company.
    pub window: Duration,
    /// Batch-size cap that ripens a batch early.
    pub max_batch: usize,
    /// Bound on pending queries (≥ `max_batch`); beyond it, `submit`
    /// reports overload.
    pub queue_cap: usize,
}

struct CoState<P: PointSet> {
    pending: PendingBatch<P>,
    /// When the oldest pending query was admitted (`None` ⇔ empty).
    since: Option<Instant>,
    open: bool,
}

/// The admission queue (see module docs).
pub struct Coalescer<P: PointSet> {
    state: Mutex<CoState<P>>,
    cv: Condvar,
    params: CoalesceParams,
}

impl<P: PointSet> Coalescer<P> {
    /// A new, open coalescer admitting points shaped like `proto`.
    pub fn new(proto: &P, params: CoalesceParams) -> Self {
        assert!(params.max_batch >= 1, "max_batch must be at least 1");
        assert!(params.queue_cap >= params.max_batch, "queue_cap must cover one full batch");
        Coalescer {
            state: Mutex::new(CoState {
                pending: PendingBatch::new_like(proto),
                since: None,
                open: true,
            }),
            cv: Condvar::new(),
            params,
        }
    }

    /// The tuning knobs this coalescer runs with.
    pub fn params(&self) -> &CoalesceParams {
        &self.params
    }

    /// Admit one query. `point` must hold exactly one point whose shape
    /// the caller has already validated against the served index.
    pub fn submit(&self, point: &P, op: QueryOp, ticket: Ticket) -> Admit {
        let mut g = self.state.lock().unwrap();
        if !g.open {
            return Admit::Closed;
        }
        if g.pending.len() >= self.params.queue_cap {
            return Admit::Overloaded;
        }
        if g.pending.is_empty() {
            g.since = Some(Instant::now());
        }
        g.pending.batch.push(point, op);
        g.pending.tickets.push(ticket);
        // Wake the dispatcher when a batch starts (arming the window
        // timer) or ripens by size; intermediate growth needs no wake.
        let wake = g.pending.len() == 1 || g.pending.len() >= self.params.max_batch;
        drop(g);
        if wake {
            self.cv.notify_all();
        }
        Admit::Accepted
    }

    /// Block until a batch is ripe, then move up to `max_batch` of the
    /// oldest pending queries into `into` (which must be empty; its
    /// buffers become the new pending buffers). Returns `false` only when
    /// the coalescer is closed **and** drained — every admitted query is
    /// handed out exactly once before that, and no drained batch ever
    /// exceeds `max_batch` (PR 9: the old code swapped out the *entire*
    /// queue, up to `queue_cap` queries, blowing past the engine's sizing
    /// and the per-request deadline accounting whenever the dispatcher
    /// fell behind admissions).
    pub fn next_batch(&self, into: &mut PendingBatch<P>) -> bool {
        debug_assert!(into.is_empty(), "next_batch needs a cleared spare buffer");
        let mut g = self.state.lock().unwrap();
        loop {
            if !g.pending.is_empty() {
                if !g.open || g.pending.len() >= self.params.max_batch {
                    break;
                }
                let since = g.since.expect("non-empty pending batch has a start time");
                let elapsed = since.elapsed();
                if elapsed >= self.params.window {
                    break;
                }
                let (back, _timeout) = self.cv.wait_timeout(g, self.params.window - elapsed).unwrap();
                g = back;
            } else {
                if !g.open {
                    return false;
                }
                g = self.cv.wait(g).unwrap();
            }
        }
        std::mem::swap(&mut g.pending, into);
        g.since = None;
        let mb = self.params.max_batch;
        if into.len() > mb {
            // Hand the tail straight back (oldest stay in `into`): the
            // remainder keeps its original order and its first query's
            // admission time, so the window timer and deadline clocks
            // behave as if those queries had simply not ripened yet.
            into.batch.give_tail(&mut g.pending.batch, mb);
            g.pending.tickets.extend(into.tickets.drain(mb..));
            g.since = g.pending.tickets.first().map(|t| t.admit);
        }
        true
    }

    /// Stop admissions and wake the dispatcher so it drains what remains.
    pub fn close(&self) {
        let mut g = self.state.lock().unwrap();
        g.open = false;
        drop(g);
        self.cv.notify_all();
    }

    /// Whether the coalescer still admits queries.
    pub fn is_open(&self) -> bool {
        self.state.lock().unwrap().open
    }

    /// Number of currently pending (admitted, undrained) queries.
    pub fn pending_len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::DenseMatrix;
    use std::sync::atomic::{AtomicUsize, Ordering};

    struct NullSink;
    impl ReplySink for NullSink {
        fn send(&self, _payload: &[u8]) {}
    }

    struct CountSink(AtomicUsize);
    impl ReplySink for CountSink {
        fn send(&self, _payload: &[u8]) {
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn one_point(v: f32) -> DenseMatrix {
        DenseMatrix::from_flat(2, vec![v, -v])
    }

    fn ticket(id: u64) -> Ticket {
        Ticket { sink: Arc::new(NullSink), id, admit: Instant::now() }
    }

    fn coalescer(window_us: u64, max_batch: usize, queue_cap: usize) -> Coalescer<DenseMatrix> {
        Coalescer::new(
            &DenseMatrix::new(2),
            CoalesceParams {
                window: Duration::from_micros(window_us),
                max_batch,
                queue_cap,
            },
        )
    }

    #[test]
    fn size_cap_ripens_immediately() {
        // Huge window: only the size trigger can ripen the batch.
        let co = coalescer(60_000_000, 3, 16);
        for i in 0..3u64 {
            assert_eq!(co.submit(&one_point(i as f32), QueryOp::Eps(0.5), ticket(i)), Admit::Accepted);
        }
        let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
        assert!(co.next_batch(&mut spare));
        assert_eq!(spare.len(), 3);
        assert_eq!(spare.tickets.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(co.pending_len(), 0);
    }

    #[test]
    fn window_ripens_a_lone_query() {
        let co = coalescer(2_000, 1024, 4096);
        co.submit(&one_point(1.0), QueryOp::Knn(2), ticket(9));
        let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
        let t0 = Instant::now();
        assert!(co.next_batch(&mut spare));
        assert_eq!(spare.len(), 1);
        // The lone query waited roughly one window, not forever (generous
        // upper bound for slow CI).
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn overload_is_reported_not_buffered() {
        let co = coalescer(60_000_000, 2, 2);
        assert_eq!(co.submit(&one_point(0.0), QueryOp::Eps(0.1), ticket(0)), Admit::Accepted);
        assert_eq!(co.submit(&one_point(1.0), QueryOp::Eps(0.1), ticket(1)), Admit::Accepted);
        assert_eq!(co.submit(&one_point(2.0), QueryOp::Eps(0.1), ticket(2)), Admit::Overloaded);
        assert_eq!(co.pending_len(), 2, "overloaded submit must not grow the queue");
    }

    #[test]
    fn close_drains_then_reports_exhaustion() {
        let co = coalescer(60_000_000, 100, 100);
        for i in 0..5u64 {
            co.submit(&one_point(i as f32), QueryOp::Eps(0.1), ticket(i));
        }
        co.close();
        assert_eq!(co.submit(&one_point(9.0), QueryOp::Eps(0.1), ticket(99)), Admit::Closed);
        let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
        assert!(co.next_batch(&mut spare), "pending queries survive close");
        assert_eq!(spare.len(), 5);
        spare.clear();
        assert!(!co.next_batch(&mut spare), "drained + closed reports exhaustion");
    }

    #[test]
    fn drained_batches_never_exceed_max_batch() {
        // Regression (PR 9): when admissions outran the dispatcher, the
        // old next_batch swapped out the ENTIRE pending queue — up to
        // queue_cap queries in one "batch". The cap must hold on every
        // drain, the excess must stay queued in admission order, and the
        // shutdown drain must chunk the same way.
        let co = coalescer(60_000_000, 3, 16);
        for i in 0..7u64 {
            let admit = co.submit(&one_point(i as f32), QueryOp::Eps(0.1), ticket(i));
            assert_eq!(admit, Admit::Accepted);
        }
        let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
        assert!(co.next_batch(&mut spare));
        assert_eq!(spare.len(), 3, "first drain capped at max_batch");
        assert_eq!(spare.batch.len(), 3, "points split with the tickets");
        assert_eq!(spare.tickets.iter().map(|t| t.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(co.pending_len(), 4, "excess stays queued");
        spare.clear();
        // The remainder is already over max_batch, so it ripens by size
        // despite the enormous window.
        assert!(co.next_batch(&mut spare));
        assert_eq!(spare.tickets.iter().map(|t| t.id).collect::<Vec<_>>(), vec![3, 4, 5]);
        // Points travel with their tickets through the split: query id 3
        // was admitted with coordinates (3, -3).
        assert_eq!(spare.batch.points().point(0), &[3.0f32, -3.0]);
        spare.clear();
        co.close();
        assert!(co.next_batch(&mut spare), "shutdown drains the remainder");
        assert_eq!(spare.tickets.iter().map(|t| t.id).collect::<Vec<_>>(), vec![6]);
        spare.clear();
        assert!(!co.next_batch(&mut spare));
    }

    #[test]
    fn split_remainder_keeps_its_window_clock() {
        // The tail handed back by a capped drain must ripen on its
        // ORIGINAL admission time, not restart the window.
        let co = coalescer(5_000, 2, 16);
        for i in 0..3u64 {
            co.submit(&one_point(i as f32), QueryOp::Eps(0.1), ticket(i));
        }
        let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
        assert!(co.next_batch(&mut spare));
        assert_eq!(spare.len(), 2);
        spare.clear();
        // Lone remainder: ripens within roughly one window of ITS
        // admission (generous bound for slow CI), no new submissions.
        let t0 = Instant::now();
        assert!(co.next_batch(&mut spare));
        assert_eq!(spare.tickets.iter().map(|t| t.id).collect::<Vec<_>>(), vec![2]);
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn double_buffer_swap_keeps_capacity_and_delivery_works() {
        let co = coalescer(60_000_000, 2, 8);
        let sink = Arc::new(CountSink(AtomicUsize::new(0)));
        let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
        for round in 0..3u64 {
            for i in 0..2u64 {
                co.submit(
                    &one_point(i as f32),
                    QueryOp::Eps(0.1),
                    Ticket { sink: sink.clone(), id: round * 2 + i, admit: Instant::now() },
                );
            }
            assert!(co.next_batch(&mut spare));
            for t in &spare.tickets {
                t.sink.send(b"payload");
            }
            spare.clear();
        }
        assert_eq!(sink.0.load(Ordering::Relaxed), 6, "every ticket delivered exactly once");
    }

    #[test]
    fn concurrent_producers_all_drain() {
        let co = std::sync::Arc::new(coalescer(500, 8, 1 << 16));
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for w in 0..4u64 {
                let co = co.clone();
                s.spawn(move || {
                    for i in 0..50u64 {
                        while co.submit(&one_point(i as f32), QueryOp::Knn(1), ticket(w * 100 + i))
                            != Admit::Accepted
                        {
                            std::thread::yield_now();
                        }
                    }
                });
            }
            let co = co.clone();
            let total = &total;
            s.spawn(move || {
                let mut spare = PendingBatch::new_like(&DenseMatrix::new(2));
                let mut got = 0usize;
                while got < 200 {
                    if co.next_batch(&mut spare) {
                        got += spare.len();
                        spare.clear();
                    }
                }
                total.store(got, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 200);
    }
}
