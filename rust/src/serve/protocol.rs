//! The serve daemon's length-prefixed binary protocol (DESIGN.md §10.1).
//!
//! Every message on the socket is one **frame**: a little-endian `u64`
//! payload length (capped at [`MAX_FRAME`] *before* any allocation)
//! followed by that many payload bytes. Payload decoders follow the same
//! [`WireError`]/length-checked discipline as the crate's other wire
//! formats (`Bundle`, `KnnBundle`, NGW-CSR1): count-prefixed,
//! `saturating_mul` length guards, trailing-bytes checks, typed errors —
//! never a panic on adversarial bytes (`tests/wire_adversarial.rs` runs
//! the full mutation battery over every frame kind).
//!
//! Request payloads (`opcode u8, request id u64, ...`):
//!
//! | opcode | frame | body |
//! |--------|-------|------|
//! | 1 | ε-query   | `eps` f64 bits, point-set length u64 + bytes (exactly one point) |
//! | 2 | k-NN query| `k` u64 (1 ..= u32::MAX), point-set length u64 + bytes (one point) |
//! | 3 | shutdown  | — |
//! | 4 | health    | — (answered on the spot, bypassing the batch queue) |
//! | 5 | mutate    | point-set length u64 + bytes (0 or more inserts), `m` u64 + m × `gid` u32 deletes |
//!
//! Response payloads:
//!
//! | opcode | frame | body |
//! |--------|-------|------|
//! | 1 | hits    | `n` u64 + n × (`gid` u32, `dist` f64 bits; finite, ≥ 0) |
//! | 2 | error   | [`ErrorCode`] u8 |
//! | 3 | bye     | — (acknowledges a shutdown request) |
//! | 4 | health  | the seven [`Health`] counters, each u64 |
//! | 5 | mutated | `first_gid`, `inserted`, `deleted`, `epoch`, `live` — each u64 |
//!
//! A mutate against a daemon launched without `--mutable` (or over a
//! backend without [`crate::index::MutableOps`]) is answered with the
//! typed [`ErrorCode::ReadOnly`], never a panic or a dropped connection.
//!
//! Responses echo the request id; the daemon may answer pipelined
//! requests in any order, so clients match on the id, not on arrival
//! order. The query point travels as a one-point [`PointSet::to_bytes`]
//! payload — the same encoding the simulated MPI layer ships, so the
//! point containers' hardened decoders are reused verbatim.

use crate::points::{
    le_u32, le_u64, put_u64, try_get_u64, try_get_u8, try_take, PointSet, WireError,
};
use std::io::{self, ErrorKind, Read, Write};

/// Hard cap on a frame payload (16 MiB) — enforced before the receive
/// buffer is grown, so a corrupt or hostile length prefix can never
/// over-allocate.
pub const MAX_FRAME: u64 = 1 << 24;

const REQ_EPS: u8 = 1;
const REQ_KNN: u8 = 2;
const REQ_SHUTDOWN: u8 = 3;
const REQ_HEALTH: u8 = 4;
const REQ_MUTATE: u8 = 5;

const RESP_HITS: u8 = 1;
const RESP_ERROR: u8 = 2;
const RESP_BYE: u8 = 3;
const RESP_HEALTH: u8 = 4;
const RESP_MUTATED: u8 = 5;

/// Typed overload/rejection reply codes (the explicit-backpressure half of
/// the protocol: a daemon under pressure answers, it never buffers
/// unboundedly or drops the connection mid-reply).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request frame failed to decode.
    BadFrame,
    /// The frame decoded but the query is unanswerable (e.g. a point whose
    /// shape does not match the served index).
    BadQuery,
    /// The bounded admission queue is full — retry later.
    Overloaded,
    /// The daemon is shutting down and no longer admits queries.
    ShuttingDown,
    /// The query waited in the daemon past its per-request deadline — the
    /// answer would have arrived too late to be useful, so it is replaced
    /// by this typed reply instead of silent tail latency.
    DeadlineExceeded,
    /// A mutate request reached a daemon serving an immutable index (no
    /// `--mutable`, or a backend without in-place mutation support).
    ReadOnly,
}

impl ErrorCode {
    fn code(self) -> u8 {
        match self {
            ErrorCode::BadFrame => 1,
            ErrorCode::BadQuery => 2,
            ErrorCode::Overloaded => 3,
            ErrorCode::ShuttingDown => 4,
            ErrorCode::DeadlineExceeded => 5,
            ErrorCode::ReadOnly => 6,
        }
    }

    fn from_code(c: u8) -> Option<ErrorCode> {
        match c {
            1 => Some(ErrorCode::BadFrame),
            2 => Some(ErrorCode::BadQuery),
            3 => Some(ErrorCode::Overloaded),
            4 => Some(ErrorCode::ShuttingDown),
            5 => Some(ErrorCode::DeadlineExceeded),
            6 => Some(ErrorCode::ReadOnly),
            _ => None,
        }
    }

    /// Human-readable name (logs and client diagnostics).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad-frame",
            ErrorCode::BadQuery => "bad-query",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting-down",
            ErrorCode::DeadlineExceeded => "deadline-exceeded",
            ErrorCode::ReadOnly => "read-only",
        }
    }
}

/// One decoded client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request<P: PointSet> {
    /// All indexed points within `eps` of the (single) carried point.
    Eps { id: u64, eps: f64, point: P },
    /// The `k` nearest indexed points to the carried point.
    Knn { id: u64, k: usize, point: P },
    /// Ask the daemon to drain in-flight queries and exit.
    Shutdown { id: u64 },
    /// Ask for the daemon's health counters. Answered on the spot by the
    /// connection reader — it never enters the batch queue, so it stays
    /// responsive while the daemon is saturated.
    Health { id: u64 },
    /// Mutate the served index: append every point of `inserts` (may be
    /// empty), then tombstone each id of `deletes`. Applied in that order,
    /// atomically with respect to concurrently answered queries (the
    /// epoch-snapshot scheme of `covertree::epoch`). Requires a daemon in
    /// `--mutable` mode; otherwise answered [`ErrorCode::ReadOnly`].
    Mutate { id: u64, inserts: P, deletes: Vec<u32> },
}

impl<P: PointSet> Request<P> {
    /// Encode as a frame payload (no length prefix — [`write_frame`] adds it).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Request::Eps { id, eps, point } => {
                buf.push(REQ_EPS);
                put_u64(&mut buf, *id);
                put_u64(&mut buf, eps.to_bits());
                let pb = point.to_bytes();
                put_u64(&mut buf, pb.len() as u64);
                buf.extend_from_slice(&pb);
            }
            Request::Knn { id, k, point } => {
                buf.push(REQ_KNN);
                put_u64(&mut buf, *id);
                put_u64(&mut buf, *k as u64);
                let pb = point.to_bytes();
                put_u64(&mut buf, pb.len() as u64);
                buf.extend_from_slice(&pb);
            }
            Request::Shutdown { id } => {
                buf.push(REQ_SHUTDOWN);
                put_u64(&mut buf, *id);
            }
            Request::Health { id } => {
                buf.push(REQ_HEALTH);
                put_u64(&mut buf, *id);
            }
            Request::Mutate { id, inserts, deletes } => {
                buf.push(REQ_MUTATE);
                put_u64(&mut buf, *id);
                let pb = inserts.to_bytes();
                put_u64(&mut buf, pb.len() as u64);
                buf.extend_from_slice(&pb);
                put_u64(&mut buf, deletes.len() as u64);
                for &gid in deletes {
                    buf.extend_from_slice(&gid.to_le_bytes());
                }
            }
        }
        buf
    }

    /// Length-checked decode of a frame payload. Rejects non-finite or
    /// negative ε, `k` outside `1 ..= u32::MAX`, and any carried point set
    /// that does not hold exactly one point.
    pub fn try_from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let op = try_get_u8(bytes, &mut off, "request opcode")?;
        let id = try_get_u64(bytes, &mut off, "request id")?;
        let req = match op {
            REQ_EPS => {
                let eps = f64::from_bits(try_get_u64(bytes, &mut off, "request eps")?);
                if !eps.is_finite() || eps < 0.0 {
                    return Err(WireError::Corrupt { what: "request eps not a radius" });
                }
                let point = decode_one_point::<P>(bytes, &mut off)?;
                Request::Eps { id, eps, point }
            }
            REQ_KNN => {
                let k = try_get_u64(bytes, &mut off, "request k")?;
                if k == 0 || k > u32::MAX as u64 {
                    return Err(WireError::Corrupt { what: "request k out of range" });
                }
                let point = decode_one_point::<P>(bytes, &mut off)?;
                Request::Knn { id, k: k as usize, point }
            }
            REQ_SHUTDOWN => Request::Shutdown { id },
            REQ_HEALTH => Request::Health { id },
            REQ_MUTATE => {
                // Unlike the query opcodes, the carried point set may hold
                // any number of points (including zero: a delete-only
                // mutate), so it is decoded directly, not through
                // `decode_one_point`.
                let plen = try_get_u64(bytes, &mut off, "mutate inserts length")? as usize;
                let body = try_take(bytes, &mut off, plen, "mutate inserts")?;
                let inserts = P::try_from_bytes(body)?;
                let m = try_get_u64(bytes, &mut off, "mutate delete count")? as usize;
                let body = try_take(bytes, &mut off, m.saturating_mul(4), "mutate deletes")?;
                let deletes: Vec<u32> = body.chunks_exact(4).map(le_u32).collect();
                Request::Mutate { id, inserts, deletes }
            }
            _ => return Err(WireError::Corrupt { what: "unknown request opcode" }),
        };
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after request" });
        }
        Ok(req)
    }
}

fn decode_one_point<P: PointSet>(bytes: &[u8], off: &mut usize) -> Result<P, WireError> {
    let len = try_get_u64(bytes, off, "request point length")? as usize;
    let point = P::try_from_bytes(try_take(bytes, off, len, "request point")?)?;
    if point.len() != 1 {
        return Err(WireError::Corrupt { what: "request must carry exactly one point" });
    }
    Ok(point)
}

/// Best-effort request id of an encoded request payload — used to address
/// the error reply when the payload itself fails to decode (0 when even
/// the id is unreadable).
pub fn peek_request_id(bytes: &[u8]) -> u64 {
    match bytes.get(1..9) {
        Some(b) => le_u64(b),
        None => 0,
    }
}

/// Daemon health counters, as reported by a `Health` request: the live
/// queue depth and lane count plus a snapshot of the lifetime stats —
/// enough for an operator (or load generator) to see saturation and
/// degradation without scraping logs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Health {
    /// Admitted-but-undispatched queries right now.
    pub queue_depth: u64,
    /// Query lanes (pool workers) answering batches.
    pub lanes: u64,
    /// Queries answered through the batch path so far.
    pub queries: u64,
    /// Batches dispatched so far.
    pub batches: u64,
    /// Typed overload replies sent so far.
    pub overloads: u64,
    /// Frames that failed to decode so far.
    pub bad_frames: u64,
    /// Queries answered with `deadline-exceeded` so far.
    pub deadline_misses: u64,
}

/// One decoded daemon response.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Query results: `(gid, distance)` pairs. ε-queries report them in
    /// traversal order; k-NN ascending by `(distance, id)`.
    Hits { id: u64, hits: Vec<(u32, f64)> },
    /// Typed rejection (see [`ErrorCode`]).
    Error { id: u64, code: ErrorCode },
    /// Shutdown acknowledged; the daemon drains and exits.
    Bye { id: u64 },
    /// Health counters (answers a `Health` request).
    Health { id: u64, health: Health },
    /// A mutate was applied (answers a `Mutate` request).
    Mutated { id: u64, outcome: MutateOutcome },
}

/// What a mutate request did, echoed back to the client. Fixed-size on
/// the wire (five u64s), so the decode path needs no length arithmetic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MutateOutcome {
    /// First global id assigned to the inserts (meaningless when
    /// `inserted == 0`); the batch got `first_gid .. first_gid + inserted`.
    pub first_gid: u64,
    /// Points appended.
    pub inserted: u64,
    /// Delete ids that actually tombstoned a live point (a miss — unknown
    /// or already-dead id — is not an error, just not counted).
    pub deleted: u64,
    /// The index epoch after the mutate (bumps on compaction).
    pub epoch: u64,
    /// Live points after the mutate.
    pub live: u64,
}

impl Response {
    /// Encode as a frame payload (owned-enum convenience; the daemon's hot
    /// path uses the `encode_*_into` borrow-encoders).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        match self {
            Response::Hits { id, hits } => encode_hits_into(&mut buf, *id, hits),
            Response::Error { id, code } => encode_error_into(&mut buf, *id, *code),
            Response::Bye { id } => encode_bye_into(&mut buf, *id),
            Response::Health { id, health } => encode_health_into(&mut buf, *id, health),
            Response::Mutated { id, outcome } => encode_mutated_into(&mut buf, *id, outcome),
        }
        buf
    }

    /// Length-checked decode of a frame payload. Hit distances must be
    /// finite and non-negative (a flipped sign/exponent bit is a typed
    /// error, not a silently wrong answer).
    pub fn try_from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let op = try_get_u8(bytes, &mut off, "response opcode")?;
        let id = try_get_u64(bytes, &mut off, "response id")?;
        let resp = match op {
            RESP_HITS => {
                let n = try_get_u64(bytes, &mut off, "response hit count")? as usize;
                let body = try_take(bytes, &mut off, n.saturating_mul(12), "response hits")?;
                let mut hits = Vec::with_capacity(n);
                for rec in body.chunks_exact(12) {
                    let (gid_b, dist_b) = rec.split_at(4);
                    let gid = le_u32(gid_b);
                    let dist = f64::from_bits(le_u64(dist_b));
                    if !dist.is_finite() || dist < 0.0 {
                        return Err(WireError::Corrupt { what: "response hit not a distance" });
                    }
                    hits.push((gid, dist));
                }
                Response::Hits { id, hits }
            }
            RESP_ERROR => {
                let c = try_get_u8(bytes, &mut off, "response error code")?;
                let code = ErrorCode::from_code(c)
                    .ok_or(WireError::Corrupt { what: "unknown response error code" })?;
                Response::Error { id, code }
            }
            RESP_BYE => Response::Bye { id },
            RESP_HEALTH => {
                let mut field = || try_get_u64(bytes, &mut off, "response health counter");
                let health = Health {
                    queue_depth: field()?,
                    lanes: field()?,
                    queries: field()?,
                    batches: field()?,
                    overloads: field()?,
                    bad_frames: field()?,
                    deadline_misses: field()?,
                };
                Response::Health { id, health }
            }
            RESP_MUTATED => {
                let mut field = || try_get_u64(bytes, &mut off, "response mutate field");
                let outcome = MutateOutcome {
                    first_gid: field()?,
                    inserted: field()?,
                    deleted: field()?,
                    epoch: field()?,
                    live: field()?,
                };
                Response::Mutated { id, outcome }
            }
            _ => return Err(WireError::Corrupt { what: "unknown response opcode" }),
        };
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after response" });
        }
        Ok(resp)
    }
}

/// Encode a hits response into `buf` (cleared first) — borrows the pair
/// slice straight out of the engine's batch output, so the daemon's reply
/// path allocates nothing once `buf` is warmed.
pub fn encode_hits_into(buf: &mut Vec<u8>, id: u64, hits: &[(u32, f64)]) {
    buf.clear();
    buf.push(RESP_HITS);
    put_u64(buf, id);
    put_u64(buf, hits.len() as u64);
    for &(gid, d) in hits {
        buf.extend_from_slice(&gid.to_le_bytes());
        buf.extend_from_slice(&d.to_bits().to_le_bytes());
    }
}

/// Encode a typed error response into `buf` (cleared first).
pub fn encode_error_into(buf: &mut Vec<u8>, id: u64, code: ErrorCode) {
    buf.clear();
    buf.push(RESP_ERROR);
    put_u64(buf, id);
    buf.push(code.code());
}

/// Encode a shutdown acknowledgement into `buf` (cleared first).
pub fn encode_bye_into(buf: &mut Vec<u8>, id: u64) {
    buf.clear();
    buf.push(RESP_BYE);
    put_u64(buf, id);
}

/// Encode a mutate acknowledgement into `buf` (cleared first).
pub fn encode_mutated_into(buf: &mut Vec<u8>, id: u64, outcome: &MutateOutcome) {
    buf.clear();
    buf.push(RESP_MUTATED);
    put_u64(buf, id);
    put_u64(buf, outcome.first_gid);
    put_u64(buf, outcome.inserted);
    put_u64(buf, outcome.deleted);
    put_u64(buf, outcome.epoch);
    put_u64(buf, outcome.live);
}

/// Encode a health response into `buf` (cleared first).
pub fn encode_health_into(buf: &mut Vec<u8>, id: u64, health: &Health) {
    buf.clear();
    buf.push(RESP_HEALTH);
    put_u64(buf, id);
    put_u64(buf, health.queue_depth);
    put_u64(buf, health.lanes);
    put_u64(buf, health.queries);
    put_u64(buf, health.batches);
    put_u64(buf, health.overloads);
    put_u64(buf, health.bad_frames);
    put_u64(buf, health.deadline_misses);
}

/// Outcome of one [`read_frame`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameRead {
    /// A complete frame payload now fills the buffer.
    Frame,
    /// The read timed out with **zero** bytes consumed — the connection is
    /// idle at a frame boundary; safe to poll a shutdown flag and retry.
    Idle,
    /// Clean end-of-stream at a frame boundary.
    Eof,
}

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if payload.len() as u64 > MAX_FRAME {
        return Err(io::Error::new(ErrorKind::InvalidInput, "frame payload exceeds MAX_FRAME"));
    }
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame into `buf` (cleared and resized in place, so a warmed
/// buffer is reused allocation-free).
///
/// Timeout reads (`WouldBlock`/`TimedOut` from a socket with a read
/// timeout) return [`FrameRead::Idle`] only while nothing of the next
/// frame has been consumed; once a frame has started, the read is retried
/// until the frame completes or `abort()` turns true (then
/// `ErrorKind::TimedOut`), so a frame is never split across calls. A
/// length prefix above [`MAX_FRAME`] is `ErrorKind::InvalidData` before
/// any buffer growth.
pub fn read_frame<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    abort: &dyn Fn() -> bool,
) -> io::Result<FrameRead> {
    let mut header = [0u8; 8];
    let mut have = 0usize;
    while have < 8 {
        match r.read(&mut header[have..]) {
            Ok(0) => {
                return if have == 0 {
                    Ok(FrameRead::Eof)
                } else {
                    Err(io::Error::new(ErrorKind::UnexpectedEof, "eof inside frame header"))
                };
            }
            Ok(n) => have += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if have == 0 {
                    return Ok(FrameRead::Idle);
                }
                if abort() {
                    return Err(io::Error::new(ErrorKind::TimedOut, "aborted inside frame header"));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u64::from_le_bytes(header);
    if len > MAX_FRAME {
        return Err(io::Error::new(ErrorKind::InvalidData, "frame length exceeds MAX_FRAME"));
    }
    buf.clear();
    buf.resize(len as usize, 0);
    let mut have = 0usize;
    while have < buf.len() {
        match r.read(&mut buf[have..]) {
            Ok(0) => {
                return Err(io::Error::new(ErrorKind::UnexpectedEof, "eof inside frame body"))
            }
            Ok(n) => have += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if abort() {
                    return Err(io::Error::new(ErrorKind::TimedOut, "aborted inside frame body"));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(FrameRead::Frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::{DenseMatrix, HammingCodes, StringSet};
    use std::io::Cursor;

    fn one_dense() -> DenseMatrix {
        DenseMatrix::from_flat(3, vec![1.0, -2.0, 0.5])
    }

    #[test]
    fn request_roundtrips_every_variant() {
        let reqs = [
            Request::Eps { id: 7, eps: 0.25, point: one_dense() },
            Request::Knn { id: u64::MAX, k: 12, point: one_dense() },
            Request::Shutdown { id: 3 },
            Request::Health { id: 4 },
            Request::Mutate { id: 5, inserts: one_dense(), deletes: vec![0, 9, u32::MAX] },
            // Delete-only (empty inserts) and insert-only mutates are legal.
            Request::Mutate { id: 6, inserts: DenseMatrix::new(3), deletes: vec![2] },
            Request::Mutate { id: 7, inserts: one_dense(), deletes: vec![] },
        ];
        for r in reqs {
            let b = r.to_bytes();
            assert_eq!(Request::<DenseMatrix>::try_from_bytes(&b), Ok(r.clone()));
            assert_eq!(
                peek_request_id(&b),
                match r {
                    Request::Eps { id, .. }
                    | Request::Knn { id, .. }
                    | Request::Shutdown { id }
                    | Request::Health { id }
                    | Request::Mutate { id, .. } => id,
                }
            );
        }
    }

    #[test]
    fn request_roundtrips_hamming_and_strings() {
        let mut h = HammingCodes::new(96);
        h.push_bits(&(0..96).map(|i| i % 3 == 0).collect::<Vec<_>>());
        let r = Request::Eps { id: 1, eps: 4.0, point: h };
        let b = r.to_bytes();
        assert_eq!(Request::<HammingCodes>::try_from_bytes(&b), Ok(r));

        let s = StringSet::from_strs(&["ACGT"]);
        let r = Request::Knn { id: 2, k: 3, point: s };
        let b = r.to_bytes();
        assert_eq!(Request::<StringSet>::try_from_bytes(&b), Ok(r));
    }

    #[test]
    fn request_rejects_bad_eps_k_and_multipoint() {
        let bad_eps = Request::Eps { id: 1, eps: f64::NAN, point: one_dense() }.to_bytes();
        assert!(Request::<DenseMatrix>::try_from_bytes(&bad_eps).is_err());
        let neg = Request::Eps { id: 1, eps: -1.0, point: one_dense() }.to_bytes();
        assert!(Request::<DenseMatrix>::try_from_bytes(&neg).is_err());
        let k0 = Request::Knn { id: 1, k: 0, point: one_dense() }.to_bytes();
        assert!(Request::<DenseMatrix>::try_from_bytes(&k0).is_err());
        let two = DenseMatrix::from_flat(2, vec![0.0, 1.0, 2.0, 3.0]);
        let multi = Request::Eps { id: 1, eps: 0.5, point: two }.to_bytes();
        assert_eq!(
            Request::<DenseMatrix>::try_from_bytes(&multi),
            Err(WireError::Corrupt { what: "request must carry exactly one point" })
        );
    }

    #[test]
    fn response_roundtrips_every_variant() {
        let resps = [
            Response::Hits { id: 9, hits: vec![(3, 0.125), (8, 2.0)] },
            Response::Hits { id: 10, hits: vec![] },
            Response::Error { id: 11, code: ErrorCode::Overloaded },
            Response::Bye { id: 12 },
            Response::Health {
                id: 13,
                health: Health {
                    queue_depth: 1,
                    lanes: 2,
                    queries: 3,
                    batches: 4,
                    overloads: 5,
                    bad_frames: 6,
                    deadline_misses: 7,
                },
            },
            Response::Mutated {
                id: 14,
                outcome: MutateOutcome {
                    first_gid: 1000,
                    inserted: 3,
                    deleted: 2,
                    epoch: 5,
                    live: 998,
                },
            },
        ];
        for r in resps {
            assert_eq!(Response::try_from_bytes(&r.to_bytes()), Ok(r.clone()));
        }
        for code in [
            ErrorCode::BadFrame,
            ErrorCode::BadQuery,
            ErrorCode::Overloaded,
            ErrorCode::ShuttingDown,
            ErrorCode::DeadlineExceeded,
            ErrorCode::ReadOnly,
        ] {
            let r = Response::Error { id: 1, code };
            assert_eq!(Response::try_from_bytes(&r.to_bytes()), Ok(r));
            assert!(!code.name().is_empty());
        }
    }

    #[test]
    fn response_rejects_nan_distance() {
        let r = Response::Hits { id: 1, hits: vec![(0, f64::NAN)] };
        assert_eq!(
            Response::try_from_bytes(&r.to_bytes()),
            Err(WireError::Corrupt { what: "response hit not a distance" })
        );
    }

    #[test]
    fn borrow_encoders_match_owned_encoding() {
        let hits = vec![(1u32, 0.5f64), (2, 1.5)];
        let mut buf = vec![0xAAu8; 3]; // stale content must be cleared
        encode_hits_into(&mut buf, 4, &hits);
        assert_eq!(buf, Response::Hits { id: 4, hits }.to_bytes());
        encode_error_into(&mut buf, 5, ErrorCode::BadQuery);
        assert_eq!(buf, Response::Error { id: 5, code: ErrorCode::BadQuery }.to_bytes());
        encode_bye_into(&mut buf, 6);
        assert_eq!(buf, Response::Bye { id: 6 }.to_bytes());
        let outcome = MutateOutcome { first_gid: 9, inserted: 1, deleted: 0, epoch: 2, live: 10 };
        encode_mutated_into(&mut buf, 7, &outcome);
        assert_eq!(buf, Response::Mutated { id: 7, outcome }.to_bytes());
    }

    #[test]
    fn mutate_rejects_truncation_and_trailing_bytes() {
        let r = Request::Mutate { id: 1, inserts: one_dense(), deletes: vec![1, 2, 3] };
        let b = r.to_bytes();
        // Every strict prefix fails typed, never panics (the full battery
        // lives in tests/wire_adversarial.rs; this is the smoke check).
        for cut in 0..b.len() {
            assert!(Request::<DenseMatrix>::try_from_bytes(&b[..cut]).is_err(), "cut={cut}");
        }
        let mut extra = b.clone();
        extra.push(0);
        assert_eq!(
            Request::<DenseMatrix>::try_from_bytes(&extra),
            Err(WireError::Corrupt { what: "trailing bytes after request" })
        );
        // A hostile delete count cannot over-allocate: saturating_mul
        // turns it into a typed truncation error.
        let mut hostile = Request::Mutate { id: 1, inserts: DenseMatrix::new(3), deletes: vec![] }
            .to_bytes();
        let n = hostile.len();
        hostile[n - 8..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Request::<DenseMatrix>::try_from_bytes(&hostile).is_err());
    }

    #[test]
    fn frames_roundtrip_and_cap_is_enforced() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello").unwrap();
        write_frame(&mut wire, b"").unwrap();
        let mut r = Cursor::new(wire);
        let mut buf = Vec::new();
        assert_eq!(read_frame(&mut r, &mut buf, &|| false).unwrap(), FrameRead::Frame);
        assert_eq!(buf, b"hello");
        assert_eq!(read_frame(&mut r, &mut buf, &|| false).unwrap(), FrameRead::Frame);
        assert!(buf.is_empty());
        assert_eq!(read_frame(&mut r, &mut buf, &|| false).unwrap(), FrameRead::Eof);

        // A poisoned length prefix errors before any allocation.
        let mut huge = Cursor::new(u64::MAX.to_le_bytes().to_vec());
        let err = read_frame(&mut huge, &mut buf, &|| false).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        // Truncated body is an error, not a short read.
        let mut trunc = Vec::new();
        write_frame(&mut trunc, b"abcdef").unwrap();
        trunc.truncate(trunc.len() - 2);
        let err = read_frame(&mut Cursor::new(trunc), &mut buf, &|| false).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);
    }
}
