//! Batch execution of coalesced queries over a [`NearIndex`] (DESIGN.md
//! §10.3).
//!
//! The dispatcher hands the engine one [`QueryBatch`] at a time; the
//! engine strides its queries across **lanes** — one per stripe, each
//! owning a long-lived [`QueryScratch`] plus warmed result buffers — via
//! [`Pool::run_indexed`], then merges the per-lane results back into
//! request order. The lane is bound to the **stripe index**, not to the
//! pool worker: `run_indexed` claims parts dynamically, so a fast worker
//! may run several stripes, and each stripe locks its own lane inside the
//! part body. Per-query answers are computed independently (query `q`
//! runs on lane `q % nlanes` with the same scratch-threaded entry points
//! a direct call would use), so the output is **bit-identical to direct
//! `NearIndex` calls at every lane count and every batch boundary** —
//! coalescing is a latency/throughput trade, never an answer change.
//!
//! Steady state allocates nothing: the batch and output double-buffers
//! are `clear()`ed (capacity kept), lanes persist across batches, and the
//! one-thread pool path runs inline (its `Vec<()>` of ZST outputs never
//! touches the heap). `examples/perf_driver.rs` arms an allocation gate
//! on exactly this path.

use crate::covertree::QueryScratch;
use crate::index::NearIndex;
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::Pool;
use std::sync::Mutex;

/// One admitted query: the operation; the point rides in the batch's
/// point set at the same position.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryOp {
    /// Fixed-radius query with the given ε.
    Eps(f64),
    /// k-nearest-neighbor query.
    Knn(usize),
}

/// A coalesced batch: one point container holding every admitted query
/// point (contiguous, cache-friendly) plus the per-query operation.
#[derive(Debug)]
pub struct QueryBatch<P: PointSet> {
    points: P,
    ops: Vec<QueryOp>,
}

impl<P: PointSet> QueryBatch<P> {
    /// An empty batch shaped like `proto` (same dimension/width).
    // lint: cold
    pub fn new_like(proto: &P) -> Self {
        QueryBatch { points: proto.empty_like(), ops: Vec::new() }
    }

    /// Number of queries in the batch.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Drop every query but keep the warmed buffer capacity — the
    /// steady-state reuse cycle of the coalescer's double buffer.
    pub fn clear(&mut self) {
        self.points.clear();
        self.ops.clear();
    }

    /// Append one query. `point` must hold exactly one point of the
    /// batch's shape (the admission path checks shape before pushing).
    pub fn push(&mut self, point: &P, op: QueryOp) {
        assert_eq!(point.len(), 1, "a query carries exactly one point");
        self.points.extend_from(point);
        self.ops.push(op);
    }

    /// Move queries `from..len` to the end of `dst`, keeping `0..from`
    /// here — the coalescer's max-batch split (PR 9). Both sides keep
    /// their warmed capacity, so the steady-state split cycle allocates
    /// nothing ([`PointSet::extend_from_range`] + [`PointSet::truncate`]).
    pub(crate) fn give_tail(&mut self, dst: &mut QueryBatch<P>, from: usize) {
        debug_assert!(from <= self.len(), "split point past the batch end");
        dst.points.extend_from_range(&self.points, from, self.len());
        dst.ops.extend_from_slice(&self.ops[from..]);
        self.points.truncate(from);
        self.ops.truncate(from);
    }

    /// The packed query points (parallel to [`QueryBatch::ops`]).
    pub fn points(&self) -> &P {
        &self.points
    }

    /// The per-query operations.
    pub fn ops(&self) -> &[QueryOp] {
        &self.ops
    }
}

/// Batch results in request order: one `(gid, dist)` span per query,
/// packed into a single reusable hits buffer.
#[derive(Debug, Default)]
pub struct BatchOutput {
    hits: Vec<(u32, f64)>,
    /// Per-query `(start, len)` into `hits`.
    spans: Vec<(usize, u32)>,
}

impl BatchOutput {
    pub fn new() -> Self {
        BatchOutput::default()
    }

    /// Number of answered queries.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// The hits of query `q`, in the same order a direct
    /// `eps_query_with`/`knn_with` call would produce them.
    pub fn hits_of(&self, q: usize) -> &[(u32, f64)] {
        let (start, len) = self.spans[q];
        &self.hits[start..start + len as usize]
    }

    fn clear(&mut self) {
        self.hits.clear();
        self.spans.clear();
    }
}

/// Per-lane state: one scratch plus result buffers. Lane `w` belongs to
/// stripe `w` of the current batch (locked by whichever pool worker runs
/// that stripe). `row` exists because `knn_with` clears its output (k-NN
/// rows are self-contained), while the lane accumulates many queries'
/// hits back to back.
#[derive(Default)]
struct Lane {
    scratch: QueryScratch,
    hits: Vec<(u32, f64)>,
    lens: Vec<u32>,
    row: Vec<(u32, f64)>,
}

/// The serve daemon's query executor: an owned index behind lane-striped
/// scratch state.
///
/// [`ServeEngine::execute`] is written for a **single consumer** (the
/// daemon's one dispatcher thread); an internal gate serializes
/// overlapping calls so misuse degrades to queueing, never to corrupted
/// lanes.
pub struct ServeEngine<P: PointSet, M: Metric<P>> {
    index: Box<dyn NearIndex<P, M>>,
    pool: Pool,
    lanes: Vec<Mutex<Lane>>,
    gate: Mutex<()>,
}

impl<P: PointSet, M: Metric<P>> ServeEngine<P, M> {
    /// Wrap an index with a `threads`-worker lane pool (clamped to ≥ 1).
    // lint: cold
    pub fn new(index: Box<dyn NearIndex<P, M>>, threads: usize) -> Self {
        let pool = Pool::new(threads);
        let lanes = (0..pool.threads()).map(|_| Mutex::new(Lane::default())).collect();
        ServeEngine { index, pool, lanes, gate: Mutex::new(()) }
    }

    /// The served index.
    pub fn index(&self) -> &dyn NearIndex<P, M> {
        self.index.as_ref()
    }

    /// Lane/worker budget.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Whether a query point could be answered against the served points
    /// (same dimension/width) — checked at admission so a mismatched point
    /// is a typed `bad-query` reply, not a panic inside a batch.
    pub fn shape_ok(&self, point: &P) -> bool {
        self.index.points().shape_matches(point)
    }

    /// Answer every query of `batch` into `out` (cleared first), request
    /// order preserved.
    pub fn execute(&self, batch: &QueryBatch<P>, out: &mut BatchOutput) {
        let _gate = self.gate.lock().unwrap();
        out.clear();
        let n = batch.len();
        if n == 0 {
            return;
        }
        let nlanes = self.lanes.len().min(n);
        // Stale lens from an earlier batch must never reach the merge,
        // whatever happens inside the run below.
        for lane in &self.lanes {
            lane.lock().unwrap().lens.clear();
        }
        // Stripe w answers queries w, w + nlanes, … into lane w. The lane
        // is bound to the *part index*, not the worker: `run_indexed`
        // claims parts dynamically, so a fast worker may run several
        // stripes — each one locks its own lane, so the merge below can
        // trust that lane w holds exactly stripe w's results.
        self.pool.run_indexed(nlanes, |w| {
            let mut lane = self.lanes[w].lock().unwrap();
            let lane = &mut *lane;
            lane.hits.clear();
            let mut q = w;
            while q < n {
                let start = lane.hits.len();
                match batch.ops[q] {
                    QueryOp::Eps(eps) => {
                        self.index.eps_query_with(
                            batch.points.point(q),
                            eps,
                            &mut lane.scratch,
                            &mut lane.hits,
                        );
                    }
                    QueryOp::Knn(k) => {
                        self.index.knn_with(
                            batch.points.point(q),
                            k,
                            &mut lane.scratch,
                            &mut lane.row,
                        );
                        lane.hits.extend_from_slice(&lane.row);
                    }
                }
                lane.lens.push((lane.hits.len() - start) as u32);
                q += nlanes;
            }
        });
        // Merge back to request order without per-call cursor allocations:
        // pass 1 scatters each query's hit count into its span slot, a
        // prefix sum turns counts into offsets, pass 2 copies the hits.
        out.spans.clear();
        out.spans.resize(n, (0, 0));
        for (w, lane) in self.lanes.iter().take(nlanes).enumerate() {
            let lane = lane.lock().unwrap();
            for (j, &len) in lane.lens.iter().enumerate() {
                out.spans[w + j * nlanes].1 = len;
            }
        }
        let mut acc = 0usize;
        for span in out.spans.iter_mut() {
            span.0 = acc;
            acc += span.1 as usize;
        }
        out.hits.resize(acc, (0, 0.0));
        for (w, lane) in self.lanes.iter().take(nlanes).enumerate() {
            let lane = lane.lock().unwrap();
            let mut src = 0usize;
            for (j, &len) in lane.lens.iter().enumerate() {
                let (start, _) = out.spans[w + j * nlanes];
                let len = len as usize;
                out.hits[start..start + len].copy_from_slice(&lane.hits[src..src + len]);
                src += len;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::covertree::QueryScratch;
    use crate::index::{build_index, IndexKind, IndexParams};
    use crate::metric::Euclidean;
    use crate::testkit::scenario;

    fn bits(pairs: &[(u32, f64)]) -> Vec<(u32, u64)> {
        pairs.iter().map(|&(g, d)| (g, d.to_bits())).collect()
    }

    #[test]
    fn batch_answers_match_direct_calls_at_every_lane_count() {
        let pts = scenario::dense_clusters(41, 180);
        for threads in [1usize, 2, 5] {
            let params = IndexParams { leaf_size: 4, ..Default::default() };
            let engine = ServeEngine::new(
                build_index(IndexKind::CoverTree, &pts, Euclidean, &params).unwrap(),
                threads,
            );
            let direct = build_index(IndexKind::CoverTree, &pts, Euclidean, &params).unwrap();

            let mut batch = QueryBatch::new_like(&pts);
            for q in 0..37 {
                let one = pts.slice(q, q + 1);
                let op = match q % 3 {
                    0 => QueryOp::Eps(0.8),
                    1 => QueryOp::Knn(5),
                    _ => QueryOp::Eps(0.0),
                };
                batch.push(&one, op);
            }
            let mut out = BatchOutput::new();
            engine.execute(&batch, &mut out);
            assert_eq!(out.len(), batch.len());

            let mut scratch = QueryScratch::new();
            let mut want = Vec::new();
            for q in 0..batch.len() {
                match batch.ops()[q] {
                    QueryOp::Eps(eps) => {
                        want.clear();
                        direct.eps_query_with(pts.point(q), eps, &mut scratch, &mut want);
                    }
                    QueryOp::Knn(k) => {
                        direct.knn_with(pts.point(q), k, &mut scratch, &mut want);
                    }
                }
                assert_eq!(
                    bits(out.hits_of(q)),
                    bits(&want),
                    "threads={threads} query={q} diverged from direct call"
                );
            }
        }
    }

    #[test]
    fn repeated_batches_survive_dynamic_stripe_claiming() {
        // Regression: `Pool::run_indexed` claims parts dynamically, so a
        // fast worker can run several stripes back to back. Lanes must be
        // bound to the stripe index (not the worker), or one lane's
        // buffers get clobbered mid-batch and stale lens from earlier
        // batches leak into the merge. Many threads over many repeated
        // batches makes multi-stripe workers overwhelmingly likely.
        let pts = scenario::dense_clusters(3, 240);
        let params = IndexParams { leaf_size: 4, ..Default::default() };
        let engine = ServeEngine::new(
            build_index(IndexKind::CoverTree, &pts, Euclidean, &params).unwrap(),
            8,
        );
        let direct = build_index(IndexKind::CoverTree, &pts, Euclidean, &params).unwrap();
        let mut scratch = QueryScratch::new();
        let mut want = Vec::new();
        let mut batch = QueryBatch::new_like(&pts);
        let mut out = BatchOutput::new();
        for round in 0..40usize {
            batch.clear();
            // Vary the batch size so lane lens lengths differ per round —
            // stale-lens leaks would misalign or overflow the merge.
            let n = 16 + (round * 7) % 48;
            for i in 0..n {
                let q = (round * 13 + i * 5) % pts.len();
                let op = if i % 2 == 0 { QueryOp::Eps(0.9) } else { QueryOp::Knn(3) };
                batch.push(&pts.slice(q, q + 1), op);
            }
            engine.execute(&batch, &mut out);
            assert_eq!(out.len(), n, "round {round} lost queries");
            for i in 0..n {
                let q = (round * 13 + i * 5) % pts.len();
                match batch.ops()[i] {
                    QueryOp::Eps(eps) => {
                        want.clear();
                        direct.eps_query_with(pts.point(q), eps, &mut scratch, &mut want);
                    }
                    QueryOp::Knn(k) => {
                        direct.knn_with(pts.point(q), k, &mut scratch, &mut want);
                    }
                }
                assert_eq!(
                    bits(out.hits_of(i)),
                    bits(&want),
                    "round {round} query {i} misattributed across lanes"
                );
            }
        }
    }

    #[test]
    fn batch_boundaries_do_not_change_answers() {
        // The same 24 queries executed as one batch, as 24 singleton
        // batches, and as uneven chunks must produce identical spans.
        let pts = scenario::dense_uniform(7, 90);
        let engine = ServeEngine::new(
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap(),
            3,
        );
        let ops: Vec<QueryOp> = (0..24)
            .map(|q| if q % 2 == 0 { QueryOp::Eps(0.6) } else { QueryOp::Knn(4) })
            .collect();

        let run_chunked = |chunk: usize| -> Vec<Vec<(u32, u64)>> {
            let mut all = Vec::new();
            let mut batch = QueryBatch::new_like(&pts);
            let mut out = BatchOutput::new();
            let mut q = 0usize;
            while q < ops.len() {
                batch.clear();
                let hi = (q + chunk).min(ops.len());
                for i in q..hi {
                    batch.push(&pts.slice(i, i + 1), ops[i]);
                }
                engine.execute(&batch, &mut out);
                for i in 0..batch.len() {
                    all.push(bits(out.hits_of(i)));
                }
                q = hi;
            }
            all
        };

        let whole = run_chunked(24);
        assert_eq!(whole, run_chunked(1), "singleton batches diverged");
        assert_eq!(whole, run_chunked(7), "uneven chunks diverged");
    }

    #[test]
    fn cleared_batch_and_output_are_reusable() {
        let pts = scenario::dense_uniform(19, 40);
        let engine = ServeEngine::new(
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap(),
            1,
        );
        let mut batch = QueryBatch::new_like(&pts);
        let mut out = BatchOutput::new();
        batch.push(&pts.slice(0, 1), QueryOp::Knn(3));
        engine.execute(&batch, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out.hits_of(0).len(), 3);
        batch.clear();
        assert!(batch.is_empty());
        // Empty batch → empty output, stale spans gone.
        engine.execute(&batch, &mut out);
        assert!(out.is_empty());
        batch.push(&pts.slice(2, 3), QueryOp::Eps(10.0));
        engine.execute(&batch, &mut out);
        assert_eq!(out.len(), 1);
        assert!(!out.hits_of(0).is_empty());
        assert!(engine.shape_ok(&pts.slice(0, 1)));
    }
}
