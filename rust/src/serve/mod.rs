//! `neargraph::serve` — a query-serving daemon with request coalescing
//! over snapshot-loaded indexes (DESIGN.md §10).
//!
//! The offline pipeline builds indexes; this module keeps one resident
//! and answers single-point ε and k-NN queries over TCP. The core idea is
//! **batch coalescing**: queries arriving within a bounded window
//! (`coalesce_us` µs or `max_batch` queries, whichever first) are drained
//! as one batch through the index's scratch-threaded batch paths on
//! [`crate::util::Pool`] workers — each worker holding one long-lived
//! [`crate::covertree::QueryScratch`] — so concurrent small queries get
//! batch-path throughput while answers stay **bit-identical** to direct
//! [`crate::index::NearIndex`] calls. Backpressure is explicit: the
//! admission queue is bounded (`queue_cap`) and overload is a typed
//! protocol reply, never unbounded buffering.
//!
//! A daemon started with `--mutable` over the `insert-cover-tree` backend
//! additionally accepts `Mutate` frames (batched inserts + tombstone
//! deletes), applied on the reader thread against the epoch tree's write
//! side while in-flight query batches keep reading the previous epoch
//! (DESIGN.md §13). Read-only daemons answer every Mutate with the typed
//! `read-only` error.
//!
//! Pieces (each its own submodule):
//!
//! * [`protocol`] — length-prefixed frames with hardened, `WireError`-typed
//!   decoders (registered in `tests/wire_adversarial.rs`);
//! * [`Coalescer`] — the bounded admission queue / batching window;
//! * [`ServeEngine`] — lane-striped batch execution over an owned index;
//! * [`serve`]/[`Server`] — listener, readers, dispatcher, clean shutdown;
//! * [`client::Client`] — a blocking pipelining client (tests, CLI, perf).
//!
//! Quickstart (in-process, ephemeral port):
//!
//! ```no_run
//! use neargraph::index::{build_index, IndexKind, IndexParams};
//! use neargraph::metric::Euclidean;
//! use neargraph::points::DenseMatrix;
//! use neargraph::serve::{serve, Client, Response, ServeConfig};
//!
//! let pts = DenseMatrix::from_flat(2, vec![0.0, 0.0, 1.0, 1.0]);
//! let index = build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default())?;
//! let server = serve(index, &ServeConfig { addr: "127.0.0.1:0".into(), ..Default::default() })?;
//! let mut client = Client::connect(&server.local_addr().to_string())?;
//! client.send_eps(7, &pts.slice(0, 1), 0.5)?;
//! match client.recv()? {
//!     Response::Hits { id, hits } => assert_eq!((id, hits.len()), (7, 1)),
//!     other => panic!("unexpected reply {other:?}"),
//! }
//! server.shutdown_and_join();
//! # Ok::<(), Box<dyn std::error::Error>>(())
//! ```
//!
//! Or from the CLI: `neargraph serve --dataset clusters --scale 2000`
//! then `neargraph query --addr 127.0.0.1:7878 --eps 0.5 --count 64`.

pub mod client;
mod coalesce;
mod engine;
pub mod protocol;
mod server;

pub use client::Client;
pub use coalesce::{Admit, CoalesceParams, Coalescer, PendingBatch, ReplySink, Ticket};
pub use engine::{BatchOutput, QueryBatch, QueryOp, ServeEngine};
pub use protocol::{ErrorCode, Health, MutateOutcome, Request, Response, MAX_FRAME};
pub use server::{serve, Server, StatsSnapshot};

/// Validated daemon settings (the `serve.*` config keys plus CLI
/// overrides; see [`crate::config`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Listen address, `ip:port` (port 0 picks an ephemeral port —
    /// read it back from [`Server::local_addr`]).
    pub addr: String,
    /// Coalescing window in microseconds (0 dispatches every admitted
    /// query immediately — the no-coalescing baseline).
    pub coalesce_us: u64,
    /// Batch-size cap that ripens a batch before the window expires.
    pub max_batch: usize,
    /// Bound on admitted-but-undispatched queries; beyond it clients get
    /// the typed overload reply.
    pub queue_cap: usize,
    /// Pool workers (query lanes) answering batches.
    pub threads: usize,
    /// Per-request deadline in microseconds, measured from admission. A
    /// query still undispatched past its deadline is answered with the
    /// typed `deadline-exceeded` error instead of a stale result — the
    /// graceful-degradation half of overload handling (0 ⇒ no deadline).
    pub deadline_us: u64,
    /// Accept `Mutate` frames (`serve.mutable` / `--mutable`). Off by
    /// default: a read-only daemon answers every Mutate with the typed
    /// `read-only` error. Even when on, the resident index must expose
    /// [`crate::index::MutableOps`] (the `insert-cover-tree` backend) or
    /// mutates are still refused.
    pub mutable: bool,
    /// Mutable daemons only (`serve.delta_cap`): the epoch tree's insert
    /// delta is compacted into a fresh batch-built base once it holds
    /// this many points ([`crate::covertree::EpochParams::delta_cap`]).
    pub delta_cap: usize,
    /// Mutable daemons only (`serve.compact_pct`): compaction also
    /// triggers once tombstones exceed this percentage of the base
    /// (1–100; becomes [`crate::covertree::EpochParams::compact_frac`]).
    pub compact_pct: u32,
}

impl ServeConfig {
    /// The epoch-tree compaction policy these settings describe (used by
    /// the CLI when it builds the resident mutable index).
    pub fn epoch_params(&self) -> crate::covertree::EpochParams {
        crate::covertree::EpochParams {
            delta_cap: self.delta_cap.max(1),
            compact_frac: f64::from(self.compact_pct.clamp(1, 100)) / 100.0,
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            coalesce_us: 200,
            max_batch: 256,
            queue_cap: 4096,
            threads: 1,
            deadline_us: 0,
            mutable: false,
            delta_cap: 256,
            compact_pct: 25,
        }
    }
}

/// Typed failure starting the daemon.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// `addr` is not an `ip:port` literal.
    BadAddr { addr: String },
    /// The listener could not bind.
    Bind { addr: String, error: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadAddr { addr } => {
                write!(f, "serve address '{addr}' is not an ip:port literal")
            }
            ServeError::Bind { addr, error } => write!(f, "cannot bind '{addr}': {error}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{build_index, IndexKind, IndexParams};
    use crate::metric::Euclidean;
    use crate::points::PointSet;
    use crate::testkit::scenario;

    fn ephemeral(threads: usize, coalesce_us: u64) -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            coalesce_us,
            threads,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_roundtrip_and_clean_shutdown() {
        let pts = scenario::dense_clusters(77, 120);
        let index =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        let server = serve(index, &ephemeral(2, 100)).unwrap();
        let addr = server.local_addr().to_string();

        let mut client = Client::connect(&addr).unwrap();
        client.send_eps(1, &pts.slice(3, 4), 0.7).unwrap();
        client.send_knn(2, &pts.slice(5, 6), 4).unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..2 {
            match client.recv().unwrap() {
                Response::Hits { id, hits } => {
                    got.insert(id, hits);
                }
                other => panic!("unexpected reply {other:?}"),
            }
        }
        assert!(got[&1].iter().any(|&(g, d)| g == 3 && d == 0.0), "self point within eps");
        assert_eq!(got[&2].len(), 4);

        client.send_shutdown(3).unwrap();
        assert_eq!(client.recv().unwrap(), Response::Bye { id: 3 });
        let stats = server.join();
        assert_eq!(stats.queries, 2);
        assert!(stats.batches >= 1);
        assert_eq!(stats.connections, 1);
    }

    #[test]
    fn bad_frame_and_bad_shape_get_typed_replies() {
        let pts = scenario::dense_uniform(3, 60); // dim 4
        let index =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        let server = serve(index, &ephemeral(1, 0)).unwrap();
        let addr = server.local_addr().to_string();

        let mut client = Client::connect(&addr).unwrap();
        // Wrong dimension: decodes fine, fails the shape check.
        let wrong = crate::points::DenseMatrix::from_flat(2, vec![0.0, 0.0]);
        client.send_eps(5, &wrong, 0.5).unwrap();
        assert_eq!(client.recv().unwrap(), Response::Error { id: 5, code: ErrorCode::BadQuery });

        // Garbage payload: typed bad-frame reply, connection stays usable.
        protocol::write_frame(
            &mut std::net::TcpStream::connect(&addr).unwrap(),
            b"\xFFnot a request",
        )
        .unwrap();
        client.send_knn(6, &pts.slice(0, 1), 2).unwrap();
        match client.recv().unwrap() {
            Response::Hits { id, hits } => assert_eq!((id, hits.len()), (6, 2)),
            other => panic!("unexpected reply {other:?}"),
        }
        let stats = server.shutdown_and_join();
        assert_eq!(stats.bad_frames, 1);
    }

    #[test]
    fn deadline_miss_is_typed_and_health_reports_it() {
        let pts = scenario::dense_uniform(9, 40);
        let index =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        // A 20 ms coalescing window with a 1 µs deadline: the lone query
        // must wait out the window, so its deadline is always blown.
        let server = serve(
            index,
            &ServeConfig {
                addr: "127.0.0.1:0".into(),
                coalesce_us: 20_000,
                deadline_us: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let addr = server.local_addr().to_string();

        let mut client = Client::connect(&addr).unwrap();
        client.send_eps(1, &pts.slice(0, 1), 0.5).unwrap();
        assert_eq!(
            client.recv().unwrap(),
            Response::Error { id: 1, code: ErrorCode::DeadlineExceeded }
        );

        // The miss counter is bumped before the error reply is sent, so the
        // probe observes it; `queries` is only settled after join.
        client.send_health(2).unwrap();
        match client.recv().unwrap() {
            Response::Health { id, health } => {
                assert_eq!(id, 2);
                assert_eq!(health.lanes, 1);
                assert_eq!(health.deadline_misses, 1);
            }
            other => panic!("unexpected reply {other:?}"),
        }
        let stats = server.shutdown_and_join();
        assert_eq!(stats.deadline_misses, 1);
        assert_eq!(stats.queries, 1);
    }

    #[test]
    fn bad_addr_is_typed() {
        let pts = scenario::dense_uniform(5, 10);
        let index =
            build_index(IndexKind::BruteForce, &pts, Euclidean, &IndexParams::default()).unwrap();
        let err = serve(index, &ServeConfig { addr: "not-an-addr".into(), ..Default::default() })
            .unwrap_err();
        assert_eq!(err, ServeError::BadAddr { addr: "not-an-addr".into() });
        assert!(format!("{err}").contains("not-an-addr"));
    }

    #[test]
    fn mutable_daemon_applies_mutations_and_serves_the_new_points() {
        let pts = scenario::dense_clusters(41, 90);
        let extra = scenario::dense_clusters(42, 95); // same generator ⇒ same dim
        let index = build_index(
            IndexKind::InsertCoverTree,
            &pts.slice(0, 90),
            Euclidean,
            &IndexParams::default(),
        )
        .unwrap();
        let server = serve(
            index,
            &ServeConfig { addr: "127.0.0.1:0".into(), mutable: true, ..Default::default() },
        )
        .unwrap();
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

        // Insert 2 new points and delete gid 7 in one frame.
        client.send_mutate(1, &extra.slice(90, 92), &[7, 9999]).unwrap();
        match client.recv().unwrap() {
            Response::Mutated { id, outcome } => {
                assert_eq!(id, 1);
                assert_eq!(outcome.first_gid, 90);
                assert_eq!(outcome.inserted, 2);
                assert_eq!(outcome.deleted, 1, "gid 9999 is a miss, not an error");
                assert_eq!(outcome.live, 91);
            }
            other => panic!("unexpected reply {other:?}"),
        }

        // The inserted point is now served, at distance 0 with its new gid.
        client.send_eps(2, &extra.slice(90, 91), 1e-9).unwrap();
        match client.recv().unwrap() {
            Response::Hits { id, hits } => {
                assert_eq!(id, 2);
                assert!(hits.iter().any(|&(g, d)| g == 90 && d == 0.0), "hits: {hits:?}");
            }
            other => panic!("unexpected reply {other:?}"),
        }

        // The tombstoned point never comes back.
        client.send_eps(3, &pts.slice(7, 8), 1e-9).unwrap();
        match client.recv().unwrap() {
            Response::Hits { id, hits } => {
                assert_eq!(id, 3);
                assert!(hits.iter().all(|&(g, _)| g != 7), "hits: {hits:?}");
            }
            other => panic!("unexpected reply {other:?}"),
        }

        // Wrong-dimension inserts get the typed bad-query reply.
        let wrong = crate::points::DenseMatrix::from_flat(1, vec![0.5]);
        client.send_mutate(4, &wrong, &[]).unwrap();
        assert_eq!(client.recv().unwrap(), Response::Error { id: 4, code: ErrorCode::BadQuery });

        let stats = server.shutdown_and_join();
        assert_eq!(stats.mutations, 1);
    }

    #[test]
    fn read_only_daemons_refuse_mutations_with_the_typed_error() {
        let pts = scenario::dense_uniform(13, 50);
        // Gate 1: mutable backend, but the operator did not pass --mutable.
        let index =
            build_index(IndexKind::InsertCoverTree, &pts, Euclidean, &IndexParams::default())
                .unwrap();
        let server = serve(index, &ephemeral(1, 0)).unwrap();
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
        client.send_mutate(1, &pts.slice(0, 1), &[]).unwrap();
        assert_eq!(client.recv().unwrap(), Response::Error { id: 1, code: ErrorCode::ReadOnly });
        let stats = server.shutdown_and_join();
        assert_eq!(stats.mutations, 0);

        // Gate 2: --mutable, but the resident backend has no MutableOps.
        let index =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        let server = serve(
            index,
            &ServeConfig { addr: "127.0.0.1:0".into(), mutable: true, ..Default::default() },
        )
        .unwrap();
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
        client.send_mutate(2, &pts.slice(0, 1), &[3]).unwrap();
        assert_eq!(client.recv().unwrap(), Response::Error { id: 2, code: ErrorCode::ReadOnly });
        server.shutdown_and_join();
    }

    #[test]
    fn server_drop_shuts_down_without_client_shutdown() {
        let pts = scenario::dense_uniform(11, 30);
        let index =
            build_index(IndexKind::CoverTree, &pts, Euclidean, &IndexParams::default()).unwrap();
        let server = serve(index, &ephemeral(1, 50)).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.send_eps(1, &pts.slice(0, 1), 0.4).unwrap();
        let _ = client.recv().unwrap();
        drop(server); // must join every thread, not hang
    }
}
