//! Simulated-client harness for the serve daemon: N concurrent pipelining
//! clients replaying scripted query plans against a live listener, with
//! per-query latency capture. The soak suite (`tests/serve_soak.rs`), the
//! CLI smoke job and the perf driver's serve section all drive the daemon
//! through this one harness.

use crate::points::PointSet;
use crate::serve::{Client, Response};
use std::io;
use std::time::{Duration, Instant};

/// One scripted query: an index into the shared query point set plus the
/// operation.
#[derive(Clone, Copy, Debug)]
pub enum SimQuery {
    Eps { point: usize, eps: f64 },
    Knn { point: usize, k: usize },
}

/// One client's script: its queries in send order and how many it keeps
/// in flight (`pipeline` ≥ 1; 1 = strict request/response lockstep).
#[derive(Clone, Debug)]
pub struct ClientPlan {
    pub queries: Vec<SimQuery>,
    pub pipeline: usize,
    /// Per-reply read deadline in milliseconds (0 = block forever).
    pub timeout_ms: u64,
}

/// One reply, matched back to its plan position.
#[derive(Clone, Debug)]
pub struct SimReply {
    /// Index into the plan's `queries`.
    pub seq: u32,
    pub response: Response,
    /// Send→receive wall latency in microseconds.
    pub micros: u64,
}

/// Everything one client observed, replies sorted by plan position.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub replies: Vec<SimReply>,
}

/// Run every plan on its own thread against the daemon at `addr`; query
/// points come from the shared `pts` (plans index into it). Request ids
/// encode `(client << 32) | seq`, so replies can arrive in any order and
/// still land on the right plan slot. Returns one report per plan, in
/// plan order.
pub fn run_clients<P: PointSet>(
    addr: &str,
    pts: &P,
    plans: &[ClientPlan],
) -> io::Result<Vec<SimReport>> {
    std::thread::scope(|s| {
        let handles: Vec<_> = plans
            .iter()
            .enumerate()
            .map(|(c, plan)| s.spawn(move || run_one(addr, pts, c as u64, plan)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("sim client panicked")).collect()
    })
}

fn run_one<P: PointSet>(
    addr: &str,
    pts: &P,
    client: u64,
    plan: &ClientPlan,
) -> io::Result<SimReport> {
    let mut cl = Client::connect_retry(addr, 40, Duration::from_millis(25))?;
    if plan.timeout_ms > 0 {
        cl.set_timeout(Some(Duration::from_millis(plan.timeout_ms)))?;
    }
    let total = plan.queries.len();
    let depth = plan.pipeline.max(1);
    let mut sent_at: Vec<Option<Instant>> = vec![None; total];
    let mut replies = Vec::with_capacity(total);
    let (mut next, mut outstanding) = (0usize, 0usize);
    while replies.len() < total {
        while next < total && outstanding < depth {
            let id = (client << 32) | next as u64;
            match plan.queries[next] {
                SimQuery::Eps { point, eps } => {
                    cl.send_eps(id, &pts.slice(point, point + 1), eps)?
                }
                SimQuery::Knn { point, k } => cl.send_knn(id, &pts.slice(point, point + 1), k)?,
            }
            sent_at[next] = Some(Instant::now());
            next += 1;
            outstanding += 1;
        }
        let response = cl.recv()?;
        let now = Instant::now();
        let id = match &response {
            Response::Hits { id, .. }
            | Response::Error { id, .. }
            | Response::Bye { id }
            | Response::Health { id, .. }
            | Response::Mutated { id, .. } => *id,
        };
        assert_eq!(id >> 32, client, "reply routed to the wrong client");
        let seq = (id & u32::MAX as u64) as usize;
        let micros = sent_at[seq]
            .map(|t| now.duration_since(t).as_micros() as u64)
            .expect("reply for a query never sent");
        replies.push(SimReply { seq: seq as u32, response, micros });
        outstanding -= 1;
    }
    replies.sort_by_key(|r| r.seq);
    Ok(SimReport { replies })
}

/// All latencies across reports, ascending — percentile input.
pub fn latencies_sorted(reports: &[SimReport]) -> Vec<u64> {
    let mut all: Vec<u64> =
        reports.iter().flat_map(|r| r.replies.iter().map(|x| x.micros)).collect();
    all.sort_unstable();
    all
}

/// Percentile (0.0 ..= 1.0) of an ascending latency slice (0 when empty).
pub fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_picks_expected_ranks() {
        let v = vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 0.5), 6);
        assert_eq!(percentile(&v, 1.0), 10);
        assert_eq!(percentile(&[], 0.5), 0);
    }
}
