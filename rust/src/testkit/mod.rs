//! Mini property-testing kit (the offline environment has no `proptest`).
//!
//! `forall` runs a property over `cases` seeded generations; on failure it
//! retries the failing case with shrunk size parameters (halving) to find a
//! smaller counterexample before panicking with the seed so the case can be
//! replayed deterministically.

use crate::util::Rng;

/// Size hints handed to generators; shrinking halves them.
#[derive(Clone, Copy, Debug)]
pub struct Size {
    /// Suggested collection size.
    pub n: usize,
    /// Suggested dimensionality.
    pub dim: usize,
}

impl Size {
    fn shrink(self) -> Option<Size> {
        if self.n <= 1 && self.dim <= 1 {
            return None;
        }
        Some(Size { n: (self.n / 2).max(1), dim: (self.dim / 2).max(1) })
    }
}

/// Run `prop(rng, size)` for `cases` random cases. A property *fails* by
/// panicking (use assert!). On failure, the same seed is retried at smaller
/// sizes to report a minimal-ish counterexample.
pub fn forall<F>(name: &str, cases: usize, base: Size, prop: F)
where
    F: Fn(&mut Rng, Size) + std::panic::RefUnwindSafe,
{
    let root = Rng::new(0x5EED ^ fx(name));
    for case in 0..cases {
        let seed_rng = root.fork(case as u64);
        let failed = std::panic::catch_unwind(|| {
            let mut rng = seed_rng.clone();
            prop(&mut rng, base);
        });
        if let Err(payload) = failed {
            // Shrink: halve sizes while the property still fails.
            let mut size = base;
            let mut last_payload = payload;
            while let Some(smaller) = size.shrink() {
                let retry = std::panic::catch_unwind(|| {
                    let mut rng = seed_rng.clone();
                    prop(&mut rng, smaller);
                });
                match retry {
                    Err(p) => {
                        size = smaller;
                        last_payload = p;
                    }
                    Ok(()) => break,
                }
            }
            let msg = last_payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| last_payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed (case {case}, shrunk to n={}, dim={}): {msg}",
                size.n, size.dim
            );
        }
    }
}

fn fx(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("tautology", 20, Size { n: 50, dim: 4 }, |rng, size| {
            let v = rng.below(size.n.max(1));
            assert!(v < size.n);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        forall("always-fails", 5, Size { n: 64, dim: 8 }, |_rng, _size| {
            panic!("nope");
        });
    }

    #[test]
    #[should_panic(expected = "shrunk to n=1, dim=1")]
    fn shrinking_reaches_minimum_when_failure_persists() {
        forall("fails-at-any-size", 1, Size { n: 64, dim: 8 }, |_rng, _size| {
            assert!(false, "independent of size");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        // The same property observes the same random values per case.
        use std::sync::Mutex;
        let seen1 = Mutex::new(Vec::new());
        forall("det", 5, Size { n: 10, dim: 2 }, |rng, _| {
            seen1.lock().unwrap().push(rng.next_u64());
        });
        let seen2 = Mutex::new(Vec::new());
        forall("det", 5, Size { n: 10, dim: 2 }, |rng, _| {
            seen2.lock().unwrap().push(rng.next_u64());
        });
        assert_eq!(*seen1.lock().unwrap(), *seen2.lock().unwrap());
    }
}
