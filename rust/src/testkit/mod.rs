//! Mini property-testing kit (the offline environment has no `proptest`)
//! and the shared test scenario source.
//!
//! Three pieces, used by every integration suite:
//!
//! * [`forall`] — seeded property runner with size-shrinking on failure;
//! * [`scenario`] — named, seeded dataset generators (dense clusters,
//!   manifolds, duplicates, Hamming codes, string pools) so tests share
//!   one scenario vocabulary instead of ad-hoc generator parameter copies;
//! * [`wire`] — the byte-mutation harness every length-checked wire
//!   decoder is held to (truncate/extend must error, bit flips must never
//!   panic);
//! * [`serve_sim`] — concurrent simulated clients for the serve daemon
//!   (scripted pipelined query plans with latency capture).

pub mod scenario;
pub mod serve_sim;
pub mod wire;

use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::Rng;

/// Reference k-NN rows by brute force under the total order
/// `(distance, id)`: row `i` holds the `min(k, n − 1)` nearest *other*
/// points of `i`. This is **the** definition every k-NN construction path
/// is pinned against (the conformance suite, `dist::knn`'s unit tests,
/// the CLI `--verify` path) — one copy, here, so the tie order and the
/// row clamp can never drift apart between suites.
pub fn brute_knn_rows<P: PointSet, M: Metric<P>>(
    pts: &P,
    metric: &M,
    k: usize,
) -> Vec<Vec<(u32, f64)>> {
    let n = pts.len();
    (0..n)
        .map(|i| {
            let mut all: Vec<(u32, f64)> = (0..n)
                .filter(|&j| j != i)
                .map(|j| (j as u32, metric.dist(pts.point(i), pts.point(j))))
                .collect();
            // total_cmp: the oracle must not panic where product code
            // degrades cleanly (NaN conformance scenarios).
            all.sort_unstable_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            all.truncate(k.min(n.saturating_sub(1)));
            all
        })
        .collect()
}

/// Size hints handed to generators; shrinking halves them.
#[derive(Clone, Copy, Debug)]
pub struct Size {
    /// Suggested collection size.
    pub n: usize,
    /// Suggested dimensionality.
    pub dim: usize,
}

impl Size {
    fn shrink(self) -> Option<Size> {
        if self.n <= 1 && self.dim <= 1 {
            return None;
        }
        Some(Size { n: (self.n / 2).max(1), dim: (self.dim / 2).max(1) })
    }
}

/// Run `prop(rng, size)` for `cases` random cases. A property *fails* by
/// panicking (use assert!). On failure, the same seed is retried at smaller
/// sizes to report a minimal-ish counterexample.
pub fn forall<F>(name: &str, cases: usize, base: Size, prop: F)
where
    F: Fn(&mut Rng, Size) + std::panic::RefUnwindSafe,
{
    let root = Rng::new(0x5EED ^ fx(name));
    for case in 0..cases {
        let seed_rng = root.fork(case as u64);
        let failed = std::panic::catch_unwind(|| {
            let mut rng = seed_rng.clone();
            prop(&mut rng, base);
        });
        if let Err(payload) = failed {
            // Shrink: halve sizes while the property still fails.
            let mut size = base;
            let mut last_payload = payload;
            while let Some(smaller) = size.shrink() {
                let retry = std::panic::catch_unwind(|| {
                    let mut rng = seed_rng.clone();
                    prop(&mut rng, smaller);
                });
                match retry {
                    Err(p) => {
                        size = smaller;
                        last_payload = p;
                    }
                    Ok(()) => break,
                }
            }
            let msg = last_payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| last_payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed (case {case}, shrunk to n={}, dim={}): {msg}",
                size.n, size.dim
            );
        }
    }
}

fn fx(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("tautology", 20, Size { n: 50, dim: 4 }, |rng, size| {
            let v = rng.below(size.n.max(1));
            assert!(v < size.n);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        forall("always-fails", 5, Size { n: 64, dim: 8 }, |_rng, _size| {
            panic!("nope");
        });
    }

    #[test]
    #[should_panic(expected = "shrunk to n=1, dim=1")]
    fn shrinking_reaches_minimum_when_failure_persists() {
        forall("fails-at-any-size", 1, Size { n: 64, dim: 8 }, |_rng, _size| {
            assert!(false, "independent of size");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        // The same property observes the same random values per case.
        use std::sync::Mutex;
        let seen1 = Mutex::new(Vec::new());
        forall("det", 5, Size { n: 10, dim: 2 }, |rng, _| {
            seen1.lock().unwrap().push(rng.next_u64());
        });
        let seen2 = Mutex::new(Vec::new());
        forall("det", 5, Size { n: 10, dim: 2 }, |rng, _| {
            seen2.lock().unwrap().push(rng.next_u64());
        });
        assert_eq!(*seen1.lock().unwrap(), *seen2.lock().unwrap());
    }
}
