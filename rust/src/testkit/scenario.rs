//! Named, seeded scenario datasets — the single source every integration
//! test draws from (`correctness_sweep`, `index_equivalence`,
//! `par_determinism`, `knn_conformance`).
//!
//! Each generator is a *named scenario* with a fixed shape (dimensionality,
//! cluster count, noise level) chosen to exercise one data regime the
//! paper's algorithms care about; tests pick a scenario, a seed and a size
//! instead of copying `data::synthetic` parameter tuples around. Same
//! `(scenario, seed, n)` ⇒ bit-identical dataset, everywhere, forever —
//! that is what makes cross-suite comparisons (and failure reproduction)
//! trivial.

use crate::data::synthetic;
use crate::points::{DenseMatrix, HammingCodes, StringSet};
use crate::util::Rng;

/// Dense Gaussian clusters (dim 5, 5 clusters, σ = 0.12) — the bread-and-
/// butter Euclidean regime where landmark partitioning localizes well.
pub fn dense_clusters(seed: u64, n: usize) -> DenseMatrix {
    synthetic::gaussian_mixture(&mut Rng::new(seed), n, 5, 5, 0.12)
}

/// Dense clustered data with intrinsic dimension 4 embedded in 24 ambient
/// dimensions — the "data manifold" regime of the high-dimensional Table-I
/// analogs.
pub fn dense_manifold(seed: u64, n: usize) -> DenseMatrix {
    synthetic::manifold_mixture(&mut Rng::new(seed), n, 24, 4, 8, 0.1)
}

/// Uniform points in `[0, 1]^4` — no cluster structure; the worst case for
/// landmarking.
pub fn dense_uniform(seed: u64, n: usize) -> DenseMatrix {
    synthetic::uniform(&mut Rng::new(seed), n, 4, 1.0)
}

/// A uniform base with `extra` exact duplicate rows — stresses zero-
/// distance ties, duplicate collapse in the cover tree, and skewed Voronoi
/// cells. `n` is the base size; the result holds `n + extra` points.
pub fn dense_duplicates(seed: u64, n: usize, extra: usize) -> DenseMatrix {
    let mut rng = Rng::new(seed);
    let base = synthetic::uniform(&mut rng, n, 3, 1.0);
    synthetic::with_duplicates(&mut rng, &base, extra)
}

/// 96-bit Hamming codes in 4 clusters (flip probability 0.07) — the
/// bit-packed metric family (sift-hamming / word2bits analogs).
pub fn hamming_codes(seed: u64, n: usize) -> HammingCodes {
    synthetic::hamming_clusters(&mut Rng::new(seed), n, 96, 4, 0.07)
}

/// Synthetic sequencing reads (length ~24, 4 ancestors, 6% mutation) — the
/// Levenshtein workload from the paper's introduction.
pub fn string_pool(seed: u64, n: usize) -> StringSet {
    synthetic::reads(&mut Rng::new(seed), n, 24, 4, 0.06)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::PointSet;

    #[test]
    fn scenarios_are_deterministic_per_seed() {
        assert_eq!(dense_clusters(7, 50), dense_clusters(7, 50));
        assert_ne!(dense_clusters(7, 50), dense_clusters(8, 50));
        assert_eq!(hamming_codes(7, 40), hamming_codes(7, 40));
        assert_eq!(string_pool(7, 30), string_pool(7, 30));
        assert_eq!(dense_manifold(7, 30), dense_manifold(7, 30));
        assert_eq!(dense_uniform(7, 30), dense_uniform(7, 30));
    }

    #[test]
    fn shapes_match_the_contract() {
        assert_eq!(dense_clusters(1, 64).dim(), 5);
        assert_eq!(dense_manifold(1, 64).dim(), 24);
        assert_eq!(dense_uniform(1, 64).dim(), 4);
        assert_eq!(dense_duplicates(1, 40, 25).len(), 65);
        assert_eq!(hamming_codes(1, 64).len(), 64);
        assert_eq!(string_pool(1, 64).len(), 64);
    }
}
