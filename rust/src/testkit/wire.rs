//! Shared byte-mutation harness for wire decoders (the adversarial side of
//! the testkit): every length-checked decoder in the crate — `Bundle`,
//! `EdgeBundle`, `KnnBundle`, `WeightedEdgeList`, `NearGraph` (NGW-CSR1),
//! `KnnGraph` (NGK-KNN1) — must satisfy the same contract against
//! malformed bytes, and this module enforces it uniformly.
//!
//! The contract, applied to a pristine encoding:
//!
//! * **pristine bytes decode** — the unmutated buffer is `Ok`;
//! * **every truncation fails** — all formats are count-prefixed with a
//!   trailing-bytes check, so *every* strict prefix must yield a typed
//!   [`WireError`] (never a panic, never a silent partial decode);
//! * **every extension fails** — appending any byte trips the
//!   trailing-bytes check;
//! * **bit flips never panic** — flipping any single bit anywhere in the
//!   buffer must produce either a typed error or a *valid* alternative
//!   decoding (e.g. a flipped coordinate bit is a different, legal point);
//!   what it must never do is panic, over-allocate from a corrupt length
//!   prefix, or read out of bounds.

use crate::points::WireError;

/// Exhaustively mutate `bytes` against `decode`, enforcing the module
/// contract. `what` labels failures.
///
/// Runs `O(len)` truncations, a few extensions and `8·len` bit-flip
/// decodes — keep sample payloads small (hundreds of bytes, not
/// megabytes).
pub fn check_wire_decoder<T>(
    what: &str,
    bytes: &[u8],
    decode: &dyn Fn(&[u8]) -> Result<T, WireError>,
) {
    assert!(decode(bytes).is_ok(), "{what}: pristine bytes must decode");

    // Truncation at every boundary.
    for cut in 0..bytes.len() {
        assert!(
            decode(&bytes[..cut]).is_err(),
            "{what}: truncation to {cut}/{} bytes decoded",
            bytes.len()
        );
    }

    // Extension by assorted bytes.
    for pad in [0u8, 1, 0x7F, 0xFF] {
        let mut extended = bytes.to_vec();
        extended.push(pad);
        assert!(decode(&extended).is_err(), "{what}: trailing byte {pad:#x} accepted");
    }

    // Single-bit flips at every position: must not panic (a panic here
    // aborts the test), and must not hang on a huge corrupt length prefix.
    let mut flipped = bytes.to_vec();
    for i in 0..flipped.len() {
        for bit in 0..8u8 {
            flipped[i] ^= 1 << bit;
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                decode(&flipped).is_ok()
            }));
            assert!(
                result.is_ok(),
                "{what}: decoder panicked on bit flip at byte {i}, bit {bit}"
            );
            flipped[i] ^= 1 << bit;
        }
    }
}

/// The replay half of the adversarial battery, for **stateful** stream
/// decoders (sequence-numbered envelope streams): a decoder that returns
/// `Ok(Some(payload))` for a fresh frame, `Ok(None)` for a duplicate, and
/// a typed [`WireError`] for corruption.
///
/// `frames` are pristine encodings of *distinct* sequence numbers in
/// their original send order; `make` builds a fresh decoder per scenario.
/// The contract:
///
/// * **in order** — every frame is fresh (`Ok(Some)`);
/// * **replayed twice** — the first copy is fresh, the immediate replay is
///   recognised and discarded (`Ok(None)`), never delivered twice;
/// * **out of order** — reversed delivery still yields each frame exactly
///   once (`Ok(Some)`), no matter the arrival order;
/// * **bit flips never panic** — a flipped envelope is a typed error or a
///   (valid) different frame, never a panic.
pub fn check_stream_decoder<T, D>(
    what: &str,
    frames: &[Vec<u8>],
    make: &mut dyn FnMut() -> D,
) where
    D: FnMut(&[u8]) -> Result<Option<T>, WireError>,
{
    // In order: everything is fresh.
    let mut dec = make();
    for (i, f) in frames.iter().enumerate() {
        assert!(
            matches!(dec(f), Ok(Some(_))),
            "{what}: in-order frame {i} was not delivered"
        );
    }

    // Each frame duplicated back-to-back: dup discarded, not redelivered.
    let mut dec = make();
    for (i, f) in frames.iter().enumerate() {
        assert!(matches!(dec(f), Ok(Some(_))), "{what}: frame {i} first copy dropped");
        assert!(
            matches!(dec(f), Ok(None)),
            "{what}: frame {i} replay was not discarded as a duplicate"
        );
    }

    // Reversed order: arrival order must not matter for exactly-once.
    let mut dec = make();
    for (i, f) in frames.iter().enumerate().rev() {
        assert!(
            matches!(dec(f), Ok(Some(_))),
            "{what}: out-of-order frame {i} was not delivered"
        );
    }

    // Single-bit flips on every frame against a fresh decoder: typed error
    // or valid alternative, never a panic.
    for (i, f) in frames.iter().enumerate() {
        let mut flipped = f.clone();
        for byte in 0..flipped.len() {
            for bit in 0..8u8 {
                flipped[byte] ^= 1 << bit;
                let mut dec = make();
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    dec(&flipped).is_ok()
                }));
                assert!(
                    outcome.is_ok(),
                    "{what}: stream decoder panicked on frame {i}, byte {byte}, bit {bit}"
                );
                flipped[byte] ^= 1 << bit;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::{put_u64, try_get_u64, try_take};

    /// A tiny well-behaved format: count-prefixed u32s + trailing check.
    fn encode(vals: &[u32]) -> Vec<u8> {
        let mut buf = Vec::new();
        put_u64(&mut buf, vals.len() as u64);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf
    }

    fn decode(bytes: &[u8]) -> Result<Vec<u32>, WireError> {
        let mut off = 0usize;
        let n = try_get_u64(bytes, &mut off, "count")? as usize;
        let payload = try_take(bytes, &mut off, n.saturating_mul(4), "values")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes" });
        }
        Ok(payload.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    #[test]
    fn well_behaved_decoder_passes() {
        check_wire_decoder("sample", &encode(&[1, 2, 0xFFFF_FFFF]), &decode);
        check_wire_decoder("empty", &encode(&[]), &decode);
    }

    #[test]
    #[should_panic(expected = "truncation")]
    fn sloppy_decoder_is_caught() {
        // A decoder that tolerates truncation must be flagged.
        let tolerant = |bytes: &[u8]| -> Result<usize, WireError> { Ok(bytes.len()) };
        check_wire_decoder("tolerant", &encode(&[5]), &tolerant);
    }

    #[test]
    fn envelope_stream_passes_the_replay_battery() {
        use crate::comm::{encode_envelope, EnvelopeStream};
        let frames: Vec<Vec<u8>> =
            (0..4u64).map(|seq| encode_envelope(seq, &[seq as u8; 9])).collect();
        check_stream_decoder("envelope stream", &frames, &mut || {
            let mut s = EnvelopeStream::default();
            move |bytes: &[u8]| s.accept(bytes)
        });
    }

    #[test]
    #[should_panic(expected = "not discarded")]
    fn redelivering_stream_decoder_is_caught() {
        // A stateless decoder that delivers every frame (no dedup) must be
        // flagged by the replay half of the battery.
        let frames = vec![encode(&[1]), encode(&[2])];
        check_stream_decoder("forgetful", &frames, &mut || {
            |_bytes: &[u8]| -> Result<Option<()>, WireError> { Ok(Some(())) }
        });
    }

    #[test]
    #[should_panic(expected = "panicked on bit flip")]
    fn panicking_decoder_is_caught() {
        let brittle = |bytes: &[u8]| -> Result<u64, WireError> {
            let mut off = 0usize;
            let n = try_get_u64(bytes, &mut off, "count")?;
            if off != bytes.len() {
                return Err(WireError::Corrupt { what: "trailing" });
            }
            assert!(n < 100, "blind internal assert");
            Ok(n)
        };
        let mut buf = Vec::new();
        put_u64(&mut buf, 5);
        check_wire_decoder("brittle", &buf, &brittle);
    }
}
