//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; typed accessors validate on retrieval and unknown-flag
//! checking is available after all expected flags are declared.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
    /// Flags the program has asked about (for unknown-flag detection).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates flags.
                    out.positionals.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    fn note(&self, key: &str) {
        self.known.borrow_mut().push(key.to_string());
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.note(key);
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Typed accessors (error on malformed values).
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.note(key);
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.note(key);
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> Result<bool, String> {
        self.note(key);
        match self.flags.get(key).map(String::as_str) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("--{key} expects a boolean, got {v:?}")),
        }
    }

    /// Error when two mutually-exclusive flags are both present (e.g.
    /// `--knn` vs `--eps`). Checks presence only — call before or after
    /// the typed accessors.
    pub fn reject_conflict(&self, a: &str, b: &str) -> Result<(), String> {
        if self.flags.contains_key(a) && self.flags.contains_key(b) {
            return Err(format!("--{a} and --{b} are mutually exclusive"));
        }
        Ok(())
    }

    /// Error if any provided flag was never queried (typo protection).
    /// Call after all `get_*` calls.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let known = self.known.borrow();
        for k in self.flags.keys() {
            if !known.iter().any(|q| q == k) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn flag_styles() {
        let a = parse("run --ranks 8 --eps=0.5 --verbose --name sift");
        assert_eq!(a.positional(0), Some("run"));
        assert_eq!(a.get_usize("ranks").unwrap(), Some(8));
        assert_eq!(a.get_f64("eps").unwrap(), Some(0.5));
        assert!(a.get_bool("verbose").unwrap());
        assert_eq!(a.get("name"), Some("sift"));
    }

    #[test]
    fn missing_flags_default() {
        let a = parse("run");
        assert_eq!(a.get_usize("ranks").unwrap(), None);
        assert!(!a.get_bool("verbose").unwrap());
        assert_eq!(a.get_or("algo", "landmark-coll"), "landmark-coll");
    }

    #[test]
    fn type_errors() {
        let a = parse("--ranks eight");
        assert!(a.get_usize("ranks").is_err());
        let b = parse("--eps very-small");
        assert!(b.get_f64("eps").is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("--ranks 4 --typo 1");
        let _ = a.get_usize("ranks");
        assert!(a.reject_unknown().is_err());
        let _ = a.get("typo");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn conflicting_flags_rejected() {
        let a = parse("run --knn 5 --eps 0.3");
        assert!(a.reject_conflict("knn", "eps").is_err());
        assert!(a.reject_conflict("knn", "scale").is_ok());
        let b = parse("run --knn 5");
        assert!(b.reject_conflict("knn", "eps").is_ok());
    }

    #[test]
    fn double_dash_stops_flag_parsing() {
        let a = parse("cmd -- --not-a-flag");
        assert_eq!(a.positional(0), Some("cmd"));
        assert_eq!(a.positional(1), Some("--not-a-flag"));
    }

    #[test]
    fn trailing_boolean_flag() {
        let a = parse("--check");
        assert!(a.get_bool("check").unwrap());
    }
}
