//! Comment- and string-aware tokenizer for `neargraph::lint`.
//!
//! A deliberately small lexer: it understands exactly as much Rust as the
//! rules need — line and nested block comments, plain/raw/byte strings,
//! char literals vs lifetimes, numbers with float classification, and
//! identifiers — and emits everything else as single-char punctuation
//! (merging only `::`, `->` and `=>`, which the rules match on).
//!
//! This file is a line-for-line port of the tokenizer in
//! `python/neargraph_lint.py`, the executable mirror that runs in the
//! toolchain-free growth container. Any behavioral divergence between the
//! two is a bug; `tests/lint_selftest.rs` re-checks the shared fixture
//! corpus under cargo to hold that equivalence.

/// Token classification. `FNum` (a float-looking literal) is split from
/// `Num` because the `total-ordering` rule keys on it to decide whether a
/// `.max(..)` call is distance-typed.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    Ident,
    Num,
    FNum,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A comment, with its raw text (markers stripped), whether it stood alone
/// on its line (no code token earlier on the same line), and the index of
/// the next significant token after it (-1 when none follows).
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    pub standalone: bool,
    pub next_tok: isize,
}

pub(crate) fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_ascii_alphabetic()
}

pub(crate) fn is_ident_cont(c: char) -> bool {
    c == '_' || c.is_ascii_alphanumeric()
}

fn push(toks: &mut Vec<Tok>, kind: TokKind, text: String, ln: u32) {
    // Merge '::' '->' '=>' from single punct chars.
    if kind == TokKind::Punct {
        if let Some(prev) = toks.last_mut() {
            if prev.kind == TokKind::Punct && prev.line == ln {
                let pair = format!("{}{}", prev.text, text);
                if pair == "::" || pair == "->" || pair == "=>" {
                    prev.text = pair;
                    return;
                }
            }
        }
    }
    toks.push(Tok { kind, text, line: ln });
}

fn settle(pending: &mut Vec<usize>, comments: &mut [Comment], toks: &[Tok]) {
    for idx in pending.drain(..) {
        comments[idx].next_tok = toks.len() as isize - 1;
    }
}

fn slice(s: &[char], a: usize, b: usize) -> String {
    let hi = b.min(s.len());
    if a >= hi {
        return String::new();
    }
    s[a..hi].iter().collect()
}

/// Tokenize `src`, returning the significant tokens and the comments.
pub fn tokenize(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut pending: Vec<usize> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut last_tok_line: u32 = 0;

    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Comments ----------------------------------------------------------
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            // strip '//', then one optional doc marker '/' or '!'
            let mut t_start = i + 2;
            if t_start < j && (s[t_start] == '/' || s[t_start] == '!') {
                t_start += 1;
            }
            let text = slice(&s, t_start, j).trim().to_string();
            comments.push(Comment { line, text, standalone: last_tok_line != line, next_tok: -1 });
            pending.push(comments.len() - 1);
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1i32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let body_end = j.saturating_sub(2).max(i + 2);
            let text = slice(&s, i + 2, body_end).trim().to_string();
            comments.push(Comment {
                line: start_line,
                text,
                standalone: last_tok_line != start_line,
                next_tok: -1,
            });
            pending.push(comments.len() - 1);
            i = j;
            continue;
        }
        // Raw / byte strings ------------------------------------------------
        if c == 'r' || c == 'b' {
            let mut j = i;
            let mut has_r = c == 'r';
            if c == 'b' && j + 1 < n && s[j + 1] == 'r' {
                has_r = true;
                j += 1;
            }
            if c == 'r' && j + 1 < n && s[j + 1] == 'b' {
                j += 1;
            }
            let mut k = j + 1;
            let mut hashes = 0usize;
            while k < n && s[k] == '#' {
                hashes += 1;
                k += 1;
            }
            if has_r && k < n && s[k] == '"' {
                // raw string: ends at '"' followed by `hashes` '#'s
                let close_len = 1 + hashes;
                let mut end = n;
                let mut p = k + 1;
                while p + close_len <= n {
                    if s[p] == '"' && s[p + 1..p + close_len].iter().all(|&h| h == '#') {
                        end = p;
                        break;
                    }
                    p += 1;
                }
                let text = slice(&s, i, end + close_len);
                let ln = line;
                line += text.matches('\n').count() as u32;
                push(&mut toks, TokKind::Str, text, ln);
                settle(&mut pending, &mut comments, &toks);
                last_tok_line = ln;
                i = end + close_len;
                continue;
            }
            if c == 'b' && i + 1 < n && s[i + 1] == '"' {
                // byte string: token starts at the quote, like the mirror
                i += 1;
                continue;
            }
            if c == 'b' && i + 1 < n && s[i + 1] == '\'' {
                // byte char literal b'x'
                let mut j = i + 2;
                if j < n && s[j] == '\\' {
                    j += 2;
                } else {
                    j += 1;
                }
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                push(&mut toks, TokKind::Char, slice(&s, i, j + 1), line);
                settle(&mut pending, &mut comments, &toks);
                last_tok_line = line;
                i = j + 1;
                continue;
            }
            // otherwise fall through: 'r'/'b' starts a plain identifier
        }
        // Strings -----------------------------------------------------------
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    j += 2;
                    continue;
                }
                if s[j] == '"' {
                    break;
                }
                j += 1;
            }
            let text = slice(&s, i, j + 1);
            let ln = line;
            line += text.matches('\n').count() as u32;
            push(&mut toks, TokKind::Str, text, ln);
            settle(&mut pending, &mut comments, &toks);
            last_tok_line = ln;
            i = j + 1;
            continue;
        }
        // Char literal vs lifetime ------------------------------------------
        if c == '\'' {
            if i + 1 < n && s[i + 1] == '\\' {
                let mut j = i + 3;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                push(&mut toks, TokKind::Char, slice(&s, i, j + 1), line);
                i = j + 1;
            } else if i + 2 < n && s[i + 2] == '\'' && s[i + 1] != '\'' {
                push(&mut toks, TokKind::Char, slice(&s, i, i + 3), line);
                i += 3;
            } else {
                let mut j = i + 1;
                while j < n && is_ident_cont(s[j]) {
                    j += 1;
                }
                push(&mut toks, TokKind::Lifetime, slice(&s, i, j), line);
                i = j;
            }
            settle(&mut pending, &mut comments, &toks);
            last_tok_line = line;
            continue;
        }
        // Numbers -----------------------------------------------------------
        if c.is_ascii_digit() {
            let mut j = i;
            let mut is_float = false;
            let radix_prefix = c == '0'
                && i + 1 < n
                && (s[i + 1] == 'x' || s[i + 1] == 'b' || s[i + 1] == 'o');
            if radix_prefix {
                j = i + 2;
                while j < n && is_ident_cont(s[j]) {
                    j += 1;
                }
            } else {
                while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                    j += 1;
                }
                if j < n && s[j] == '.' && j + 1 < n && s[j + 1].is_ascii_digit() {
                    is_float = true;
                    j += 1;
                    while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                        j += 1;
                    }
                } else if j < n
                    && s[j] == '.'
                    && !(j + 1 < n && (s[j + 1] == '.' || is_ident_start(s[j + 1])))
                {
                    // trailing-dot float like `1.`
                    is_float = true;
                    j += 1;
                }
                if j < n
                    && (s[j] == 'e' || s[j] == 'E')
                    && j + 1 < n
                    && (s[j + 1].is_ascii_digit() || s[j + 1] == '+' || s[j + 1] == '-')
                {
                    is_float = true;
                    j += 2;
                    while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                        j += 1;
                    }
                }
                // suffix (f32, u8, usize...)
                let sfx = j;
                while j < n && is_ident_cont(s[j]) {
                    j += 1;
                }
                let suffix = slice(&s, sfx, j);
                if suffix == "f32" || suffix == "f64" {
                    is_float = true;
                }
            }
            let kind = if is_float { TokKind::FNum } else { TokKind::Num };
            push(&mut toks, kind, slice(&s, i, j), line);
            settle(&mut pending, &mut comments, &toks);
            last_tok_line = line;
            i = j;
            continue;
        }
        // Identifiers -------------------------------------------------------
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            push(&mut toks, TokKind::Ident, slice(&s, i, j), line);
            settle(&mut pending, &mut comments, &toks);
            last_tok_line = line;
            i = j;
            continue;
        }
        // Punctuation -------------------------------------------------------
        push(&mut toks, TokKind::Punct, c.to_string(), line);
        settle(&mut pending, &mut comments, &toks);
        last_tok_line = line;
        i += 1;
    }
    (toks, comments)
}
