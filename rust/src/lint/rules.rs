//! The five invariant rules plus waiver application — the semantic core of
//! `neargraph::lint` (DESIGN.md §12), ported from the Python mirror.

use std::collections::HashSet;

use super::parse::{DirKind, FileModel, FnModel};
use super::tokenize::{Tok, TokKind};
use super::{Finding, HOT_FILES, HOT_PREFIXES, KNOWN_RULES, R3_FILES};

const ALLOC_CALLS: [&str; 3] = ["collect", "to_vec", "clone"];
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
const ASSERT_MACROS: [&str; 3] = ["assert", "assert_eq", "assert_ne"];

fn tok_text(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

// ---- R1: no-alloc-hot-path ------------------------------------------------

pub fn r1_hot_alloc(fm: &FileModel, findings: &mut Vec<Finding>) {
    let rel = fm.path.as_str();
    if !HOT_FILES.contains(&rel) && !HOT_PREFIXES.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    let toks = &fm.toks;
    for f in &fm.fns {
        if !f.is_scanned() || f.is_cold {
            continue;
        }
        let mut i = f.body_start as usize;
        while i <= f.body_end {
            let t = &toks[i];
            let nxt = tok_text(toks, i + 1);
            let nx2 = tok_text(toks, i + 2);
            let mut hit: Option<String> = None;
            if t.kind == TokKind::Ident && t.text == "Vec" && nxt == "::" && nx2 == "new" {
                hit = Some("Vec::new".to_string());
            } else if t.kind == TokKind::Ident && t.text == "vec" && nxt == "!" {
                hit = Some("vec!".to_string());
            } else if t.kind == TokKind::Ident && t.text == "String" && nxt == "::" && nx2 == "from"
            {
                hit = Some("String::from".to_string());
            } else if t.kind == TokKind::Ident && t.text == "format" && nxt == "!" {
                hit = Some("format!".to_string());
            } else if t.kind == TokKind::Ident && t.text == "Box" && nxt == "::" && nx2 == "new" {
                hit = Some("Box::new".to_string());
            } else if t.text == "." {
                if let Some(nt) = toks.get(i + 1) {
                    if nt.kind == TokKind::Ident && ALLOC_CALLS.contains(&nt.text.as_str()) {
                        hit = Some(format!(".{}", nt.text));
                    }
                }
            }
            if let Some(h) = hit {
                findings.push(Finding::new(
                    "no-alloc-hot-path",
                    rel,
                    t.line,
                    format!("{h} in hot fn `{}` (mark `// lint: cold` or waive)", f.name),
                ));
            }
            i += 1;
        }
    }
}

// ---- R2: total-ordering ---------------------------------------------------

/// `open_paren` indexes '('; true when the argument tokens contain a float
/// literal or an .abs()/.sqrt() call — the distance-typed heuristic.
fn call_args_float(toks: &[Tok], open_paren: usize) -> bool {
    let mut depth = 0i32;
    let mut i = open_paren;
    let n = toks.len();
    while i < n {
        let t = &toks[i];
        if t.text == "(" {
            depth += 1;
        } else if t.text == ")" {
            depth -= 1;
            if depth == 0 {
                return false;
            }
        } else if t.kind == TokKind::FNum {
            return true;
        } else if t.text == "." && matches!(tok_text(toks, i + 1), "abs" | "sqrt") {
            return true;
        }
        i += 1;
    }
    false
}

pub fn r2_total_ordering(fm: &FileModel, findings: &mut Vec<Finding>) {
    let toks = &fm.toks;
    for f in &fm.fns {
        if !f.is_scanned() {
            continue;
        }
        let mut i = f.body_start as usize;
        while i <= f.body_end {
            let t = &toks[i];
            let nxt = tok_text(toks, i + 1);
            let nx2 = tok_text(toks, i + 2);
            let nxt_is_ident = toks.get(i + 1).map(|n| n.kind == TokKind::Ident).unwrap_or(false);
            if t.text == "." && nxt_is_ident {
                if nxt == "partial_cmp" {
                    findings.push(Finding::new(
                        "total-ordering",
                        &fm.path,
                        t.line,
                        ".partial_cmp on distances — use total_cmp".to_string(),
                    ));
                } else if (nxt == "max" || nxt == "min")
                    && nx2 == "("
                    && call_args_float(toks, i + 2)
                {
                    findings.push(Finding::new(
                        "total-ordering",
                        &fm.path,
                        t.line,
                        format!(".{nxt}(..) with float argument — use total_cmp selection"),
                    ));
                }
            } else if t.kind == TokKind::Ident
                && (t.text == "f32" || t.text == "f64")
                && nxt == "::"
                && (nx2 == "max" || nx2 == "min")
            {
                findings.push(Finding::new(
                    "total-ordering",
                    &fm.path,
                    t.line,
                    format!("{}::{nx2} as fn value — use total_cmp selection", t.text),
                ));
            }
            i += 1;
        }
    }
}

// ---- R3: panic-free-decode ------------------------------------------------

fn ret_is_wire_result(f: &FnModel) -> bool {
    f.ret.iter().any(|t| t == "Result") && f.ret.iter().any(|t| t == "WireError")
}

pub fn r3_panic_free(fm: &FileModel, findings: &mut Vec<Finding>) {
    let toks = &fm.toks;
    let file_scope = R3_FILES.contains(&fm.path.as_str());
    for f in &fm.fns {
        if !f.is_scanned() {
            continue;
        }
        let wire = ret_is_wire_result(f);
        if !(wire || file_scope) {
            continue;
        }
        let ctx = if wire { "WireError decoder" } else { "serve runtime" };
        let mut i = f.body_start as usize;
        while i <= f.body_end {
            let t = &toks[i];
            let nxt = tok_text(toks, i + 1);
            let nxt_is_ident = toks.get(i + 1).map(|n| n.kind == TokKind::Ident).unwrap_or(false);
            if t.text == "." && nxt_is_ident && (nxt == "unwrap" || nxt == "expect") {
                findings.push(Finding::new(
                    "panic-free-decode",
                    &fm.path,
                    t.line,
                    format!(".{nxt} in {ctx} — return a typed error"),
                ));
            } else if t.kind == TokKind::Ident
                && nxt == "!"
                && (PANIC_MACROS.contains(&t.text.as_str())
                    || (wire && ASSERT_MACROS.contains(&t.text.as_str())))
            {
                findings.push(Finding::new(
                    "panic-free-decode",
                    &fm.path,
                    t.line,
                    format!("{}! in {ctx} — return a typed error", t.text),
                ));
            } else if wire && t.text == "[" && i > f.body_start as usize {
                let prev = &toks[i - 1];
                if prev.kind == TokKind::Ident || prev.text == ")" || prev.text == "]" {
                    findings.push(Finding::new(
                        "panic-free-decode",
                        &fm.path,
                        t.line,
                        "indexing in WireError decoder — use .get()/try_take".to_string(),
                    ));
                }
            }
            i += 1;
        }
    }
}

// ---- R4: harness-registration ---------------------------------------------

const DECODER_EXACT: [&str; 3] = ["try_from_bytes", "from_bytes", "try_from_snapshot_bytes"];

fn is_decoder(f: &FnModel) -> bool {
    if f.in_trait || f.is_test {
        return false;
    }
    let nm = f.name.as_str();
    let named = DECODER_EXACT.contains(&nm)
        || nm.ends_with("_from_bytes")
        || (nm.starts_with("decode_") && ret_is_wire_result(f));
    if !named {
        return false;
    }
    // exactly one parameter, and it mentions u8 (i.e. &[u8]), not self
    let mut depth = 0i32;
    let mut commas = 0usize;
    let mut has_any = false;
    for t in &f.params {
        has_any = true;
        match t.text.as_str() {
            "(" | "[" | "<" => depth += 1,
            ")" | "]" | ">" => depth -= 1,
            "," if depth == 0 => commas += 1,
            _ => {}
        }
    }
    if !has_any || commas != 0 {
        return false;
    }
    if !f.params.iter().any(|t| t.text == "u8") {
        return false;
    }
    if f.params.iter().any(|t| t.text == "self") {
        return false;
    }
    true
}

pub fn r4_registration(
    files: &[FileModel],
    registry_idents: &HashSet<String>,
    findings: &mut Vec<Finding>,
) {
    for fm in files {
        for f in &fm.fns {
            if f.body_start < 0 || !is_decoder(f) {
                continue;
            }
            let name_ok = registry_idents.contains(&f.name);
            let type_ok =
                f.impl_type.as_ref().map(|t| registry_idents.contains(t)).unwrap_or(true);
            if !(name_ok && type_ok) {
                let who = match &f.impl_type {
                    Some(t) => format!("{t}::{}", f.name),
                    None => f.name.clone(),
                };
                findings.push(Finding::new(
                    "harness-registration",
                    &fm.path,
                    f.sig_line,
                    format!("decoder `{who}` is not exercised by tests/wire_adversarial.rs"),
                ));
            }
        }
    }
}

// ---- R5: config-doc-parity ------------------------------------------------

fn is_config_key(s: &str) -> bool {
    if s.is_empty() {
        return false;
    }
    for part in s.split('.') {
        let mut chars = part.chars();
        match chars.next() {
            Some(c) if c.is_ascii_lowercase() => {}
            _ => return false,
        }
        if !part.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_') {
            return false;
        }
    }
    true
}

fn boundary_char(c: char) -> bool {
    // Not ident-continuation and not '.': a dotted-key boundary.
    !(c == '_' || c == '.' || c.is_ascii_alphanumeric())
}

fn word_bounded(doc: &str, key: &str) -> bool {
    let mut start = 0usize;
    while let Some(off) = doc[start..].find(key) {
        let idx = start + off;
        let before = doc[..idx].chars().next_back().unwrap_or(' ');
        let after = doc[idx + key.len()..].chars().next().unwrap_or(' ');
        if boundary_char(before) && boundary_char(after) {
            return true;
        }
        start = idx + 1;
    }
    false
}

pub fn r5_config_docs(fm: &FileModel, docs_text: &str, findings: &mut Vec<Finding>) {
    if !fm.path.starts_with("config/") {
        return;
    }
    let toks = &fm.toks;
    for f in &fm.fns {
        if !f.is_scanned() {
            continue;
        }
        let mut i = f.body_start as usize;
        while i <= f.body_end {
            let t = &toks[i];
            if t.kind == TokKind::Str && i + 1 <= f.body_end && toks[i + 1].text == "=>" {
                let lit = t.text.as_str();
                if lit.len() >= 2 && lit.starts_with('"') && lit.ends_with('"') {
                    let key = &lit[1..lit.len() - 1];
                    if is_config_key(key) && !word_bounded(docs_text, key) {
                        findings.push(Finding::new(
                            "config-doc-parity",
                            &fm.path,
                            t.line,
                            format!("config key \"{key}\" is not documented in README.md/DESIGN.md"),
                        ));
                    }
                }
            }
            i += 1;
        }
    }
}

// ---- Waiver application ---------------------------------------------------

/// Mark findings in `fm` waived per its directives; emit `lint-directive`
/// findings for malformed or unused directives.
pub fn apply_waivers(fm: &mut FileModel, findings: &mut Vec<Finding>) {
    let mine: Vec<usize> = findings
        .iter()
        .enumerate()
        .filter(|(_, f)| f.file == fm.path && KNOWN_RULES.contains(&f.rule))
        .map(|(i, _)| i)
        .collect();
    let mut extra: Vec<Finding> = Vec::new();
    for d in fm.directives.iter_mut() {
        if d.kind == DirKind::Bad {
            extra.push(Finding::new("lint-directive", &fm.path, d.line, d.error.clone()));
            continue;
        }
        if d.kind == DirKind::Cold {
            if !d.used {
                extra.push(Finding::new(
                    "lint-directive",
                    &fm.path,
                    d.line,
                    "`lint: cold` marker does not precede a fn".to_string(),
                ));
            }
            continue;
        }
        // allow(...)
        let mut scope_fn: Option<&FnModel> = None;
        if d.standalone {
            for f in &fm.fns {
                if f.item_start as isize <= d.next_tok && d.next_tok <= f.header_end() {
                    scope_fn = Some(f);
                    break;
                }
            }
        }
        let lines: (i64, i64) = if let Some(f) = scope_fn {
            (f.sig_line as i64, f.body_end_line as i64)
        } else if d.standalone {
            let nxt_line = if d.next_tok >= 0 && (d.next_tok as usize) < fm.toks.len() {
                fm.toks[d.next_tok as usize].line as i64
            } else {
                -1
            };
            (nxt_line, nxt_line)
        } else {
            (d.line as i64, d.line as i64)
        };
        let mut hit = false;
        for &idx in &mine {
            let f = &mut findings[idx];
            if f.waived.is_none()
                && d.rules.iter().any(|r| r == f.rule)
                && lines.0 <= f.line as i64
                && (f.line as i64) <= lines.1
            {
                f.waived = Some(d.reason.clone());
                hit = true;
            }
        }
        if hit {
            d.used = true;
        } else {
            extra.push(Finding::new(
                "lint-directive",
                &fm.path,
                d.line,
                format!("unused waiver for {} — remove it", d.rules.join(",")),
            ));
        }
    }
    findings.extend(extra);
}
