//! `neargraph::lint` — a zero-dependency source-level invariant checker.
//!
//! The crate's hot-path, ordering, and wire-safety disciplines are easy to
//! state and easy to erode: one `.max(0.0)` on a distance reintroduces the
//! NaN-absorbing IEEE semantics the traversal code was debugged away from,
//! one `.unwrap()` in a decoder turns adversarial bytes into a panic, and a
//! decoder that never gets registered in the adversarial harness is an
//! untested attack surface. This module scans `rust/src` at the token level
//! (comment- and string-aware — no regexes over raw text) and enforces five
//! rules (DESIGN.md §12):
//!
//! * `no-alloc-hot-path` — bans `Vec::new` / `vec!` / `.collect` / `.to_vec`
//!   / `.clone` / `String::from` / `format!` / `Box::new` inside the hot
//!   modules (`covertree/{query,layout,scratch,knn}.rs`, `metric/*`,
//!   `serve/engine.rs`) except in fns marked `// lint: cold`.
//! * `total-ordering` — bans `.partial_cmp`, `f32/f64::max|min` paths, and
//!   `.max(..)`/`.min(..)` with float-looking arguments, crate-wide.
//! * `panic-free-decode` — bans `.unwrap`/`.expect`/panic-family macros in
//!   any fn returning `Result<_, WireError>` and in `serve/{protocol,
//!   server}.rs`; the `WireError` fns additionally ban assert-family macros
//!   and `[`-indexing.
//! * `harness-registration` — every wire decoder must be exercised by
//!   `tests/wire_adversarial.rs` (impl-type ident and method ident).
//! * `config-doc-parity` — every `"key" =>` match arm in `config/` must be
//!   documented word-bounded in README.md or DESIGN.md.
//!
//! Violations are waived in place with
//! `// lint: allow(<rules>) reason="..."` — trailing on the offending line,
//! standalone above it, or standalone above a fn header (fn-wide scope).
//! Malformed or unused directives are themselves findings (rule
//! `lint-directive`) so waiver creep shows up in review.
//!
//! `python/neargraph_lint.py` is the executable mirror that runs in the
//! toolchain-free growth container and produced the committed
//! `LINT_REPORT.json`; this module is its line-for-line Rust port and
//! `tests/lint_selftest.rs` holds the two equivalent over the shared
//! fixture corpus in `tests/lint_fixtures/`.

pub mod parse;
pub mod rules;
pub mod tokenize;

use std::collections::HashSet;
use std::io;
use std::path::{Path, PathBuf};

use parse::{parse_file, DirKind, FileModel};
use rules::{
    apply_waivers, r1_hot_alloc, r2_total_ordering, r3_panic_free, r4_registration, r5_config_docs,
};
use tokenize::{tokenize, TokKind};

/// Rule names a waiver may reference.
pub const KNOWN_RULES: [&str; 5] = [
    "no-alloc-hot-path",
    "total-ordering",
    "panic-free-decode",
    "harness-registration",
    "config-doc-parity",
];

/// Files where `no-alloc-hot-path` applies (paths relative to the scan
/// root), plus prefix-matched directories.
pub const HOT_FILES: [&str; 7] = [
    "covertree/query.rs",
    "covertree/layout.rs",
    "covertree/scratch.rs",
    "covertree/knn.rs",
    "covertree/epoch.rs",
    "covertree/dualtree.rs",
    "serve/engine.rs",
];
pub const HOT_PREFIXES: [&str; 1] = ["metric/"];

/// Files where `panic-free-decode` applies to every fn, not just the
/// `WireError`-returning ones.
pub const R3_FILES: [&str; 2] = ["serve/protocol.rs", "serve/server.rs"];

/// One rule violation (or directive problem), with the waiver reason when a
/// matching `lint: allow` covered it.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
    pub waived: Option<String>,
}

impl Finding {
    pub(crate) fn new(rule: &'static str, file: &str, line: u32, message: String) -> Self {
        Finding { rule, file: file.to_string(), line, message, waived: None }
    }
}

/// A used waiver, inventoried into the JSON report (and checked against the
/// committed report by `perf_driver`).
#[derive(Clone, Debug)]
pub struct Waiver {
    pub file: String,
    pub line: u32,
    pub rules: Vec<String>,
    pub reason: String,
}

pub fn used_waivers(files: &[FileModel]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for fm in files {
        for d in &fm.directives {
            if d.kind == DirKind::Allow && d.used {
                out.push(Waiver {
                    file: fm.path.clone(),
                    line: d.line,
                    rules: d.rules.clone(),
                    reason: d.reason.clone(),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree scanning
// ---------------------------------------------------------------------------

/// Collect `.rs` files under `root` in the mirror's deterministic order:
/// each directory's files sorted, then its subdirectories sorted,
/// recursively.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut files: Vec<PathBuf> = Vec::new();
    let mut dirs: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(root)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            dirs.push(path);
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            files.push(path);
        }
    }
    files.sort();
    dirs.sort();
    out.extend(files);
    for d in dirs {
        collect_rs(&d, out)?;
    }
    Ok(())
}

fn registry_idents_from(text: &str) -> HashSet<String> {
    let (toks, _) = tokenize(text);
    toks.into_iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text).collect()
}

fn run_rules(
    files: &mut [FileModel],
    registry_idents: &HashSet<String>,
    docs_text: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for fm in files.iter() {
        r1_hot_alloc(fm, &mut findings);
        r2_total_ordering(fm, &mut findings);
        r3_panic_free(fm, &mut findings);
        r5_config_docs(fm, docs_text, &mut findings);
    }
    r4_registration(files, registry_idents, &mut findings);
    for fm in files.iter_mut() {
        apply_waivers(fm, &mut findings);
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    findings
}

/// Scan every `.rs` file under `src_root` and return the parsed models plus
/// the sorted findings (waivers already applied).
pub fn scan_tree(
    src_root: &Path,
    registry_path: Option<&Path>,
    docs_text: &str,
) -> io::Result<(Vec<FileModel>, Vec<Finding>)> {
    let mut paths = Vec::new();
    collect_rs(src_root, &mut paths)?;
    let mut files = Vec::new();
    for path in &paths {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let text = std::fs::read_to_string(path)?;
        files.push(parse_file(&rel, &text));
    }
    let registry_idents = match registry_path {
        Some(rp) if rp.exists() => registry_idents_from(&std::fs::read_to_string(rp)?),
        _ => HashSet::new(),
    };
    let findings = run_rules(&mut files, &registry_idents, docs_text);
    Ok((files, findings))
}

// ---------------------------------------------------------------------------
// Fixture corpus
// ---------------------------------------------------------------------------

/// First-line `// lint-fixture: virtual=<path>` header of a fixture file.
pub fn fixture_virtual_path(text: &str) -> Option<String> {
    for raw in text.lines() {
        let line = raw.trim();
        if let Some(stripped) = line.strip_prefix("//") {
            let body = stripped.trim_start_matches('/').trim_start_matches('!').trim();
            if let Some(rest) = body.strip_prefix("lint-fixture:") {
                if let Some(v) = rest.trim().strip_prefix("virtual=") {
                    return Some(v.trim().to_string());
                }
            }
        } else if !line.is_empty() {
            break;
        }
    }
    None
}

/// `//~ rule-a, rule-b` trailing expectation comments in a fixture.
pub fn fixture_expectations(fm: &FileModel) -> Vec<(String, u32, String)> {
    let mut exp = Vec::new();
    for cm in &fm.comments {
        if let Some(rest) = cm.text.strip_prefix('~') {
            for nm in rest.split(',') {
                let nm = nm.trim();
                if !nm.is_empty() {
                    exp.push((fm.path.clone(), cm.line, nm.to_string()));
                }
            }
        }
    }
    exp
}

#[derive(Debug)]
pub struct FixtureOutcome {
    pub expected: Vec<(String, u32, String)>,
    pub actual: Vec<(String, u32, String)>,
    pub ok: bool,
}

/// Run the rules over the fixture corpus: each `.rs` carries a
/// `// lint-fixture: virtual=<path>` header naming the path it plays;
/// `DOCS.md` is the doc corpus; the file playing
/// `tests/wire_adversarial.rs` is the registry. The unwaived findings must
/// equal the `//~` expectations exactly.
pub fn scan_fixtures(fixture_root: &Path) -> io::Result<FixtureOutcome> {
    let docs_path = fixture_root.join("DOCS.md");
    let docs_text = if docs_path.exists() {
        std::fs::read_to_string(&docs_path)?
    } else {
        String::new()
    };
    let mut paths = Vec::new();
    collect_rs(fixture_root, &mut paths)?;
    let mut files = Vec::new();
    let mut registry_idents = HashSet::new();
    let mut expected = Vec::new();
    for path in &paths {
        let text = std::fs::read_to_string(path)?;
        let virtual_path = fixture_virtual_path(&text).unwrap_or_else(|| {
            path.file_name().map(|f| f.to_string_lossy().into_owned()).unwrap_or_default()
        });
        if virtual_path == "tests/wire_adversarial.rs" {
            registry_idents = registry_idents_from(&text);
            continue;
        }
        let fm = parse_file(&virtual_path, &text);
        expected.extend(fixture_expectations(&fm));
        files.push(fm);
    }
    let findings = run_rules(&mut files, &registry_idents, &docs_text);
    let mut actual: Vec<(String, u32, String)> = findings
        .iter()
        .filter(|f| f.waived.is_none())
        .map(|f| (f.file.clone(), f.line, f.rule.to_string()))
        .collect();
    actual.sort();
    expected.sort();
    expected.dedup();
    let ok = expected == actual;
    Ok(FixtureOutcome { expected, actual, ok })
}

// ---------------------------------------------------------------------------
// JSON report
// ---------------------------------------------------------------------------

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the machine-readable report (same schema as the committed
/// `LINT_REPORT.json`, which the Python mirror generates).
pub fn render_report(
    src: &str,
    files: &[FileModel],
    findings: &[Finding],
    fixtures: Option<&FixtureOutcome>,
) -> String {
    let unwaived = findings.iter().filter(|f| f.waived.is_none()).count();
    let waivers = used_waivers(files);
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"version\": 1,\n");
    out.push_str("  \"generator\": \"rust/src/lint\",\n");
    out.push_str(&format!("  \"src\": \"{}\",\n", json_escape(src)));
    out.push_str(&format!("  \"files_scanned\": {},\n", files.len()));
    out.push_str(&format!(
        "  \"fns_scanned\": {},\n",
        files.iter().map(|fm| fm.fns.len()).sum::<usize>()
    ));
    out.push_str(&format!("  \"findings_unwaived\": {unwaived},\n"));
    out.push_str(&format!("  \"waiver_count\": {},\n", waivers.len()));
    out.push_str("  \"waivers\": [");
    for (i, w) in waivers.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let rules: Vec<String> =
            w.rules.iter().map(|r| format!("\"{}\"", json_escape(r))).collect();
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rules\": [{}], \"reason\": \"{}\"}}",
            json_escape(&w.file),
            w.line,
            rules.join(", "),
            json_escape(&w.reason)
        ));
    }
    out.push_str(if waivers.is_empty() { "],\n" } else { "\n  ],\n" });
    out.push_str("  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let waived = match &f.waived {
            Some(r) => format!("\"{}\"", json_escape(r)),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\", \"waived\": {}}}",
            json_escape(f.rule),
            json_escape(&f.file),
            f.line,
            json_escape(&f.message),
            waived
        ));
    }
    out.push_str(if findings.is_empty() { "]" } else { "\n  ]" });
    if let Some(fx) = fixtures {
        out.push_str(&format!(
            ",\n  \"fixtures\": {{\"expected\": {}, \"actual\": {}, \"matched\": {}}}",
            fx.expected.len(),
            fx.actual.len(),
            fx.ok
        ));
    }
    out.push_str("\n}\n");
    out
}

// ---------------------------------------------------------------------------
// CLI driver (shared by `neargraph lint` and `examples/lint_driver.rs`)
// ---------------------------------------------------------------------------

pub const LINT_USAGE: &str = "usage: lint [--src rust/src] [--registry <file>] \
[--docs <file>]... [--json <out>] [--fixtures <dir>] [--deny-warnings] [--quiet]";

/// Parse the mirror's CLI flags and run. Returns the process exit code:
/// 0 clean, 1 when `--deny-warnings` and there are unwaived findings or a
/// fixture mismatch, 2 on a bad flag.
pub fn main_from_args(args: &[String]) -> io::Result<i32> {
    let mut src = "rust/src".to_string();
    let mut registry: Option<PathBuf> = None;
    let mut docs: Vec<PathBuf> = Vec::new();
    let mut json_out: Option<PathBuf> = None;
    let mut fixtures: Option<PathBuf> = None;
    let mut deny = false;
    let mut quiet = false;
    let mut i = 0usize;
    while i < args.len() {
        let a = args[i].as_str();
        let take = |i: &mut usize| -> Option<String> {
            *i += 1;
            args.get(*i).cloned()
        };
        match a {
            "--src" => match take(&mut i) {
                Some(v) => src = v,
                None => return missing_value(a),
            },
            "--registry" => match take(&mut i) {
                Some(v) => registry = Some(PathBuf::from(v)),
                None => return missing_value(a),
            },
            "--docs" => match take(&mut i) {
                Some(v) => docs.push(PathBuf::from(v)),
                None => return missing_value(a),
            },
            "--json" => match take(&mut i) {
                Some(v) => json_out = Some(PathBuf::from(v)),
                None => return missing_value(a),
            },
            "--fixtures" => match take(&mut i) {
                Some(v) => fixtures = Some(PathBuf::from(v)),
                None => return missing_value(a),
            },
            "--deny-warnings" => deny = true,
            "--quiet" => quiet = true,
            other => {
                eprintln!("unknown arg {other}\n{LINT_USAGE}");
                return Ok(2);
            }
        }
        i += 1;
    }

    let src_abs = if Path::new(&src).is_absolute() {
        PathBuf::from(&src)
    } else {
        std::env::current_dir()?.join(&src)
    };
    let crate_root = src_abs.parent().map(Path::to_path_buf).unwrap_or_else(|| src_abs.clone());
    let repo_root =
        crate_root.parent().map(Path::to_path_buf).unwrap_or_else(|| crate_root.clone());
    let registry =
        registry.unwrap_or_else(|| crate_root.join("tests").join("wire_adversarial.rs"));
    if docs.is_empty() {
        docs.push(repo_root.join("README.md"));
        docs.push(repo_root.join("DESIGN.md"));
    }
    let mut docs_text = String::new();
    for d in &docs {
        if d.exists() {
            docs_text.push_str(&std::fs::read_to_string(d)?);
            docs_text.push('\n');
        }
    }

    let (files, findings) = scan_tree(&src_abs, Some(&registry), &docs_text)?;
    let unwaived = findings.iter().filter(|f| f.waived.is_none()).count();
    let waived = findings.len() - unwaived;

    let fixture_outcome = match &fixtures {
        Some(root) => {
            let fx = scan_fixtures(root)?;
            if !fx.ok {
                for e in fx.expected.iter().filter(|e| !fx.actual.contains(e)) {
                    eprintln!("fixture MISSING {}:{} {}", e.0, e.1, e.2);
                }
                for s in fx.actual.iter().filter(|a| !fx.expected.contains(a)) {
                    eprintln!("fixture SURPLUS {}:{} {}", s.0, s.1, s.2);
                }
            }
            Some(fx)
        }
        None => None,
    };

    if !quiet {
        for f in &findings {
            let tag = match &f.waived {
                Some(r) => format!("waived({r})"),
                None => "DENY".to_string(),
            };
            println!("{}:{} [{}] {} {}", f.file, f.line, f.rule, f.message, tag);
        }
        println!(
            "lint: {} file(s), {} fn(s), {} finding(s) ({} waived, {} unwaived)",
            files.len(),
            files.iter().map(|fm| fm.fns.len()).sum::<usize>(),
            findings.len(),
            waived,
            unwaived
        );
        if let Some(fx) = &fixture_outcome {
            println!("fixtures: {}", if fx.ok { "ok" } else { "MISMATCH" });
        }
    }

    if let Some(out_path) = &json_out {
        let report = render_report(&src, &files, &findings, fixture_outcome.as_ref());
        std::fs::write(out_path, report)?;
    }

    let bad = unwaived > 0 || fixture_outcome.as_ref().map(|fx| !fx.ok).unwrap_or(false);
    if deny && bad {
        return Ok(1);
    }
    Ok(0)
}

fn missing_value(flag: &str) -> io::Result<i32> {
    eprintln!("{flag} expects a value\n{LINT_USAGE}");
    Ok(2)
}
