//! Source model for `neargraph::lint`: directives, functions with their
//! impl/trait context, and `#[cfg(test)]` line regions.
//!
//! Like the tokenizer, this is a port of the corresponding section of
//! `python/neargraph_lint.py` and must stay semantically identical to it.

use std::collections::HashSet;

use super::tokenize::{tokenize, Comment, Tok, TokKind};
use super::KNOWN_RULES;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DirKind {
    Cold,
    Allow,
    Bad,
}

/// A parsed `// lint: ...` directive. Malformed ones keep `kind: Bad` and
/// carry the diagnostic in `error`; the waiver pass turns those (and any
/// directive that never matched a finding) into `lint-directive` findings
/// so waiver creep stays visible in review.
#[derive(Clone, Debug)]
pub struct Directive {
    pub kind: DirKind,
    pub rules: Vec<String>,
    pub reason: String,
    pub line: u32,
    pub standalone: bool,
    pub next_tok: isize,
    pub used: bool,
    pub error: String,
}

pub fn parse_directives(comments: &[Comment]) -> Vec<Directive> {
    let mut out = Vec::new();
    for cm in comments {
        let t = cm.text.as_str();
        if !t.starts_with("lint:") {
            continue;
        }
        let body = t[5..].trim();
        let mut d = Directive {
            kind: DirKind::Bad,
            rules: Vec::new(),
            reason: String::new(),
            line: cm.line,
            standalone: cm.standalone,
            next_tok: cm.next_tok,
            used: false,
            error: String::new(),
        };
        if body == "cold" {
            d.kind = DirKind::Cold;
        } else if let Some(after) = body.strip_prefix("allow") {
            let rest = after.trim_start();
            if !rest.starts_with('(') {
                d.error = "expected '(' after allow".to_string();
            } else {
                match rest.find(')') {
                    None => d.error = "unclosed allow(...)".to_string(),
                    Some(close) => {
                        let names: Vec<String> = rest[1..close]
                            .split(',')
                            .map(str::trim)
                            .filter(|nm| !nm.is_empty())
                            .map(str::to_string)
                            .collect();
                        let bad = names.iter().find(|nm| !KNOWN_RULES.contains(&nm.as_str()));
                        let tail = rest[close + 1..].trim();
                        if names.is_empty() {
                            d.error = "allow() lists no rules".to_string();
                        } else if let Some(b) = bad {
                            d.error = format!("unknown rule '{b}'");
                        } else if !tail.starts_with("reason=\"") {
                            d.error = "waiver missing reason=\"...\"".to_string();
                        } else {
                            let endq = tail[8..].find('"').map(|p| p + 8);
                            let reason = match endq {
                                Some(e) if e > 8 => tail[8..e].to_string(),
                                _ => String::new(),
                            };
                            if reason.trim().is_empty() {
                                d.error = "waiver reason is empty".to_string();
                            } else {
                                d.kind = DirKind::Allow;
                                d.rules = names;
                                d.reason = reason;
                            }
                        }
                    }
                }
            }
        } else {
            let first = body.split(' ').next().unwrap_or("");
            d.error = format!("unknown lint directive '{first}'");
        }
        out.push(d);
    }
    out
}

/// A function item: name, impl/trait context, parameter and return-type
/// tokens, and the token range of its body (`body_start == -1` for
/// declaration-only trait methods).
#[derive(Clone, Debug, Default)]
pub struct FnModel {
    pub name: String,
    pub impl_type: Option<String>,
    pub in_trait: bool,
    pub is_test: bool,
    pub is_cold: bool,
    pub params: Vec<Tok>,
    pub ret: Vec<String>,
    pub item_start: usize,
    pub fn_kw: usize,
    pub body_start: isize,
    pub body_end: usize,
    pub sig_line: u32,
    pub body_end_line: u32,
}

impl FnModel {
    /// A fn the body rules scan: non-test, with a body.
    pub fn is_scanned(&self) -> bool {
        !self.is_test && self.body_start >= 0
    }

    /// Upper token bound for "a standalone directive anchored inside this
    /// fn's header": the body brace when there is one, a short window past
    /// the `fn` keyword for declaration-only methods.
    pub fn header_end(&self) -> isize {
        if self.body_start >= 0 {
            self.body_start
        } else {
            self.fn_kw as isize + 4
        }
    }
}

#[derive(Debug, Default)]
pub struct FileModel {
    /// Path relative to the scan root, '/'-separated (the rules key on it).
    pub path: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    pub directives: Vec<Directive>,
    pub fns: Vec<FnModel>,
    /// Lines inside `#[cfg(test)] mod` bodies.
    pub test_lines: HashSet<u32>,
}

/// `i` points at '{'; returns the index of the matching '}' (or the last
/// token on unbalanced input).
fn match_brace(toks: &[Tok], mut i: usize) -> usize {
    let mut depth = 0i32;
    let n = toks.len();
    while i < n {
        let t = toks[i].text.as_str();
        if t == "{" {
            depth += 1;
        } else if t == "}" {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    n.saturating_sub(1)
}

/// `i` points at '<'; returns the index just past the matching '>'.
fn skip_angles(toks: &[Tok], mut i: usize) -> usize {
    let mut depth = 0i32;
    let n = toks.len();
    while i < n {
        let t = toks[i].text.as_str();
        if t == "<" {
            depth += 1;
        } else if t == ">" {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        } else if t == "{" || t == ";" {
            return i; // malformed; bail
        }
        i += 1;
    }
    n
}

/// `i` points at '#'; returns (end index exclusive, identifiers inside the
/// attribute brackets).
fn attr_info(toks: &[Tok], i: usize) -> (usize, Vec<String>) {
    let n = toks.len();
    let mut j = i + 1;
    if j < n && toks[j].text == "!" {
        j += 1;
    }
    if j >= n || toks[j].text != "[" {
        return (i + 1, Vec::new());
    }
    let mut depth = 0i32;
    let mut idents = Vec::new();
    while j < n {
        let t = &toks[j];
        if t.text == "[" {
            depth += 1;
        } else if t.text == "]" {
            depth -= 1;
            if depth == 0 {
                return (j + 1, idents);
            }
        } else if t.kind == TokKind::Ident {
            idents.push(t.text.clone());
        }
        j += 1;
    }
    (n, idents)
}

/// Walk back from the `fn` keyword over visibility/qualifiers/attributes to
/// the first token of the item.
fn item_start(toks: &[Tok], fn_kw: usize) -> usize {
    let mut j = fn_kw as isize - 1;
    while j >= 0 {
        let ju = j as usize;
        let t = toks[ju].text.as_str();
        if matches!(t, "pub" | "unsafe" | "const" | "async" | "default" | "extern") {
            j -= 1;
        } else if toks[ju].kind == TokKind::Str && ju >= 1 && toks[ju - 1].text == "extern" {
            j -= 1;
        } else if t == ")" {
            // pub(crate) / pub(in path)
            let mut depth = 0i32;
            let mut k = j;
            while k >= 0 {
                let kt = toks[k as usize].text.as_str();
                if kt == ")" {
                    depth += 1;
                } else if kt == "(" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k -= 1;
            }
            j = k - 1;
        } else if t == "]" {
            // attribute group
            let mut depth = 0i32;
            let mut k = j;
            while k >= 0 {
                let kt = toks[k as usize].text.as_str();
                if kt == "]" {
                    depth += 1;
                } else if kt == "[" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k -= 1;
            }
            if k >= 1 && toks[k as usize - 1].text == "#" {
                j = k - 2;
            } else {
                break;
            }
        } else {
            break;
        }
    }
    (j + 1) as usize
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Scope {
    Impl,
    Trait,
    Mod,
    ModTest,
    FnBody,
}

pub fn parse_file(path: &str, text: &str) -> FileModel {
    let mut fm = FileModel { path: path.to_string(), ..FileModel::default() };
    let (toks, comments) = tokenize(text);
    fm.directives = parse_directives(&comments);
    fm.comments = comments;
    let n = toks.len();

    // context stack: (kind, name, depth at open); depth counts '{'
    let mut stack: Vec<(Scope, String, i32)> = Vec::new();
    let mut depth = 0i32;
    let mut pending_attrs: Vec<String> = Vec::new();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        let txt = t.text.as_str();
        if txt == "#" {
            let (end, idents) = attr_info(&toks, i);
            pending_attrs.extend(idents);
            i = end;
            continue;
        }
        if txt == "{" {
            depth += 1;
            pending_attrs.clear();
            i += 1;
            continue;
        }
        if txt == "}" {
            depth -= 1;
            while stack.last().map(|s| s.2 > depth).unwrap_or(false) {
                stack.pop();
            }
            i += 1;
            continue;
        }
        if txt == "impl" && t.kind == TokKind::Ident {
            let mut j = i + 1;
            if j < n && toks[j].text == "<" {
                j = skip_angles(&toks, j);
            }
            // collect header tokens until '{' or ';' at angle depth 0
            let mut run: Vec<usize> = Vec::new();
            let mut angle = 0i32;
            while j < n {
                let tt = toks[j].text.as_str();
                if tt == "<" {
                    angle += 1;
                } else if tt == ">" {
                    angle -= 1;
                } else if angle == 0 && (tt == "{" || tt == ";" || tt == "where") {
                    break;
                }
                run.push(j);
                j += 1;
            }
            // skip a where clause
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                // type name: after the last top-level 'for' if present
                let mut segs: &[usize] = &run;
                for k in (0..run.len()).rev() {
                    if toks[run[k]].text == "for" {
                        segs = &run[k + 1..];
                        break;
                    }
                }
                let mut name: Option<String> = None;
                for &ki in segs {
                    let tk = &toks[ki];
                    if tk.text == "<" {
                        break;
                    }
                    if tk.kind == TokKind::Ident && tk.text != "dyn" && tk.text != "mut" {
                        name = Some(tk.text.clone());
                    }
                }
                stack.push((Scope::Impl, name.unwrap_or_else(|| "?".to_string()), depth + 1));
                depth += 1;
            }
            i = j + 1;
            pending_attrs.clear();
            continue;
        }
        if txt == "trait" && t.kind == TokKind::Ident {
            let mut j = i + 1;
            let name = if j < n && toks[j].kind == TokKind::Ident {
                toks[j].text.clone()
            } else {
                "?".to_string()
            };
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                stack.push((Scope::Trait, name, depth + 1));
                depth += 1;
            }
            i = j + 1;
            pending_attrs.clear();
            continue;
        }
        if txt == "mod" && t.kind == TokKind::Ident {
            let mut j = i + 1;
            let is_test_mod = pending_attrs.iter().any(|a| a == "cfg")
                && pending_attrs.iter().any(|a| a == "test");
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                let in_test = is_test_mod || stack.iter().any(|s| s.0 == Scope::ModTest);
                let kind = if in_test { Scope::ModTest } else { Scope::Mod };
                if kind == Scope::ModTest {
                    let close = match_brace(&toks, j);
                    for ln in toks[j].line..=toks[close].line {
                        fm.test_lines.insert(ln);
                    }
                }
                stack.push((kind, String::new(), depth + 1));
                depth += 1;
            }
            i = j + 1;
            pending_attrs.clear();
            continue;
        }
        if txt == "fn" && t.kind == TokKind::Ident {
            let mut f = FnModel { fn_kw: i, body_start: -1, ..FnModel::default() };
            f.item_start = item_start(&toks, i);
            f.sig_line = toks[f.item_start].line;
            let has_test = pending_attrs.iter().any(|a| a == "test");
            let has_cfg = pending_attrs.iter().any(|a| a == "cfg");
            f.is_test = (has_test && !has_cfg) || stack.iter().any(|s| s.0 == Scope::ModTest);
            if has_cfg && has_test {
                f.is_test = true;
            }
            for sc in stack.iter().rev() {
                if sc.0 == Scope::Impl {
                    f.impl_type = Some(sc.1.clone());
                    break;
                }
                if sc.0 == Scope::Trait {
                    f.in_trait = true;
                    break;
                }
            }
            let mut j = i + 1;
            if j < n && toks[j].kind == TokKind::Ident {
                f.name = toks[j].text.clone();
                j += 1;
            }
            if j < n && toks[j].text == "<" {
                j = skip_angles(&toks, j);
            }
            if j < n && toks[j].text == "(" {
                let mut pd = 0i32;
                let j0 = j;
                while j < n {
                    if toks[j].text == "(" {
                        pd += 1;
                    } else if toks[j].text == ")" {
                        pd -= 1;
                        if pd == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
                f.params = toks[j0 + 1..j.min(n)].to_vec();
                j += 1;
            }
            if j < n && toks[j].text == "->" {
                j += 1;
                let mut angle = 0i32;
                while j < n {
                    let tt = toks[j].text.as_str();
                    if tt == "<" {
                        angle += 1;
                    } else if tt == ">" {
                        angle -= 1;
                    } else if angle <= 0 && (tt == "{" || tt == ";" || tt == "where") {
                        break;
                    }
                    f.ret.push(tt.to_string());
                    j += 1;
                }
            }
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                f.body_start = j as isize;
                f.body_end = match_brace(&toks, j);
                f.body_end_line = toks[f.body_end].line;
                let fname = f.name.clone();
                fm.fns.push(f);
                // walk *into* the body (nested fns are parsed too)
                depth += 1;
                stack.push((Scope::FnBody, fname, depth));
                i = j + 1;
            } else {
                f.body_end_line = toks[j.min(n - 1)].line;
                fm.fns.push(f);
                i = j + 1;
            }
            pending_attrs.clear();
            continue;
        }
        pending_attrs.clear();
        i += 1;
    }
    fm.toks = toks;

    // attach cold markers
    for d in fm.directives.iter_mut() {
        if d.kind != DirKind::Cold {
            continue;
        }
        for f in fm.fns.iter_mut() {
            if f.item_start as isize <= d.next_tok && d.next_tok <= f.header_end() {
                f.is_cold = true;
                d.used = true;
                break;
            }
        }
    }
    fm
}
