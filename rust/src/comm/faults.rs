//! Seeded, deterministic fault injection for the simulated comm runtime
//! (DESIGN.md §11). A [`FaultPlan`] turns every point-to-point `send`
//! into a lottery — drop, corrupt, duplicate, delay or deliver clean —
//! driven by one [`crate::util::Rng`] stream per rank, so a given
//! `(plan, seed, rank count)` replays **bit-identically** on every run.
//!
//! Recovery is sender-driven: each logical message is wrapped in a
//! sequence-numbered, FNV-1a-checksummed envelope and retransmitted
//! until one clean copy leaves the wire (bounded by [`MAX_ATTEMPTS`]);
//! the receiver discards corrupt copies (checksum mismatch) and
//! duplicate sequence numbers ([`EnvelopeStream`]), so the payload
//! stream delivered to the algorithm is byte-identical to the
//! fault-free run — only the virtual-time accounting (and therefore
//! the makespan) changes. Unsurvivable schedules — a rank killed at a
//! phase boundary, or a peer that never gets a clean copy through —
//! abort the world with a typed [`WorldAbort`] panic payload that the
//! dist driver catches and converts into a typed error; the shared
//! abort flag bounds every other rank's blocking receive.

use crate::covertree::fnv1a64;
use crate::points::{put_u64, try_get_u64, try_take, WireError};
use crate::util::Rng;
use std::collections::HashSet;

/// Retransmission bound per logical message: after this many faulted
/// attempts the sender declares the peer unreachable and aborts the
/// world (typed, bounded — never an unbounded retry loop).
pub const MAX_ATTEMPTS: u32 = 16;

/// A seeded fault schedule for one world. Probabilities are cumulative
/// lottery shares (validated to sum ≤ 1 at the config layer); the
/// remainder of the unit interval is clean delivery. `kill_rank` +
/// `kill_phase` kill one rank at the moment it enters the named phase
/// (any phase boundary when `kill_phase` is `None`).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// P(message vanishes in flight).
    pub drop: f64,
    /// P(one bit of the envelope flips in flight).
    pub corrupt: f64,
    /// P(message arrives twice).
    pub duplicate: f64,
    /// P(message is late by `delay_us` of virtual time).
    pub delay: f64,
    /// Virtual-time lateness of a delayed message, in microseconds.
    pub delay_us: u64,
    /// Seed of the fault lottery (forked per rank).
    pub seed: u64,
    /// Rank to kill at a phase boundary (`None` = nobody dies).
    pub kill_rank: Option<usize>,
    /// Phase whose entry kills `kill_rank` (`None` = first boundary).
    pub kill_phase: Option<String>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            drop: 0.0,
            corrupt: 0.0,
            duplicate: 0.0,
            delay: 0.0,
            delay_us: 100,
            seed: 0xFA17,
            kill_rank: None,
            kill_phase: None,
        }
    }
}

impl FaultPlan {
    /// Whether this plan can perturb anything at all — an all-zero plan
    /// routes through the fault-free fast path.
    pub fn any_faults(&self) -> bool {
        self.drop > 0.0
            || self.corrupt > 0.0
            || self.duplicate > 0.0
            || self.delay > 0.0
            || self.kill_rank.is_some()
    }
}

/// Per-rank fault event counters, merged across ranks into
/// `RunResult::faults` and surfaced in the perf-driver JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Messages the lottery vanished in flight.
    pub drops: u64,
    /// Envelopes that left the sender with a flipped bit.
    pub corrupts: u64,
    /// Envelopes delivered twice by the lottery.
    pub duplicates: u64,
    /// Retransmissions the sender performed (drops + corrupts).
    pub retries: u64,
    /// Duplicate sequence numbers discarded on receive.
    pub dup_discards: u64,
    /// Checksum-failed envelopes discarded on receive.
    pub corrupt_discards: u64,
    /// Total virtual-time lateness injected, in microseconds.
    pub delayed_us: u64,
}

impl FaultCounters {
    /// Fold another rank's counters into this one.
    pub fn merge(&mut self, other: &FaultCounters) {
        self.drops += other.drops;
        self.corrupts += other.corrupts;
        self.duplicates += other.duplicates;
        self.retries += other.retries;
        self.dup_discards += other.dup_discards;
        self.corrupt_discards += other.corrupt_discards;
        self.delayed_us += other.delayed_us;
    }

    /// Whether any fault event was recorded at all.
    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }
}

/// Typed panic payload for world-ending faults. Rank closures in the
/// dist driver catch these (`catch_unwind` + downcast) and convert them
/// into `DistError`; any other panic is a real bug and is re-raised.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorldAbort {
    /// The fault plan killed this rank at a phase boundary.
    Killed { rank: usize, phase: String },
    /// `MAX_ATTEMPTS` transmissions of one message all faulted.
    Unreachable { from: usize, to: usize },
    /// This rank observed the shared abort flag while blocked.
    Aborted { rank: usize },
}

/// Install (once, process-wide) a panic-hook wrapper that suppresses
/// the default "thread panicked" stderr spew for [`WorldAbort`]
/// payloads — those are typed control flow, not bugs. All other panics
/// keep the previous hook's output.
pub(crate) fn install_quiet_abort_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<WorldAbort>().is_none() {
                prev(info);
            }
        }));
    });
}

// ---- the envelope -------------------------------------------------------
//
// Layout: [seq u64][fnv u64][len u64][payload]. The checksum covers
// seq ‖ len ‖ payload, so a flip anywhere — sequence number, length,
// checksum itself, or payload — fails verification and the copy is
// discarded; the sender's retransmit loop owns making progress.

/// Wrap `payload` in the sequence-numbered checksummed envelope.
pub fn encode_envelope(seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut covered = Vec::with_capacity(16 + payload.len());
    put_u64(&mut covered, seq);
    put_u64(&mut covered, payload.len() as u64);
    covered.extend_from_slice(payload);
    let fnv = fnv1a64(&covered);
    let mut out = Vec::with_capacity(24 + payload.len());
    put_u64(&mut out, seq);
    put_u64(&mut out, fnv);
    put_u64(&mut out, payload.len() as u64);
    out.extend_from_slice(payload);
    out
}

/// Unwrap an envelope: `(seq, payload)`. Any length or checksum
/// violation is a typed [`WireError`] — never a panic.
pub fn decode_envelope(bytes: &[u8]) -> Result<(u64, Vec<u8>), WireError> {
    let mut off = 0usize;
    let seq = try_get_u64(bytes, &mut off, "envelope seq")?;
    let fnv = try_get_u64(bytes, &mut off, "envelope checksum")?;
    let len = try_get_u64(bytes, &mut off, "envelope length")?;
    let len_usize =
        usize::try_from(len).map_err(|_| WireError::Corrupt { what: "envelope length" })?;
    let payload = try_take(bytes, &mut off, len_usize, "envelope payload")?;
    if off != bytes.len() {
        return Err(WireError::Corrupt { what: "envelope trailing bytes" });
    }
    let mut covered = Vec::with_capacity(16 + payload.len());
    put_u64(&mut covered, seq);
    put_u64(&mut covered, len);
    covered.extend_from_slice(payload);
    if fnv1a64(&covered) != fnv {
        return Err(WireError::Corrupt { what: "envelope checksum" });
    }
    Ok((seq, payload.to_vec()))
}

/// Receive-side dedup over one peer's envelope stream: remembers every
/// delivered sequence number, so retransmits and lottery duplicates are
/// idempotently discarded.
///
/// `accept` is the whole verdict surface: `Ok(Some(payload))` — fresh,
/// deliver; `Ok(None)` — duplicate, discard; `Err(_)` — corrupt,
/// discard (the sender will retransmit).
#[derive(Debug, Default)]
pub struct EnvelopeStream {
    delivered: HashSet<u64>,
}

impl EnvelopeStream {
    pub fn accept(&mut self, bytes: &[u8]) -> Result<Option<Vec<u8>>, WireError> {
        let (seq, payload) = decode_envelope(bytes)?;
        if self.delivered.insert(seq) {
            Ok(Some(payload))
        } else {
            Ok(None)
        }
    }
}

/// One send's lottery outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum FaultEvent {
    Clean,
    Drop,
    Corrupt { bit: usize },
    Duplicate,
    Delay,
}

/// Per-rank fault machinery: the plan, this rank's lottery stream,
/// per-destination sequence counters and per-source dedup streams.
#[derive(Debug)]
pub(crate) struct FaultState {
    pub(crate) plan: FaultPlan,
    rng: Rng,
    next_seq: Vec<u64>,
    pub(crate) streams: Vec<EnvelopeStream>,
    pub(crate) kill_fired: bool,
}

impl FaultState {
    pub(crate) fn new(plan: FaultPlan, rank: usize, size: usize) -> Self {
        let rng = Rng::new(plan.seed).fork(rank as u64);
        FaultState {
            plan,
            rng,
            next_seq: vec![0; size],
            streams: (0..size).map(|_| EnvelopeStream::default()).collect(),
            kill_fired: false,
        }
    }

    /// Allocate the sequence number for the next logical message to
    /// `to` (shared by all retransmits of that message).
    pub(crate) fn alloc_seq(&mut self, to: usize) -> u64 {
        let seq = self.next_seq[to];
        self.next_seq[to] += 1;
        seq
    }

    /// Draw the lottery for one transmission of an `env_bits`-bit
    /// envelope. Single-threaded program order per rank ⇒ the draw
    /// sequence is deterministic regardless of scheduling.
    pub(crate) fn draw(&mut self, env_bits: usize) -> FaultEvent {
        let x = self.rng.f64();
        let mut edge = self.plan.drop;
        if x < edge {
            return FaultEvent::Drop;
        }
        edge += self.plan.corrupt;
        if x < edge {
            return FaultEvent::Corrupt { bit: self.rng.below(env_bits.max(1)) };
        }
        edge += self.plan.duplicate;
        if x < edge {
            return FaultEvent::Duplicate;
        }
        edge += self.plan.delay;
        if x < edge {
            return FaultEvent::Delay;
        }
        FaultEvent::Clean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_roundtrips() {
        for payload in [&b""[..], b"x", b"hello world", &[0u8; 1000]] {
            let env = encode_envelope(42, payload);
            let (seq, got) = decode_envelope(&env).unwrap();
            assert_eq!(seq, 42);
            assert_eq!(got, payload);
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let env = encode_envelope(7, b"payload under test");
        for byte in 0..env.len() {
            for bit in 0..8 {
                let mut bad = env.clone();
                bad[byte] ^= 1 << bit;
                // A flip may shrink the announced length (truncated /
                // trailing-bytes error) or just break the checksum —
                // either way it must be a typed error, never a decode.
                assert!(
                    decode_envelope(&bad).is_err(),
                    "flip byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn truncation_and_extension_are_typed() {
        let env = encode_envelope(3, b"abc");
        for cut in 0..env.len() {
            assert!(decode_envelope(&env[..cut]).is_err(), "cut at {cut} decoded");
        }
        let mut long = env.clone();
        long.push(0);
        assert!(matches!(
            decode_envelope(&long),
            Err(WireError::Corrupt { what: "envelope trailing bytes" })
        ));
    }

    #[test]
    fn stream_dedups_by_sequence_number() {
        let mut s = EnvelopeStream::default();
        let a = encode_envelope(0, b"first");
        let b = encode_envelope(1, b"second");
        assert_eq!(s.accept(&a).unwrap(), Some(b"first".to_vec()));
        assert_eq!(s.accept(&a).unwrap(), None, "retransmit must discard");
        assert_eq!(s.accept(&b).unwrap(), Some(b"second".to_vec()));
        assert_eq!(s.accept(&b).unwrap(), None);
        // Out-of-order fresh sequence numbers still deliver.
        let late = encode_envelope(10, b"late");
        assert_eq!(s.accept(&late).unwrap(), Some(b"late".to_vec()));
    }

    #[test]
    fn lottery_is_deterministic_and_roughly_proportioned() {
        let plan = FaultPlan {
            drop: 0.1,
            corrupt: 0.1,
            duplicate: 0.1,
            delay: 0.1,
            ..Default::default()
        };
        let mut a = FaultState::new(plan.clone(), 3, 8);
        let mut b = FaultState::new(plan, 3, 8);
        let mut counts = [0usize; 5];
        for _ in 0..4000 {
            let ea = a.draw(256);
            assert_eq!(ea, b.draw(256), "same seed+rank must replay the same lottery");
            let slot = match ea {
                FaultEvent::Drop => 0,
                FaultEvent::Corrupt { .. } => 1,
                FaultEvent::Duplicate => 2,
                FaultEvent::Delay => 3,
                FaultEvent::Clean => 4,
            };
            counts[slot] += 1;
        }
        for (i, &c) in counts[..4].iter().enumerate() {
            assert!((200..=600).contains(&c), "event {i} count {c} far from 10%");
        }
        assert!(counts[4] > 2000, "clean share collapsed: {}", counts[4]);
    }

    #[test]
    fn rank_streams_differ() {
        let plan = FaultPlan { drop: 0.5, ..Default::default() };
        let mut r0 = FaultState::new(plan.clone(), 0, 4);
        let mut r1 = FaultState::new(plan, 1, 4);
        let seq0: Vec<_> = (0..64).map(|_| r0.draw(64)).collect();
        let seq1: Vec<_> = (0..64).map(|_| r1.draw(64)).collect();
        assert_ne!(seq0, seq1, "per-rank forks must decorrelate the lottery");
    }

    #[test]
    fn sequence_numbers_are_per_destination() {
        let mut fs = FaultState::new(FaultPlan::default(), 0, 3);
        assert_eq!(fs.alloc_seq(1), 0);
        assert_eq!(fs.alloc_seq(2), 0);
        assert_eq!(fs.alloc_seq(1), 1);
        assert_eq!(fs.alloc_seq(2), 1);
    }

    #[test]
    fn default_plan_is_inert() {
        assert!(!FaultPlan::default().any_faults());
        let killer = FaultPlan { kill_rank: Some(1), ..Default::default() };
        assert!(killer.any_faults());
    }
}
