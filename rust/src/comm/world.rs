//! World launcher: spawns one OS thread per rank and runs the SPMD closure.

use super::faults::{self, FaultPlan};
use super::{Comm, CommStats, CostModel, Msg};
use crate::util::fmax;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};

/// Result of one rank's execution.
#[derive(Clone, Debug)]
pub struct RankOutput<T> {
    pub rank: usize,
    pub result: T,
    /// Final virtual clock (the rank's makespan contribution).
    pub virtual_time: f64,
    pub stats: CommStats,
}

/// Build the fully-connected channel mesh for `n` ranks. When a fault
/// plan is given, every rank carries its own forked lottery stream plus
/// one abort flag shared by the whole world.
pub(crate) fn spawn_comms(n: usize, cost: CostModel, plan: Option<&FaultPlan>) -> Vec<Comm> {
    if plan.is_some() {
        faults::install_quiet_abort_hook();
    }
    let abort = Arc::new(AtomicBool::new(false));
    let mut txs: Vec<mpsc::Sender<Msg>> = Vec::with_capacity(n);
    let mut rxs: Vec<mpsc::Receiver<Msg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| {
            let fs = plan.map(|p| faults::FaultState::new(p.clone(), rank, n));
            Comm::new(rank, n, txs.clone(), rx, cost, fs, abort.clone())
        })
        .collect()
}

/// Run `f` as an SPMD program on `n` simulated ranks (one thread each) and
/// collect every rank's result, final virtual time and statistics.
///
/// The returned vector is indexed by rank. The *makespan* of the simulated
/// job is `outputs.iter().map(|o| o.virtual_time).fold(0.0, fmax)`.
pub fn run_world<T, F>(n: usize, cost: CostModel, f: F) -> Vec<RankOutput<T>>
where
    T: Send,
    F: Fn(&mut Comm) -> T + Sync,
{
    run_world_with(n, cost, None, f)
}

/// [`run_world`] with an optional fault plan injected into every rank's
/// point-to-point path (DESIGN.md §11). The closure is responsible for
/// catching [`super::WorldAbort`] panics (the dist driver wraps the
/// algorithm body in `catch_unwind`), so rank threads never unwind out.
pub fn run_world_with<T, F>(
    n: usize,
    cost: CostModel,
    plan: Option<&FaultPlan>,
    f: F,
) -> Vec<RankOutput<T>>
where
    T: Send,
    F: Fn(&mut Comm) -> T + Sync,
{
    assert!(n >= 1, "need at least one rank");
    let comms = spawn_comms(n, cost, plan);
    let f = &f;
    let mut outputs: Vec<Option<RankOutput<T>>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for (rank, mut comm) in comms.into_iter().enumerate() {
            handles.push((
                rank,
                scope.spawn(move || {
                    // Reset the CPU mark *inside* the rank thread: the handle
                    // was created on the spawner thread whose clock differs.
                    comm.cpu_mark = crate::util::thread_cpu_time();
                    let result = f(&mut comm);
                    comm.finish();
                    RankOutput {
                        rank: comm.rank,
                        result,
                        virtual_time: comm.vt,
                        stats: comm.stats.clone(),
                    }
                }),
            ));
        }
        for (rank, h) in handles {
            outputs[rank] = Some(h.join().expect("rank thread panicked"));
        }
    });
    outputs.into_iter().map(Option::unwrap).collect()
}

/// Makespan of a finished world (max rank virtual time).
pub fn makespan<T>(outputs: &[RankOutput<T>]) -> f64 {
    outputs.iter().map(|o| o.virtual_time).fold(0.0, fmax)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_world() {
        let outs = run_world(1, CostModel::default(), |c| c.rank() * 10);
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].result, 0);
    }

    #[test]
    fn results_indexed_by_rank() {
        let outs = run_world(5, CostModel::default(), |c| c.rank());
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.rank, i);
            assert_eq!(o.result, i);
        }
    }

    #[test]
    fn makespan_is_max() {
        let outs = run_world(3, CostModel::default(), |c| {
            if c.rank() == 1 {
                let mut acc = 0u64;
                for i in 0..4_000_000u64 {
                    acc = acc.wrapping_add(i.wrapping_mul(31));
                }
                std::hint::black_box(acc);
            }
            c.virtual_time()
        });
        let ms = makespan(&outs);
        assert!(ms >= outs[0].virtual_time);
        assert!(ms >= outs[2].virtual_time);
    }
}
