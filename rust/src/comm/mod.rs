//! Simulated MPI runtime.
//!
//! The paper runs on NERSC Perlmutter with Cray MPICH over Slingshot-11;
//! this box has one physical core and no MPI. The substitution (DESIGN.md
//! §3) keeps the *algorithms* bit-for-bit identical — same SPMD structure,
//! same message patterns, same collectives — and replaces physical time
//! with **virtual time**:
//!
//! * each MPI rank is an OS thread running the same SPMD closure;
//! * compute segments are charged at the rank's own thread-CPU time
//!   (`CLOCK_THREAD_CPUTIME_ID`), so ranks that time-share one core are
//!   still charged only for their own work;
//! * communication is charged by an α-β (latency–bandwidth) model with
//!   standard per-collective cost formulas (see [`CostModel`]), which
//!   exposes exactly the effects the paper reports — the `α·(P−1)`
//!   alltoallv term that degrades `landmark-coll` at scale, the linear ring
//!   latency of the systolic algorithm, and compute/comm overlap.
//!
//! Message payloads really move between threads (over channels), so the
//! distributed algorithms are tested end-to-end, not just cost-modeled.

mod faults;
mod stats;
mod world;

pub use faults::{
    decode_envelope, encode_envelope, EnvelopeStream, FaultCounters, FaultPlan, WorldAbort,
    MAX_ATTEMPTS,
};
pub use stats::{CommStats, PhaseTimes};
pub use world::{makespan, run_world, run_world_with, RankOutput};

use crate::util::fmax;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// α-β communication cost model (plus per-collective formulas).
///
/// Defaults approximate a Slingshot-class interconnect as seen from one
/// rank: ~2 µs small-message latency, ~25 GB/s effective per-rank
/// bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Seconds per byte (inverse bandwidth).
    pub beta_inv: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { alpha: 2e-6, beta_inv: 1.0 / 25e9 }
    }
}

impl CostModel {
    /// Point-to-point transfer cost for `b` payload bytes.
    #[inline]
    pub fn p2p(&self, b: u64) -> f64 {
        self.alpha + b as f64 * self.beta_inv
    }

    /// Barrier / small allreduce: logarithmic latency term.
    #[inline]
    pub fn barrier(&self, p: usize) -> f64 {
        self.alpha * (p.max(2) as f64).log2().ceil()
    }

    /// Allgather: log α term + all remote bytes through one NIC.
    #[inline]
    pub fn allgather(&self, p: usize, remote_bytes: u64) -> f64 {
        self.barrier(p) + remote_bytes as f64 * self.beta_inv
    }

    /// Alltoallv as implemented by pairwise exchanges: the `α·(P−1)` term
    /// is the scaling bottleneck the paper's Figures 3–5 highlight.
    #[inline]
    pub fn alltoallv(&self, p: usize, send_bytes: u64, recv_bytes: u64) -> f64 {
        self.alpha * (p.saturating_sub(1)) as f64
            + send_bytes.max(recv_bytes) as f64 * self.beta_inv
    }
}

/// In-flight message.
struct Msg {
    from: usize,
    tag: u64,
    payload: Vec<u8>,
    /// Virtual time at which the message is fully delivered at the
    /// receiver (sender's clock at send + α + bytes/β). Internal collective
    /// traffic uses 0.0 (cost charged analytically by the collective).
    arrival_vt: f64,
}

/// Per-rank communicator handle (the `MPI_Comm` analog).
pub struct Comm {
    rank: usize,
    size: usize,
    txs: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    /// Out-of-order messages awaiting a matching recv.
    pending: Vec<Msg>,
    cost: CostModel,
    /// Virtual clock (seconds).
    vt: f64,
    /// Thread-CPU reading at the end of the last accounted segment.
    cpu_mark: f64,
    /// Monotone sequence number for collective operations (tag namespace).
    coll_seq: u64,
    stats: CommStats,
    /// Fault-injection state (`None` = the fault-free fast path).
    faults: Option<faults::FaultState>,
    /// World-wide abort flag: set by a dying rank so every peer blocked
    /// in a receive aborts in bounded wall time instead of hanging.
    abort: Arc<AtomicBool>,
}

/// Tag bit reserved for internal collective traffic.
const COLL_BIT: u64 = 1 << 63;

impl Comm {
    fn new(
        rank: usize,
        size: usize,
        txs: Vec<Sender<Msg>>,
        rx: Receiver<Msg>,
        cost: CostModel,
        faults: Option<faults::FaultState>,
        abort: Arc<AtomicBool>,
    ) -> Self {
        Comm {
            rank,
            size,
            txs,
            rx,
            pending: Vec::new(),
            cost,
            vt: 0.0,
            cpu_mark: crate::util::thread_cpu_time(),
            coll_seq: 0,
            stats: CommStats::new(),
            faults,
            abort,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Current virtual time in seconds.
    pub fn virtual_time(&self) -> f64 {
        self.vt
    }

    /// Borrow the accumulated statistics.
    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    /// Switch the accounting bucket for subsequent compute/comm time
    /// (Fig 3–5 phase breakdowns). Charges any outstanding compute to the
    /// previous phase first.
    pub fn set_phase(&mut self, name: &str) {
        self.absorb_compute();
        self.stats.set_phase(name);
        self.check_kill(name);
    }

    /// Kill this rank at a phase boundary if the fault plan says so:
    /// set the world abort flag and unwind with the typed payload (the
    /// dist driver's `catch_unwind` converts it into a typed error).
    fn check_kill(&mut self, phase: &str) {
        let Some(fs) = self.faults.as_mut() else { return };
        if fs.kill_fired || fs.plan.kill_rank != Some(self.rank) {
            return;
        }
        if let Some(kp) = &fs.plan.kill_phase {
            if kp != phase {
                return;
            }
        }
        fs.kill_fired = true;
        self.abort.store(true, Ordering::SeqCst);
        std::panic::panic_any(WorldAbort::Killed { rank: self.rank, phase: phase.to_string() });
    }

    /// Charge CPU time since the last mark to the current phase as compute.
    fn absorb_compute(&mut self) {
        let now = crate::util::thread_cpu_time();
        let dt = fmax(now - self.cpu_mark, 0.0);
        self.cpu_mark = now;
        self.vt += dt;
        self.stats.add_compute(dt);
    }

    /// Charge `dt` seconds of modeled communication time.
    fn charge_comm(&mut self, dt: f64) {
        let dt = fmax(dt, 0.0);
        self.vt += dt;
        self.stats.add_comm(dt);
    }

    /// Charge CPU seconds consumed by helper threads owned by this rank
    /// (its intra-rank task pool) as compute in the current phase.
    /// `absorb_compute` reads only the rank thread's own clock
    /// (`CLOCK_THREAD_CPUTIME_ID`) — a rank blocked on its pool accrues
    /// ~zero there while the workers burn real cores — so pool-worker CPU
    /// must be folded in explicitly to keep virtual time honest
    /// (DESIGN.md §7.1). Typical call: `comm.charge_child_cpu(pool.drain_cpu())`.
    pub fn charge_child_cpu(&mut self, dt: f64) {
        if dt <= 0.0 {
            return;
        }
        self.absorb_compute();
        self.vt += dt;
        self.stats.add_compute(dt);
    }

    // ------------------------------------------------------------------
    // point-to-point
    // ------------------------------------------------------------------

    /// Push one raw [`Msg`] onto `to`'s channel, converting a hung-up
    /// receiver into the typed abort when the world is going down.
    fn transmit(&mut self, to: usize, tag: u64, payload: Vec<u8>, arrival_vt: f64) {
        if self.txs[to].send(Msg { from: self.rank, tag, payload, arrival_vt }).is_err() {
            if self.abort.load(Ordering::SeqCst) {
                std::panic::panic_any(WorldAbort::Aborted { rank: self.rank });
            }
            panic!("receiver hung up");
        }
    }

    /// Send `payload` to `to` with `tag`. Non-blocking (channels are
    /// unbounded); the sender is charged the α overhead.
    pub fn send(&mut self, to: usize, tag: u32, payload: Vec<u8>) {
        self.absorb_compute();
        if self.faults.is_some() && to != self.rank {
            return self.send_faulty(to, tag, payload);
        }
        let bytes = payload.len() as u64;
        self.charge_comm(self.cost.alpha);
        let arrival = self.vt + bytes as f64 * self.cost.beta_inv;
        self.stats.count_send(bytes);
        self.transmit(to, tag as u64, payload, arrival);
    }

    /// Faulted send: wrap the payload in a sequence-numbered checksummed
    /// envelope, run the per-attempt lottery, and retransmit until one
    /// deliverable copy is on the wire (at most [`MAX_ATTEMPTS`], else
    /// the typed [`WorldAbort::Unreachable`]). Every attempt is charged
    /// α into the current phase's comm time, so retries lengthen the
    /// makespan — fault overhead stays visible in the α-β accounting.
    fn send_faulty(&mut self, to: usize, tag: u32, payload: Vec<u8>) {
        let (seq, delay_us) = {
            let fs = self.faults.as_mut().expect("send_faulty without a plan");
            (fs.alloc_seq(to), fs.plan.delay_us)
        };
        let env = faults::encode_envelope(seq, &payload);
        let bytes = env.len() as u64;
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            if attempts > MAX_ATTEMPTS {
                self.abort.store(true, Ordering::SeqCst);
                std::panic::panic_any(WorldAbort::Unreachable { from: self.rank, to });
            }
            let event = self.faults.as_mut().expect("checked above").draw(env.len() * 8);
            self.charge_comm(self.cost.alpha);
            let arrival = self.vt + bytes as f64 * self.cost.beta_inv;
            self.stats.count_send(bytes);
            match event {
                faults::FaultEvent::Drop => {
                    let f = self.stats.faults_mut();
                    f.drops += 1;
                    f.retries += 1;
                }
                faults::FaultEvent::Corrupt { bit } => {
                    let mut bad = env.clone();
                    bad[bit / 8] ^= 1 << (bit % 8);
                    self.transmit(to, tag as u64, bad, arrival);
                    let f = self.stats.faults_mut();
                    f.corrupts += 1;
                    f.retries += 1;
                }
                faults::FaultEvent::Duplicate => {
                    self.stats.count_send(bytes);
                    self.transmit(to, tag as u64, env.clone(), arrival);
                    self.transmit(to, tag as u64, env, arrival);
                    self.stats.faults_mut().duplicates += 1;
                    return;
                }
                faults::FaultEvent::Delay => {
                    let late = arrival + delay_us as f64 * 1e-6;
                    self.transmit(to, tag as u64, env, late);
                    self.stats.faults_mut().delayed_us += delay_us;
                    return;
                }
                faults::FaultEvent::Clean => {
                    self.transmit(to, tag as u64, env, arrival);
                    return;
                }
            }
        }
    }

    /// Blocking receive of a message from `from` with `tag`.
    pub fn recv(&mut self, from: usize, tag: u32) -> Vec<u8> {
        self.absorb_compute();
        if self.faults.is_none() || from == self.rank {
            let msg = self.take_matching(from, tag as u64);
            // Wait until the message is delivered in virtual time.
            let wait = msg.arrival_vt - self.vt;
            self.charge_comm(wait);
            return msg.payload;
        }
        // Faulted receive: unwrap envelopes, discarding corrupt copies
        // (checksum mismatch — the sender retransmits) and duplicate
        // sequence numbers (idempotent dedup), until a fresh payload
        // arrives. The delivered payload stream is byte-identical to the
        // fault-free run; only the arrival clock differs.
        loop {
            let msg = self.take_matching(from, tag as u64);
            let verdict =
                self.faults.as_mut().expect("checked above").streams[from].accept(&msg.payload);
            match verdict {
                Ok(Some(payload)) => {
                    let wait = msg.arrival_vt - self.vt;
                    self.charge_comm(wait);
                    return payload;
                }
                Ok(None) => self.stats.faults_mut().dup_discards += 1,
                Err(_) => self.stats.faults_mut().corrupt_discards += 1,
            }
        }
    }

    /// Simultaneous send+recv (the ring primitive), with the communication
    /// *overlapped* against `compute`: the step's virtual duration is
    /// `max(compute_cpu, comm_cost)`, mirroring how the paper's systolic
    /// algorithm hides the ring transfer behind the query step.
    ///
    /// Returns `(compute_result, received_payload)`.
    pub fn sendrecv_overlapped<R>(
        &mut self,
        to: usize,
        from: usize,
        tag: u32,
        payload: Vec<u8>,
        compute: impl FnOnce() -> R,
    ) -> (R, Vec<u8>) {
        if self.faults.is_some() && to != self.rank {
            // Under fault injection the overlap window closes: the send
            // may retransmit and the receive may discard copies, so the
            // step is accounted sequentially (send, compute, recv).
            // Only faulted runs lose the overlap modeling.
            self.send(to, tag, payload);
            let cpu0 = crate::util::thread_cpu_time();
            let out = compute();
            let cpu1 = crate::util::thread_cpu_time();
            let c = fmax(cpu1 - cpu0, 0.0);
            self.cpu_mark = cpu1;
            self.vt += c;
            self.stats.add_compute(c);
            let got = self.recv(from, tag);
            return (out, got);
        }
        self.absorb_compute();
        let start = self.vt;
        let bytes = payload.len() as u64;
        let arrival = start + self.cost.p2p(bytes);
        self.stats.count_send(bytes);
        self.transmit(to, tag as u64, payload, arrival);

        // Run the overlapped compute and measure its CPU cost.
        let cpu0 = crate::util::thread_cpu_time();
        let out = compute();
        let cpu1 = crate::util::thread_cpu_time();
        let c = fmax(cpu1 - cpu0, 0.0);
        self.cpu_mark = cpu1;
        self.stats.add_compute(c);

        let msg = self.take_matching(from, tag as u64);
        // Step ends when both the compute and the incoming transfer finish.
        let end = fmax(fmax(start + c, msg.arrival_vt), start + self.cost.p2p(bytes));
        self.stats.add_comm(fmax(end - start - c, 0.0));
        self.vt = end;
        (out, msg.payload)
    }

    /// Pull the next message matching `(from, tag)`, buffering others.
    /// In a faulted world the blocking wait polls the shared abort flag
    /// every 5 ms, so a killed peer bounds every receive instead of
    /// hanging it (the typed [`WorldAbort::Aborted`] unwind).
    fn take_matching(&mut self, from: usize, tag: u64) -> Msg {
        if let Some(pos) = self.pending.iter().position(|m| m.from == from && m.tag == tag) {
            return self.pending.swap_remove(pos);
        }
        if self.faults.is_none() {
            // Fault-free worlds never abort: plain blocking receive.
            loop {
                let msg = self.rx.recv().expect("world shut down while receiving");
                if msg.from == from && msg.tag == tag {
                    return msg;
                }
                self.pending.push(msg);
            }
        }
        loop {
            if self.abort.load(Ordering::SeqCst) {
                std::panic::panic_any(WorldAbort::Aborted { rank: self.rank });
            }
            match self.rx.recv_timeout(Duration::from_millis(5)) {
                Ok(msg) => {
                    if msg.from == from && msg.tag == tag {
                        return msg;
                    }
                    self.pending.push(msg);
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    if self.abort.load(Ordering::SeqCst) {
                        std::panic::panic_any(WorldAbort::Aborted { rank: self.rank });
                    }
                    panic!("world shut down while receiving");
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // collectives (SPMD: every rank must call in the same order)
    // ------------------------------------------------------------------

    fn next_coll_tag(&mut self) -> u64 {
        self.coll_seq += 1;
        COLL_BIT | self.coll_seq
    }

    fn raw_send(&mut self, to: usize, tag: u64, payload: Vec<u8>) {
        // Collective traffic bypasses the fault lottery by construction
        // (arrival_vt 0.0; cost charged analytically), but still routes
        // through `transmit` so a dying world aborts typed, not panics.
        self.transmit(to, tag, payload, 0.0);
    }

    fn raw_recv(&mut self, from: usize, tag: u64) -> Vec<u8> {
        self.take_matching(from, tag).payload
    }

    /// Synchronize virtual clocks to the world maximum and return it.
    /// This is the entry barrier implicit in every collective.
    fn sync_vt_max(&mut self, tag: u64) -> f64 {
        if self.size == 1 {
            return self.vt;
        }
        // Rank 0 gathers all clocks, computes the max, broadcasts it.
        if self.rank == 0 {
            let mut mx = self.vt;
            for r in 1..self.size {
                let b = self.raw_recv(r, tag);
                mx = mx.max(f64::from_le_bytes(b[..8].try_into().unwrap()));
            }
            for r in 1..self.size {
                self.raw_send(r, tag, mx.to_le_bytes().to_vec());
            }
            mx
        } else {
            self.raw_send(0, tag, self.vt.to_le_bytes().to_vec());
            let b = self.raw_recv(0, tag);
            f64::from_le_bytes(b[..8].try_into().unwrap())
        }
    }

    /// Barrier: clocks jump to `max + α·⌈log₂P⌉`.
    pub fn barrier(&mut self) {
        self.absorb_compute();
        let tag = self.next_coll_tag();
        let mx = self.sync_vt_max(tag);
        let end = mx + self.cost.barrier(self.size);
        self.charge_comm(end - self.vt);
    }

    /// Allgather: every rank contributes `payload`; returns all payloads
    /// indexed by rank.
    pub fn allgather(&mut self, payload: Vec<u8>) -> Vec<Vec<u8>> {
        self.absorb_compute();
        let tag = self.next_coll_tag();
        let own_bytes = payload.len() as u64;
        self.stats.count_send(own_bytes * (self.size as u64 - 1));
        for r in 0..self.size {
            if r != self.rank {
                self.raw_send(r, tag, payload.clone());
            }
        }
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.size];
        let mut remote_bytes = 0u64;
        for r in 0..self.size {
            if r == self.rank {
                out[r] = payload.clone();
            } else {
                let b = self.raw_recv(r, tag);
                remote_bytes += b.len() as u64;
                out[r] = b;
            }
        }
        let tag2 = self.next_coll_tag();
        let mx = self.sync_vt_max(tag2);
        let end = mx + self.cost.allgather(self.size, remote_bytes);
        self.charge_comm(end - self.vt);
        out
    }

    /// Alltoallv: `bufs[r]` is sent to rank `r`; returns what each rank
    /// sent to us, indexed by source. Cost includes the `α·(P−1)` term.
    pub fn alltoallv(&mut self, bufs: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(bufs.len(), self.size, "alltoallv needs one buffer per rank");
        self.absorb_compute();
        let tag = self.next_coll_tag();
        let send_bytes: u64 = bufs
            .iter()
            .enumerate()
            .filter(|(r, _)| *r != self.rank)
            .map(|(_, b)| b.len() as u64)
            .sum();
        self.stats.count_send(send_bytes);
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.size];
        for (r, buf) in bufs.into_iter().enumerate() {
            if r == self.rank {
                out[r] = buf;
            } else {
                self.raw_send(r, tag, buf);
            }
        }
        let mut recv_bytes = 0u64;
        for r in 0..self.size {
            if r != self.rank {
                let b = self.raw_recv(r, tag);
                recv_bytes += b.len() as u64;
                out[r] = b;
            }
        }
        let tag2 = self.next_coll_tag();
        let mx = self.sync_vt_max(tag2);
        let end = mx + self.cost.alltoallv(self.size, send_bytes, recv_bytes);
        self.charge_comm(end - self.vt);
        out
    }

    /// Broadcast from `root`.
    pub fn bcast(&mut self, root: usize, payload: Vec<u8>) -> Vec<u8> {
        self.absorb_compute();
        let tag = self.next_coll_tag();
        let out = if self.rank == root {
            self.stats.count_send(payload.len() as u64 * (self.size as u64 - 1));
            for r in 0..self.size {
                if r != root {
                    self.raw_send(r, tag, payload.clone());
                }
            }
            payload
        } else {
            self.raw_recv(root, tag)
        };
        let tag2 = self.next_coll_tag();
        let mx = self.sync_vt_max(tag2);
        let end = mx
            + self.cost.barrier(self.size)
            + out.len() as f64 * self.cost.beta_inv;
        self.charge_comm(end - self.vt);
        out
    }

    /// Allreduce for a single f64.
    pub fn allreduce_f64(&mut self, x: f64, op: ReduceOp) -> f64 {
        let all = self.allgather(x.to_le_bytes().to_vec());
        let vals = all.iter().map(|b| f64::from_le_bytes(b[..8].try_into().unwrap()));
        match op {
            ReduceOp::Sum => vals.sum(),
            ReduceOp::Max => vals.fold(f64::NEG_INFINITY, fmax),
            ReduceOp::Min => vals.fold(f64::INFINITY, fmin),
        }
    }

    /// Allreduce for a single u64.
    pub fn allreduce_u64(&mut self, x: u64, op: ReduceOp) -> u64 {
        let all = self.allgather(x.to_le_bytes().to_vec());
        let vals = all.iter().map(|b| u64::from_le_bytes(b[..8].try_into().unwrap()));
        match op {
            ReduceOp::Sum => vals.sum(),
            ReduceOp::Max => vals.max().unwrap(),
            ReduceOp::Min => vals.min().unwrap(),
        }
    }

    /// Flush outstanding compute into the stats (call at the end of an
    /// algorithm so the last segment is attributed).
    pub fn finish(&mut self) {
        self.absorb_compute();
    }

    #[cfg(test)]
    pub(crate) fn new_loopback() -> Comm {
        let (tx, rx) = std::sync::mpsc::channel();
        Comm::new(0, 1, vec![tx], rx, CostModel::default(), None, Arc::new(AtomicBool::new(false)))
    }
}

/// Reduction operators for the scalar allreduce helpers.
#[derive(Clone, Copy, Debug)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_collectives() {
        let mut c = Comm::new_loopback();
        let all = c.allgather(vec![1, 2, 3]);
        assert_eq!(all, vec![vec![1, 2, 3]]);
        let back = c.alltoallv(vec![vec![9]]);
        assert_eq!(back, vec![vec![9]]);
        assert_eq!(c.allreduce_f64(4.0, ReduceOp::Sum), 4.0);
        c.barrier();
        assert_eq!(c.bcast(0, vec![7]), vec![7]);
    }

    #[test]
    fn p2p_roundtrip_two_ranks() {
        let outs = run_world(2, CostModel::default(), |c| {
            if c.rank() == 0 {
                c.send(1, 5, vec![42]);
                c.recv(1, 6)
            } else {
                let got = c.recv(0, 5);
                c.send(0, 6, vec![got[0] + 1]);
                got
            }
        });
        assert_eq!(outs[0].result, vec![43]);
        assert_eq!(outs[1].result, vec![42]);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let outs = run_world(2, CostModel::default(), |c| {
            if c.rank() == 0 {
                // Send tag 2 first, then tag 1; receiver asks for 1 first.
                c.send(1, 2, vec![2]);
                c.send(1, 1, vec![1]);
                Vec::new()
            } else {
                let first = c.recv(0, 1);
                let second = c.recv(0, 2);
                vec![first[0], second[0]]
            }
        });
        assert_eq!(outs[1].result, vec![1, 2]);
    }

    #[test]
    fn allgather_four_ranks() {
        let outs = run_world(4, CostModel::default(), |c| {
            let all = c.allgather(vec![c.rank() as u8]);
            all.iter().map(|b| b[0]).collect::<Vec<u8>>()
        });
        for o in &outs {
            assert_eq!(o.result, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn alltoallv_contents() {
        let outs = run_world(3, CostModel::default(), |c| {
            // rank r sends [r*10 + dest] to each dest
            let bufs: Vec<Vec<u8>> =
                (0..3).map(|d| vec![(c.rank() * 10 + d) as u8]).collect();
            let got = c.alltoallv(bufs);
            got.iter().map(|b| b[0]).collect::<Vec<u8>>()
        });
        // rank d receives from each src: src*10 + d
        for (d, o) in outs.iter().enumerate() {
            let want: Vec<u8> = (0..3).map(|s| (s * 10 + d) as u8).collect();
            assert_eq!(o.result, want, "rank {d}");
        }
    }

    #[test]
    fn bcast_from_nonzero_root() {
        let outs = run_world(3, CostModel::default(), |c| {
            let payload = if c.rank() == 2 { vec![99] } else { Vec::new() };
            c.bcast(2, payload)
        });
        for o in &outs {
            assert_eq!(o.result, vec![99]);
        }
    }

    #[test]
    fn allreduce_ops() {
        let outs = run_world(4, CostModel::default(), |c| {
            let s = c.allreduce_u64(c.rank() as u64, ReduceOp::Sum);
            let mx = c.allreduce_f64(c.rank() as f64, ReduceOp::Max);
            let mn = c.allreduce_f64(c.rank() as f64, ReduceOp::Min);
            (s, mx, mn)
        });
        for o in &outs {
            assert_eq!(o.result, (6, 3.0, 0.0));
        }
    }

    #[test]
    fn virtual_time_advances_with_comm() {
        let outs = run_world(2, CostModel { alpha: 1e-3, beta_inv: 1e-9 }, |c| {
            c.barrier();
            c.virtual_time()
        });
        for o in &outs {
            // one barrier = α·⌈log₂2⌉ = 1 ms minimum
            assert!(o.result >= 1e-3, "vt={} too small", o.result);
        }
    }

    #[test]
    fn alltoallv_alpha_scales_with_ranks() {
        // The modeled alltoallv cost must grow linearly in P (the paper's
        // landmark-coll bottleneck).
        let cost = CostModel { alpha: 1e-3, beta_inv: 0.0 };
        let t4 = run_world(4, cost, |c| {
            let bufs = vec![Vec::new(); c.size()];
            c.alltoallv(bufs);
            c.virtual_time()
        })[0]
            .result;
        let t8 = run_world(8, cost, |c| {
            let bufs = vec![Vec::new(); c.size()];
            c.alltoallv(bufs);
            c.virtual_time()
        })[0]
            .result;
        assert!(t8 > t4 * 1.5, "t4={t4} t8={t8}");
    }

    #[test]
    fn sendrecv_overlapped_moves_payload_and_overlaps() {
        let cost = CostModel { alpha: 5e-3, beta_inv: 0.0 };
        let outs = run_world(2, cost, |c| {
            let to = (c.rank() + 1) % 2;
            let from = (c.rank() + 1) % 2;
            let (busy, got) = c.sendrecv_overlapped(to, from, 9, vec![c.rank() as u8], || {
                // trivial compute, far below the 5ms α
                1 + 1
            });
            assert_eq!(busy, 2);
            (got, c.virtual_time())
        });
        assert_eq!(outs[0].result.0, vec![1]);
        assert_eq!(outs[1].result.0, vec![0]);
        // Step cost should be ≈ α (comm dominated), not α + compute.
        for o in &outs {
            assert!(o.result.1 >= 5e-3 && o.result.1 < 50e-3, "vt={}", o.result.1);
        }
    }

    #[test]
    fn child_cpu_charged_to_current_phase() {
        let mut c = Comm::new_loopback();
        c.set_phase("tree");
        c.charge_child_cpu(0.75);
        c.charge_child_cpu(0.0); // no-op
        c.charge_child_cpu(-1.0); // no-op (defensive)
        c.finish();
        assert!(c.virtual_time() >= 0.75);
        assert!(c.stats().phases()["tree"].compute >= 0.75);
    }

    #[test]
    fn faulted_p2p_payloads_survive_the_lottery() {
        let plan = FaultPlan {
            drop: 0.2,
            corrupt: 0.2,
            duplicate: 0.1,
            delay: 0.1,
            ..Default::default()
        };
        let outs = run_world_with(2, CostModel::default(), Some(&plan), |c| {
            if c.rank() == 0 {
                for i in 0..48u32 {
                    c.send(1, i, vec![i as u8; (i as usize % 7) + 1]);
                }
                Vec::new()
            } else {
                (0..48u32).flat_map(|i| c.recv(0, i)).collect()
            }
        });
        let want: Vec<u8> =
            (0..48u32).flat_map(|i| vec![i as u8; (i as usize % 7) + 1]).collect();
        assert_eq!(outs[1].result, want, "delivered payloads must match the fault-free stream");
        let mut total = FaultCounters::default();
        total.merge(outs[0].stats.faults());
        total.merge(outs[1].stats.faults());
        assert!(total.any(), "a 60% fault share over 48 sends must perturb something");
        assert_eq!(
            total.retries,
            total.drops + total.corrupts,
            "every drop/corrupt costs exactly one retry"
        );
    }

    #[test]
    fn faulted_runs_replay_bit_identically() {
        let plan = FaultPlan {
            drop: 0.15,
            corrupt: 0.15,
            duplicate: 0.1,
            delay: 0.1,
            seed: 99,
            ..Default::default()
        };
        let run = || {
            run_world_with(3, CostModel::default(), Some(&plan), |c| {
                let next = (c.rank() + 1) % c.size();
                let prev = (c.rank() + c.size() - 1) % c.size();
                let mut got = Vec::new();
                for s in 0..8u32 {
                    c.send(next, s, vec![c.rank() as u8, s as u8]);
                    got.extend(c.recv(prev, s));
                }
                (got, *c.stats().faults())
            })
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.result, y.result, "rank {} diverged across replays", x.rank);
        }
    }

    #[test]
    fn kill_aborts_every_rank_with_typed_payloads() {
        let plan = FaultPlan {
            kill_rank: Some(0),
            kill_phase: Some("work".into()),
            ..Default::default()
        };
        let outs = run_world_with(2, CostModel::default(), Some(&plan), |c| {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                c.set_phase("work");
                if c.rank() == 1 {
                    // Would block forever in a fault-free world — the
                    // abort flag must free it in bounded wall time.
                    let _ = c.recv(0, 77);
                }
            }));
            caught.err().and_then(|p| p.downcast_ref::<WorldAbort>().cloned())
        });
        assert_eq!(outs[0].result, Some(WorldAbort::Killed { rank: 0, phase: "work".into() }));
        assert_eq!(outs[1].result, Some(WorldAbort::Aborted { rank: 1 }));
    }

    #[test]
    fn total_loss_is_unreachable_not_a_hang() {
        let plan = FaultPlan { drop: 1.0, ..Default::default() };
        let outs = run_world_with(2, CostModel::default(), Some(&plan), |c| {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if c.rank() == 0 {
                    c.send(1, 9, vec![1, 2, 3]);
                    c.recv(1, 10)
                } else {
                    let got = c.recv(0, 9);
                    c.send(0, 10, vec![4]);
                    got
                }
            }));
            caught.err().and_then(|p| p.downcast_ref::<WorldAbort>().cloned())
        });
        assert_eq!(outs[0].result, Some(WorldAbort::Unreachable { from: 0, to: 1 }));
        assert_eq!(outs[1].result, Some(WorldAbort::Aborted { rank: 1 }));
    }

    #[test]
    fn delay_inflates_virtual_time_but_not_payloads() {
        // delay=1.0 ⇒ every message is late by exactly delay_us; the
        // receiver's clock must absorb the lateness.
        let plan = FaultPlan { delay: 1.0, delay_us: 50_000, ..Default::default() };
        let outs = run_world_with(2, CostModel { alpha: 0.0, beta_inv: 0.0 }, Some(&plan), |c| {
            if c.rank() == 0 {
                c.send(1, 1, vec![7]);
                0.0
            } else {
                let got = c.recv(0, 1);
                assert_eq!(got, vec![7]);
                c.virtual_time()
            }
        });
        assert!(outs[1].result >= 0.05, "50ms of injected delay missing: {}", outs[1].result);
        assert_eq!(outs[0].stats.faults().delayed_us, 50_000);
    }

    #[test]
    fn phase_accounting_splits_compute_and_comm() {
        let outs = run_world(2, CostModel { alpha: 1e-3, beta_inv: 0.0 }, |c| {
            c.set_phase("work");
            let mut acc = 0u64;
            for i in 0..2_000_000u64 {
                acc = acc.wrapping_add(i.wrapping_mul(0x9E3779B9));
            }
            std::hint::black_box(acc);
            c.set_phase("sync");
            c.barrier();
            c.finish();
            c.stats().clone()
        });
        for o in &outs {
            let phases = o.result.phases();
            let work = &phases["work"];
            let sync = &phases["sync"];
            assert!(work.compute > 0.0, "work compute missing");
            assert!(sync.comm >= 0.9e-3, "sync comm missing: {}", sync.comm);
        }
    }
}
