//! Per-rank communication/computation statistics with named phases —
//! the data behind the paper's Figure 3–5 breakdowns.

use super::faults::FaultCounters;
use std::collections::BTreeMap;

/// Compute vs (modeled) communication seconds inside one phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimes {
    pub compute: f64,
    pub comm: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.compute + self.comm
    }
}

/// Statistics accumulated by a [`super::Comm`] handle.
#[derive(Clone, Debug)]
pub struct CommStats {
    phases: BTreeMap<String, PhaseTimes>,
    phase_order: Vec<String>,
    current: String,
    bytes_sent: u64,
    msgs_sent: u64,
    faults: FaultCounters,
}

impl CommStats {
    pub(crate) fn new() -> Self {
        let current = "default".to_string();
        let mut phases = BTreeMap::new();
        phases.insert(current.clone(), PhaseTimes::default());
        CommStats {
            phases,
            phase_order: vec![current.clone()],
            current,
            bytes_sent: 0,
            msgs_sent: 0,
            faults: FaultCounters::default(),
        }
    }

    pub(crate) fn set_phase(&mut self, name: &str) {
        if !self.phases.contains_key(name) {
            self.phases.insert(name.to_string(), PhaseTimes::default());
            self.phase_order.push(name.to_string());
        }
        self.current = name.to_string();
    }

    pub(crate) fn add_compute(&mut self, dt: f64) {
        self.phases.get_mut(&self.current).unwrap().compute += dt;
    }

    pub(crate) fn add_comm(&mut self, dt: f64) {
        self.phases.get_mut(&self.current).unwrap().comm += dt;
    }

    pub(crate) fn count_send(&mut self, bytes: u64) {
        self.bytes_sent += bytes;
        self.msgs_sent += 1;
    }

    /// Phase name → times.
    pub fn phases(&self) -> &BTreeMap<String, PhaseTimes> {
        &self.phases
    }

    /// Phases in first-use order (for stable breakdown tables).
    pub fn phase_order(&self) -> &[String] {
        &self.phase_order
    }

    pub fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    pub fn msgs_sent(&self) -> u64 {
        self.msgs_sent
    }

    /// Fault events observed by this rank (all zero when no plan is set).
    pub fn faults(&self) -> &FaultCounters {
        &self.faults
    }

    pub(crate) fn faults_mut(&mut self) -> &mut FaultCounters {
        &mut self.faults
    }

    /// Total across phases.
    pub fn total(&self) -> PhaseTimes {
        let mut t = PhaseTimes::default();
        for p in self.phases.values() {
            t.compute += p.compute;
            t.comm += p.comm;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_independently() {
        let mut s = CommStats::new();
        s.add_compute(1.0);
        s.set_phase("a");
        s.add_compute(2.0);
        s.add_comm(0.5);
        s.set_phase("b");
        s.add_comm(0.25);
        // revisiting an existing phase continues accumulation
        s.set_phase("a");
        s.add_compute(1.0);

        assert_eq!(s.phases()["default"].compute, 1.0);
        assert_eq!(s.phases()["a"].compute, 3.0);
        assert_eq!(s.phases()["a"].comm, 0.5);
        assert_eq!(s.phases()["b"].comm, 0.25);
        assert_eq!(s.phase_order(), &["default", "a", "b"]);
        let t = s.total();
        assert!((t.compute - 4.0).abs() < 1e-12);
        assert!((t.comm - 0.75).abs() < 1e-12);
    }

    #[test]
    fn send_counters() {
        let mut s = CommStats::new();
        s.count_send(100);
        s.count_send(50);
        assert_eq!(s.bytes_sent(), 150);
        assert_eq!(s.msgs_sent(), 2);
    }
}
