//! Bipartite ε-join: match a (small) query batch against an indexed corpus
//! without recomputing the corpus self-join — the serving shape of the
//! genomic-reads example.
//!
//! The corpus is block-partitioned across the simulated ranks and each
//! rank builds a cover tree over its block; the query batch is broadcast
//! and every rank reports its local hits. This is the paper's distributed
//! query pattern with the "queries" side degenerate (no self-join).

use super::{RankReport, RunConfig};
use crate::comm;
use crate::covertree::{BuildParams, CoverTree};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::block_partition;

/// Result of a bipartite join: `(query index, corpus vertex id)` pairs.
#[derive(Clone, Debug)]
pub struct BipartiteResult {
    /// Sorted, deduplicated `(query, corpus)` hit pairs.
    pub pairs: Vec<(u32, u32)>,
    /// Simulated job makespan.
    pub makespan: f64,
    /// Per-rank reports, indexed by rank.
    pub ranks: Vec<RankReport>,
}

/// For every point of `queries`, find all points of `corpus` within `eps`
/// under `metric`, on `cfg.ranks` simulated MPI ranks.
pub fn run_bipartite_join<P: PointSet, M: Metric<P>>(
    corpus: &P,
    queries: &P,
    metric: M,
    eps: f64,
    cfg: &RunConfig,
) -> BipartiteResult {
    let p = cfg.ranks.max(1);
    let outputs = comm::run_world(p, cfg.cost, |c| {
        let mut pairs: Vec<(u32, u32)> = Vec::new();
        let n = corpus.len();
        if n == 0 || queries.is_empty() {
            return pairs;
        }
        c.set_phase("tree");
        let (off, len) = block_partition(n, p, c.rank());
        let gids: Vec<u32> = (off as u32..(off + len) as u32).collect();
        let params = BuildParams { leaf_size: cfg.leaf_size.max(1), root: 0 };
        let tree = CoverTree::build_with_ids(corpus.slice(off, off + len), gids, &metric, &params);
        c.set_phase("query");
        let qbytes = if c.rank() == 0 { queries.to_bytes() } else { Vec::new() };
        let q = P::from_bytes(&c.bcast(0, qbytes));
        tree.query_batch(&metric, &q, eps, |qi, gid| pairs.push((qi as u32, gid)));
        pairs
    });
    let makespan = comm::makespan(&outputs);
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    let mut ranks = Vec::with_capacity(outputs.len());
    for o in outputs {
        pairs.extend(o.result);
        ranks.push(RankReport { rank: o.rank, virtual_time: o.virtual_time, stats: o.stats });
    }
    pairs.sort_unstable();
    pairs.dedup();
    BipartiteResult { pairs, makespan, ranks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::metric::{Euclidean, Metric};
    use crate::util::Rng;

    #[test]
    fn bipartite_matches_scan() {
        let mut rng = Rng::new(700);
        let corpus = synthetic::gaussian_mixture(&mut rng, 120, 4, 3, 0.2);
        let queries = synthetic::gaussian_mixture(&mut rng, 25, 4, 3, 0.2);
        let eps = 0.5;
        let mut want: Vec<(u32, u32)> = Vec::new();
        for qi in 0..queries.len() {
            for ci in 0..corpus.len() {
                if Euclidean.dist_between(&queries, qi, &corpus, ci) <= eps {
                    want.push((qi as u32, ci as u32));
                }
            }
        }
        want.sort_unstable();
        for ranks in [1usize, 3, 6] {
            let cfg = RunConfig { ranks, ..Default::default() };
            let got = run_bipartite_join(&corpus, &queries, Euclidean, eps, &cfg);
            assert_eq!(got.pairs, want, "ranks={ranks}");
        }
    }

    #[test]
    fn empty_sides_yield_no_pairs() {
        let mut rng = Rng::new(701);
        let corpus = synthetic::uniform(&mut rng, 30, 2, 1.0);
        let empty = crate::points::DenseMatrix::new(2);
        let cfg = RunConfig { ranks: 3, ..Default::default() };
        assert!(run_bipartite_join(&corpus, &empty, Euclidean, 1.0, &cfg).pairs.is_empty());
        assert!(run_bipartite_join(&empty, &corpus, Euclidean, 1.0, &cfg).pairs.is_empty());
    }
}
