//! Bipartite ε-join: match a (small) query batch against an indexed corpus
//! without recomputing the corpus self-join — the serving shape of the
//! genomic-reads example.
//!
//! The corpus is block-partitioned across the simulated ranks and each
//! rank builds a cover tree over its block; the query batch is broadcast
//! and every rank reports its local hits — with their distances, which
//! become edge weights of the bipartite [`NearGraph`]. This is the paper's
//! distributed query pattern with the "queries" side degenerate (no
//! self-join).

use super::{RankReport, RunConfig};
use crate::comm;
use crate::covertree::{BuildParams, CoverTree, QueryScratch};
use crate::graph::{NearGraph, WeightedEdgeList};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::block_partition;

/// Result of a bipartite join.
#[derive(Clone, Debug)]
pub struct BipartiteResult {
    /// Sorted, deduplicated `(query, corpus)` hit pairs.
    pub pairs: Vec<(u32, u32)>,
    /// Distances aligned with `pairs`.
    pub dists: Vec<f32>,
    /// The join as a weighted bipartite graph: vertices `0..nq` are the
    /// queries, `nq..nq + nc` the corpus points.
    pub graph: NearGraph,
    /// Simulated job makespan.
    pub makespan: f64,
    /// Per-rank reports, indexed by rank.
    pub ranks: Vec<RankReport>,
}

/// For every point of `queries`, find all points of `corpus` within `eps`
/// under `metric`, on `cfg.ranks` simulated MPI ranks.
pub fn run_bipartite_join<P: PointSet, M: Metric<P>>(
    corpus: &P,
    queries: &P,
    metric: M,
    eps: f64,
    cfg: &RunConfig,
) -> BipartiteResult {
    let p = cfg.ranks.max(1);
    let nq = queries.len();
    let outputs = comm::run_world(p, cfg.cost, |c| {
        let mut hits: Vec<(u32, u32, f64)> = Vec::new();
        let n = corpus.len();
        if n == 0 || queries.is_empty() {
            return hits;
        }
        c.set_phase("tree");
        let (off, len) = block_partition(n, p, c.rank());
        let gids: Vec<u32> = (off as u32..(off + len) as u32).collect();
        let params = BuildParams { leaf_size: cfg.leaf_size.max(1), root: 0 };
        let tree = CoverTree::build_with_ids(corpus.slice(off, off + len), gids, &metric, &params);
        c.set_phase("query");
        let qbytes = if c.rank() == 0 { queries.to_bytes() } else { Vec::new() };
        let q = P::from_bytes(&c.bcast(0, qbytes));
        // Rank-local scratch: repeated joins on a serving rank reuse one
        // warmed arena (the query batch is one bundle here).
        let mut scratch = QueryScratch::new();
        tree.query_batch_with(&metric, &q, eps, &mut scratch, |qi, gid, d| {
            hits.push((qi as u32, gid, d))
        });
        hits
    });
    let makespan = comm::makespan(&outputs);
    let mut hits: Vec<(u32, u32, f64)> = Vec::new();
    let mut ranks = Vec::with_capacity(outputs.len());
    for o in outputs {
        hits.extend(o.result);
        ranks.push(RankReport { rank: o.rank, virtual_time: o.virtual_time, stats: o.stats });
    }
    hits.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)).then(a.2.total_cmp(&b.2)));
    hits.dedup_by_key(|h| (h.0, h.1));
    let mut pairs = Vec::with_capacity(hits.len());
    let mut dists = Vec::with_capacity(hits.len());
    let mut weighted = WeightedEdgeList::with_capacity(hits.len());
    for &(qi, cid, d) in &hits {
        pairs.push((qi, cid));
        dists.push(d as f32);
        // Corpus ids shift past the query block in the bipartite graph.
        weighted.push(qi, nq as u32 + cid, d);
    }
    let graph = weighted.into_near_graph(nq + corpus.len());
    BipartiteResult { pairs, dists, graph, makespan, ranks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::metric::{Euclidean, Metric};
    use crate::util::Rng;

    #[test]
    fn bipartite_matches_scan() {
        let mut rng = Rng::new(700);
        let corpus = synthetic::gaussian_mixture(&mut rng, 120, 4, 3, 0.2);
        let queries = synthetic::gaussian_mixture(&mut rng, 25, 4, 3, 0.2);
        let eps = 0.5;
        let mut want: Vec<(u32, u32)> = Vec::new();
        for qi in 0..queries.len() {
            for ci in 0..corpus.len() {
                if Euclidean.dist_between(&queries, qi, &corpus, ci) <= eps {
                    want.push((qi as u32, ci as u32));
                }
            }
        }
        want.sort_unstable();
        for ranks in [1usize, 3, 6] {
            let cfg = RunConfig { ranks, ..Default::default() };
            let got = run_bipartite_join(&corpus, &queries, Euclidean, eps, &cfg);
            assert_eq!(got.pairs, want, "ranks={ranks}");
            // Weights are the exact pair distances.
            for (&(qi, ci), &d) in got.pairs.iter().zip(&got.dists) {
                let exact = Euclidean.dist_between(&queries, qi as usize, &corpus, ci as usize);
                assert_eq!(d, exact as f32, "({qi},{ci})");
            }
            // The bipartite graph has a vertex per query + corpus point and
            // an edge per hit.
            assert_eq!(got.graph.num_vertices(), queries.len() + corpus.len());
            assert_eq!(got.graph.num_edges(), want.len());
            // Query vertex adjacency mirrors the pair list (shifted ids).
            let q0_hits: Vec<u32> = got
                .pairs
                .iter()
                .filter(|&&(q, _)| q == 0)
                .map(|&(_, c)| c + queries.len() as u32)
                .collect();
            assert_eq!(got.graph.neighbors(0), &q0_hits[..], "ranks={ranks}");
        }
    }

    #[test]
    fn empty_sides_yield_no_pairs() {
        let mut rng = Rng::new(701);
        let corpus = synthetic::uniform(&mut rng, 30, 2, 1.0);
        let empty = crate::points::DenseMatrix::new(2);
        let cfg = RunConfig { ranks: 3, ..Default::default() };
        let a = run_bipartite_join(&corpus, &empty, Euclidean, 1.0, &cfg);
        assert!(a.pairs.is_empty());
        assert_eq!(a.graph.num_vertices(), corpus.len());
        let b = run_bipartite_join(&empty, &corpus, Euclidean, 1.0, &cfg);
        assert!(b.pairs.is_empty());
        assert_eq!(b.graph.num_vertices(), corpus.len());
    }
}
