//! Algorithm 4 — `systolic-ring`: point partitioning with rotating point
//! blocks.
//!
//! Each rank owns a contiguous block of the input (the canonical block
//! distribution) and builds a cover tree over it. The blocks then travel
//! the ring: at every step each rank forwards the block it is holding to
//! its successor while — overlapped with the transfer — querying that same
//! block against its local tree. After `P − 1` steps every block has
//! visited every rank, so every cross-block pair has been examined (twice,
//! once in each direction; the duplicate is removed when the driver
//! canonicalizes the merged edge list). Intra-block pairs come from a
//! self-join during the first step's transfer window.
//!
//! The overlap mirrors the paper's observation that the systolic transfer
//! hides behind the query step until the ring latency `α·(P−1)` dominates.

use super::checkpoint::Checkpointer;
use super::{Bundle, EdgeBundle, RunConfig};
use crate::comm::Comm;
use crate::covertree::{BuildParams, CoverTree, QueryScratch};
use crate::graph::{GraphSink, WeightedEdgeList};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::{block_partition, Pool};

/// Tag base for the rotating point blocks (one tag per ring step).
const TAG_RING: u32 = 0x5100;

pub(super) fn run<P: PointSet, M: Metric<P>>(
    comm: &mut Comm,
    pts: &P,
    metric: &M,
    eps: f64,
    cfg: &RunConfig,
    ckpt: Option<&Checkpointer>,
) -> WeightedEdgeList {
    let mut edges = WeightedEdgeList::new();
    let n = pts.len();
    if n == 0 {
        return edges;
    }
    let p = comm.size();
    let rank = comm.rank();

    // Intra-rank task pool; worker CPU is folded into the rank's compute
    // charge at phase boundaries (DESIGN.md §7.1).
    let pool = Pool::new(cfg.pool_threads());

    comm.set_phase("tree");
    let (off, len) = block_partition(n, p, rank);
    let block = pts.slice(off, off + len);
    let gids: Vec<u32> = (off as u32..(off + len) as u32).collect();
    let params = BuildParams { leaf_size: cfg.leaf_size.max(1), root: 0 };
    let tree = CoverTree::build_with_ids_par(block.clone(), gids.clone(), metric, &params, &pool);
    comm.charge_child_cpu(pool.drain_cpu());

    comm.set_phase("ring");
    // One traversal scratch per rank, reused across the self-join and
    // every visiting bundle (zero steady-state query allocations on the
    // inline path; the pooled path keeps one scratch per worker instead).
    let mut scratch = QueryScratch::new();
    if p == 1 {
        if cfg.dualtree {
            tree.eps_self_join_dual_par_with(metric, eps, &pool, &mut scratch, |a, b, d| {
                edges.accept(a, b, d)
            });
        } else {
            tree.eps_self_join_par_with(metric, eps, &pool, &mut scratch, |a, b, d| {
                edges.accept(a, b, d)
            });
        }
        comm.charge_child_cpu(pool.drain_cpu());
        save_selfjoin(ckpt, rank, &edges);
        return edges;
    }
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    let mut visiting = Bundle { pts: block, gids, cells: Vec::new(), dpc: Vec::new() };
    for s in 1..p {
        let bytes = visiting.to_bytes();
        let ((), received) =
            comm.sendrecv_overlapped(next, prev, TAG_RING + s as u32, bytes, || {
                if s == 1 {
                    // First transfer window: the block in hand is our own —
                    // run the intra-block self-join.
                    if cfg.dualtree {
                        tree.eps_self_join_dual_par_with(
                            metric,
                            eps,
                            &pool,
                            &mut scratch,
                            |a, b, d| edges.accept(a, b, d),
                        );
                    } else {
                        tree.eps_self_join_par_with(metric, eps, &pool, &mut scratch, |a, b, d| {
                            edges.accept(a, b, d)
                        });
                    }
                } else {
                    cross_query(&tree, metric, eps, &visiting, &pool, &mut scratch, &mut edges);
                }
            });
        visiting = Bundle::from_bytes(&received);
        if s == 1 {
            // The intra-block self-join is complete — persist it so a
            // restarted run has the phase's partial edges on disk.
            save_selfjoin(ckpt, rank, &edges);
        }
    }
    // The block received on the last step still needs querying.
    cross_query(&tree, metric, eps, &visiting, &pool, &mut scratch, &mut edges);
    // Pool CPU from the ring steps, charged additively after the overlaps
    // (conservative — the makespan never understates the work done).
    comm.charge_child_cpu(pool.drain_cpu());
    edges
}

/// Best-effort "selfjoin" partial checkpoint: the rank's intra-block
/// edges in [`EdgeBundle`] wire form (DESIGN.md §11).
fn save_selfjoin(ckpt: Option<&Checkpointer>, rank: usize, edges: &WeightedEdgeList) {
    if let Some(ck) = ckpt {
        let bytes = EdgeBundle { source: rank as u32, edges: edges.clone() }.to_bytes();
        ck.save(rank, "selfjoin", &bytes);
    }
}

/// Emit every (visiting, local) pair within `eps` — with its distance —
/// into the sink. The caller's scratch serves the sequential
/// fall-through, so consecutive bundles reuse one warmed arena.
fn cross_query<P: PointSet, M: Metric<P>>(
    tree: &CoverTree<P>,
    metric: &M,
    eps: f64,
    visiting: &Bundle<P>,
    pool: &Pool,
    scratch: &mut QueryScratch,
    sink: &mut dyn GraphSink,
) {
    tree.query_batch_par_with(metric, &visiting.pts, eps, pool, scratch, |qi, gid, d| {
        sink.accept(visiting.gids[qi], gid, d);
    });
}

#[cfg(test)]
mod tests {
    use super::super::{run_epsilon_graph, Algorithm, RunConfig};
    use crate::baseline::brute_force_edges;
    use crate::data::synthetic;
    use crate::metric::Euclidean;
    use crate::util::Rng;

    #[test]
    fn exact_across_ring_sizes() {
        let mut rng = Rng::new(404);
        let pts = synthetic::gaussian_mixture(&mut rng, 90, 3, 3, 0.2);
        let want = brute_force_edges(&pts, &Euclidean, 0.4);
        for ranks in [1usize, 2, 3, 7, 16] {
            let cfg = RunConfig { ranks, algorithm: Algorithm::SystolicRing, ..Default::default() };
            let got = run_epsilon_graph(&pts, Euclidean, 0.4, &cfg);
            assert_eq!(got.edges.edges(), want.edges(), "ranks={ranks}");
        }
    }

    #[test]
    fn more_ranks_than_points() {
        let mut rng = Rng::new(405);
        let pts = synthetic::uniform(&mut rng, 5, 2, 1.0);
        let want = brute_force_edges(&pts, &Euclidean, 0.8);
        let cfg = RunConfig { ranks: 9, algorithm: Algorithm::SystolicRing, ..Default::default() };
        let got = run_epsilon_graph(&pts, Euclidean, 0.8, &cfg);
        assert_eq!(got.edges.edges(), want.edges());
    }
}
