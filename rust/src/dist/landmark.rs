//! Algorithms 5–6 — spatial partitioning over Voronoi landmark cells with
//! ghost-point exchange (`landmark-coll` and `landmark-ring`).
//!
//! Both variants share the first two phases:
//!
//! * **partition** — rank 0 selects `m` landmarks (random or greedy
//!   permutation) and broadcasts them; every rank assigns its block of the
//!   canonical point distribution to the nearest landmark, the global cell
//!   sizes are combined, cells are coalesced onto ranks by multiway number
//!   partitioning (or cyclically, for the ablation), and an alltoallv moves
//!   every point to the rank owning its cell;
//! * **tree** — each rank builds one cover tree over its home points and
//!   self-joins it, which yields every edge whose endpoints live on the
//!   same rank (same or different cells).
//!
//! They differ in the **ghost** phase, which finds the cross-rank edges.
//! A home point `p` is a *ghost candidate* for a foreign cell `V_i` when
//! the Lemma-1 rule `d(p, c_i) ≤ d(p, C) + 2ε` holds (see DESIGN.md §5);
//! any cross-rank ε-neighbor pair has its two endpoints related by this
//! rule, so querying ghosts against home trees finds every remaining edge.
//!
//! * `landmark-coll` materializes one ghost bundle per destination rank and
//!   exchanges them with a single alltoallv — fastest at moderate scale but
//!   exposed to the collective's `α·(P−1)` latency term;
//! * `landmark-ring` instead circulates each rank's *union* ghost bundle
//!   around the ring; every rank filters the visitors relevant to its own
//!   cells and queries them while the bundle is being forwarded
//!   (compute/communication overlap), trading extra bandwidth for latency
//!   that hides behind the query work.

use super::checkpoint::Checkpointer;
use super::{AssignStrategy, Bundle, CenterStrategy, EdgeBundle, GhostMode, RunConfig};
use crate::comm::Comm;
use crate::covertree::{BuildParams, CoverTree, QueryScratch};
use crate::graph::{GraphSink, WeightedEdgeList};
use crate::metric::Metric;
use crate::points::PointSet;
use crate::util::{block_partition, Pool, Rng};
use crate::voronoi;

/// Tag base for the circulating ghost bundles (one tag per ring step).
const TAG_GHOST_RING: u32 = 0x6100;

/// Floating-point slack for the Lemma-1 prune: admitting extra ghost
/// candidates only costs traffic, while a rounding-induced rejection would
/// lose an edge. The bound scales with the magnitudes involved. The k-NN
/// refinement loop reuses it with a per-point radius cap in place of ε
/// (DESIGN.md §9); an infinite cap yields an infinite bound, i.e. "ship
/// everywhere", which is the exact degenerate behavior wanted before k
/// candidates are known.
#[inline]
pub(super) fn lemma1_bound(dpc: f64, eps: f64) -> f64 {
    dpc + 2.0 * eps + 1e-9 * (1.0 + dpc + eps)
}

/// Output of the shared **partition** phase (the first phase of both the
/// ε-graph and k-NN landmark algorithms): the broadcast landmark bundle,
/// the deterministic cell → rank map, and this rank's home points.
pub(super) struct Partitioned<P: PointSet> {
    /// Landmark points + their global ids (broadcast from rank 0).
    pub centers: Bundle<P>,
    /// Cell → owning rank, identical on every rank.
    pub cell_rank: Vec<usize>,
    /// Points homed on this rank, with `gids`, `cells` and `dpc` attached.
    pub home: Bundle<P>,
}

/// The landmark algorithms' partition phase, shared verbatim between the
/// ε-graph and k-NN paths: rank 0 selects `m` landmarks (random or greedy
/// permutation) and broadcasts them; every rank assigns its block of the
/// canonical distribution to the nearest landmark; global cell sizes are
/// combined; cells are coalesced onto ranks (multiway LPT or cyclic); one
/// alltoallv moves every point to the rank owning its cell.
pub(super) fn partition_points<P: PointSet, M: Metric<P>>(
    comm: &mut Comm,
    pts: &P,
    metric: &M,
    cfg: &RunConfig,
) -> Partitioned<P> {
    let n = pts.len();
    let p = comm.size();
    let rank = comm.rank();
    comm.set_phase("partition");

    // Landmark selection on rank 0, broadcast as a Bundle so the α-β model
    // sees the real payload.
    let bytes = if rank == 0 {
        let m = cfg.resolved_centers(n);
        let idx = match cfg.centers {
            CenterStrategy::Random => {
                let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
                voronoi::random_centers(&mut rng, n, m)
            }
            // Greedy may stop early when fewer distinct points exist.
            CenterStrategy::Greedy => voronoi::greedy_permutation(pts, metric, m, 0),
        };
        Bundle {
            pts: pts.gather(&idx),
            gids: idx.iter().map(|&i| i as u32).collect(),
            cells: Vec::new(),
            dpc: Vec::new(),
        }
        .to_bytes()
    } else {
        Vec::new()
    };
    let centers: Bundle<P> = Bundle::from_bytes(&comm.bcast(0, bytes));
    let m = centers.gids.len();

    // Assign the locally owned block to its nearest landmarks.
    let (off, len) = block_partition(n, p, rank);
    let block = pts.slice(off, off + len);
    let assignment = voronoi::assign_to_centers(&block, &centers.pts, metric);

    // Global cell sizes (sum of the per-rank counts) → cell→rank map,
    // computed identically on every rank.
    let local_sizes = voronoi::cell_sizes(&assignment, m);
    let mut sizes = vec![0u64; m];
    for b in &comm.allgather(encode_u64s(&local_sizes)) {
        for (i, s) in decode_u64s(b).into_iter().enumerate() {
            sizes[i] += s;
        }
    }
    let cell_rank: Vec<usize> = match cfg.assignment {
        AssignStrategy::Multiway => voronoi::multiway_partition(&sizes, p),
        AssignStrategy::Cyclic => voronoi::cyclic_assignment(&sizes, p),
    };

    // Redistribute: every point moves to the rank owning its cell, carrying
    // its global id, cell and d(p, C).
    let mut outgoing: Vec<Vec<usize>> = vec![Vec::new(); p];
    for (li, &(cell, _)) in assignment.iter().enumerate() {
        outgoing[cell_rank[cell as usize]].push(li);
    }
    let bufs: Vec<Vec<u8>> = outgoing
        .iter()
        .map(|idx| {
            Bundle {
                pts: block.gather(idx),
                gids: idx.iter().map(|&li| (off + li) as u32).collect(),
                cells: idx.iter().map(|&li| assignment[li].0).collect(),
                dpc: idx.iter().map(|&li| assignment[li].1).collect(),
            }
            .to_bytes()
        })
        .collect();
    let mut home: Bundle<P> = Bundle::empty_like(pts);
    for b in &comm.alltoallv(bufs) {
        home.append(&Bundle::from_bytes(b));
    }
    Partitioned { centers, cell_rank, home }
}

pub(super) fn run<P: PointSet, M: Metric<P>>(
    comm: &mut Comm,
    pts: &P,
    metric: &M,
    eps: f64,
    cfg: &RunConfig,
    ring: bool,
    ckpt: Option<&Checkpointer>,
) -> WeightedEdgeList {
    let mut edges = WeightedEdgeList::new();
    let n = pts.len();
    if n == 0 {
        return edges;
    }
    let p = comm.size();
    let rank = comm.rank();
    // Intra-rank task pool for the build/query phases; its worker CPU is
    // folded into this rank's compute charge at each phase boundary.
    let pool = Pool::new(cfg.pool_threads());

    // ------------------------------------------------------------------
    // phase: partition (shared with the k-NN path — see partition_points)
    // ------------------------------------------------------------------
    let Partitioned { centers, cell_rank, home } = partition_points(comm, pts, metric, cfg);
    let m = centers.gids.len();

    // ------------------------------------------------------------------
    // phase: tree
    // ------------------------------------------------------------------
    comm.set_phase("tree");
    let params = BuildParams { leaf_size: cfg.leaf_size.max(1), root: 0 };
    let tree =
        CoverTree::build_with_ids_par(home.pts.clone(), home.gids.clone(), metric, &params, &pool);
    // One traversal scratch per rank, reused by the self-join and every
    // incoming ghost bundle below (the pooled paths keep one per worker).
    let mut scratch = QueryScratch::new();
    // One tree per rank covers every intra-rank pair (same or different
    // cell) in a single self-join.
    if cfg.dualtree {
        tree.eps_self_join_dual_par_with(metric, eps, &pool, &mut scratch, |a, b, d| {
            edges.accept(a, b, d)
        });
    } else {
        tree.eps_self_join_par_with(metric, eps, &pool, &mut scratch, |a, b, d| {
            edges.accept(a, b, d)
        });
    }
    comm.charge_child_cpu(pool.drain_cpu());
    if let Some(ck) = ckpt {
        // Best-effort "selfjoin" partial checkpoint: every intra-rank
        // edge is known once the tree-phase self-join completes
        // (DESIGN.md §11).
        let bytes = EdgeBundle { source: rank as u32, edges: edges.clone() }.to_bytes();
        ck.save(rank, "selfjoin", &bytes);
    }

    // ------------------------------------------------------------------
    // phase: ghost
    // ------------------------------------------------------------------
    comm.set_phase("ghost");
    if !ring {
        // landmark-coll: per-destination ghost bundles, one alltoallv.
        let mut ghost_idx: Vec<Vec<usize>> = vec![Vec::new(); p];
        let mut stamp: Vec<usize> = vec![usize::MAX; p];
        for hi in 0..home.len() {
            let bound = lemma1_bound(home.dpc[hi], eps);
            for c in 0..m {
                let dest = cell_rank[c];
                if dest == rank || stamp[dest] == hi {
                    continue;
                }
                let keep = match cfg.ghost {
                    GhostMode::All => true,
                    GhostMode::Lemma1 => {
                        metric.dist_between(&home.pts, hi, &centers.pts, c) <= bound
                    }
                };
                if keep {
                    stamp[dest] = hi;
                    ghost_idx[dest].push(hi);
                }
            }
        }
        // Coll-mode receivers only need points + gids; shipping cells/dpc
        // would inflate the measured ghost-phase traffic with dead bytes.
        let bufs: Vec<Vec<u8>> = ghost_idx
            .iter()
            .map(|idx| {
                let mut b = home.select(idx);
                b.cells = Vec::new();
                b.dpc = Vec::new();
                b.to_bytes()
            })
            .collect();
        for b in &comm.alltoallv(bufs) {
            let ghosts: Bundle<P> = Bundle::from_bytes(b);
            tree.query_batch_par_with(metric, &ghosts.pts, eps, &pool, &mut scratch, |qi, gid, d| {
                edges.accept(ghosts.gids[qi], gid, d);
            });
        }
        comm.charge_child_cpu(pool.drain_cpu());
    } else {
        // landmark-ring: the union ghost bundle circulates the ring.
        let my_cells: Vec<usize> = (0..m).filter(|&c| cell_rank[c] == rank).collect();
        let any_foreign_cell = (0..m).any(|c| cell_rank[c] != rank);
        let union_idx: Vec<usize> = (0..home.len())
            .filter(|&hi| match cfg.ghost {
                GhostMode::All => any_foreign_cell,
                GhostMode::Lemma1 => {
                    let bound = lemma1_bound(home.dpc[hi], eps);
                    (0..m).any(|c| {
                        cell_rank[c] != rank
                            && metric.dist_between(&home.pts, hi, &centers.pts, c) <= bound
                    })
                }
            })
            .collect();
        let mut visiting = home.select(&union_idx);
        // Ring receivers re-apply the Lemma-1 filter, so dpc must travel;
        // cell ids are dead weight on the wire.
        visiting.cells = Vec::new();
        let next = (rank + 1) % p;
        let prev = (rank + p - 1) % p;
        for s in 1..p {
            let bytes = visiting.to_bytes();
            let ((), received) =
                comm.sendrecv_overlapped(next, prev, TAG_GHOST_RING + s as u32, bytes, || {
                    if s > 1 {
                        // Overlap: query the visitors received on the
                        // previous step while this transfer is in flight.
                        ghost_ring_query(
                            &tree, metric, eps, &visiting, &centers, &my_cells, cfg.ghost, &pool,
                            &mut scratch, &mut edges,
                        );
                    }
                });
            visiting = Bundle::from_bytes(&received);
        }
        if p > 1 {
            ghost_ring_query(
                &tree, metric, eps, &visiting, &centers, &my_cells, cfg.ghost, &pool,
                &mut scratch, &mut edges,
            );
        }
        // Pool-worker CPU from the ring queries lands here, in the ghost
        // phase. It is charged additively (after the overlapped steps)
        // rather than inside the overlap window — conservative: the
        // simulated makespan never understates the work.
        comm.charge_child_cpu(pool.drain_cpu());
    }
    edges
}

/// Filter a visiting ghost bundle down to the points relevant to this
/// rank's cells (the receiver side of the Lemma-1 rule) and query them
/// against the home tree, feeding weighted edges into the sink. The
/// caller's scratch serves the sequential fall-through so consecutive
/// bundles reuse one warmed arena.
#[allow(clippy::too_many_arguments)]
fn ghost_ring_query<P: PointSet, M: Metric<P>>(
    tree: &CoverTree<P>,
    metric: &M,
    eps: f64,
    visiting: &Bundle<P>,
    centers: &Bundle<P>,
    my_cells: &[usize],
    ghost: GhostMode,
    pool: &Pool,
    scratch: &mut QueryScratch,
    edges: &mut dyn GraphSink,
) {
    if tree.num_points() == 0 || visiting.is_empty() || my_cells.is_empty() {
        return;
    }
    let keep: Vec<usize> = (0..visiting.len())
        .filter(|&i| match ghost {
            GhostMode::All => true,
            GhostMode::Lemma1 => {
                let bound = lemma1_bound(visiting.dpc[i], eps);
                my_cells.iter().any(|&c| {
                    metric.dist_between(&visiting.pts, i, &centers.pts, c) <= bound
                })
            }
        })
        .collect();
    if keep.is_empty() {
        return;
    }
    let sub = visiting.select(&keep);
    tree.query_batch_par_with(metric, &sub.pts, eps, pool, scratch, |qi, gid, d| {
        edges.accept(sub.gids[qi], gid, d);
    });
}

fn encode_u64s(xs: &[u64]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

fn decode_u64s(bytes: &[u8]) -> Vec<u64> {
    bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
}

#[cfg(test)]
mod tests {
    use super::super::{run_epsilon_graph, Algorithm, GhostMode, RunConfig};
    use crate::baseline::brute_force_edges;
    use crate::data::synthetic;
    use crate::metric::Euclidean;
    use crate::util::Rng;

    #[test]
    fn coll_and_ring_exact_across_rank_counts() {
        let mut rng = Rng::new(500);
        let pts = synthetic::gaussian_mixture(&mut rng, 110, 4, 4, 0.15);
        let want = brute_force_edges(&pts, &Euclidean, 0.35);
        for algorithm in [Algorithm::LandmarkColl, Algorithm::LandmarkRing] {
            for ranks in [1usize, 2, 5, 11] {
                let cfg = RunConfig { ranks, algorithm, ..Default::default() };
                let got = run_epsilon_graph(&pts, Euclidean, 0.35, &cfg);
                assert_eq!(
                    got.edges.edges(),
                    want.edges(),
                    "{} ranks={ranks}",
                    algorithm.name()
                );
            }
        }
    }

    #[test]
    fn ghost_mode_all_matches_lemma1() {
        let mut rng = Rng::new(501);
        let pts = synthetic::uniform(&mut rng, 80, 3, 1.0);
        let want = brute_force_edges(&pts, &Euclidean, 0.3);
        for algorithm in [Algorithm::LandmarkColl, Algorithm::LandmarkRing] {
            for ghost in [GhostMode::Lemma1, GhostMode::All] {
                let cfg = RunConfig { ranks: 5, algorithm, ghost, ..Default::default() };
                let got = run_epsilon_graph(&pts, Euclidean, 0.3, &cfg);
                assert_eq!(got.edges.edges(), want.edges(), "{} {ghost:?}", algorithm.name());
            }
        }
    }

    #[test]
    fn single_center_degenerates_gracefully() {
        let mut rng = Rng::new(502);
        let pts = synthetic::gaussian_mixture(&mut rng, 50, 3, 2, 0.2);
        let want = brute_force_edges(&pts, &Euclidean, 0.4);
        for algorithm in [Algorithm::LandmarkColl, Algorithm::LandmarkRing] {
            let cfg = RunConfig { ranks: 4, algorithm, num_centers: 1, ..Default::default() };
            let got = run_epsilon_graph(&pts, Euclidean, 0.4, &cfg);
            assert_eq!(got.edges.edges(), want.edges(), "{}", algorithm.name());
        }
    }
}
