//! The wire format of the distributed algorithms: a batch of points plus
//! the per-point metadata the landmark algorithms need (global ids, Voronoi
//! cell ids, distance to the nearest center `d(p, C)`).
//!
//! Layout (little-endian, see `tests/properties.rs` for the pinned
//! roundtrip): a u64 byte-length prefix followed by the `PointSet`
//! serialization, then three length-prefixed arrays (`gids` as u32,
//! `cells` as u32, `dpc` as f64). `cells`/`dpc` may be empty — point blocks
//! moving through the systolic ring and ghost bundles carry only what their
//! receiver needs.

use crate::points::{get_u64, put_u64, PointSet};

/// A batch of points with optional per-point metadata, movable between
/// ranks through the simulated MPI layer.
#[derive(Clone, Debug)]
pub struct Bundle<P: PointSet> {
    /// The points themselves.
    pub pts: P,
    /// Global vertex id of each point (parallel to `pts`).
    pub gids: Vec<u32>,
    /// Voronoi cell of each point (empty when the receiver doesn't need it).
    pub cells: Vec<u32>,
    /// Distance to the nearest center `d(p, C)` (empty when not needed).
    pub dpc: Vec<f64>,
}

impl<P: PointSet> Bundle<P> {
    /// An empty bundle with the same per-point shape as `like`.
    pub fn empty_like(like: &P) -> Self {
        Bundle { pts: like.empty_like(), gids: Vec::new(), cells: Vec::new(), dpc: Vec::new() }
    }

    /// Number of points carried.
    pub fn len(&self) -> usize {
        self.gids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gids.is_empty()
    }

    /// Sub-bundle of the points at `idx` (metadata arrays follow when
    /// present).
    pub fn select(&self, idx: &[usize]) -> Self {
        Bundle {
            pts: self.pts.gather(idx),
            gids: idx.iter().map(|&i| self.gids[i]).collect(),
            cells: if self.cells.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.cells[i]).collect()
            },
            dpc: if self.dpc.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.dpc[i]).collect()
            },
        }
    }

    /// Append all points (and metadata) of `other`.
    pub fn append(&mut self, other: &Self) {
        self.pts.extend_from(&other.pts);
        self.gids.extend_from_slice(&other.gids);
        self.cells.extend_from_slice(&other.cells);
        self.dpc.extend_from_slice(&other.dpc);
    }

    /// Serialize for the comm layer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let pb = self.pts.to_bytes();
        let mut buf = Vec::with_capacity(
            32 + pb.len() + 4 * self.gids.len() + 4 * self.cells.len() + 8 * self.dpc.len(),
        );
        put_u64(&mut buf, pb.len() as u64);
        buf.extend_from_slice(&pb);
        put_u64(&mut buf, self.gids.len() as u64);
        for &g in &self.gids {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        put_u64(&mut buf, self.cells.len() as u64);
        for &c in &self.cells {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        put_u64(&mut buf, self.dpc.len() as u64);
        for &d in &self.dpc {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        buf
    }

    /// Deserialize from `to_bytes` output.
    pub fn from_bytes(bytes: &[u8]) -> Self {
        let mut off = 0usize;
        let pn = get_u64(bytes, &mut off) as usize;
        let pts = P::from_bytes(&bytes[off..off + pn]);
        off += pn;
        let ng = get_u64(bytes, &mut off) as usize;
        let mut gids = Vec::with_capacity(ng);
        for _ in 0..ng {
            gids.push(u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        let nc = get_u64(bytes, &mut off) as usize;
        let mut cells = Vec::with_capacity(nc);
        for _ in 0..nc {
            cells.push(u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        let nd = get_u64(bytes, &mut off) as usize;
        let mut dpc = Vec::with_capacity(nd);
        for _ in 0..nd {
            dpc.push(f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap()));
            off += 8;
        }
        Bundle { pts, gids, cells, dpc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::{DenseMatrix, StringSet};

    fn sample() -> Bundle<DenseMatrix> {
        Bundle {
            pts: DenseMatrix::from_flat(2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]),
            gids: vec![10, 20, 30],
            cells: vec![0, 1, 0],
            dpc: vec![0.5, 1.5, 2.5],
        }
    }

    #[test]
    fn roundtrip_full_metadata() {
        let b = sample();
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn roundtrip_empty_point_set() {
        let b: Bundle<DenseMatrix> = Bundle::empty_like(&DenseMatrix::new(7));
        assert!(b.is_empty());
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts.len(), 0);
        assert_eq!(b2.pts.dim(), 7, "per-point shape survives an empty bundle");
        assert!(b2.gids.is_empty() && b2.cells.is_empty() && b2.dpc.is_empty());
    }

    #[test]
    fn roundtrip_metadata_less() {
        // Systolic blocks carry only points + gids; cells/dpc stay empty.
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![9.0, 8.0]),
            gids: vec![3, 4],
            cells: Vec::new(),
            dpc: Vec::new(),
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.gids, vec![3, 4]);
        assert!(b2.cells.is_empty());
        assert!(b2.dpc.is_empty());
        assert_eq!(b2.pts, b.pts);
    }

    #[test]
    fn roundtrip_max_u32_global_ids() {
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![1.0, 2.0, 3.0]),
            gids: vec![u32::MAX, 0, u32::MAX - 1],
            cells: vec![u32::MAX, u32::MAX, 0],
            dpc: vec![f64::MAX, 0.0, -0.0],
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn roundtrip_strings() {
        let b = Bundle {
            pts: StringSet::from_strs(&["ACGT", "", "TTTT"]),
            gids: vec![0, 1, 2],
            cells: Vec::new(),
            dpc: vec![1.0, 2.0, 3.0],
        };
        let b2: Bundle<StringSet> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn select_subsets_and_append_concatenates() {
        let b = sample();
        let s = b.select(&[2, 0]);
        assert_eq!(s.gids, vec![30, 10]);
        assert_eq!(s.cells, vec![0, 0]);
        assert_eq!(s.dpc, vec![2.5, 0.5]);
        assert_eq!(s.pts.row(0), &[4.0, 5.0]);

        let mut acc: Bundle<DenseMatrix> = Bundle::empty_like(&b.pts);
        acc.append(&s);
        acc.append(&b.select(&[1]));
        assert_eq!(acc.len(), 3);
        assert_eq!(acc.gids, vec![30, 10, 20]);
    }

    #[test]
    fn metadata_less_select_stays_metadata_less() {
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![1.0, 2.0]),
            gids: vec![5, 6],
            cells: Vec::new(),
            dpc: Vec::new(),
        };
        let s = b.select(&[1]);
        assert!(s.cells.is_empty() && s.dpc.is_empty());
        assert_eq!(s.gids, vec![6]);
    }
}
