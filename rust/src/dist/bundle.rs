//! The wire formats of the distributed algorithms: a batch of points plus
//! the per-point metadata the landmark algorithms need ([`Bundle`]: global
//! ids, Voronoi cell ids, distance to the nearest center `d(p, C)`), a
//! batch of weighted edges ([`EdgeBundle`]: the graph-side payload, e.g. a
//! gathered partial result), and the k-NN radius-refinement message
//! ([`KnnBundle`]: query points with per-point radius caps and running
//! top-k candidate rows — DESIGN.md §9).
//!
//! [`Bundle`] layout (little-endian, see `tests/properties.rs` for the
//! pinned roundtrip): a u64 byte-length prefix followed by the `PointSet`
//! serialization, then three length-prefixed arrays (`gids` as u32,
//! `cells` as u32, `dpc` as f64). `cells`/`dpc` may be empty — point blocks
//! moving through the systolic ring and ghost bundles carry only what their
//! receiver needs.
//!
//! Both decoders are length-checked ([`Bundle::try_from_bytes`],
//! [`EdgeBundle::from_bytes`]): truncated or odd-length input yields a
//! typed [`WireError`], never a blind slice panic.

use crate::graph::WeightedEdgeList;
use crate::points::{le_f64, le_u32, put_u64, try_get_u64, try_take, PointSet, WireError};

/// A batch of points with optional per-point metadata, movable between
/// ranks through the simulated MPI layer.
#[derive(Clone, Debug)]
pub struct Bundle<P: PointSet> {
    /// The points themselves.
    pub pts: P,
    /// Global vertex id of each point (parallel to `pts`).
    pub gids: Vec<u32>,
    /// Voronoi cell of each point (empty when the receiver doesn't need it).
    pub cells: Vec<u32>,
    /// Distance to the nearest center `d(p, C)` (empty when not needed).
    pub dpc: Vec<f64>,
}

impl<P: PointSet> Bundle<P> {
    /// An empty bundle with the same per-point shape as `like`.
    pub fn empty_like(like: &P) -> Self {
        Bundle { pts: like.empty_like(), gids: Vec::new(), cells: Vec::new(), dpc: Vec::new() }
    }

    /// Number of points carried.
    pub fn len(&self) -> usize {
        self.gids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gids.is_empty()
    }

    /// Sub-bundle of the points at `idx` (metadata arrays follow when
    /// present).
    pub fn select(&self, idx: &[usize]) -> Self {
        Bundle {
            pts: self.pts.gather(idx),
            gids: idx.iter().map(|&i| self.gids[i]).collect(),
            cells: if self.cells.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.cells[i]).collect()
            },
            dpc: if self.dpc.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.dpc[i]).collect()
            },
        }
    }

    /// Append all points (and metadata) of `other`.
    pub fn append(&mut self, other: &Self) {
        self.pts.extend_from(&other.pts);
        self.gids.extend_from_slice(&other.gids);
        self.cells.extend_from_slice(&other.cells);
        self.dpc.extend_from_slice(&other.dpc);
    }

    /// Serialize for the comm layer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let pb = self.pts.to_bytes();
        let mut buf = Vec::with_capacity(
            32 + pb.len() + 4 * self.gids.len() + 4 * self.cells.len() + 8 * self.dpc.len(),
        );
        put_u64(&mut buf, pb.len() as u64);
        buf.extend_from_slice(&pb);
        put_u64(&mut buf, self.gids.len() as u64);
        for &g in &self.gids {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        put_u64(&mut buf, self.cells.len() as u64);
        for &c in &self.cells {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        put_u64(&mut buf, self.dpc.len() as u64);
        for &d in &self.dpc {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        buf
    }

    /// Length-checked deserialization from [`Bundle::to_bytes`] output.
    /// The embedded point payload decodes through
    /// [`PointSet::try_from_bytes`], so a corrupt point serialization is a
    /// typed error too, not a panic inside the container.
    pub fn try_from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let pn = try_get_u64(bytes, &mut off, "bundle point-bytes length")? as usize;
        let pts = P::try_from_bytes(try_take(bytes, &mut off, pn, "bundle point payload")?)?;
        let ng = try_get_u64(bytes, &mut off, "bundle gid count")? as usize;
        let gbytes = try_take(bytes, &mut off, ng.saturating_mul(4), "bundle gids")?;
        let gids: Vec<u32> = gbytes.chunks_exact(4).map(le_u32).collect();
        let nc = try_get_u64(bytes, &mut off, "bundle cell count")? as usize;
        let cbytes = try_take(bytes, &mut off, nc.saturating_mul(4), "bundle cells")?;
        let cells: Vec<u32> = cbytes.chunks_exact(4).map(le_u32).collect();
        let nd = try_get_u64(bytes, &mut off, "bundle dpc count")? as usize;
        let dbytes = try_take(bytes, &mut off, nd.saturating_mul(8), "bundle dpc")?;
        let dpc: Vec<f64> = dbytes.chunks_exact(8).map(le_f64).collect();
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after bundle payload" });
        }
        if pts.len() != gids.len()
            || (!cells.is_empty() && cells.len() != gids.len())
            || (!dpc.is_empty() && dpc.len() != gids.len())
        {
            return Err(WireError::Corrupt { what: "bundle array lengths disagree" });
        }
        Ok(Bundle { pts, gids, cells, dpc })
    }

    /// Deserialize from [`Bundle::to_bytes`] output, panicking (with the
    /// decode diagnostic) on malformed bytes — the in-process simulated
    /// MPI layer only ever hands back bytes it was given, so a failure
    /// here is a bug, not an input error.
    pub fn from_bytes(bytes: &[u8]) -> Self {
        match Self::try_from_bytes(bytes) {
            Ok(b) => b,
            Err(e) => panic!("bundle decode failed: {e}"),
        }
    }
}

/// A batch of weighted edges on the wire: the graph-side counterpart of
/// [`Bundle`], wrapping the canonical [`WeightedEdgeList`] encoding with
/// the sender's rank so gathered partial results stay attributable.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeBundle {
    /// Rank that produced these edges.
    pub source: u32,
    /// The weighted edges.
    pub edges: WeightedEdgeList,
}

impl EdgeBundle {
    /// Serialize for the comm layer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.edges.to_bytes();
        let mut buf = Vec::with_capacity(12 + payload.len());
        buf.extend_from_slice(&self.source.to_le_bytes());
        put_u64(&mut buf, payload.len() as u64);
        buf.extend_from_slice(&payload);
        buf
    }

    /// Length-checked inverse of [`EdgeBundle::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let src = try_take(bytes, &mut off, 4, "edge-bundle source rank")?;
        let source = le_u32(src);
        let pn = try_get_u64(bytes, &mut off, "edge-bundle payload length")? as usize;
        let payload = try_take(bytes, &mut off, pn, "edge-bundle payload")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after edge bundle" });
        }
        Ok(EdgeBundle { source, edges: WeightedEdgeList::from_bytes(payload)? })
    }
}

/// The k-NN radius-refinement wire message (DESIGN.md §9): a batch of
/// query points with their per-point **radius caps** and running top-k
/// candidate rows, movable between ranks.
///
/// Three shapes travel, all through the same decoder:
///
/// * **circulating bundles** (systolic-ring / landmark-ring): points +
///   gids + caps + carried rows (+ `dpc` on the landmark ring, which
///   re-applies the per-point Lemma-1 relevance filter at every stop);
/// * **requests** (landmark-coll): points + gids + caps, rows empty — the
///   receiver answers from its own tree;
/// * **replies** (landmark-coll, and every per-rank final result handed to
///   the driver): gids + rows only; `pts`, `dpc` and `caps` stay empty.
///
/// [`KnnBundle::try_from_bytes`] is length-checked like [`EdgeBundle`] and
/// re-validates every structural invariant (parallel array lengths, row
/// width ≤ k, rows strictly ascending by `(distance, id)`, finite
/// non-negative distances, candidates within their cap), returning a typed
/// [`WireError`] on any malformed input — never a panic.
#[derive(Clone, Debug)]
pub struct KnnBundle<P: PointSet> {
    /// The `k` this exchange refines toward (bounds every row).
    pub k: u32,
    /// The query points (empty for reply bundles, which travel by gid).
    pub pts: P,
    /// Global vertex id of each query (parallel to rows; to `pts` when
    /// points travel).
    pub gids: Vec<u32>,
    /// Distance to the nearest Voronoi center `d(p, C)` — present only on
    /// landmark-ring bundles, whose receivers re-apply the Lemma-1 rule.
    pub dpc: Vec<f64>,
    /// Current per-point radius cap (`+∞` until k candidates are known);
    /// empty on replies.
    pub caps: Vec<f64>,
    /// Row offsets into `cand_ids`/`cand_dists` (`gids.len() + 1` entries).
    pub cand_off: Vec<u32>,
    /// Flattened candidate ids, row-major, each row ascending by
    /// `(distance, id)`.
    pub cand_ids: Vec<u32>,
    /// Candidate distances parallel to `cand_ids` (exact `f64` — merges
    /// stay bit-deterministic; narrowing to `f32` happens only at final
    /// graph storage).
    pub cand_dists: Vec<f64>,
}

impl<P: PointSet> KnnBundle<P> {
    /// Number of query points carried.
    pub fn len(&self) -> usize {
        self.gids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gids.is_empty()
    }

    /// Candidate row `i` as parallel `(ids, dists)` slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.cand_off[i] as usize;
        let hi = self.cand_off[i + 1] as usize;
        (&self.cand_ids[lo..hi], &self.cand_dists[lo..hi])
    }

    /// Flatten per-point `(id, distance)` rows into a bundle. `pts`, `dpc`
    /// and `caps` follow the shape rules of the struct docs (empty or
    /// parallel to `gids`).
    pub fn from_rows(
        k: usize,
        pts: P,
        gids: Vec<u32>,
        dpc: Vec<f64>,
        caps: Vec<f64>,
        rows: &[Vec<(u32, f64)>],
    ) -> Self {
        assert_eq!(rows.len(), gids.len(), "one candidate row per query");
        let total: usize = rows.iter().map(Vec::len).sum();
        let mut cand_off = Vec::with_capacity(rows.len() + 1);
        let mut cand_ids = Vec::with_capacity(total);
        let mut cand_dists = Vec::with_capacity(total);
        cand_off.push(0u32);
        for row in rows {
            debug_assert!(row.len() <= k, "row wider than k");
            for &(id, d) in row {
                cand_ids.push(id);
                cand_dists.push(d);
            }
            cand_off.push(cand_ids.len() as u32);
        }
        KnnBundle { k: k as u32, pts, gids, dpc, caps, cand_off, cand_ids, cand_dists }
    }

    /// Unflatten into per-point `(id, distance)` rows.
    pub fn rows(&self) -> Vec<Vec<(u32, f64)>> {
        (0..self.len())
            .map(|i| {
                let (ids, ds) = self.row(i);
                ids.iter().copied().zip(ds.iter().copied()).collect()
            })
            .collect()
    }

    /// Serialize for the comm layer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let pb = self.pts.to_bytes();
        let mut buf = Vec::with_capacity(
            64 + pb.len()
                + 4 * self.gids.len()
                + 8 * (self.dpc.len() + self.caps.len() + self.cand_dists.len())
                + 4 * (self.cand_off.len() + self.cand_ids.len()),
        );
        buf.extend_from_slice(&self.k.to_le_bytes());
        put_u64(&mut buf, pb.len() as u64);
        buf.extend_from_slice(&pb);
        put_u64(&mut buf, self.gids.len() as u64);
        for &g in &self.gids {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        put_u64(&mut buf, self.dpc.len() as u64);
        for &d in &self.dpc {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        put_u64(&mut buf, self.caps.len() as u64);
        for &c in &self.caps {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        put_u64(&mut buf, self.cand_off.len() as u64);
        for &o in &self.cand_off {
            buf.extend_from_slice(&o.to_le_bytes());
        }
        put_u64(&mut buf, self.cand_ids.len() as u64);
        for &id in &self.cand_ids {
            buf.extend_from_slice(&id.to_le_bytes());
        }
        put_u64(&mut buf, self.cand_dists.len() as u64);
        for &d in &self.cand_dists {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        buf
    }

    /// Length-checked, invariant-checked inverse of
    /// [`KnnBundle::to_bytes`].
    pub fn try_from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let kb = try_take(bytes, &mut off, 4, "knn-bundle k")?;
        let k = le_u32(kb);
        let pn = try_get_u64(bytes, &mut off, "knn-bundle point-bytes length")? as usize;
        let pts = P::try_from_bytes(try_take(bytes, &mut off, pn, "knn-bundle point payload")?)?;
        let gids = take_u32s(bytes, &mut off, "knn-bundle gids")?;
        let dpc = take_f64s(bytes, &mut off, "knn-bundle dpc")?;
        let caps = take_f64s(bytes, &mut off, "knn-bundle caps")?;
        let cand_off = take_u32s(bytes, &mut off, "knn-bundle row offsets")?;
        let cand_ids = take_u32s(bytes, &mut off, "knn-bundle candidate ids")?;
        let cand_dists = take_f64s(bytes, &mut off, "knn-bundle candidate dists")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after knn bundle" });
        }
        let m = gids.len();
        if (pts.len() != 0 && pts.len() != m)
            || (!dpc.is_empty() && dpc.len() != m)
            || (!caps.is_empty() && caps.len() != m)
        {
            return Err(WireError::Corrupt { what: "knn bundle array lengths disagree" });
        }
        if cand_off.len() != m + 1
            || cand_off.first().copied() != Some(0)
            || cand_off.iter().zip(cand_off.iter().skip(1)).any(|(a, b)| a > b)
            || cand_off.last().map(|&v| v as usize) != Some(cand_ids.len())
            || cand_ids.len() != cand_dists.len()
        {
            return Err(WireError::Corrupt { what: "knn bundle row offsets inconsistent" });
        }
        if dpc.iter().any(|d| !d.is_finite() || *d < 0.0) {
            return Err(WireError::Corrupt { what: "non-finite or negative dpc" });
        }
        if caps.iter().any(|c| c.is_nan() || *c < 0.0) {
            return Err(WireError::Corrupt { what: "NaN or negative cap" });
        }
        if cand_dists.iter().any(|d| !d.is_finite() || *d < 0.0) {
            return Err(WireError::Corrupt { what: "non-finite or negative candidate distance" });
        }
        let mut lo = 0usize;
        for (i, &end) in cand_off.iter().skip(1).enumerate() {
            let hi = end as usize;
            if hi.saturating_sub(lo) > k as usize {
                return Err(WireError::Corrupt { what: "candidate row wider than k" });
            }
            // Row offsets were just validated monotone with last == len, so
            // these range borrows always succeed; `.get` keeps the decoder
            // free of panicking slices all the same.
            let row_d = cand_dists.get(lo..hi).unwrap_or(&[]);
            let row_i = cand_ids.get(lo..hi).unwrap_or(&[]);
            let pairs = row_d.iter().zip(row_i.iter());
            let nexts = row_d.iter().zip(row_i.iter()).skip(1);
            if pairs.zip(nexts).any(|(a, b)| a >= b) {
                return Err(WireError::Corrupt {
                    what: "candidate row not strictly ascending by (distance, id)",
                });
            }
            if let Some(cap) = caps.get(i) {
                if row_d.iter().any(|d| d > cap) {
                    return Err(WireError::Corrupt { what: "candidate beyond its radius cap" });
                }
            }
            lo = hi;
        }
        Ok(KnnBundle { k, pts, gids, dpc, caps, cand_off, cand_ids, cand_dists })
    }

    /// Deserialize, panicking on malformed bytes — for the in-process
    /// simulated MPI layer (mirrors [`Bundle::from_bytes`]).
    pub fn from_bytes(bytes: &[u8]) -> Self {
        match Self::try_from_bytes(bytes) {
            Ok(b) => b,
            Err(e) => panic!("knn bundle decode failed: {e}"),
        }
    }
}

fn take_u32s(bytes: &[u8], off: &mut usize, what: &'static str) -> Result<Vec<u32>, WireError> {
    let n = try_get_u64(bytes, off, what)? as usize;
    let payload = try_take(bytes, off, n.saturating_mul(4), what)?;
    Ok(payload.chunks_exact(4).map(le_u32).collect())
}

fn take_f64s(bytes: &[u8], off: &mut usize, what: &'static str) -> Result<Vec<f64>, WireError> {
    let n = try_get_u64(bytes, off, what)? as usize;
    let payload = try_take(bytes, off, n.saturating_mul(8), what)?;
    Ok(payload.chunks_exact(8).map(le_f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::{DenseMatrix, StringSet};

    fn sample() -> Bundle<DenseMatrix> {
        Bundle {
            pts: DenseMatrix::from_flat(2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]),
            gids: vec![10, 20, 30],
            cells: vec![0, 1, 0],
            dpc: vec![0.5, 1.5, 2.5],
        }
    }

    #[test]
    fn roundtrip_full_metadata() {
        let b = sample();
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn roundtrip_empty_point_set() {
        let b: Bundle<DenseMatrix> = Bundle::empty_like(&DenseMatrix::new(7));
        assert!(b.is_empty());
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts.len(), 0);
        assert_eq!(b2.pts.dim(), 7, "per-point shape survives an empty bundle");
        assert!(b2.gids.is_empty() && b2.cells.is_empty() && b2.dpc.is_empty());
    }

    #[test]
    fn roundtrip_metadata_less() {
        // Systolic blocks carry only points + gids; cells/dpc stay empty.
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![9.0, 8.0]),
            gids: vec![3, 4],
            cells: Vec::new(),
            dpc: Vec::new(),
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.gids, vec![3, 4]);
        assert!(b2.cells.is_empty());
        assert!(b2.dpc.is_empty());
        assert_eq!(b2.pts, b.pts);
    }

    #[test]
    fn roundtrip_max_u32_global_ids() {
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![1.0, 2.0, 3.0]),
            gids: vec![u32::MAX, 0, u32::MAX - 1],
            cells: vec![u32::MAX, u32::MAX, 0],
            dpc: vec![f64::MAX, 0.0, -0.0],
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn roundtrip_strings() {
        let b = Bundle {
            pts: StringSet::from_strs(&["ACGT", "", "TTTT"]),
            gids: vec![0, 1, 2],
            cells: Vec::new(),
            dpc: vec![1.0, 2.0, 3.0],
        };
        let b2: Bundle<StringSet> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn select_subsets_and_append_concatenates() {
        let b = sample();
        let s = b.select(&[2, 0]);
        assert_eq!(s.gids, vec![30, 10]);
        assert_eq!(s.cells, vec![0, 0]);
        assert_eq!(s.dpc, vec![2.5, 0.5]);
        assert_eq!(s.pts.row(0), &[4.0, 5.0]);

        let mut acc: Bundle<DenseMatrix> = Bundle::empty_like(&b.pts);
        acc.append(&s);
        acc.append(&b.select(&[1]));
        assert_eq!(acc.len(), 3);
        assert_eq!(acc.gids, vec![30, 10, 20]);
    }

    #[test]
    fn malformed_bundle_bytes_are_typed_errors() {
        use crate::points::WireError;
        let good = sample().to_bytes();
        // Truncation anywhere in the framing or arrays is reported, not
        // panicked. (Cuts inside the point payload are caught by the
        // byte-length prefix check before `P::from_bytes` runs.)
        for cut in [0usize, 4, 8, good.len() / 2, good.len() - 1] {
            let r: Result<Bundle<DenseMatrix>, _> = Bundle::try_from_bytes(&good[..cut]);
            assert!(r.is_err(), "cut={cut} decoded");
        }
        // Trailing garbage is rejected.
        let mut padded = good.clone();
        padded.push(7);
        assert!(matches!(
            Bundle::<DenseMatrix>::try_from_bytes(&padded),
            Err(WireError::Corrupt { .. })
        ));
        // A huge declared array length must not allocate/panic.
        let ppay = DenseMatrix::new(2).to_bytes();
        let mut huge = Vec::new();
        crate::points::put_u64(&mut huge, ppay.len() as u64);
        huge.extend_from_slice(&ppay);
        crate::points::put_u64(&mut huge, u64::MAX); // absurd gid count
        assert!(matches!(
            Bundle::<DenseMatrix>::try_from_bytes(&huge),
            Err(WireError::Truncated { .. })
        ));
        // Round trip still OK.
        let b: Bundle<DenseMatrix> = Bundle::try_from_bytes(&good).unwrap();
        assert_eq!(b.gids, sample().gids);
    }

    #[test]
    fn edge_bundle_roundtrip_and_truncation() {
        let mut edges = crate::graph::WeightedEdgeList::new();
        edges.push(3, 9, 0.5);
        edges.push(1, 2, 1.25);
        let eb = EdgeBundle { source: 7, edges };
        let bytes = eb.to_bytes();
        assert_eq!(EdgeBundle::from_bytes(&bytes).unwrap(), eb);
        for cut in 0..bytes.len() {
            assert!(EdgeBundle::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    fn knn_sample() -> KnnBundle<DenseMatrix> {
        KnnBundle::from_rows(
            3,
            DenseMatrix::from_flat(2, vec![0.0, 1.0, 2.0, 3.0]),
            vec![7, 9],
            vec![0.25, 0.5],
            vec![1.5, f64::INFINITY],
            &[vec![(3, 0.5), (1, 1.5)], vec![(2, 0.75)]],
        )
    }

    #[test]
    fn knn_bundle_roundtrip_shapes() {
        // Circulating shape: points + dpc + caps + rows.
        let b = knn_sample();
        let b2: KnnBundle<DenseMatrix> = KnnBundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.k, 3);
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.dpc, b.dpc);
        assert_eq!(b2.caps, b.caps);
        assert_eq!(b2.rows(), b.rows());
        assert_eq!(b2.row(1), (&[2u32][..], &[0.75f64][..]));

        // Request shape: points + caps, rows empty.
        let req = KnnBundle::from_rows(
            5,
            DenseMatrix::from_flat(1, vec![4.0]),
            vec![11],
            Vec::new(),
            vec![2.0],
            &[Vec::new()],
        );
        let req2: KnnBundle<DenseMatrix> = KnnBundle::from_bytes(&req.to_bytes());
        assert!(req2.dpc.is_empty() && req2.cand_ids.is_empty());
        assert_eq!(req2.caps, vec![2.0]);

        // Reply shape: gids + rows only, no points.
        let reply = KnnBundle::from_rows(
            2,
            DenseMatrix::new(4),
            vec![3, 4],
            Vec::new(),
            Vec::new(),
            &[vec![(0, 0.0), (9, 0.25)], vec![(1, 1.0)]],
        );
        let reply2: KnnBundle<DenseMatrix> = KnnBundle::from_bytes(&reply.to_bytes());
        assert_eq!(reply2.pts.len(), 0);
        assert_eq!(reply2.rows(), reply.rows());
    }

    #[test]
    fn knn_bundle_malformed_bytes_are_typed_errors() {
        use crate::points::WireError;
        let good = knn_sample().to_bytes();
        // Every truncation fails (count prefixes + trailing check).
        for cut in 0..good.len() {
            let r: Result<KnnBundle<DenseMatrix>, _> = KnnBundle::try_from_bytes(&good[..cut]);
            assert!(r.is_err(), "cut={cut} decoded");
        }
        // Trailing garbage rejected.
        let mut padded = good.clone();
        padded.push(1);
        assert!(matches!(
            KnnBundle::<DenseMatrix>::try_from_bytes(&padded),
            Err(WireError::Corrupt { .. })
        ));
        // Structural corruption: rows wider than k.
        let wide = KnnBundle::from_rows(
            1,
            DenseMatrix::from_flat(1, vec![0.0]),
            vec![0],
            Vec::new(),
            Vec::new(),
            &[vec![(1, 0.1)]],
        );
        let mut bytes = wide.to_bytes();
        // Patch k (first 4 bytes) down to 0: the one-candidate row now
        // exceeds k.
        bytes[0] = 0;
        assert!(matches!(
            KnnBundle::<DenseMatrix>::try_from_bytes(&bytes),
            Err(WireError::Corrupt { .. })
        ));
        // A row out of (distance, id) order is rejected.
        let mut unsorted = knn_sample();
        unsorted.cand_ids.swap(0, 1);
        unsorted.cand_dists.swap(0, 1);
        assert!(matches!(
            KnnBundle::<DenseMatrix>::try_from_bytes(&unsorted.to_bytes()),
            Err(WireError::Corrupt { .. })
        ));
        // Candidate beyond its cap rejected.
        let mut beyond = knn_sample();
        beyond.caps[0] = 0.1;
        assert!(matches!(
            KnnBundle::<DenseMatrix>::try_from_bytes(&beyond.to_bytes()),
            Err(WireError::Corrupt { .. })
        ));
        // NaN cap rejected (infinite caps are legal).
        let mut nan = knn_sample();
        nan.caps[1] = f64::NAN;
        assert!(matches!(
            KnnBundle::<DenseMatrix>::try_from_bytes(&nan.to_bytes()),
            Err(WireError::Corrupt { .. })
        ));
        // A huge declared array length must not allocate/panic.
        let mut huge = Vec::new();
        huge.extend_from_slice(&3u32.to_le_bytes());
        let ppay = DenseMatrix::new(2).to_bytes();
        crate::points::put_u64(&mut huge, ppay.len() as u64);
        huge.extend_from_slice(&ppay);
        crate::points::put_u64(&mut huge, u64::MAX); // absurd gid count
        assert!(matches!(
            KnnBundle::<DenseMatrix>::try_from_bytes(&huge),
            Err(WireError::Truncated { .. })
        ));
        // Pristine bytes still decode.
        assert!(KnnBundle::<DenseMatrix>::try_from_bytes(&good).is_ok());
    }

    #[test]
    fn metadata_less_select_stays_metadata_less() {
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![1.0, 2.0]),
            gids: vec![5, 6],
            cells: Vec::new(),
            dpc: Vec::new(),
        };
        let s = b.select(&[1]);
        assert!(s.cells.is_empty() && s.dpc.is_empty());
        assert_eq!(s.gids, vec![6]);
    }
}
