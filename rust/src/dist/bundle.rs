//! The wire formats of the distributed algorithms: a batch of points plus
//! the per-point metadata the landmark algorithms need ([`Bundle`]: global
//! ids, Voronoi cell ids, distance to the nearest center `d(p, C)`), and a
//! batch of weighted edges ([`EdgeBundle`]: the graph-side payload, e.g. a
//! gathered partial result).
//!
//! [`Bundle`] layout (little-endian, see `tests/properties.rs` for the
//! pinned roundtrip): a u64 byte-length prefix followed by the `PointSet`
//! serialization, then three length-prefixed arrays (`gids` as u32,
//! `cells` as u32, `dpc` as f64). `cells`/`dpc` may be empty — point blocks
//! moving through the systolic ring and ghost bundles carry only what their
//! receiver needs.
//!
//! Both decoders are length-checked ([`Bundle::try_from_bytes`],
//! [`EdgeBundle::from_bytes`]): truncated or odd-length input yields a
//! typed [`WireError`], never a blind slice panic.

use crate::graph::WeightedEdgeList;
use crate::points::{put_u64, try_get_u64, try_take, PointSet, WireError};

/// A batch of points with optional per-point metadata, movable between
/// ranks through the simulated MPI layer.
#[derive(Clone, Debug)]
pub struct Bundle<P: PointSet> {
    /// The points themselves.
    pub pts: P,
    /// Global vertex id of each point (parallel to `pts`).
    pub gids: Vec<u32>,
    /// Voronoi cell of each point (empty when the receiver doesn't need it).
    pub cells: Vec<u32>,
    /// Distance to the nearest center `d(p, C)` (empty when not needed).
    pub dpc: Vec<f64>,
}

impl<P: PointSet> Bundle<P> {
    /// An empty bundle with the same per-point shape as `like`.
    pub fn empty_like(like: &P) -> Self {
        Bundle { pts: like.empty_like(), gids: Vec::new(), cells: Vec::new(), dpc: Vec::new() }
    }

    /// Number of points carried.
    pub fn len(&self) -> usize {
        self.gids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gids.is_empty()
    }

    /// Sub-bundle of the points at `idx` (metadata arrays follow when
    /// present).
    pub fn select(&self, idx: &[usize]) -> Self {
        Bundle {
            pts: self.pts.gather(idx),
            gids: idx.iter().map(|&i| self.gids[i]).collect(),
            cells: if self.cells.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.cells[i]).collect()
            },
            dpc: if self.dpc.is_empty() {
                Vec::new()
            } else {
                idx.iter().map(|&i| self.dpc[i]).collect()
            },
        }
    }

    /// Append all points (and metadata) of `other`.
    pub fn append(&mut self, other: &Self) {
        self.pts.extend_from(&other.pts);
        self.gids.extend_from_slice(&other.gids);
        self.cells.extend_from_slice(&other.cells);
        self.dpc.extend_from_slice(&other.dpc);
    }

    /// Serialize for the comm layer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let pb = self.pts.to_bytes();
        let mut buf = Vec::with_capacity(
            32 + pb.len() + 4 * self.gids.len() + 4 * self.cells.len() + 8 * self.dpc.len(),
        );
        put_u64(&mut buf, pb.len() as u64);
        buf.extend_from_slice(&pb);
        put_u64(&mut buf, self.gids.len() as u64);
        for &g in &self.gids {
            buf.extend_from_slice(&g.to_le_bytes());
        }
        put_u64(&mut buf, self.cells.len() as u64);
        for &c in &self.cells {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        put_u64(&mut buf, self.dpc.len() as u64);
        for &d in &self.dpc {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        buf
    }

    /// Length-checked deserialization from [`Bundle::to_bytes`] output.
    pub fn try_from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let pn = try_get_u64(bytes, &mut off, "bundle point-bytes length")? as usize;
        let pts = P::from_bytes(try_take(bytes, &mut off, pn, "bundle point payload")?);
        let ng = try_get_u64(bytes, &mut off, "bundle gid count")? as usize;
        let gbytes = try_take(bytes, &mut off, ng.saturating_mul(4), "bundle gids")?;
        let gids: Vec<u32> =
            gbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
        let nc = try_get_u64(bytes, &mut off, "bundle cell count")? as usize;
        let cbytes = try_take(bytes, &mut off, nc.saturating_mul(4), "bundle cells")?;
        let cells: Vec<u32> =
            cbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
        let nd = try_get_u64(bytes, &mut off, "bundle dpc count")? as usize;
        let dbytes = try_take(bytes, &mut off, nd.saturating_mul(8), "bundle dpc")?;
        let dpc: Vec<f64> =
            dbytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after bundle payload" });
        }
        if pts.len() != gids.len()
            || (!cells.is_empty() && cells.len() != gids.len())
            || (!dpc.is_empty() && dpc.len() != gids.len())
        {
            return Err(WireError::Corrupt { what: "bundle array lengths disagree" });
        }
        Ok(Bundle { pts, gids, cells, dpc })
    }

    /// Deserialize from [`Bundle::to_bytes`] output, panicking (with the
    /// decode diagnostic) on malformed bytes — the in-process simulated
    /// MPI layer only ever hands back bytes it was given, so a failure
    /// here is a bug, not an input error.
    pub fn from_bytes(bytes: &[u8]) -> Self {
        match Self::try_from_bytes(bytes) {
            Ok(b) => b,
            Err(e) => panic!("bundle decode failed: {e}"),
        }
    }
}

/// A batch of weighted edges on the wire: the graph-side counterpart of
/// [`Bundle`], wrapping the canonical [`WeightedEdgeList`] encoding with
/// the sender's rank so gathered partial results stay attributable.
#[derive(Clone, Debug, PartialEq)]
pub struct EdgeBundle {
    /// Rank that produced these edges.
    pub source: u32,
    /// The weighted edges.
    pub edges: WeightedEdgeList,
}

impl EdgeBundle {
    /// Serialize for the comm layer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.edges.to_bytes();
        let mut buf = Vec::with_capacity(12 + payload.len());
        buf.extend_from_slice(&self.source.to_le_bytes());
        put_u64(&mut buf, payload.len() as u64);
        buf.extend_from_slice(&payload);
        buf
    }

    /// Length-checked inverse of [`EdgeBundle::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, WireError> {
        let mut off = 0usize;
        let src = try_take(bytes, &mut off, 4, "edge-bundle source rank")?;
        let source = u32::from_le_bytes(src.try_into().unwrap());
        let pn = try_get_u64(bytes, &mut off, "edge-bundle payload length")? as usize;
        let payload = try_take(bytes, &mut off, pn, "edge-bundle payload")?;
        if off != bytes.len() {
            return Err(WireError::Corrupt { what: "trailing bytes after edge bundle" });
        }
        Ok(EdgeBundle { source, edges: WeightedEdgeList::from_bytes(payload)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::points::{DenseMatrix, StringSet};

    fn sample() -> Bundle<DenseMatrix> {
        Bundle {
            pts: DenseMatrix::from_flat(2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]),
            gids: vec![10, 20, 30],
            cells: vec![0, 1, 0],
            dpc: vec![0.5, 1.5, 2.5],
        }
    }

    #[test]
    fn roundtrip_full_metadata() {
        let b = sample();
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn roundtrip_empty_point_set() {
        let b: Bundle<DenseMatrix> = Bundle::empty_like(&DenseMatrix::new(7));
        assert!(b.is_empty());
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts.len(), 0);
        assert_eq!(b2.pts.dim(), 7, "per-point shape survives an empty bundle");
        assert!(b2.gids.is_empty() && b2.cells.is_empty() && b2.dpc.is_empty());
    }

    #[test]
    fn roundtrip_metadata_less() {
        // Systolic blocks carry only points + gids; cells/dpc stay empty.
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![9.0, 8.0]),
            gids: vec![3, 4],
            cells: Vec::new(),
            dpc: Vec::new(),
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.gids, vec![3, 4]);
        assert!(b2.cells.is_empty());
        assert!(b2.dpc.is_empty());
        assert_eq!(b2.pts, b.pts);
    }

    #[test]
    fn roundtrip_max_u32_global_ids() {
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![1.0, 2.0, 3.0]),
            gids: vec![u32::MAX, 0, u32::MAX - 1],
            cells: vec![u32::MAX, u32::MAX, 0],
            dpc: vec![f64::MAX, 0.0, -0.0],
        };
        let b2: Bundle<DenseMatrix> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.gids, b.gids);
        assert_eq!(b2.cells, b.cells);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn roundtrip_strings() {
        let b = Bundle {
            pts: StringSet::from_strs(&["ACGT", "", "TTTT"]),
            gids: vec![0, 1, 2],
            cells: Vec::new(),
            dpc: vec![1.0, 2.0, 3.0],
        };
        let b2: Bundle<StringSet> = Bundle::from_bytes(&b.to_bytes());
        assert_eq!(b2.pts, b.pts);
        assert_eq!(b2.dpc, b.dpc);
    }

    #[test]
    fn select_subsets_and_append_concatenates() {
        let b = sample();
        let s = b.select(&[2, 0]);
        assert_eq!(s.gids, vec![30, 10]);
        assert_eq!(s.cells, vec![0, 0]);
        assert_eq!(s.dpc, vec![2.5, 0.5]);
        assert_eq!(s.pts.row(0), &[4.0, 5.0]);

        let mut acc: Bundle<DenseMatrix> = Bundle::empty_like(&b.pts);
        acc.append(&s);
        acc.append(&b.select(&[1]));
        assert_eq!(acc.len(), 3);
        assert_eq!(acc.gids, vec![30, 10, 20]);
    }

    #[test]
    fn malformed_bundle_bytes_are_typed_errors() {
        use crate::points::WireError;
        let good = sample().to_bytes();
        // Truncation anywhere in the framing or arrays is reported, not
        // panicked. (Cuts inside the point payload are caught by the
        // byte-length prefix check before `P::from_bytes` runs.)
        for cut in [0usize, 4, 8, good.len() / 2, good.len() - 1] {
            let r: Result<Bundle<DenseMatrix>, _> = Bundle::try_from_bytes(&good[..cut]);
            assert!(r.is_err(), "cut={cut} decoded");
        }
        // Trailing garbage is rejected.
        let mut padded = good.clone();
        padded.push(7);
        assert!(matches!(
            Bundle::<DenseMatrix>::try_from_bytes(&padded),
            Err(WireError::Corrupt { .. })
        ));
        // A huge declared array length must not allocate/panic.
        let ppay = DenseMatrix::new(2).to_bytes();
        let mut huge = Vec::new();
        crate::points::put_u64(&mut huge, ppay.len() as u64);
        huge.extend_from_slice(&ppay);
        crate::points::put_u64(&mut huge, u64::MAX); // absurd gid count
        assert!(matches!(
            Bundle::<DenseMatrix>::try_from_bytes(&huge),
            Err(WireError::Truncated { .. })
        ));
        // Round trip still OK.
        let b: Bundle<DenseMatrix> = Bundle::try_from_bytes(&good).unwrap();
        assert_eq!(b.gids, sample().gids);
    }

    #[test]
    fn edge_bundle_roundtrip_and_truncation() {
        let mut edges = crate::graph::WeightedEdgeList::new();
        edges.push(3, 9, 0.5);
        edges.push(1, 2, 1.25);
        let eb = EdgeBundle { source: 7, edges };
        let bytes = eb.to_bytes();
        assert_eq!(EdgeBundle::from_bytes(&bytes).unwrap(), eb);
        for cut in 0..bytes.len() {
            assert!(EdgeBundle::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn metadata_less_select_stays_metadata_less() {
        let b = Bundle {
            pts: DenseMatrix::from_flat(1, vec![1.0, 2.0]),
            gids: vec![5, 6],
            cells: Vec::new(),
            dpc: Vec::new(),
        };
        let s = b.select(&[1]);
        assert!(s.cells.is_empty() && s.dpc.is_empty());
        assert_eq!(s.gids, vec![6]);
    }
}
